"""Shared infrastructure for the paper-validation benchmarks.

Trains (and caches) three small models on deterministic synthetic data —
the offline stand-ins for the paper's ResNet50/MobilenetV2/BERT:

  * "cnn"  — 3-conv + head image classifier (per-channel granularity works)
  * "mlp"  — 4-layer tabular classifier
  * "bert" — 2-layer bidirectional mini-BERT on a 3-way entailment task,
             with EVERY matmul (incl. QK^T and AV, per the paper's shot-noise
             BERT setup) running through analog_dot

Each model exposes an ``AnalogProblem``: apply_fn(energies, x, key) under a
chosen AnalogConfig, MAC trees (per-layer / per-channel), calibrated
SiteQuant ranges (min/max for weight noise; 99.99th-percentile clipping for
thermal, per paper Appendix A), train/test batches, and the clean accuracy.
"""
from __future__ import annotations

import dataclasses
import datetime
import json
import os
import platform
import subprocess
import sys
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import restore_checkpoint, save_checkpoint
from repro.core import (
    AnalogConfig,
    CalibConfig,
    SiteQuant,
    analog_conv2d,
    analog_dot,
    dense_site_macs,
    eval_accuracy,
    learn_energies,
    site_key,
)
from repro.data import make_entailment_dataset, make_image_dataset, make_tabular_dataset
from repro.quant import calibrate_minmax, calibrate_percentile

KEY = jax.random.PRNGKey(0)
ART_DIR = os.path.join(os.path.dirname(__file__), "artifacts")
MODEL_DIR = os.path.join(ART_DIR, "models")
PAPER_DIR = os.path.join(ART_DIR, "paper")
os.makedirs(PAPER_DIR, exist_ok=True)


def atomic_write_json(path: str, record) -> str:
    """Write JSON via a same-directory temp file + ``os.replace``: readers
    (CI artifact upload, a dashboard tailing the repo root) never observe a
    truncated file, and a crash mid-write leaves the previous record intact."""
    path = os.path.normpath(path)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")
    os.replace(tmp, path)
    return path


def run_provenance() -> dict:
    """What produced this artifact: git sha (+dirty flag), UTC timestamp,
    and the software stack. Benchmarks embed it as a top-level block so a
    checked-in BENCH_*.json is auditable — numbers without the commit and
    jax version that produced them are not comparable across PRs."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    def _git(*argv):
        try:
            return subprocess.run(
                ("git",) + argv, cwd=repo_root, stdout=subprocess.PIPE,
                stderr=subprocess.DEVNULL, text=True, timeout=10,
            ).stdout.strip() or None
        except (OSError, subprocess.SubprocessError):
            return None

    return {
        "git_sha": _git("rev-parse", "HEAD"),
        "git_dirty": bool(_git("status", "--porcelain")),
        "timestamp_utc": datetime.datetime.now(datetime.timezone.utc)
        .isoformat(timespec="seconds"),
        "jax_version": jax.__version__,
        "numpy_version": np.__version__,
        "jax_backend": jax.default_backend(),
        "python": sys.version.split()[0],
        "platform": platform.platform(),
    }


def cache_json(name: str):
    """Decorator: run once, cache the result JSON under artifacts/paper."""

    def deco(fn):
        def wrapped(force: bool = False):
            path = os.path.join(PAPER_DIR, f"{name}.json")
            if os.path.exists(path) and not force:
                return json.load(open(path))
            out = fn()
            atomic_write_json(path, out)
            return out

        wrapped.__name__ = fn.__name__
        return wrapped

    return deco


# ===========================================================================
# model zoo
# ===========================================================================


@dataclasses.dataclass
class AnalogProblem:
    name: str
    params: list
    sites: List[str]
    macs_layer: Dict[str, jax.Array]
    macs_channel: Dict[str, jax.Array]
    train_batches: list
    test_batches: list
    clean_acc: float
    #: apply(cfg, quants) -> apply_fn(energies, x, key) -> logits
    make_apply: Callable
    #: calibrated SiteQuants per noise kind ("thermal" uses percentile clip)
    quants: Dict[str, Dict[str, SiteQuant]]

    def apply_fn(self, cfg: AnalogConfig):
        kind = cfg.noise.kind
        q = self.quants.get(kind if kind in self.quants else "minmax", {})
        return self.make_apply(cfg, q)


def _sgd(loss_fn, params, batches, steps, lr):
    opt = jax.jit(
        lambda p, xb, yb: jax.tree.map(
            lambda w, g: w - lr * g, p, jax.grad(loss_fn)(p, xb, yb)
        )
    )
    for i in range(steps):
        xb, yb = batches[i % len(batches)]
        params = opt(params, xb, yb)
    return params


def _xent(logits, yb):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))


def _accuracy(fwd, params, batches):
    correct = total = 0
    for xb, yb in batches:
        pred = jnp.argmax(fwd(params, xb), axis=-1)
        correct += int(jnp.sum(pred == yb))
        total += int(yb.size)
    return correct / total


def _site_quants(tensors: Dict[str, Tuple[jax.Array, jax.Array, jax.Array]]):
    """tensors: site -> (w_matrix, x_sample, out_sample). Returns quants per
    noise regime: 'minmax' (weight noise; moving min/max) and 'thermal'
    (99.99th percentile activation clipping)."""
    mm, th = {}, {}
    for s, (w, x, o) in tensors.items():
        wqp = calibrate_minmax(w, channel_axis=1)
        mm[s] = SiteQuant(wqp=wqp, xqp=calibrate_minmax(x), oqp=calibrate_minmax(o))
        th[s] = SiteQuant(
            wqp=wqp,
            xqp=calibrate_percentile(x, percentile=99.99),
            oqp=calibrate_percentile(o, percentile=99.99),
        )
    return {"minmax": mm, "weight": mm, "thermal": th, "shot": {}}


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

MLP_DIMS = [32, 96, 96, 64, 8]


def build_mlp(force: bool = False) -> AnalogProblem:
    x, y = make_tabular_dataset(6144, dim=MLP_DIMS[0], n_classes=MLP_DIMS[-1], depth=2, seed=3)
    x, y = jnp.asarray(x), jnp.asarray(y)
    n_train = 4096
    sizes = list(zip(MLP_DIMS[:-1], MLP_DIMS[1:]))
    sites = [f"l{i}" for i in range(len(sizes))]

    def fwd(params, xb):
        h = xb
        for i, w in enumerate(params):
            h = h @ w
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        return h

    params = _load_or_train(
        "mlp",
        lambda: [
            jax.random.normal(k, s, jnp.float32) / np.sqrt(s[0])
            for k, s in zip(jax.random.split(KEY, len(sizes)), sizes)
        ],
        lambda p: _sgd(
            lambda pp, xb, yb: _xent(fwd(pp, xb), yb),
            p,
            [(x[i : i + 512], y[i : i + 512]) for i in range(0, n_train, 512)],
            1500,
            0.5,
        ),
        force,
    )

    train_b = [(x[i : i + 512], y[i : i + 512]) for i in range(0, n_train, 512)]
    test_b = [(x[n_train:], y[n_train:])]
    clean = _accuracy(fwd, params, test_b)

    # calibration tensors from one train batch
    tensors = {}
    h = train_b[0][0]
    for i, w in enumerate(params):
        o = h @ w
        tensors[sites[i]] = (w, h, o)
        h = jax.nn.relu(o) if i < len(params) - 1 else o

    def make_apply(cfg, quants):
        def apply_fn(energies, xb, key):
            h = xb
            for i, w in enumerate(params):
                s = sites[i]
                h = analog_dot(
                    h, w, cfg=cfg, energy=energies[s],
                    key=site_key(jax.random.fold_in(key, i), s), sq=quants.get(s),
                )
                if i < len(params) - 1:
                    h = jax.nn.relu(h)
            return h

        return apply_fn

    macs_l = {
        s: dense_site_macs(1, a, b, per_channel=False)
        for s, (a, b) in zip(sites, sizes)
    }
    macs_c = {
        s: dense_site_macs(1, a, b, per_channel=True)
        for s, (a, b) in zip(sites, sizes)
    }
    return AnalogProblem(
        "mlp", params, sites, macs_l, macs_c, train_b, test_b, clean,
        make_apply, _site_quants(tensors),
    )


# --------------------------------------------------------------------------
# CNN
# --------------------------------------------------------------------------

CNN_CHANNELS = [(3, 16), (16, 32), (32, 32)]
CNN_CLASSES = 10


def build_cnn(force: bool = False) -> AnalogProblem:
    size = 16
    x, y = make_image_dataset(6144, n_classes=CNN_CLASSES, size=size, seed=5)
    x, y = jnp.asarray(x), jnp.asarray(y)
    n_train = 4096
    sites = [f"c{i}" for i in range(len(CNN_CHANNELS))] + ["head"]
    head_in = CNN_CHANNELS[-1][1]

    def init():
        keys = jax.random.split(KEY, 4)
        ps = [
            jax.random.normal(keys[i], (3, 3, cin, cout), jnp.float32)
            / np.sqrt(9 * cin)
            for i, (cin, cout) in enumerate(CNN_CHANNELS)
        ]
        ps.append(jax.random.normal(keys[3], (head_in, CNN_CLASSES), jnp.float32) / np.sqrt(head_in))
        return ps

    def fwd(params, xb):
        h = xb
        for i, kern in enumerate(params[:-1]):
            stride = 2 if i > 0 else 1
            h = jax.lax.conv_general_dilated(
                h, kern, (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            )
            h = jax.nn.relu(h)
        h = jnp.mean(h, axis=(1, 2))
        return h @ params[-1]

    params = _load_or_train(
        "cnn",
        init,
        lambda p: _sgd(
            lambda pp, xb, yb: _xent(fwd(pp, xb), yb),
            p,
            [(x[i : i + 256], y[i : i + 256]) for i in range(0, n_train, 256)],
            1200,
            0.2,
        ),
        force,
    )

    train_b = [(x[i : i + 256], y[i : i + 256]) for i in range(0, n_train, 256)]
    test_b = [(x[n_train : n_train + 1024], y[n_train : n_train + 1024])]
    clean = _accuracy(fwd, params, test_b)

    # calibration tensors (w as im2col matrices)
    tensors = {}
    h = train_b[0][0]
    for i, kern in enumerate(params[:-1]):
        stride = 2 if i > 0 else 1
        kh, kw, cin, cout = kern.shape
        w_mat = jnp.transpose(kern, (2, 0, 1, 3)).reshape(kh * kw * cin, cout)
        o = jax.lax.conv_general_dilated(
            h, kern, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
        )
        tensors[f"c{i}"] = (w_mat, h.reshape(-1, h.shape[-1]), o)
        h = jax.nn.relu(o)
    pooled = jnp.mean(h, axis=(1, 2))
    tensors["head"] = (params[-1], pooled, pooled @ params[-1])

    def make_apply(cfg, quants):
        def apply_fn(energies, xb, key):
            h = xb
            for i, kern in enumerate(params[:-1]):
                s = f"c{i}"
                stride = 2 if i > 0 else 1
                h = analog_conv2d(
                    h, kern, cfg=cfg, stride=stride, padding="SAME",
                    energy=energies[s],
                    key=site_key(jax.random.fold_in(key, i), s), sq=quants.get(s),
                )
                h = jax.nn.relu(h)
            h = jnp.mean(h, axis=(1, 2))
            return analog_dot(
                h, params[-1], cfg=cfg, energy=energies["head"],
                key=site_key(key, "head"), sq=quants.get("head"),
            )

        return apply_fn

    hw = size * size
    macs_l, macs_c = {}, {}
    for i, (cin, cout) in enumerate(CNN_CHANNELS):
        elems = hw if i == 0 else hw // (4 ** i)
        macs_l[f"c{i}"] = dense_site_macs(elems, 9 * cin, cout, per_channel=False)
        macs_c[f"c{i}"] = dense_site_macs(elems, 9 * cin, cout, per_channel=True)
    macs_l["head"] = dense_site_macs(1, head_in, CNN_CLASSES, per_channel=False)
    macs_c["head"] = dense_site_macs(1, head_in, CNN_CLASSES, per_channel=True)
    return AnalogProblem(
        "cnn", params, sites, macs_l, macs_c, train_b, test_b, clean,
        make_apply, _site_quants(tensors),
    )


# --------------------------------------------------------------------------
# mini-BERT (bidirectional encoder; all matmuls analog, incl. QK^T and AV)
# --------------------------------------------------------------------------

BERT_L, BERT_D, BERT_H, BERT_FF = 2, 64, 4, 128
BERT_VOCAB, BERT_T, BERT_CLASSES = 64, 24, 3


def build_bert(force: bool = False) -> AnalogProblem:
    toks, y = make_entailment_dataset(8192, vocab=BERT_VOCAB, seq_len=BERT_T, seed=11)
    toks, y = jnp.asarray(toks), jnp.asarray(y)
    n_train = 6144
    hd = BERT_D // BERT_H

    sites = []
    for l in range(BERT_L):
        sites += [f"{l}.q", f"{l}.k", f"{l}.v", f"{l}.scores", f"{l}.av", f"{l}.o",
                  f"{l}.ff1", f"{l}.ff2"]
    sites += ["cls"]

    def init():
        keys = iter(jax.random.split(KEY, 6 * BERT_L + 3))
        p = {"embed": jax.random.normal(next(keys), (BERT_VOCAB, BERT_D)) * 0.05,
             "pos": jax.random.normal(next(keys), (BERT_T, BERT_D)) * 0.05}
        for l in range(BERT_L):
            p[f"{l}.wq"] = jax.random.normal(next(keys), (BERT_D, BERT_D)) / np.sqrt(BERT_D)
            p[f"{l}.wk"] = jax.random.normal(next(keys), (BERT_D, BERT_D)) / np.sqrt(BERT_D)
            p[f"{l}.wv"] = jax.random.normal(next(keys), (BERT_D, BERT_D)) / np.sqrt(BERT_D)
            p[f"{l}.wo"] = jax.random.normal(next(keys), (BERT_D, BERT_D)) / np.sqrt(BERT_D)
            p[f"{l}.w1"] = jax.random.normal(next(keys), (BERT_D, BERT_FF)) / np.sqrt(BERT_D)
            p[f"{l}.w2"] = jax.random.normal(next(keys), (BERT_FF, BERT_D)) / np.sqrt(BERT_FF)
        p["cls"] = jax.random.normal(next(keys), (BERT_D, BERT_CLASSES)) / np.sqrt(BERT_D)
        return p

    def _attention(q, k, v, mm):
        b, t, _ = q.shape
        q4 = q.reshape(b, t, BERT_H, hd).transpose(0, 2, 1, 3).reshape(b * BERT_H, t, hd)
        k4 = k.reshape(b, t, BERT_H, hd).transpose(0, 2, 1, 3).reshape(b * BERT_H, t, hd)
        v4 = v.reshape(b, t, BERT_H, hd).transpose(0, 2, 1, 3).reshape(b * BERT_H, t, hd)
        scores = mm("scores", q4, k4.transpose(0, 2, 1)) / np.sqrt(hd)
        probs = jax.nn.softmax(scores, axis=-1)
        out = mm("av", probs, v4)
        return out.reshape(b, BERT_H, t, hd).transpose(0, 2, 1, 3).reshape(b, t, BERT_D)

    def fwd(p, xb, mm=None):
        if mm is None:
            mm = lambda s, a, b_: jnp.matmul(a, b_)
        h = p["embed"][xb] + p["pos"][None]
        for l in range(BERT_L):
            q = mm(f"{l}.q", h, p[f"{l}.wq"])
            k = mm(f"{l}.k", h, p[f"{l}.wk"])
            v = mm(f"{l}.v", h, p[f"{l}.wv"])
            att = _attention(q, k, v, lambda s, a, b_: mm(f"{l}.{s}", a, b_))
            h = _ln(h + mm(f"{l}.o", att, p[f"{l}.wo"]))
            ff = mm(f"{l}.ff2", jax.nn.gelu(mm(f"{l}.ff1", h, p[f"{l}.w1"])), p[f"{l}.w2"])
            h = _ln(h + ff)
        return mm("cls", jnp.mean(h, axis=1), p["cls"])

    def _ln(x):
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.var(x, axis=-1, keepdims=True)
        return (x - mu) * jax.lax.rsqrt(var + 1e-5)

    params = _load_or_train(
        "bert",
        init,
        lambda p: _sgd(
            lambda pp, xb, yb: _xent(fwd(pp, xb), yb),
            p,
            [(toks[i : i + 256], y[i : i + 256]) for i in range(0, n_train, 256)],
            2500,
            0.1,
        ),
        force,
    )

    train_b = [(toks[i : i + 256], y[i : i + 256]) for i in range(0, n_train, 256)]
    test_b = [(toks[n_train:], y[n_train:])]
    clean = _accuracy(lambda p, xb: fwd(p, xb), params, test_b)

    def make_apply(cfg, quants):
        def apply_fn(energies, xb, key):
            def mm(site, a, b_):
                if b_.ndim == 3:  # activation x activation (scores / av):
                    # batched analog dot per the shot-noise BERT setup
                    def one(aa, bb, kk):
                        return analog_dot(aa, bb, cfg=cfg, energy=energies[site], key=kk)

                    keys = jax.random.split(site_key(key, site), a.shape[0])
                    return jax.vmap(one)(a, b_, keys)
                return analog_dot(
                    a, b_, cfg=cfg, energy=energies[site], key=site_key(key, site)
                )

            return fwd(params, xb, mm)

        return apply_fn

    t, d, ff = BERT_T, BERT_D, BERT_FF
    per_l = {
        "q": t * d * d, "k": t * d * d, "v": t * d * d,
        "scores": BERT_H * t * t * hd, "av": BERT_H * t * t * hd,
        "o": t * d * d, "ff1": t * d * ff, "ff2": t * ff * d,
    }
    macs_l = {}
    for l in range(BERT_L):
        for s, m in per_l.items():
            macs_l[f"{l}.{s}"] = jnp.asarray(float(m), jnp.float32)
    macs_l["cls"] = jnp.asarray(float(d * BERT_CLASSES), jnp.float32)
    return AnalogProblem(
        "bert", params, sites, macs_l, macs_l, train_b, test_b, clean,
        make_apply, {"shot": {}},
    )


# --------------------------------------------------------------------------


def _load_or_train(name: str, init_fn, train_fn, force: bool):
    path = os.path.join(MODEL_DIR, name)
    if not force:
        try:
            _, params = restore_checkpoint(path, template=init_fn())
            return jax.tree.map(jnp.asarray, params)
        except (FileNotFoundError, Exception):
            pass
    params = train_fn(init_fn())
    save_checkpoint(path, 0, params)
    return params


PROBLEMS = {"mlp": build_mlp, "cnn": build_cnn, "bert": build_bert}
