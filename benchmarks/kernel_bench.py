"""Microbenchmark: fused K-repeat analog matmul vs the unfused composition.

Sweeps (shape x K) over the dynamic-precision repeat count K (paper §IV).
Three execution forms per cell:

  explicit — ``time_averaged_dot_explicit``: K full analog matmuls + K
             HBM-resident (M, N) noise tensors, then a mean. What the
             simulation cost USED to be.
  fused    — the model hot path: one ``analog_dot`` with ``n_repeats=K``
             (on CPU the jnp single-draw-at-K*E equivalent; on TPU the
             fused Pallas kernel).
  kernel   — the Pallas kernel itself. On CPU this runs in interpret mode
             (a correctness vehicle, not a timing proxy for TPU), so it is
             timed with few iters and reported separately.

ANALYTIC HBM traffic per cell (f32 bytes; the fusion argument on TPU):

  unfused: per draw — read x, w; write y; write+read noise; read+write y
           (add); read+write y (requant) = xw + 6*|y| touches, times K
           draws, plus the K-way mean ((K+1)*|y|).
  fused:   read x, w once; write y once — noise generated and averaged
           in-register, INDEPENDENT of K.

Persisted via ``cache_json`` (itself atomic) and summarized into the
repo-root ``BENCH_kernel.json`` through ``atomic_write_json`` with a
``run_provenance()`` block — the artifact carries the commit/jax stack
that produced it, and a crash mid-write never truncates the previous
record. ``--smoke`` runs a tiny sweep for CI.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import atomic_write_json, cache_json, run_provenance

TRAJECTORY_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_kernel.json",
)
from repro.core import AnalogConfig, analog_dot
from repro.core.redundant import time_averaged_dot_explicit
from repro.kernels import analog_matmul

SHAPES = [(256, 256, 256), (512, 512, 512), (384, 640, 512)]
K_REPEATS = [1, 4, 16]
SMOKE_SHAPES = [(128, 128, 128)]
SMOKE_K_REPEATS = [1, 4]


def analytic_traffic(m: int, k: int, n: int, k_repeats: int) -> dict:
    """Analytic HBM byte counts (f32) for the unfused vs fused K-repeat op."""
    bytes_xw = (m * k + k * n) * 4
    bytes_y = m * n * 4
    unfused = k_repeats * (bytes_xw + 6 * bytes_y) + (k_repeats + 1) * bytes_y
    fused = bytes_xw + bytes_y  # one x/w read + one y write, regardless of K
    return {
        "hbm_bytes_unfused": unfused,
        "hbm_bytes_fused": fused,
        "hbm_traffic_saving_x": unfused / fused,
    }


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _sweep(shapes, k_repeats, iters, kernel_iters):
    key = jax.random.PRNGKey(0)
    cfg = AnalogConfig.shot()
    e = jnp.asarray(10.0)
    rows = []
    for m, k, n in shapes:
        x = jax.random.normal(key, (m, k))
        w = jax.random.normal(jax.random.fold_in(key, 1), (k, n)) * 0.1
        t_plain = _time(jax.jit(lambda a, b: a @ b), x, w, iters=iters)
        for r in k_repeats:
            explicit = jax.jit(
                lambda a, b, kk, r=r: time_averaged_dot_explicit(
                    a, b, cfg=cfg, base_energy=e, key=kk, k_repeats=r
                )
            )
            fused = jax.jit(
                lambda a, b, kk, r=r: analog_dot(
                    a, b, cfg=cfg, energy=e, key=kk, n_repeats=r
                )
            )
            row = {
                "shape": [m, k, n],
                "k_repeats": r,
                "plain_matmul_us": t_plain,
                "explicit_us": _time(explicit, x, w, key, iters=iters),
                "fused_us": _time(fused, x, w, key, iters=iters),
                **analytic_traffic(m, k, n, r),
            }
            row["speedup_x"] = row["explicit_us"] / row["fused_us"]
            row["analog_overhead_x"] = row["fused_us"] / t_plain
            # interpret-mode kernel timing is K-independent noise on CPU:
            # record it once per shape, not per K
            if kernel_iters and r == k_repeats[0]:
                kern = jax.jit(
                    lambda a, b, kk, r=r: analog_matmul(
                        a, b, energy=e, key=kk, cfg=cfg, n_repeats=r,
                        block=(min(256, m), min(256, n), min(256, k)),
                    )
                )
                row["kernel_interpret_us"] = _time(kern, x, w, key, iters=kernel_iters)
            rows.append(row)
    # headline rows for the CSV trajectory: the biggest (MACs) shape, with
    # analog_overhead_x defined at K=1 (fused single draw vs plain matmul,
    # the pre-sweep definition) and speedup/saving at the largest K.
    big = max(rows, key=lambda r: (r["shape"][0] * r["shape"][1] * r["shape"][2], r["k_repeats"]))
    base = next(
        r for r in rows if r["shape"] == big["shape"] and r["k_repeats"] == k_repeats[0]
    )
    return {
        "backend": jax.default_backend(),
        "provenance": run_provenance(),
        "rows": rows,
        "analog_overhead_x": base["analog_overhead_x"],
        "hbm_traffic_saving_x": big["hbm_traffic_saving_x"],
        "speedup_x": big["speedup_x"],
    }


# "_sweep" cache names: the pre-sweep "kernel_bench" JSON had a different
# (flat) schema; a fresh name keeps stale caches from crashing the readers.
@cache_json("kernel_bench_sweep")
def kernel_bench():
    return _sweep(SHAPES, K_REPEATS, iters=20, kernel_iters=2)


@cache_json("kernel_bench_sweep_smoke")
def kernel_bench_smoke():
    return _sweep(SMOKE_SHAPES, SMOKE_K_REPEATS, iters=3, kernel_iters=1)


def _print_table(out):
    hdr = (
        f"{'shape':>16} {'K':>3} {'explicit_us':>12} {'fused_us':>10} "
        f"{'speedup':>8} {'unfused_MB':>11} {'fused_MB':>9} {'saving':>7}"
    )
    print(f"backend={out['backend']}")
    print(hdr)
    for r in out["rows"]:
        print(
            f"{'x'.join(map(str, r['shape'])):>16} {r['k_repeats']:>3} "
            f"{r['explicit_us']:>12.1f} {r['fused_us']:>10.1f} "
            f"{r['speedup_x']:>7.1f}x {r['hbm_bytes_unfused'] / 1e6:>10.2f} "
            f"{r['hbm_bytes_fused'] / 1e6:>8.2f} {r['hbm_traffic_saving_x']:>6.1f}x"
        )


def _write_trajectory(out, smoke: bool) -> str:
    """Atomic repo-root summary: headline numbers + provenance, never the
    full row dump (that lives in the artifacts/paper cache)."""
    record = {
        "bench": "kernel_bench",
        "smoke": smoke,
        "backend": out["backend"],
        "provenance": out.get("provenance", run_provenance()),
        "n_rows": len(out["rows"]),
        "analog_overhead_x": out["analog_overhead_x"],
        "hbm_traffic_saving_x": out["hbm_traffic_saving_x"],
        "speedup_x": out["speedup_x"],
    }
    return atomic_write_json(TRAJECTORY_PATH, record)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny sweep for CI")
    ap.add_argument("--force", action="store_true", help="ignore cached JSON")
    args = ap.parse_args()
    fn = kernel_bench_smoke if args.smoke else kernel_bench
    out = fn(force=args.force)
    _print_table(out)
    print(f"trajectory -> {_write_trajectory(out, args.smoke)}")


if __name__ == "__main__":
    main()
