"""Microbenchmark: fused analog-matmul kernel vs unfused jnp composition.

On CPU the Pallas kernel runs in interpret mode (a correctness vehicle, not
a timing proxy for TPU), so the wall-clock comparison that matters here is
jnp analog path vs plain matmul (the analog-simulation overhead XLA pays),
plus the ANALYTIC HBM-traffic comparison that motivates the fusion on TPU:

  unfused: read x, w; write y; write+read noise tensor; read+write y (add);
           read+write y (requant)            = xw + 6*|y| HBM touches
  fused:   read x, w; write y (noise + requant in-register)
                                             = xw + 1*|y|
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import cache_json
from repro.core import AnalogConfig, analog_dot
from repro.kernels import analog_matmul

M, K, N = 512, 512, 512


def _time(fn, *args, iters=20):
    fn(*args).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6  # us


@cache_json("kernel_bench")
def kernel_bench():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (M, K))
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.1
    cfg = AnalogConfig.shot()
    e = jnp.asarray(10.0)

    plain = jax.jit(lambda a, b: a @ b)
    analog_jnp = jax.jit(lambda a, b, k: analog_dot(a, b, cfg=cfg, energy=e, key=k))
    kernel = jax.jit(
        lambda a, b, k: analog_matmul(a, b, energy=e, key=k, cfg=cfg, block=(256, 256, 256))
    )

    t_plain = _time(plain, x, w)
    t_jnp = _time(analog_jnp, x, w, key)
    t_kernel = _time(kernel, x, w, key, iters=3)  # interpret mode: slow, correctness only

    bytes_xw = (M * K + K * N) * 4
    bytes_y = M * N * 4
    unfused_traffic = bytes_xw + 6 * bytes_y
    fused_traffic = bytes_xw + 1 * bytes_y
    return {
        "shape": [M, K, N],
        "plain_matmul_us": t_plain,
        "analog_jnp_us": t_jnp,
        "analog_overhead_x": t_jnp / t_plain,
        "kernel_interpret_us": t_kernel,
        "hbm_bytes_unfused": unfused_traffic,
        "hbm_bytes_fused": fused_traffic,
        "hbm_traffic_saving_x": unfused_traffic / fused_traffic,
    }
