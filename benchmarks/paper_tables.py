"""Paper-validation benchmarks: one function per paper table/figure.

All results are cached as JSON under artifacts/paper (``--force`` to rerun).
The models are in-container-trained synthetic-task stand-ins (DESIGN.md §6);
we validate the paper's *relations*: noise<->bits equivalence (Tables I/III),
dynamic-beats-uniform energy savings (Tables II/IV), energy-accuracy
monotonicity and discrete-photon robustness (Fig. 4).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PROBLEMS, cache_json
from repro.core import (
    AnalogConfig,
    CalibConfig,
    avg_energy_per_mac,
    eval_accuracy,
    eval_profile_accuracy,
    learn_energies,
    min_energy_search,
    noise_bits,
    noise_var_from_bits,
    repeat_profile_search,
    to_energy,
    total_macs,
    uniform_log_energies,
)
from repro.core.calibrate import softmax_xent
from repro.core.precision import empirical_noise_var
from repro.quant import QuantParams, fake_quant

KEY = jax.random.PRNGKey(42)
SEARCH = dict(lo=1e-4, hi=200.0, max_iters=5)
CAL = dict(lam=20.0, lr=0.05, steps=100, init_mult=4.0)


def _noisy_and_lowbit_accuracy(prob, cfg, energies, n_samples=8):
    """Table-I machinery: (noisy accuracy, per-site noise bits, accuracy with
    noise replaced by equivalent-bit output quantization)."""
    apply_fn = prob.apply_fn(cfg)
    acc_noisy = eval_accuracy(
        apply_fn, energies, prob.test_batches, key=KEY, n_noise_samples=n_samples
    )

    # measure per-site output ranges + empirical noise variance on one batch
    xb, _ = prob.train_batches[0]
    clean_cfg = dataclasses.replace(
        cfg, noise=cfg.noise.__class__(kind="none"), out_bits=None
    )
    kind = cfg.noise.kind
    q = prob.quants.get(kind if kind in prob.quants else "minmax", {})
    clean_apply = prob.make_apply(clean_cfg, q)  # same quant ranges, no noise
    # per-site probing: run with noise only at one site at a time
    bits: Dict[str, float] = {}
    for s in prob.sites:
        e_probe = {k: (energies[k] if k == s else jnp.asarray(1e9)) for k in prob.sites}
        clean = clean_apply({k: jnp.asarray(1e9) for k in prob.sites}, xb, KEY)
        noisy = apply_fn(e_probe, xb, jax.random.fold_in(KEY, 1))
        var = float(empirical_noise_var(clean, noisy))
        rng = float(jnp.max(clean) - jnp.min(clean))
        bits[s] = float(noise_bits(rng, max(var, 1e-30)))

    # low-bit run: noise removed, each site's OUTPUT quantized to its
    # (fractional) noise-bit count over the calibrated output range — the
    # paper's Table-I protocol (footnote 1: fractional B -> ceil(2^B - 1)
    # uniform bins).
    avg_bits = float(np.mean(list(bits.values())))
    base_q = prob.quants.get(kind if kind in prob.quants else "minmax", {})
    mm_q = prob.quants.get("minmax", {})
    lowbit_quants = {}
    for s in prob.sites:
        base = base_q.get(s) or mm_q.get(s)
        if base is None or base.oqp is None:
            continue
        lowbit_quants[s] = dataclasses.replace(
            base, oqp=dataclasses.replace(base.oqp, bits=max(bits[s], 1.0))
        )
    lowbit_cfg = dataclasses.replace(clean_cfg, out_bits=8.0)  # enable oqp path
    lowbit_apply = prob.make_apply(lowbit_cfg, lowbit_quants)
    acc_lowbit = eval_accuracy(
        lowbit_apply, {k: jnp.asarray(1e9) for k in prob.sites},
        prob.test_batches, key=KEY, n_noise_samples=1,
    )
    return acc_noisy, bits, avg_bits, acc_lowbit


@cache_json("table1_noise_bits")
def table1():
    """Table I analogue: thermal noise sweep on the CNN; noisy accuracy vs
    accuracy at the equivalent (fractional) bit precision."""
    prob = PROBLEMS["cnn"]()
    rows = []
    for sigma_1000 in (20.0, 10.0, 5.0, 2.0, 1.0, 0.0):
        sigma = sigma_1000 / 1000.0
        if sigma == 0.0:
            rows.append({"sigma_t_x1000": 0.0, "noisy_acc": prob.clean_acc,
                         "avg_bits": None, "lowbit_acc": prob.clean_acc})
            continue
        cfg = AnalogConfig.thermal(sigma)
        energies = {s: jnp.asarray(1.0) for s in prob.sites}
        acc_noisy, bits, avg_bits, acc_lowbit = _noisy_and_lowbit_accuracy(
            prob, cfg, energies
        )
        rows.append({
            "sigma_t_x1000": sigma_1000,
            "noisy_acc": acc_noisy,
            "avg_bits": avg_bits,
            "per_layer_bits": bits,
            "lowbit_acc": acc_lowbit,
        })
    return {"model": "cnn", "clean_acc": prob.clean_acc, "rows": rows}


def _min_energy(prob, cfg, granularity: str):
    """Binary search the minimum avg energy/MAC at <2% degradation for one
    (problem, noise, granularity) cell."""
    macs = prob.macs_channel if granularity == "per_channel" else prob.macs_layer
    apply_fn = prob.apply_fn(
        dataclasses.replace(cfg, granularity="per_channel")
        if granularity == "per_channel"
        else cfg
    )

    def acc_fn(energies):
        return eval_accuracy(apply_fn, energies, prob.test_batches, key=KEY, n_noise_samples=4)

    if granularity == "uniform":
        def make(target):
            e = to_energy(uniform_log_energies(macs, target))
            return e, float(avg_energy_per_mac(e, macs))
    else:
        def make(target, init=None):
            # warm start from the search's best feasible allocation: nearby
            # bisection targets share structure, so the optimization starts
            # at a neighbouring optimum and half the Eq.-14 steps suffice
            # (floored at 40 — an under-converged probe near the feasibility
            # boundary would flip the bisection the wrong way)
            cal = CAL if init is None else {**CAL, "steps": max(CAL["steps"] // 2, 40)}
            init_log_e = None if init is None else jax.tree.map(jnp.log, init)
            e, d = learn_energies(
                apply_fn, macs, prob.train_batches, key=KEY,
                target_e_per_mac=target, cfg=CalibConfig(**cal),
                init_log_e=init_log_e,
            )
            return e, d["avg_e_per_mac"]

    res = min_energy_search(make, acc_fn, float_acc=prob.clean_acc, **SEARCH)
    return {
        "min_e_per_mac": res.achieved_e_per_mac,
        "accuracy": res.accuracy,
        "floor": prob.clean_acc - 0.02,
    }


@cache_json("table2_min_energy")
def table2():
    """Table II analogue: min energy/MAC (<2% degradation) for CV models
    x {shot, thermal, weight} x {uniform, dynamic/layer, dynamic/channel}."""
    out = {}
    for model in ("cnn", "mlp"):
        prob = PROBLEMS[model]()
        out[model] = {"clean_acc": prob.clean_acc}
        for noise_name, cfg in (
            ("shot", AnalogConfig.shot()),
            ("thermal", AnalogConfig.thermal(0.01)),
            ("weight", AnalogConfig.weight(0.1)),
        ):
            cell = {}
            for gran in ("uniform", "per_layer", "per_channel"):
                cell[gran] = _min_energy(prob, cfg, gran)
            base = cell["uniform"]["min_e_per_mac"]
            best = min(cell["per_layer"]["min_e_per_mac"], cell["per_channel"]["min_e_per_mac"])
            cell["improvement_pct"] = (
                100.0 * (1 - best / base) if math.isfinite(base) and base > 0 else None
            )
            out[model][noise_name] = cell
    return out


@cache_json("table3_dynamic_bits")
def table3():
    """Table III analogue: noise-bits under DYNAMIC energies — at matched
    average energy, the dynamic model has similar avg bits but higher
    accuracy than uniform (better allocation of precision)."""
    prob = PROBLEMS["cnn"]()
    cfg = AnalogConfig.thermal(0.01)
    rows = []
    for target in (0.5, 2.0, 8.0):
        uni = to_energy(uniform_log_energies(prob.macs_layer, target))
        acc_u, _, bits_u, _ = _noisy_and_lowbit_accuracy(prob, cfg, uni, n_samples=6)
        dyn, d = learn_energies(
            prob.apply_fn(cfg), prob.macs_layer, prob.train_batches, key=KEY,
            target_e_per_mac=target, cfg=CalibConfig(**CAL),
        )
        acc_d, _, bits_d, _ = _noisy_and_lowbit_accuracy(prob, cfg, dyn, n_samples=6)
        rows.append({
            "target_e_per_mac": target,
            "uniform": {"acc": acc_u, "avg_bits": bits_u},
            "dynamic": {"acc": acc_d, "avg_bits": bits_d,
                        "achieved_e_per_mac": d["avg_e_per_mac"]},
        })
    return {"model": "cnn", "rows": rows}


@cache_json("table4_bert_shot")
def table4():
    """Table IV analogue: mini-BERT under shot noise (all matmuls analog,
    incl. attention): uniform vs dynamic per-layer min energy/MAC in aJ."""
    prob = PROBLEMS["bert"]()
    cfg = AnalogConfig.shot()
    uni = _min_energy(prob, cfg, "uniform")
    dyn = _min_energy(prob, cfg, "per_layer")
    imp = 100.0 * (1 - dyn["min_e_per_mac"] / uni["min_e_per_mac"])
    return {
        "model": "bert", "clean_acc": prob.clean_acc,
        "uniform_aj_per_mac": uni, "dynamic_aj_per_mac": dyn,
        "improvement_pct": imp,
    }


@cache_json("fig4_energy_curve")
def fig4():
    """Fig. 4 analogue: accuracy vs optical energy/MAC for uniform vs
    dynamic, continuous vs discrete photon counts (CNN, shot noise)."""
    prob = PROBLEMS["cnn"]()
    curve = []
    targets = [0.003, 0.01, 0.03, 0.1, 0.3, 1.0, 3.0]
    for target in targets:
        cfg = AnalogConfig.shot()
        apply_fn = prob.apply_fn(cfg)
        uni = to_energy(uniform_log_energies(prob.macs_layer, target))
        acc_u = eval_accuracy(apply_fn, uni, prob.test_batches, key=KEY, n_noise_samples=6)
        dyn, d = learn_energies(
            apply_fn, prob.macs_layer, prob.train_batches, key=KEY,
            target_e_per_mac=target, cfg=CalibConfig(**CAL),
        )
        acc_d = eval_accuracy(apply_fn, dyn, prob.test_batches, key=KEY, n_noise_samples=6)
        # discrete photon levels (paper: quantized energy via STE)
        cfg_q = AnalogConfig.shot(discrete_energy=True)
        dyn_q, dq = learn_energies(
            prob.apply_fn(cfg_q), prob.macs_layer, prob.train_batches, key=KEY,
            target_e_per_mac=target,
            cfg=CalibConfig(**{**CAL, "discrete": True,
                               "quantum": cfg_q.energy_quantum}),
        )
        acc_q = eval_accuracy(
            prob.apply_fn(cfg_q), dyn_q, prob.test_batches, key=KEY, n_noise_samples=6
        )
        curve.append({
            "target_e_per_mac_aj": target,
            "uniform_acc": acc_u,
            "dynamic_acc": acc_d,
            "dynamic_achieved": d["avg_e_per_mac"],
            "dynamic_discrete_acc": acc_q,
            "dynamic_discrete_achieved": dq["avg_e_per_mac"],
        })
    return {"model": "cnn", "clean_acc": prob.clean_acc, "curve": curve}


@cache_json("fig6_energy_allocations")
def fig6():
    """Figs. 5/6 analogue: learned per-layer energy allocations — first/last
    layers get more energy/MAC than the middle (CNN, shot noise)."""
    prob = PROBLEMS["cnn"]()
    cfg = AnalogConfig.shot()
    dyn, d = learn_energies(
        prob.apply_fn(cfg), prob.macs_layer, prob.train_batches, key=KEY,
        target_e_per_mac=0.1, cfg=CalibConfig(**CAL),
    )
    return {
        "model": "cnn",
        "allocations_aj_per_mac": {k: float(v) for k, v in dyn.items()},
        "achieved_avg": d["avg_e_per_mac"],
    }


@cache_json("table5_profile_vs_uniform")
def table5_profile():
    """Uniform-K vs learned per-layer K profile (the Fig.-5 / §VI tradeoff
    as a servable artifact): on the MLP under shot noise, fix a per-site
    energy allocation where K=1 breaks the 2% floor, learn the per-layer
    repeat schedule with the greedy search, and report energy/accuracy of
    every uniform K next to the learned profile. The learned schedule's
    energy must undercut the cheapest *feasible* uniform K at matched
    accuracy — the serving-side restatement of dynamic-beats-uniform."""
    prob = PROBLEMS["mlp"]()
    cfg = AnalogConfig.shot()
    apply_fn = prob.apply_fn(cfg)
    macs = prob.macs_layer
    sites = list(prob.sites)
    floor = prob.clean_acc - 0.02
    k_levels = (1, 2, 4, 8)
    k_max = max(k_levels)

    memo = {}  # (base, reps) -> acc: the base scan, uniform rows, and the
    # search's own start evaluation all revisit the same schedules

    def acc_at(energies, base, reps):
        if (base, reps) not in memo:
            rep_tree = {s: k for s, k in zip(sites, reps)}
            memo[(base, reps)] = eval_profile_accuracy(
                apply_fn, energies, rep_tree, prob.test_batches, key=KEY,
                n_noise_samples=4,
            )
        return memo[(base, reps)]

    # base energy: smallest power-of-two multiple where uniform K_max meets
    # the floor while K=1 misses it — the regime where per-layer K matters.
    # Both halves are checked: if no base puts K=1 below the floor the table
    # is vacuous (K repeats buy nothing) and says so via k1_infeasible; if
    # none makes K_max feasible the search below reports feasible=False.
    uni_1, uni_max = (1,) * len(sites), (k_max,) * len(sites)
    for base in (0.25, 0.5, 1.0, 2.0, 4.0, 8.0):
        energies = to_energy(uniform_log_energies(macs, base))
        if acc_at(energies, base, uni_max) >= floor:
            break
    k1_infeasible = acc_at(energies, base, uni_1) < floor
    kmax_feasible = acc_at(energies, base, uni_max) >= floor

    weights = tuple(float(energies[s] * macs[s]) for s in sites)
    res = repeat_profile_search(
        lambda reps: acc_at(energies, base, tuple(reps)),
        n_layers=len(sites), float_acc=prob.clean_acc, k_levels=k_levels,
        weights=weights,
    )
    n_mac = float(total_macs(macs))
    base_e_per_mac = sum(weights) / n_mac  # aJ/MAC at K=1

    uniform_rows = []
    for k in k_levels:
        uniform_rows.append({
            "k": k,
            "acc": acc_at(energies, base, (k,) * len(sites)),
            "e_per_mac_aj": k * base_e_per_mac,
        })
    feasible_uniform = [r for r in uniform_rows if r["acc"] >= floor]
    cheapest_uniform = min(
        (r["e_per_mac_aj"] for r in feasible_uniform), default=None
    )
    prof_e_per_mac = res.cost / n_mac
    return {
        "model": "mlp",
        "clean_acc": prob.clean_acc,
        "floor": floor,
        "base_e_per_mac_aj": base,
        # precondition flags: the comparison is meaningful iff K=1 breaks the
        # floor (repeats buy something) and uniform K_max recovers it
        "k1_infeasible": k1_infeasible,
        "uniform_kmax_feasible": kmax_feasible,
        "uniform": uniform_rows,
        "profile": {
            "repeats": {s: k for s, k in zip(sites, res.repeats)},
            "feasible": res.feasible,
            "acc": res.accuracy,
            "e_per_mac_aj": prof_e_per_mac,
            "search_evals": res.n_evals,
        },
        "improvement_pct_vs_cheapest_uniform": (
            100.0 * (1.0 - prof_e_per_mac / cheapest_uniform)
            if cheapest_uniform
            else None
        ),
    }


ALL = {
    "table1": table1,
    "table2": table2,
    "table3": table3,
    "table4": table4,
    "table5_profile": table5_profile,
    "fig4": fig4,
    "fig6": fig6,
}
