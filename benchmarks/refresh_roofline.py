"""Recompute roofline terms in dry-run artifacts from stored HLO stats
(after memory-model fixes) without recompiling."""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import SHAPES, get_config
from repro.launch import roofline

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

for name in sorted(os.listdir(ART)):
    if not name.endswith(".json"):
        continue
    path = os.path.join(ART, name)
    art = json.load(open(path))
    if art.get("status") != "ok":
        continue
    cfg = get_config(art["arch"])
    if art.get("causal_skip"):
        import dataclasses
        cfg = dataclasses.replace(cfg, causal_skip=True)
    shape = SHAPES[art["shape"]]
    cache_bytes = None
    if art.get("kv_dtype"):
        # fp8 halves the analytic default (bf16)
        from repro.launch.roofline import _cache_bytes
        import numpy as np
        scale = np.dtype(art["kv_dtype"]).itemsize / 2.0
        cache_bytes = _cache_bytes(cfg, shape) * scale
    rt = roofline.terms(
        cfg, shape, art["n_devices"],
        hlo_dot_flops=art["hlo"]["dot_flops_per_device"],
        collective_link_bytes=art["hlo"]["collective_link_bytes_per_device"],
        cache_bytes_global=cache_bytes,
    )
    art["roofline"] = rt.as_dict()
    json.dump(art, open(path, "w"), indent=2)
print("refreshed")
