"""Render §Dry-run / §Roofline tables from the dry-run artifacts."""
from __future__ import annotations

import json
import os
from typing import List

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")


def load_cells() -> List[dict]:
    rows = []
    if not os.path.isdir(ART):
        return rows
    for name in sorted(os.listdir(ART)):
        if name.endswith(".json"):
            rows.append(json.load(open(os.path.join(ART, name))))
    return rows


def markdown_table(rows: List[dict], mesh: str = "single") -> str:
    out = [
        "| arch | shape | variant | compile_s | peak GB/dev | fits 16GB | compute_s | memory_s | collective_s | dominant | useful |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | SKIP: {r['reason'][:60]} | | | | | |")
            continue
        rf = r["roofline"]
        variant = []
        if r.get("analog", "none") != "none":
            variant.append(r["analog"])
        if r.get("microbatch", 1) > 1:
            variant.append(f"mb{r['microbatch']}")
        if r.get("causal_skip"):
            variant.append("cskip")
        out.append(
            f"| {r['arch']} | {r['shape']} | {'+'.join(variant) or 'base'} | {r['compile_s']} | "
            f"{r['peak_bytes_per_device']/1e9:.2f} | {'Y' if r['fits_16gb'] else 'N'} | "
            f"{rf['compute_s']:.4f} | {rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"{rf['dominant']} | {rf['useful_ratio']:.3f} |"
        )
    return "\n".join(out)


def summary(rows: List[dict]) -> dict:
    ok = [r for r in rows if r["status"] == "ok"]
    skips = [r for r in rows if r["status"] != "ok"]
    fits = [r for r in ok if r["fits_16gb"]]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    return {
        "cells_ok": len(ok),
        "cells_skipped": len(skips),
        "fits": len(fits),
        "dominant_histogram": doms,
    }


def main():
    rows = load_cells()
    s = summary(rows)
    print(f"dryrun cells: {s['cells_ok']} ok, {s['cells_skipped']} skipped, "
          f"{s['fits']}/{s['cells_ok']} fit 16GB; dominant: {s['dominant_histogram']}")
    for mesh in ("single", "multi"):
        path = os.path.join(os.path.dirname(ART), f"roofline_{mesh}.md")
        with open(path, "w") as f:
            f.write(markdown_table(rows, mesh))
        print(f"wrote {path}")


if __name__ == "__main__":
    main()
