"""Benchmark harness entry point: one benchmark per paper table/figure plus
the kernel microbench and the roofline report.

Prints ``name,us_per_call,derived`` CSV per benchmark (us_per_call = wall
time of the benchmark computation; derived = its headline number). Results
are cached under benchmarks/artifacts/paper; pass --force to recompute.
"""
import argparse
import json
import sys
import time


def _row(name, us, derived):
    print(f"{name},{us:.0f},{derived}")
    sys.stdout.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated benchmark names")
    args = ap.parse_args()

    from benchmarks import kernel_bench as kb
    from benchmarks import paper_tables as pt
    from benchmarks import roofline_report as rr
    from benchmarks import serving_bench as sb

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")

    def run(name, fn, derive):
        if only and name not in only:
            return
        t0 = time.perf_counter()
        out = fn(force=args.force)
        us = (time.perf_counter() - t0) * 1e6
        _row(name, us, derive(out))

    run("table1_noise_bits", pt.table1,
        lambda o: "max|noisy-lowbit|=%.3f" % max(
            abs(r["noisy_acc"] - r["lowbit_acc"]) for r in o["rows"] if r["avg_bits"]
        ))
    run("table2_min_energy", pt.table2,
        lambda o: "improvements=" + ";".join(
            f"{m}/{n}:{o[m][n]['improvement_pct']:.0f}%"
            for m in ("cnn", "mlp") for n in ("shot", "thermal", "weight")
        ))
    run("table3_dynamic_bits", pt.table3,
        lambda o: "dyn-uni acc gain=" + ";".join(
            f"{r['target_e_per_mac']}:{r['dynamic']['acc']-r['uniform']['acc']:+.3f}"
            for r in o["rows"]
        ))
    run("table4_bert_shot", pt.table4,
        lambda o: f"bert uniform {o['uniform_aj_per_mac']['min_e_per_mac']:.3f} -> "
                  f"dynamic {o['dynamic_aj_per_mac']['min_e_per_mac']:.3f} aJ/MAC "
                  f"({o['improvement_pct']:.0f}%)")
    run("table5_profile_vs_uniform", pt.table5_profile,
        lambda o: f"profile K={list(o['profile']['repeats'].values())} "
                  f"{o['profile']['e_per_mac_aj']:.3f} aJ/MAC, "
                  f"saves {o['improvement_pct_vs_cheapest_uniform']:.0f}% vs "
                  f"cheapest feasible uniform"
                  if o["improvement_pct_vs_cheapest_uniform"] is not None
                  else "no feasible uniform K")
    run("fig4_energy_curve", pt.fig4,
        lambda o: "monotone_acc=" + str(all(
            o["curve"][i]["dynamic_acc"] <= o["curve"][i + 1]["dynamic_acc"] + 0.05
            for i in range(len(o["curve"]) - 1)
        )))
    run("fig6_energy_allocations", pt.fig6,
        lambda o: "allocs=" + ";".join(
            f"{k}:{v:.3f}" for k, v in o["allocations_aj_per_mac"].items()
        ))
    run("kernel_bench", kb.kernel_bench,
        lambda o: f"fused_speedup={o['speedup_x']:.2f}x "
                  f"analog_overhead={o['analog_overhead_x']:.2f}x "
                  f"hbm_saving={o['hbm_traffic_saving_x']:.2f}x")
    run("serving_bench", sb.serving_bench,
        lambda o: f"engine={o['engine']['tokens_per_s']:.0f}tok/s "
                  f"naive={o['naive']['tokens_per_s']:.0f}tok/s "
                  f"speedup={o['throughput_speedup_x']:.2f}x "
                  f"hit_rate={o['steady_hit_rate']:.0%} "
                  f"retraces={o['engine']['steady_retraces']}")

    if only is None or "roofline" in only:
        t0 = time.perf_counter()
        rows = rr.load_cells()
        s = rr.summary(rows)
        rr.main()
        _row("roofline_report", (time.perf_counter() - t0) * 1e6,
             f"cells_ok={s['cells_ok']} fits={s['fits']}/{s['cells_ok']} "
             f"dominant={s['dominant_histogram']}")


if __name__ == '__main__':
    main()
