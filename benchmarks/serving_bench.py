"""Serving benchmark: bucket-batched engine vs the naive per-request path.

Drives synthetic mixed-tier traffic — prompt lengths and dynamic-precision
tiers (K = n_repeats) drawn from a seeded distribution — through both:

  engine — ``repro.serving.ServingEngine``: tier-grouped, bucket-padded
           batches through AOT-compiled executables (one per (bucket, K)).
  naive  — one ``jax.jit`` prefill + decode per request at its *exact*
           shape: every new (prompt_len, K) combination re-traces, and every
           request runs at batch 1. What serving cost before this engine.

Both sides replay the trace twice: the first replay is warmup (compiles),
the second is the steady state that the headline numbers come from. The
engine's contract — asserted here and in CI via --smoke — is a 100%
steady-state executable-cache hit rate, i.e. ZERO steady-state retraces.

Records tokens/s, p50/p99 request latency, cache hit/miss counters, and
trace counts; the JSON under artifacts/paper is the repo's serving perf
trajectory point for this PR.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import cache_json
from repro.core import AnalogConfig, PrecisionProfile, coalesce_runs, repeat_profile_search
from repro.models import init_energy_tree, init_params, lm
from repro.models.config import ModelConfig
from repro.serving import ServingEngine

MODEL = dict(
    name="serve-bench", family="dense", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=1024, attn_q_chunk=64,
    attn_kv_chunk=64, loss_chunk=128, dtype="float32",
)
SMOKE_MODEL = dict(MODEL, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
#: non-dense smoke coverage: length-aware prefill serves stateful families;
#: window 16 < the seq buckets, so ring gathers + recurrent pad suffixes run
GRIFFIN_SMOKE_MODEL = dict(
    name="serve-bench-griffin", family="griffin", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=1024,
    rnn_width=64, conv_width=4, local_window=16, attn_q_chunk=32,
    attn_kv_chunk=32, loss_chunk=128, dtype="float32",
)

TIERS = (1, 2, 4)  # precision tiers: K repeats per analog op
TIER_WEIGHTS = (0.5, 0.3, 0.2)
ENERGY_AJ = 20.0


def make_trace(n_requests: int, gen: int, max_len: int, seed: int = 0,
               tiers=TIERS, weights=TIER_WEIGHTS):
    """Deterministic mixed-tier traffic: [(prompt tokens, tier, gen)] where a
    tier is a uniform K int or a registered profile id string."""
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_requests):
        length = int(rng.integers(8, max_len + 1))
        k = rng.choice(np.asarray(tiers, dtype=object), p=weights)
        k = k if isinstance(k, str) else int(k)
        prompt = rng.integers(0, MODEL["vocab_size"], length)
        trace.append((prompt, k, gen))
    return trace


def _percentiles(latencies):
    arr = np.asarray(sorted(latencies))
    return {
        "p50_ms": float(np.percentile(arr, 50) * 1e3),
        "p99_ms": float(np.percentile(arr, 99) * 1e3),
    }


# ---------------------------------------------------------------------------
# engine side
# ---------------------------------------------------------------------------


def _median_by_throughput(candidates):
    """The median-tokens/s replay's record: one noisy-neighbour window on a
    shared box can halve (or double) a single replay's wall time, so the
    steady-state headline comes from the median of several replays."""
    ranked = sorted(candidates, key=lambda c: c["tokens_per_s"])
    return ranked[len(ranked) // 2]


def run_engine(params, cfg, energies, trace, *, max_gen, steady_replays=3,
               profiles=()):
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=max_gen, max_batch=8, max_wait=1.0,
        batch_buckets=(1, 2, 4, 8), seq_buckets=(32, 64, 128),
        profiles=profiles,
    )
    candidates = []
    for replay in range(1 + steady_replays):  # replay 0 is warmup (compiles)
        if replay == 1:
            eng.exe_cache.reset_stats()
        traces_before = eng.trace_count
        batches_before = eng.stats["batches"]
        padded_before = eng.stats["padded_rows"]
        # scheduling runs on a VIRTUAL clock (1ms per arrival) so batch
        # composition is deterministic and replay-invariant: warmup compiles
        # exactly the executables steady state hits. Wall time is real.
        t0 = time.perf_counter()
        submit_t, finish_t = {}, {}
        for i, (prompt, k, gen) in enumerate(trace):
            # a tier is an int K (uniform) or a registered profile id
            tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
            uid = eng.submit(prompt, max_new_tokens=gen, now=i * 1e-3, **tier_kw)
            submit_t[uid] = time.perf_counter()
            for done_uid in eng.poll(now=i * 1e-3):
                finish_t[done_uid] = time.perf_counter()
        for done_uid in eng.flush():
            finish_t[done_uid] = time.perf_counter()
        wall = time.perf_counter() - t0
        if replay >= 1:
            tokens = sum(gen for _, _, gen in trace)
            lat = [finish_t[u] - submit_t[u] for u in submit_t]
            candidates.append({
                "tokens_per_s": tokens / wall,
                "wall_s": wall,
                **_percentiles(lat),
                # engine latency = submit -> completion through the serial
                # replay drain: it INCLUDES queueing/batching delay and the
                # service time of batches dispatched ahead of the request.
                # Compare tokens/s head-to-head with the naive side; compare
                # latencies only with this semantic difference in mind.
                "latency_semantics": "submit->completion incl. queueing",
                "steady_retraces": eng.trace_count - traces_before,
                "batches": eng.stats["batches"] - batches_before,
                "padded_rows": eng.stats["padded_rows"] - padded_before,
            })
    out = _median_by_throughput(candidates)
    out["steady_retraces"] = sum(c["steady_retraces"] for c in candidates)
    out["cache"] = eng.exe_cache.stats()  # accumulated over all steady replays
    return out


# ---------------------------------------------------------------------------
# naive side: per-request jit at exact shapes
# ---------------------------------------------------------------------------


def make_naive(params, cfg, energies, *, max_gen):
    """Per-request serving closures with a trace counter (the old hot path)."""
    counters = {"traces": 0}
    jitted = {}

    def fns_for(k_repeats):
        if k_repeats in jitted:
            return jitted[k_repeats]

        def pre(params, tokens, key):
            counters["traces"] += 1
            analog = lm.AnalogSpec(
                cfg=AnalogConfig.shot(), energies=energies, key=key,
                n_repeats=k_repeats,
            )
            cache, h_last = lm.prefill(
                params, {"tokens": tokens}, cfg, analog=analog,
                cache_len=tokens.shape[1] + max_gen,
            )
            logits = lm.logits_last(params, h_last, cfg)
            return cache, jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32)

        def dec(params, cache, tok, pos, key):
            counters["traces"] += 1
            analog = lm.AnalogSpec(
                cfg=AnalogConfig.shot(), energies=energies,
                key=jax.random.fold_in(key, pos), n_repeats=k_repeats,
            )
            logits, new_cache = lm.decode_step(
                params, cache, {"tokens": tok}, pos, cfg, analog=analog
            )
            return jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32), new_cache

        jitted[k_repeats] = (jax.jit(pre), jax.jit(dec, donate_argnums=(1,)))
        return jitted[k_repeats]

    def serve(prompt, k_repeats, gen, key):
        pre, dec = fns_for(k_repeats)
        tokens = jnp.asarray(prompt, jnp.int32)[None, :]
        cache, tok = pre(params, tokens, key)
        toks = [tok]
        for t in range(gen - 1):
            pos = jnp.asarray(len(prompt) + t, jnp.int32)
            tok, cache = dec(params, cache, tok[:, None], pos, key)
            toks.append(tok)
        return np.stack([np.asarray(t) for t in toks], axis=1)

    return serve, counters


def run_naive(params, cfg, energies, trace, *, max_gen, steady_replays=3):
    serve, counters = make_naive(params, cfg, energies, max_gen=max_gen)
    base_key = jax.random.PRNGKey(123)
    candidates = []
    for replay in range(1 + steady_replays):  # replay 0 is warmup (compiles)
        traces_before = counters["traces"]
        t0 = time.perf_counter()
        lat = []
        for i, (prompt, k, gen) in enumerate(trace):
            r0 = time.perf_counter()
            serve(prompt, k, gen, jax.random.fold_in(base_key, i))
            lat.append(time.perf_counter() - r0)
        wall = time.perf_counter() - t0
        if replay >= 1:
            tokens = sum(gen for _, _, gen in trace)
            candidates.append({
                "tokens_per_s": tokens / wall,
                "wall_s": wall,
                **_percentiles(lat),
                "latency_semantics": "per-request serve time, no queueing",
                "steady_retraces": counters["traces"] - traces_before,
            })
    out = _median_by_throughput(candidates)
    out["steady_retraces"] = sum(c["steady_retraces"] for c in candidates)
    out["total_traces"] = counters["traces"]
    return out


# ---------------------------------------------------------------------------
# profile tier: learn -> freeze -> serve a per-layer K schedule (paper §V-VI)
# ---------------------------------------------------------------------------

PROFILE_K_LEVELS = (1, 2, 4)


def _contrast_energies(cfg, per_layer_aj):
    """``init_energy_tree`` with a distinct energy per layer — the serving
    stand-in for a learned Eq.-14 allocation. Layer sensitivities then differ
    by orders of magnitude, so the learned K schedule is non-uniform: the
    low-energy layer needs repeats, the high-energy layer serves at K=1."""
    tree = init_energy_tree(cfg, 1.0)
    scale = jnp.asarray(per_layer_aj, jnp.float32)
    groups = {
        s: v * scale.reshape((scale.shape[0],) + (1,) * (v.ndim - 1))
        for s, v in tree["groups"].items()
    }
    return {"groups": groups, "lm_head": tree["lm_head"] * scale[-1]}


def profile_smoke_bench():
    """Learn a per-layer K profile against the 2% agreement floor, freeze it,
    serve it as a tier next to the uniform-K tier, and record the uniform-K
    vs learned-profile energy/accuracy tradeoff (the paper's Fig.-5 story,
    live in the serving path). The returned record carries everything main()
    asserts: 100% steady-state hit rate for the mixed uniform+profile
    traffic, zero retraces, lower sum_l K_l*E_l*MACs_l than uniform-K at
    matched accuracy, and solo-vs-padded-batch bit-identity under the
    profile."""
    cfg = ModelConfig(**dict(SMOKE_MODEL, name="serve-bench-profile"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = _contrast_energies(cfg, (2.0, 2000.0))
    key = jax.random.PRNGKey(42)
    eval_toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def greedy_tokens(analog):
        h, _ = lm.forward_hidden(
            params, {"tokens": eval_toks}, cfg, mode="train", analog=analog
        )
        return np.asarray(jnp.argmax(jnp.matmul(h, head), axis=-1))

    ref = greedy_tokens(None)  # the digital model's greedy next tokens
    shot = AnalogConfig.shot()

    def agreement(profile):
        """Accuracy proxy for a frozen LM: greedy next-token agreement with
        the digital model over every prefix position (deterministic keys)."""
        analog = lm.AnalogSpec(cfg=shot, energies=energies, key=key, profile=profile)
        return float((greedy_tokens(analog) == ref).mean())

    # --- learn: greedy per-layer descent against the 2% floor --------------
    k_max = max(PROFILE_K_LEVELS)
    float_acc = agreement(PrecisionProfile.uniform(k_max, cfg.n_layers))
    base = lm.profile_token_energy(cfg, energies, PrecisionProfile.uniform(1, cfg.n_layers))
    weights = tuple(
        lm.profile_token_energy(
            cfg, energies,
            PrecisionProfile(tuple(2 if i == l else 1 for i in range(cfg.n_layers)), name="w"),
        ) - base
        for l in range(cfg.n_layers)
    )  # w_l = E_l * MACs_l exactly (the delta of one extra repeat at layer l)
    search = repeat_profile_search(
        lambda reps: agreement(PrecisionProfile(tuple(reps), name="cand")),
        n_layers=cfg.n_layers, float_acc=float_acc,
        k_levels=PROFILE_K_LEVELS, weights=weights,
    )
    profile = PrecisionProfile(search.repeats, name="learned")  # freeze

    # --- serve: mixed uniform-K + profile traffic, warmup then steady ------
    eng = ServingEngine(
        params, cfg, analog_cfg=shot, energies=energies, max_gen=6,
        max_batch=8, max_wait=1.0, batch_buckets=(1, 2, 4, 8),
        seq_buckets=(32, 64), profiles=[profile],
    )
    trace = make_trace(16, 6, 48, seed=1, tiers=(k_max, "learned"),
                       weights=(0.5, 0.5))
    req_keys = [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(len(trace))]
    results = {}
    steady = {}
    for replay in range(2):  # replay 0 is warmup (compiles)
        if replay == 1:
            eng.exe_cache.reset_stats()
            traces_before = eng.trace_count
        uid_of = {}
        for i, (prompt, k, gen) in enumerate(trace):
            tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
            uid_of[i] = eng.submit(
                prompt, max_new_tokens=gen, key=req_keys[i], now=i * 1e-3, **tier_kw
            )
        done = eng.flush()
        results = {i: done[uid] for i, uid in uid_of.items()}
        if replay == 1:
            steady = {
                **eng.exe_cache.stats(),
                "retraces": eng.trace_count - traces_before,
            }

    # --- bit-identity: a profile request solo vs its padded batched run ----
    i0 = next(i for i, (_, k, _) in enumerate(trace) if isinstance(k, str))
    prompt, _, gen = trace[i0]
    solo_uid = eng.submit(prompt, profile="learned", max_new_tokens=gen,
                          key=req_keys[i0], now=0.0)
    solo = eng.flush()[solo_uid]
    solo_matches = bool(np.array_equal(results[i0], solo))

    rows, _ = lm.profile_rows(cfg, profile)
    e_prof = eng.tier_energy_per_token("learned")
    e_uni = eng.tier_energy_per_token(k_max)
    return {
        "k_levels": list(PROFILE_K_LEVELS),
        "accuracy_metric": "greedy token agreement vs digital, all prefix positions",
        "float_acc": float_acc,
        "search_evals": search.n_evals,
        "learned": {
            "repeats": list(profile.repeats),
            "non_uniform": not profile.is_uniform,
            "accuracy": search.accuracy,
            "energy_per_token_aj": e_prof,
            "segments": len(coalesce_runs(rows)),
        },
        "uniform": {
            "k": k_max,
            "accuracy": float_acc,
            "energy_per_token_aj": e_uni,
        },
        "energy_saving_pct": 100.0 * (1.0 - e_prof / e_uni),
        "accuracy_within_floor": search.accuracy >= float_acc - 0.02,
        "solo_matches_batched": solo_matches,
        "steady": steady,
    }


# ---------------------------------------------------------------------------


def _bench(model_kw, n_requests, gen, max_len, tiers=TIERS, weights=TIER_WEIGHTS):
    cfg = ModelConfig(**model_kw)
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    trace = make_trace(n_requests, gen, max_len, tiers=tiers, weights=weights)
    engine = run_engine(params, cfg, energies, trace, max_gen=gen)
    naive = run_naive(params, cfg, energies, trace, max_gen=gen)
    return {
        "backend": jax.default_backend(),
        "n_requests": n_requests,
        "gen_per_request": gen,
        "tiers": list(tiers),
        "engine": engine,
        "naive": naive,
        "throughput_speedup_x": engine["tokens_per_s"] / naive["tokens_per_s"],
        "steady_hit_rate": engine["cache"]["hit_rate"],
    }


@cache_json("serving_bench")
def serving_bench():
    return _bench(MODEL, n_requests=48, gen=16, max_len=96)


@cache_json("serving_bench_smoke")
def serving_bench_smoke():
    # two tiers + tight length range: groups fill even with few requests
    out = _bench(SMOKE_MODEL, n_requests=16, gen=6, max_len=48,
                 tiers=(1, 4), weights=(0.6, 0.4))
    # one stateful (non-dense) family through the same engine-vs-naive
    # harness: CI proof that length-aware prefill serves it retrace-free
    out["griffin"] = _bench(GRIFFIN_SMOKE_MODEL, n_requests=8, gen=4,
                            max_len=40, tiers=(1, 2), weights=(0.5, 0.5))
    # learned per-layer K profile served as a tier next to uniform K: the
    # paper's per-layer tradeoff (Fig. 5) live in the serving path
    out["profile"] = profile_smoke_bench()
    return out


def _print(out):
    e, n = out["engine"], out["naive"]
    print(f"backend={out['backend']} requests={out['n_requests']} "
          f"gen={out['gen_per_request']} tiers={out['tiers']}")
    print(f"{'':>8} {'tok/s':>9} {'p50_ms':>8} {'p99_ms':>9} {'retraces':>9}")
    print(f"{'engine':>8} {e['tokens_per_s']:>9.1f} {e['p50_ms']:>8.1f} "
          f"{e['p99_ms']:>9.1f} {e['steady_retraces']:>9}")
    print(f"{'naive':>8} {n['tokens_per_s']:>9.1f} {n['p50_ms']:>8.1f} "
          f"{n['p99_ms']:>9.1f} {n['steady_retraces']:>9}")
    print(f"speedup={out['throughput_speedup_x']:.2f}x "
          f"steady_hit_rate={out['steady_hit_rate']:.0%} "
          f"cache_entries={e['cache']['entries']}")
    print("(engine latency includes queueing/batching delay; naive latency "
          "is pure per-request serve time — compare tok/s head-to-head)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny run for CI")
    ap.add_argument("--force", action="store_true", help="ignore cached JSON")
    args = ap.parse_args()
    fn = serving_bench_smoke if args.smoke else serving_bench
    out = fn(force=args.force)
    records = [("dense", out)]
    if "griffin" in out:
        records.append(("griffin", out["griffin"]))
    for label, rec in records:
        print(f"--- {label} ---")
        _print(rec)
        assert rec["steady_hit_rate"] == 1.0, (
            f"{label} engine re-traced in steady state"
        )
        assert rec["engine"]["steady_retraces"] == 0
    if "profile" in out:
        p = out["profile"]
        lr, un = p["learned"], p["uniform"]
        print("--- profile tier ---")
        print(f"learned K schedule {lr['repeats']} ({lr['segments']} scan "
              f"segment(s)) vs uniform K={un['k']}")
        print(f"energy/token {lr['energy_per_token_aj']:.0f} aJ vs "
              f"{un['energy_per_token_aj']:.0f} aJ "
              f"(-{p['energy_saving_pct']:.0f}%) at agreement "
              f"{lr['accuracy']:.3f} vs {un['accuracy']:.3f} "
              f"(floor {p['float_acc'] - 0.02:.3f})")
        print(f"steady: hit_rate={p['steady']['hit_rate']:.0%} "
              f"retraces={p['steady']['retraces']} "
              f"solo==batched: {p['solo_matches_batched']}")
        assert p["learned"]["non_uniform"], "profile search degenerated to uniform"
        assert p["accuracy_within_floor"], "profile broke the 2% accuracy floor"
        assert p["energy_saving_pct"] > 0, "profile tier saved no energy"
        assert p["steady"]["hit_rate"] == 1.0 and p["steady"]["misses"] == 0
        assert p["steady"]["retraces"] == 0, "profile serving re-traced"
        assert p["solo_matches_batched"], "profile batch changed a request's tokens"


if __name__ == "__main__":
    main()
