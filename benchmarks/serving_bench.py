"""Serving benchmark: bucket-batched engine vs the naive per-request path,
and continuous batching vs batch-synchronous decode on the same traffic.

Drives synthetic mixed-tier traffic — prompt lengths and dynamic-precision
tiers (K = n_repeats) drawn from a seeded distribution — through:

  engine — ``repro.serving.ServingEngine``: tier-grouped, bucket-padded
           batches through AOT-compiled executables (one per (bucket, K)).
  naive  — one ``jax.jit`` prefill + decode per request at its *exact*
           shape: every new (prompt_len, K) combination re-traces, and every
           request runs at batch 1. What serving cost before this engine.

The continuous section replays *heterogeneous-budget* traffic
(``max_new_tokens`` mixed 4/16/64 — the regime where run-to-completion
batching decodes a 4-token request for 64 steps) through the same engine in
both decode disciplines and asserts the continuous contract: bit-identical
per-request outputs (vs batch-synchronous AND vs solo runs), zero
steady-state retraces, strictly fewer dispatched decode row-slots, and
>= 1.5x steady-state tokens/s.

Every side replays its trace with a warmup pass first (compiles); the
steady state the headline numbers come from is the median of the remaining
replays. The engine's contract — asserted here and in CI via --smoke — is
a 100% steady-state executable-cache hit rate, i.e. ZERO steady retraces.

Records tokens/s, p50/p99 request latency, cache hit/miss counters, and
trace counts. The JSON under artifacts/paper is this PR's serving perf
record, and the repo-root ``BENCH_serving.json`` is the machine-readable
perf-trajectory artifact (uploaded by CI) future PRs baseline against.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import PAPER_DIR, atomic_write_json, cache_json, run_provenance
from repro.core import (
    DIGITAL_INT8_AJ_PER_MAC,
    AnalogConfig,
    PrecisionProfile,
    coalesce_runs,
    online_repeat_profile_search,
    repeat_profile_search,
    total_macs,
)
from repro.models import init_energy_tree, init_params, lm
from repro.models.config import ModelConfig
from repro.serving import (
    ClusterRouter,
    DriftRamp,
    FaultPlan,
    Int8DigitalTier,
    MetricsFeed,
    NoiseDriftWatchdog,
    PolicyConfig,
    QueueFull,
    ReplicaCrash,
    RequestFailure,
    ServingEngine,
    TierSpec,
    TimedOut,
    WatchdogConfig,
)

#: repo-root perf-trajectory artifact (machine-readable baseline for future PRs)
TRAJECTORY_PATH = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_serving.json"
)

MODEL = dict(
    name="serve-bench", family="dense", n_layers=2, d_model=128, n_heads=8,
    n_kv_heads=4, d_ff=256, vocab_size=1024, attn_q_chunk=64,
    attn_kv_chunk=64, loss_chunk=128, dtype="float32",
)
SMOKE_MODEL = dict(MODEL, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128)
#: non-dense smoke coverage: length-aware prefill serves stateful families;
#: window 16 < the seq buckets, so ring gathers + recurrent pad suffixes run
GRIFFIN_SMOKE_MODEL = dict(
    name="serve-bench-griffin", family="griffin", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=1024,
    rnn_width=64, conv_width=4, local_window=16, attn_q_chunk=32,
    attn_kv_chunk=32, loss_chunk=128, dtype="float32",
)

TIERS = (1, 2, 4)  # precision tiers: K repeats per analog op
TIER_WEIGHTS = (0.5, 0.3, 0.2)
ENERGY_AJ = 20.0


def make_trace(n_requests: int, gen: int, max_len: int, seed: int = 0,
               tiers=TIERS, weights=TIER_WEIGHTS):
    """Deterministic mixed-tier traffic: [(prompt tokens, tier, gen)] where a
    tier is a uniform K int or a registered profile id string."""
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_requests):
        length = int(rng.integers(8, max_len + 1))
        k = rng.choice(np.asarray(tiers, dtype=object), p=weights)
        k = k if isinstance(k, str) else int(k)
        prompt = rng.integers(0, MODEL["vocab_size"], length)
        trace.append((prompt, k, gen))
    return trace


def _percentiles(latencies):
    arr = np.asarray(sorted(latencies))
    return {
        "p50_ms": float(np.percentile(arr, 50) * 1e3),
        "p99_ms": float(np.percentile(arr, 99) * 1e3),
    }


# ---------------------------------------------------------------------------
# engine side
# ---------------------------------------------------------------------------


def _median_by_throughput(candidates):
    """The median-tokens/s replay's record: one noisy-neighbour window on a
    shared box can halve (or double) a single replay's wall time, so the
    steady-state headline comes from the median of several replays."""
    ranked = sorted(candidates, key=lambda c: c["tokens_per_s"])
    return ranked[len(ranked) // 2]


def run_engine(params, cfg, energies, trace, *, max_gen, steady_replays=3,
               profiles=()):
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=max_gen, max_batch=8, max_wait=1.0,
        batch_buckets=(1, 2, 4, 8), seq_buckets=(32, 64, 128),
        profiles=profiles,
    )
    candidates = []
    for replay in range(1 + steady_replays):  # replay 0 is warmup (compiles)
        if replay == 1:
            eng.exe_cache.reset_stats()
        traces_before = eng.trace_count
        batches_before = eng.stats["batches"]
        padded_before = eng.stats["padded_rows"]
        # scheduling runs on a VIRTUAL clock (1ms per arrival) so batch
        # composition is deterministic and replay-invariant: warmup compiles
        # exactly the executables steady state hits. Wall time is real.
        t0 = time.perf_counter()
        submit_t, finish_t = {}, {}
        for i, (prompt, k, gen) in enumerate(trace):
            # a tier is an int K (uniform) or a registered profile id
            tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
            uid = eng.submit(prompt, max_new_tokens=gen, now=i * 1e-3, **tier_kw)
            submit_t[uid] = time.perf_counter()
            for done_uid in eng.poll(now=i * 1e-3):
                finish_t[done_uid] = time.perf_counter()
        for done_uid in eng.flush():
            finish_t[done_uid] = time.perf_counter()
        wall = time.perf_counter() - t0
        if replay >= 1:
            tokens = sum(gen for _, _, gen in trace)
            lat = [finish_t[u] - submit_t[u] for u in submit_t]
            candidates.append({
                "tokens_per_s": tokens / wall,
                "wall_s": wall,
                **_percentiles(lat),
                # engine latency = submit -> completion through the serial
                # replay drain: it INCLUDES queueing/batching delay and the
                # service time of batches dispatched ahead of the request.
                # Compare tokens/s head-to-head with the naive side; compare
                # latencies only with this semantic difference in mind.
                "latency_semantics": "submit->completion incl. queueing",
                "steady_retraces": eng.trace_count - traces_before,
                "batches": eng.stats["batches"] - batches_before,
                "padded_rows": eng.stats["padded_rows"] - padded_before,
            })
    out = _median_by_throughput(candidates)
    out["steady_retraces"] = sum(c["steady_retraces"] for c in candidates)
    out["cache"] = eng.exe_cache.stats()  # accumulated over all steady replays
    return out


# ---------------------------------------------------------------------------
# continuous batching vs batch-synchronous decode, same replayed traffic
# ---------------------------------------------------------------------------

#: heterogeneous decode budgets: the regime continuous batching exists for —
#: a 4-token request co-batched with a 64-token one pays 16x its own decode
#: work under run-to-completion batching
HETERO_GENS = (4, 16, 64)
HETERO_GEN_WEIGHTS = (0.5, 0.3, 0.2)


def make_hetero_trace(n_requests: int, max_len: int, seed: int = 0,
                      tiers=(1, 4), weights=(0.6, 0.4)):
    """Mixed-tier traffic with per-request decode budgets drawn from
    HETERO_GENS: [(prompt tokens, tier, max_new_tokens)]."""
    rng = np.random.default_rng(seed)
    trace = []
    for _ in range(n_requests):
        length = int(rng.integers(8, max_len + 1))
        k = rng.choice(np.asarray(tiers, dtype=object), p=weights)
        k = k if isinstance(k, str) else int(k)
        gen = int(rng.choice(HETERO_GENS, p=HETERO_GEN_WEIGHTS))
        trace.append((rng.integers(0, MODEL["vocab_size"], length), k, gen))
    return trace


def _traffic_energy_per_token(cfg, energies, trace, profiles=None) -> float:
    """Token-weighted mean analog energy per generated token of a trace:
    sum_req gen * E(tier) / sum_req gen, E(tier) = sum_l K_l*E_l*MACs_l.
    String tiers are priced from ``profiles`` (tier id -> PrecisionProfile);
    a trace naming an unregistered profile tier is rejected here rather
    than mispriced."""
    per_tier = {}
    total_e = total_t = 0.0
    for _, k, gen in trace:
        if k not in per_tier:
            if isinstance(k, str):
                if not profiles or k not in profiles:
                    raise ValueError(
                        f"profile tier {k!r} needs its PrecisionProfile to "
                        "be priced; pass profiles={id: profile}"
                    )
                profile = profiles[k]
            else:
                profile = PrecisionProfile.uniform(int(k), cfg.n_layers)
            per_tier[k] = lm.profile_token_energy(cfg, energies, profile)
        total_e += gen * per_tier[k]
        total_t += gen
    return total_e / total_t


def run_continuous_comparison(params, cfg, energies, trace, *, max_gen,
                              steady_replays=3, pool_slots=8,
                              batch_buckets=(1, 2, 4, 8), seq_buckets=(32,)):
    """Same traffic, same per-request keys, two decode disciplines.

    Submissions land on a deterministic virtual clock and the drain is
    flush-style (deadline-free), so batch/admission composition is
    replay-invariant: warmup compiles exactly the executables steady state
    hits. Latency semantics differ per mode and are labeled in each record:
    the continuous side drains through ``pump_step``, stamping a request
    the iteration it retires (queueing + pool wait included), while the
    batch-synchronous side is stamped when ``flush()`` returns the whole
    drain — its p50/p99 measure the full drain wall, an upper bound on any
    request's latency. Compare tokens/s head-to-head; compare latencies
    only with that asymmetry in mind.
    """
    req_keys = [
        jax.random.fold_in(jax.random.PRNGKey(77), i) for i in range(len(trace))
    ]
    recs, outputs = {}, {}
    solo_matches = True
    for mode in ("batch_sync", "continuous"):
        continuous = mode == "continuous"
        eng = ServingEngine(
            params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
            max_gen=max_gen, max_batch=8, max_wait=1.0,
            batch_buckets=batch_buckets, seq_buckets=seq_buckets,
            continuous=continuous, pool_slots=pool_slots,
        )
        candidates = []
        for replay in range(1 + steady_replays):  # replay 0 warms up compiles
            if replay == 1:
                eng.exe_cache.reset_stats()
            traces_before = eng.trace_count
            slots_before = eng.stats["decode_slot_steps"]
            tokens_before = eng.stats["tokens_generated"]
            t0 = time.perf_counter()
            submit_t, finish_t, done = {}, {}, {}
            uid_of = {}
            for i, (prompt, k, gen) in enumerate(trace):
                tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
                uid_of[i] = eng.submit(
                    prompt, max_new_tokens=gen, key=req_keys[i], now=i * 1e-3,
                    **tier_kw,
                )
                submit_t[uid_of[i]] = time.perf_counter()
            if continuous:
                vt = len(trace) * 1e-3
                while eng.n_in_flight:
                    for uid, toks in eng.pump_step(now=vt, force=True).items():
                        done[uid] = toks
                        finish_t[uid] = time.perf_counter()
            else:
                for uid, toks in eng.flush().items():
                    done[uid] = toks
                    finish_t[uid] = time.perf_counter()
            wall = time.perf_counter() - t0
            res = {i: done[uid] for i, uid in uid_of.items()}
            prev = outputs.setdefault(mode, res)
            for i in res:  # every replay reproduces identical tokens
                assert np.array_equal(res[i], prev[i]), (mode, i)
            if replay >= 1:
                tokens = eng.stats["tokens_generated"] - tokens_before
                lat = [finish_t[u] - submit_t[u] for u in submit_t]
                candidates.append({
                    "tokens_per_s": tokens / wall,
                    "wall_s": wall,
                    **_percentiles(lat),
                    "steady_retraces": eng.trace_count - traces_before,
                    "decode_slot_steps": eng.stats["decode_slot_steps"] - slots_before,
                })
        rec = _median_by_throughput(candidates)
        rec["steady_retraces"] = sum(c["steady_retraces"] for c in candidates)
        rec["decode_slot_steps"] = candidates[0]["decode_slot_steps"]
        rec["cache"] = eng.exe_cache.stats()
        rec["latency_semantics"] = (
            "submit->retirement pump iteration incl. queueing + pool wait"
            if continuous
            else "submit->flush() return: whole-drain wall, an upper bound"
        )
        recs[mode] = rec
        if continuous:
            # bit-identity vs solo: sample requests re-served alone through
            # the SAME pool (fresh slot, no neighbors, no co-admissions)
            for i in range(0, len(trace), max(1, len(trace) // 3)):
                prompt, k, gen = trace[i]
                tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
                solo_uid = eng.submit(
                    prompt, max_new_tokens=gen, key=req_keys[i], now=0.0, **tier_kw
                )
                solo = eng.flush()[solo_uid]
                solo_matches &= bool(np.array_equal(solo, outputs[mode][i]))
    equal = all(
        np.array_equal(outputs["batch_sync"][i], outputs["continuous"][i])
        for i in outputs["batch_sync"]
    )
    return {
        "backend": jax.default_backend(),
        "n_requests": len(trace),
        "gens": list(HETERO_GENS),
        "tokens_total": int(sum(gen for _, _, gen in trace)),
        "energy_per_token_aj": _traffic_energy_per_token(cfg, energies, trace),
        "batch_sync": recs["batch_sync"],
        "continuous": recs["continuous"],
        "speedup_x": recs["continuous"]["tokens_per_s"]
        / recs["batch_sync"]["tokens_per_s"],
        "decode_slot_steps": {
            m: recs[m]["decode_slot_steps"] for m in ("batch_sync", "continuous")
        },
        "equal_outputs": bool(equal),
        "solo_matches": bool(solo_matches),
    }


SPEEDUP_TARGET_X = 1.5


def continuous_bench(model_kw, n_requests, max_len, *, pool_slots=8,
                     seq_buckets=(32,), steady_replays=3, retries=1):
    """Continuous-vs-batch-sync record for one model config.

    The tokens/s speedup is a wall-clock quantity: a noisy-neighbor window
    on a shared runner can depress one side of the comparison even through
    the median-of-replays, so a sub-target measurement is re-measured up to
    ``retries`` times (best attempt kept, all attempts recorded). The
    structural metrics — output equality, solo bit-identity, decode
    row-slot counts, retrace counts — are deterministic, never retried,
    and must hold on every attempt.
    """
    cfg = ModelConfig(**dict(model_kw, name=model_kw["name"] + "-continuous"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    trace = make_hetero_trace(n_requests, max_len)

    def measure():
        rec = run_continuous_comparison(
            params, cfg, energies, trace, max_gen=max(HETERO_GENS),
            pool_slots=pool_slots, seq_buckets=seq_buckets,
            steady_replays=steady_replays,
        )
        # the deterministic contract holds per attempt, noise or not
        assert rec["equal_outputs"] and rec["solo_matches"]
        assert rec["decode_slot_steps"]["continuous"] < rec["decode_slot_steps"]["batch_sync"]
        return rec

    out = measure()
    attempts = [out["speedup_x"]]
    for _ in range(retries):
        if out["speedup_x"] >= SPEEDUP_TARGET_X:
            break
        nxt = measure()
        attempts.append(nxt["speedup_x"])
        if nxt["speedup_x"] > out["speedup_x"]:
            out = nxt
    out["speedup_target_x"] = SPEEDUP_TARGET_X
    out["speedup_attempts"] = attempts
    return out


# ---------------------------------------------------------------------------
# naive side: per-request jit at exact shapes
# ---------------------------------------------------------------------------


def make_naive(params, cfg, energies, *, max_gen):
    """Per-request serving closures with a trace counter (the old hot path)."""
    counters = {"traces": 0}
    jitted = {}

    def fns_for(k_repeats):
        if k_repeats in jitted:
            return jitted[k_repeats]

        def pre(params, tokens, key):
            counters["traces"] += 1
            analog = lm.AnalogSpec(
                cfg=AnalogConfig.shot(), energies=energies, key=key,
                n_repeats=k_repeats,
            )
            cache, h_last = lm.prefill(
                params, {"tokens": tokens}, cfg, analog=analog,
                cache_len=tokens.shape[1] + max_gen,
            )
            logits = lm.logits_last(params, h_last, cfg)
            return cache, jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32)

        def dec(params, cache, tok, pos, key):
            counters["traces"] += 1
            analog = lm.AnalogSpec(
                cfg=AnalogConfig.shot(), energies=energies,
                key=jax.random.fold_in(key, pos), n_repeats=k_repeats,
            )
            logits, new_cache = lm.decode_step(
                params, cache, {"tokens": tok}, pos, cfg, analog=analog
            )
            return jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32), new_cache

        jitted[k_repeats] = (jax.jit(pre), jax.jit(dec, donate_argnums=(1,)))
        return jitted[k_repeats]

    def serve(prompt, k_repeats, gen, key):
        pre, dec = fns_for(k_repeats)
        tokens = jnp.asarray(prompt, jnp.int32)[None, :]
        cache, tok = pre(params, tokens, key)
        toks = [tok]
        for t in range(gen - 1):
            pos = jnp.asarray(len(prompt) + t, jnp.int32)
            tok, cache = dec(params, cache, tok[:, None], pos, key)
            toks.append(tok)
        return np.stack([np.asarray(t) for t in toks], axis=1)

    return serve, counters


def run_naive(params, cfg, energies, trace, *, max_gen, steady_replays=3):
    serve, counters = make_naive(params, cfg, energies, max_gen=max_gen)
    base_key = jax.random.PRNGKey(123)
    candidates = []
    for replay in range(1 + steady_replays):  # replay 0 is warmup (compiles)
        traces_before = counters["traces"]
        t0 = time.perf_counter()
        lat = []
        for i, (prompt, k, gen) in enumerate(trace):
            r0 = time.perf_counter()
            serve(prompt, k, gen, jax.random.fold_in(base_key, i))
            lat.append(time.perf_counter() - r0)
        wall = time.perf_counter() - t0
        if replay >= 1:
            tokens = sum(gen for _, _, gen in trace)
            candidates.append({
                "tokens_per_s": tokens / wall,
                "wall_s": wall,
                **_percentiles(lat),
                "latency_semantics": "per-request serve time, no queueing",
                "steady_retraces": counters["traces"] - traces_before,
            })
    out = _median_by_throughput(candidates)
    out["steady_retraces"] = sum(c["steady_retraces"] for c in candidates)
    out["total_traces"] = counters["traces"]
    return out


# ---------------------------------------------------------------------------
# profile tier: learn -> freeze -> serve a per-layer K schedule (paper §V-VI)
# ---------------------------------------------------------------------------

PROFILE_K_LEVELS = (1, 2, 4)


def _contrast_energies(cfg, per_layer_aj):
    """``init_energy_tree`` with a distinct energy per layer — the serving
    stand-in for a learned Eq.-14 allocation. Layer sensitivities then differ
    by orders of magnitude, so the learned K schedule is non-uniform: the
    low-energy layer needs repeats, the high-energy layer serves at K=1."""
    tree = init_energy_tree(cfg, 1.0)
    scale = jnp.asarray(per_layer_aj, jnp.float32)
    groups = {
        s: v * scale.reshape((scale.shape[0],) + (1,) * (v.ndim - 1))
        for s, v in tree["groups"].items()
    }
    return {"groups": groups, "lm_head": tree["lm_head"] * scale[-1]}


def profile_smoke_bench():
    """Learn a per-layer K profile against the 2% agreement floor, freeze it,
    serve it as a tier next to the uniform-K tier, and record the uniform-K
    vs learned-profile energy/accuracy tradeoff (the paper's Fig.-5 story,
    live in the serving path). The returned record carries everything main()
    asserts: 100% steady-state hit rate for the mixed uniform+profile
    traffic, zero retraces, lower sum_l K_l*E_l*MACs_l than uniform-K at
    matched accuracy, and solo-vs-padded-batch bit-identity under the
    profile."""
    cfg = ModelConfig(**dict(SMOKE_MODEL, name="serve-bench-profile"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = _contrast_energies(cfg, (2.0, 2000.0))
    key = jax.random.PRNGKey(42)
    eval_toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def greedy_tokens(analog):
        h, _ = lm.forward_hidden(
            params, {"tokens": eval_toks}, cfg, mode="train", analog=analog
        )
        return np.asarray(jnp.argmax(jnp.matmul(h, head), axis=-1))

    ref = greedy_tokens(None)  # the digital model's greedy next tokens
    shot = AnalogConfig.shot()

    def agreement(profile):
        """Accuracy proxy for a frozen LM: greedy next-token agreement with
        the digital model over every prefix position (deterministic keys)."""
        analog = lm.AnalogSpec(cfg=shot, energies=energies, key=key, profile=profile)
        return float((greedy_tokens(analog) == ref).mean())

    # --- learn: greedy per-layer descent against the 2% floor --------------
    k_max = max(PROFILE_K_LEVELS)
    float_acc = agreement(PrecisionProfile.uniform(k_max, cfg.n_layers))
    base = lm.profile_token_energy(cfg, energies, PrecisionProfile.uniform(1, cfg.n_layers))
    weights = tuple(
        lm.profile_token_energy(
            cfg, energies,
            PrecisionProfile(tuple(2 if i == l else 1 for i in range(cfg.n_layers)), name="w"),
        ) - base
        for l in range(cfg.n_layers)
    )  # w_l = E_l * MACs_l exactly (the delta of one extra repeat at layer l)
    search = repeat_profile_search(
        lambda reps: agreement(PrecisionProfile(tuple(reps), name="cand")),
        n_layers=cfg.n_layers, float_acc=float_acc,
        k_levels=PROFILE_K_LEVELS, weights=weights,
    )
    profile = PrecisionProfile(search.repeats, name="learned")  # freeze

    # --- serve: mixed uniform-K + profile traffic, warmup then steady ------
    eng = ServingEngine(
        params, cfg, analog_cfg=shot, energies=energies, max_gen=6,
        max_batch=8, max_wait=1.0, batch_buckets=(1, 2, 4, 8),
        seq_buckets=(32, 64), profiles=[profile],
    )
    trace = make_trace(16, 6, 48, seed=1, tiers=(k_max, "learned"),
                       weights=(0.5, 0.5))
    req_keys = [jax.random.fold_in(jax.random.PRNGKey(7), i) for i in range(len(trace))]
    results = {}
    steady = {}
    for replay in range(2):  # replay 0 is warmup (compiles)
        if replay == 1:
            eng.exe_cache.reset_stats()
            traces_before = eng.trace_count
        uid_of = {}
        for i, (prompt, k, gen) in enumerate(trace):
            tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
            uid_of[i] = eng.submit(
                prompt, max_new_tokens=gen, key=req_keys[i], now=i * 1e-3, **tier_kw
            )
        done = eng.flush()
        results = {i: done[uid] for i, uid in uid_of.items()}
        if replay == 1:
            steady = {
                **eng.exe_cache.stats(),
                "retraces": eng.trace_count - traces_before,
            }

    # --- bit-identity: a profile request solo vs its padded batched run ----
    i0 = next(i for i, (_, k, _) in enumerate(trace) if isinstance(k, str))
    prompt, _, gen = trace[i0]
    solo_uid = eng.submit(prompt, profile="learned", max_new_tokens=gen,
                          key=req_keys[i0], now=0.0)
    solo = eng.flush()[solo_uid]
    solo_matches = bool(np.array_equal(results[i0], solo))

    rows, _ = lm.profile_rows(cfg, profile)
    e_prof = eng.tier_energy_per_token("learned")
    e_uni = eng.tier_energy_per_token(k_max)
    return {
        "k_levels": list(PROFILE_K_LEVELS),
        "accuracy_metric": "greedy token agreement vs digital, all prefix positions",
        "float_acc": float_acc,
        "search_evals": search.n_evals,
        "learned": {
            "repeats": list(profile.repeats),
            "non_uniform": not profile.is_uniform,
            "accuracy": search.accuracy,
            "energy_per_token_aj": e_prof,
            "segments": len(coalesce_runs(rows)),
        },
        "uniform": {
            "k": k_max,
            "accuracy": float_acc,
            "energy_per_token_aj": e_uni,
        },
        "energy_saving_pct": 100.0 * (1.0 - e_prof / e_uni),
        "accuracy_within_floor": search.accuracy >= float_acc - 0.02,
        "solo_matches_batched": solo_matches,
        "steady": steady,
    }


# ---------------------------------------------------------------------------
# fault-tolerance smoke: injected faults, drift watchdog, graceful degradation
# ---------------------------------------------------------------------------


@cache_json("serving_bench_faults")
def fault_smoke_bench():
    """Serve continuous analog traffic through an injected fault storm and a
    noise-drift episode, recording the fault-tolerance contract main()
    asserts: every request resolves exactly once (tokens or a structured
    failure), requests untouched by any fault stay bit-identical to the
    fault-free run, retried requests complete, deadlines produce TimedOut
    (never hangs), slots never leak, the watchdog detects an injected drift
    ramp within its probe budget, and the whole drift episode — drifted
    dispatch, probes, recovery — causes ZERO retraces (the drift factor is
    a runtime operand, not a compile-time constant)."""
    cfg = ModelConfig(**dict(SMOKE_MODEL, name="serve-bench-faults"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    shot = AnalogConfig.shot()

    def make_engine(plan=None):
        return ServingEngine(
            params, cfg, analog_cfg=shot, energies=energies, max_gen=6,
            max_batch=4, max_wait=0.0, batch_buckets=(1, 2, 4),
            seq_buckets=(32,), continuous=True, pool_slots=4,
            fault_plan=plan,
        )

    rng = np.random.default_rng(0)
    n = 9
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 28)))
               for _ in range(n)]
    gens = [int(rng.integers(2, 7)) for _ in range(n - 1)] + [6]
    tiers = [int(rng.choice([1, 2])) for _ in range(n)]
    req_keys = [jax.random.fold_in(jax.random.PRNGKey(9), i) for i in range(n)]
    # the last request carries a deadline the fault run cannot meet (its
    # decode budget is the full max_gen and the plan stalls early steps)
    fault_deadlines = [None] * (n - 1) + [0.002]

    def run(eng, deadlines=None):
        uids = [
            eng.submit(p, n_repeats=k, max_new_tokens=g, key=kk, now=0.0,
                       deadline=None if deadlines is None else deadlines[i])
            for i, (p, k, g, kk) in enumerate(zip(prompts, tiers, gens, req_keys))
        ]
        results, t, steps = {}, 0.0, 0
        while eng.n_in_flight:
            t += 1e-3
            for uid, res in eng.pump_step(now=t, force=True).items():
                assert uid not in results, "uid resolved twice"
                results[uid] = res
            steps += 1
            assert steps < 2000, "faulted drain hung"
        return uids, results

    # --- A: fault storm vs fault-free baseline -----------------------------
    base_uids, baseline = run(make_engine())
    plan = FaultPlan(
        seed=3, stall_steps=(2, 3), stall_sleep_s=0.0,
        exe_faults=(("decode", 4),),
        # several scheduled (clock, slot) overrides: only ones landing on a
        # live row fire, and at least one must (asserted via poisoned_rows)
        poison={(5, 0): -5, (6, 0): -5, (7, 1): -5},
    )
    eng = make_engine(plan)
    uids, results = run(eng, deadlines=fault_deadlines)
    # stalls delay but never touch outputs; exe faults / poison / timeouts do
    affected = set()
    for entry in eng.fault_log:
        if entry.get("kind") in ("exe_fault", "poison", "timeout"):
            affected.update(entry.get("uids", ()))
    idx_of = {uid: i for i, uid in enumerate(uids)}
    unaffected_identical = all(
        isinstance(results[uid], np.ndarray)
        and np.array_equal(results[uid], baseline[base_uids[idx_of[uid]]])
        for uid in uids if uid not in affected
    )
    retried_uids = {
        u for e in eng.fault_log for u in e.get("retried", ())
    }
    timeout_uids = {u for u, r in results.items() if isinstance(r, TimedOut)}
    pools_clean = all(
        p.n_active == 0 and p.allocator.n_free == p.slots
        for p in eng.pools.values()
    ) and eng.scheduler.n_pending == 0
    inject = {
        "n_requests": n,
        "resolved_once": set(results) == set(uids),
        "n_affected": len(affected),
        "unaffected_bit_identical": unaffected_identical,
        "retried_completed": all(
            isinstance(results[u], np.ndarray) for u in retried_uids
            if u not in timeout_uids
        ) and bool(retried_uids),
        "timeouts": len(timeout_uids),
        "structured_failures": sum(
            isinstance(r, RequestFailure) for r in results.values()
        ),
        "slot_hygiene": bool(pools_clean),
        "stats": {k: eng.stats[k] for k in (
            "stalled_steps", "exe_faults", "poisoned_rows", "retried",
            "timed_out", "failed", "promotions",
        )},
    }

    # --- B: drift ramp -> watchdog -> promote -> recalibrate, zero retraces
    eng = make_engine()
    run(eng)  # warmup: compiles every steady-state executable
    probe_toks = np.asarray(
        jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0, cfg.vocab_size)
    )
    wd = NoiseDriftWatchdog(
        eng, probe_toks, config=WatchdogConfig(interval=2, n_samples=4),
    )
    nominal = wd.probe(step=0)  # must be None: healthy device, in-band
    nominal_estimate = wd.estimates[-1][1]
    eng.exe_cache.reset_stats()
    traces_before = eng.trace_count
    onset = eng._fault_clock + 4
    eng.fault_plan = FaultPlan(
        drift=DriftRamp(start=onset, rate=0.5, max_scale=2.0)
    )
    event, detect_clock, t = None, None, 0.0
    for step in range(1, 120):
        if not eng.n_in_flight:
            for i in range(n - 1):
                eng.submit(prompts[i], n_repeats=tiers[i],
                           max_new_tokens=gens[i], key=req_keys[i], now=t)
        t += 1e-3
        eng.pump_step(now=t, force=True)
        event = wd.maybe_probe(step)
        if event is not None:
            detect_clock = eng._fault_clock
            break
    steady = {**eng.exe_cache.stats(),
              "retraces": eng.trace_count - traces_before}
    detected = event is not None
    if detected:
        eng.promote_tiers(event)
    promoted = bool(eng.promoted)
    eng.flush()  # drain the in-flight drifted traffic
    # repaired hardware: drop the injected drift, re-trim, clear the event
    eng.fault_plan = None
    eng.recalibrate()
    wd.clear()
    recovery = wd.probe(step=999)
    recovered_estimate = wd.estimates[-1][1]
    lo, hi = wd.config.band
    drift = {
        "baseline_rms": wd.baseline_rms,
        "band": [lo, hi],
        "nominal_in_band": nominal is None,
        "nominal_estimate": nominal_estimate,
        "onset_clock": int(onset),
        "detected": detected,
        "detect_clock": int(detect_clock) if detected else None,
        "detect_estimate": event.estimate if detected else None,
        "detect_within_clocks": (
            int(detect_clock - onset) if detected else None
        ),
        "promoted": promoted,
        "recovered_in_band": recovery is None and lo < recovered_estimate < hi,
        "recovered_estimate": recovered_estimate,
        "steady": steady,
    }
    return {"backend": jax.default_backend(), "inject": inject, "drift": drift}


# ---------------------------------------------------------------------------
# overload smoke: SLA-aware precision governor vs no governor, 3x burst
# ---------------------------------------------------------------------------

#: the governor's tier ladder in the overload replay (uniform K)
OVERLOAD_TIERS = (1, 2, 4)
#: SLO every overload request carries (modeled time units; arms the deadline)
OVERLOAD_SLO = 25.0
#: floor mix drawn per request: no floor / K=2's accuracy / K=4's accuracy
OVERLOAD_FLOOR_WEIGHTS = (0.5, 0.3, 0.2)


def make_overload_schedule(accs, *, steady_gap=6.0, n_steady=6, n_burst=30,
                           seed=5, vocab=1024):
    """Steady arrivals, a 3x burst, then steady recovery traffic. Every
    request asks for the top tier (K=4) with an SLO; floors are drawn from
    (None, acc(K=2), acc(K=4)) so most of the burst has demotion headroom
    but a slice is pinned at the top. Returns [(arrival, prompt, floor,
    gen, phase)] on the modeled clock."""
    rng = np.random.default_rng(seed)
    floors = (None, accs[2], accs[4])
    sched, t = [], 0.0

    def add(n, gap, phase):
        nonlocal t
        for _ in range(n):
            floor = floors[rng.choice(3, p=OVERLOAD_FLOOR_WEIGHTS)]
            prompt = rng.integers(0, vocab, int(rng.integers(8, 25)))
            sched.append((t, prompt, floor, int(rng.integers(2, 5)), phase))
            t += gap

    add(n_steady, steady_gap, "steady")
    add(n_burst, steady_gap / 3.0, "burst")  # 3x the steady arrival rate
    add(n_steady, steady_gap, "recover")
    return sched


def _replay_overload(eng, schedule, *, slo=OVERLOAD_SLO, t_unit=1.0,
                     base_tick=0.25):
    """Drive one arrival schedule through an engine on an
    energy-proportional virtual clock.

    The fused kernel makes K free in *host* wall time, so overload is
    modeled the way time-redundant analog hardware pays for it: each
    pump's clock advance is ``base_tick`` (scheduling/prefill overhead)
    plus ``t_unit * E_tier/E_(K=1)`` per decode step each active tier ran
    (pools share one accelerator, so active tiers add up). Demotion then
    genuinely buys modeled latency as well as energy. Deterministic:
    replays of the same schedule produce identical clocks and batches.
    """
    base_e = eng.tier_energy_per_token(1)
    cost = {k: eng.tier_energy_per_token(k) / base_e for k in OVERLOAD_TIERS}
    t, i, pumps = 0.0, 0, 0
    arrivals, completions = {}, {}
    rejected = []  # schedule indices refused with QueueFull
    while i < len(schedule) or eng.n_in_flight:
        if not eng.n_in_flight and i < len(schedule) and schedule[i][0] > t:
            t = schedule[i][0]  # idle: jump the clock to the next arrival
        while i < len(schedule) and schedule[i][0] <= t:
            _, prompt, floor, gen, _ = schedule[i]
            try:
                uid = eng.submit(prompt, n_repeats=max(OVERLOAD_TIERS),
                                 max_new_tokens=gen, now=t,
                                 target_latency=slo, accuracy_floor=floor)
                arrivals[uid] = (t, i)
            except QueueFull:
                rejected.append(i)
            i += 1
        before = dict(eng.stats["tier_decode_steps"])
        res = eng.pump_step(now=t)
        dt = base_tick
        for tier, n in eng.stats["tier_decode_steps"].items():
            d = n - before.get(tier, 0)
            if d:
                dt += d * t_unit * cost[tier]
        t += dt
        for uid, r in res.items():
            completions[uid] = (t - arrivals[uid][0], r)
        pumps += 1
        assert pumps < 20000, "overload replay hung"
    return {"arrivals": arrivals, "completions": completions,
            "rejected": rejected, "end": t}


def _summarize_overload(eng, rec, schedule, accs):
    """Per-side record: SLA outcomes, burst-window energy/token at the
    tiers requests were actually SERVED at, realized accuracy proxy, and
    floor-violation count."""
    lat_ok = []
    timeouts = 0
    served_tok, served_e, served_acc = 0, 0.0, 0.0
    burst_tok, burst_e = 0, 0.0
    violations = 0
    for uid, (lat, r) in rec["completions"].items():
        if isinstance(r, TimedOut):
            timeouts += 1
            continue
        if not isinstance(r, np.ndarray):
            continue
        lat_ok.append(lat)
        _, idx = rec["arrivals"][uid]
        floor, phase = schedule[idx][2], schedule[idx][4]
        tier = eng.served_tiers[uid]
        n = int(r.size)
        e = eng.tier_energy_per_token(tier)
        served_tok += n
        served_e += n * e
        served_acc += n * accs[tier]
        if phase == "burst":
            burst_tok += n
            burst_e += n * e
        if floor is not None and accs[tier] < floor - 1e-9:
            violations += 1
    p = _percentiles(lat_ok) if lat_ok else {"p50_ms": None, "p99_ms": None}
    return {
        "completed": len(lat_ok),
        "timeouts": timeouts,
        "rejected": len(rec["rejected"]),
        # modeled-clock latencies (time units, not ms despite the key names)
        "p50": p["p50_ms"] / 1e3 if lat_ok else None,
        "p99": p["p99_ms"] / 1e3 if lat_ok else None,
        "energy_per_token_aj": served_e / max(1, served_tok),
        "burst_energy_per_token_aj": (burst_e / burst_tok) if burst_tok else None,
        "realized_accuracy": served_acc / max(1, served_tok),
        "floor_violations": violations,
    }


@cache_json("serving_bench_overload")
def overload_smoke_bench():
    """Replay a 3x overload burst through the SAME traffic twice — once with
    the SLA-aware precision governor, once without — and record the
    graceful-degradation contract main() asserts: with the governor on,
    demotion engages before any shedding, modeled p99 stays under the SLO,
    strictly fewer requests are lost (TimedOut + QueueFull + shed) than
    governor-off, burst energy/token drops below governor-off's, no
    request is ever served below its accuracy floor, the governor walks
    back to nominal after the drain, and the whole episode — demotions,
    promotions, retier sweeps — causes ZERO steady-state retraces (tier
    reassignment only ever lands on already-warmed executables). Also runs
    the online profile re-trim (``online_repeat_profile_search``) against
    the same accuracy proxy as the between-epochs maintenance step."""
    cfg = ModelConfig(**dict(SMOKE_MODEL, name="serve-bench-overload"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    # a genuinely noisy device (low per-site energy): K visibly buys
    # accuracy, so the tier ladder has real floors to respect
    energies = init_energy_tree(cfg, 20.0)
    shot = AnalogConfig.shot()
    key = jax.random.PRNGKey(21)
    eval_toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]

    def greedy_tokens(analog):
        h, _ = lm.forward_hidden(
            params, {"tokens": eval_toks}, cfg, mode="train", analog=analog
        )
        return np.asarray(jnp.argmax(jnp.matmul(h, head), axis=-1))

    ref = greedy_tokens(None)

    def agreement(profile):
        analog = lm.AnalogSpec(cfg=shot, energies=energies, key=key,
                               profile=profile)
        return float((greedy_tokens(analog) == ref).mean())

    # measured accuracy proxy per tier: the governor's demotion metadata
    accs = {k: agreement(PrecisionProfile.uniform(k, cfg.n_layers))
            for k in OVERLOAD_TIERS}
    schedule = make_overload_schedule(accs, vocab=cfg.vocab_size)
    policy = PolicyConfig(
        tiers=tuple(TierSpec(k, accs[k]) for k in OVERLOAD_TIERS),
        demote_at=1.5, promote_at=0.5, shed_at=5.0, min_dwell=2,
    )

    def run_side(with_governor):
        eng = ServingEngine(
            params, cfg, analog_cfg=shot, energies=energies, max_gen=6,
            max_batch=4, max_wait=0.0, batch_buckets=(1, 2, 4),
            seq_buckets=(32,), continuous=True, pool_slots=2,
            k_ladder=OVERLOAD_TIERS, max_queue=8,
            policy=policy if with_governor else None,
        )
        rec = None
        for replay in range(2):  # replay 0 is warmup (compiles)
            if replay == 1:
                eng.exe_cache.reset_stats()
            traces_before = eng.trace_count
            rec = _replay_overload(eng, schedule)
            t = rec["end"]
            if eng.governor is not None:  # idle ticks: walk back to nominal
                for _ in range(2 * policy.min_dwell + 2):
                    t += 1.0
                    eng.pump_step(now=t)
            rec["steady_retraces"] = eng.trace_count - traces_before
        side = _summarize_overload(eng, rec, schedule, accs)
        side["steady_retraces"] = rec["steady_retraces"]
        side["cache"] = eng.exe_cache.stats()
        side["shed"] = eng.stats["shed"]
        if eng.governor is not None:
            gov = eng.governor
            side["demoted"] = eng.stats["demoted"]
            side["promoted_back"] = eng.stats["promoted_back"]
            side["transitions"] = eng.stats["policy_transitions"]
            side["final_mode"] = gov.mode
            first = {}
            for e in gov.events:
                first.setdefault(e.kind, e.step)
            side["first_event_step"] = first
            side["demote_before_shed"] = "demote" in first and (
                "shed_on" not in first or first["demote"] < first["shed_on"]
            )
        return side

    on = run_side(True)
    off = run_side(False)
    lost_on = on["timeouts"] + on["rejected"]
    lost_off = off["timeouts"] + off["rejected"]

    # --- online re-trim: the between-epochs profile maintenance step -------
    base = lm.profile_token_energy(
        cfg, energies, PrecisionProfile.uniform(1, cfg.n_layers))
    weights = tuple(
        lm.profile_token_energy(
            cfg, energies,
            PrecisionProfile(
                tuple(2 if i == l else 1 for i in range(cfg.n_layers)),
                name="w"),
        ) - base
        for l in range(cfg.n_layers)
    )
    acc_fn = lambda reps: agreement(PrecisionProfile(tuple(reps), name="online"))
    frozen_hi = PrecisionProfile.uniform(max(OVERLOAD_TIERS), cfg.n_layers)
    retrim = online_repeat_profile_search(
        acc_fn, frozen=frozen_hi, float_acc=accs[max(OVERLOAD_TIERS)],
        max_degradation=0.05, k_levels=OVERLOAD_TIERS, weights=weights,
    )
    frozen_cost = sum(w * k for w, k in zip(weights, frozen_hi.repeats))
    repair = online_repeat_profile_search(  # drifted floor: warm-start repair
        acc_fn, frozen=PrecisionProfile.uniform(1, cfg.n_layers),
        float_acc=accs[max(OVERLOAD_TIERS)], max_degradation=0.05,
        k_levels=OVERLOAD_TIERS, weights=weights,
    )
    return {
        "backend": jax.default_backend(),
        "accuracy_metric": "greedy token agreement vs digital, all prefix positions",
        "tier_accuracy": {str(k): accs[k] for k in OVERLOAD_TIERS},
        "slo": OVERLOAD_SLO,
        "n_requests": len(schedule),
        "burst_x": 3,
        "governor_on": on,
        "governor_off": off,
        "lost": {"on": lost_on + on["shed"], "off": lost_off + off["shed"]},
        "online_retrim": {
            "trim": {"repeats": list(retrim.repeats), "feasible": retrim.feasible,
                     "repaired": retrim.repaired, "n_evals": retrim.n_evals,
                     "cost": retrim.cost, "frozen_cost": frozen_cost,
                     "accuracy": retrim.accuracy},
            "repair": {"repeats": list(repair.repeats),
                       "feasible": repair.feasible, "repaired": repair.repaired,
                       "n_evals": repair.n_evals, "accuracy": repair.accuracy},
        },
    }


# ---------------------------------------------------------------------------
# hybrid smoke: analog uniform-K + analog profile + int8 digital, one engine
# ---------------------------------------------------------------------------

#: the streaming MetricsFeed's JSONL artifact (uploaded by CI)
METRICS_JSONL_PATH = os.path.join(PAPER_DIR, "serving_metrics.jsonl")


@cache_json("serving_bench_hybrid")
def hybrid_smoke_bench():
    """Serve int8 digital traffic NEXT TO uniform-K and per-layer-profile
    analog traffic in one continuous engine — three implementations of one
    ``ExecutionTier`` interface sharing the scheduler, the AOT cache, and
    the slot pools. Records the cross-domain contract main() asserts:
    100% steady-state hit rate and zero retraces across all four tiers,
    per-request bit-identity per tier (pooled == solo, analog and digital
    alike), honest per-tier energy/token — the digital tier priced from
    the per-MAC digital cost model, never the analog energy tree — with
    the expected ordering e(K=1) < e(profile) < e(K=4) < e(int8), and the
    per-tier MetricsFeed time series streamed to the JSONL artifact."""
    cfg = ModelConfig(**dict(SMOKE_MODEL, name="serve-bench-hybrid"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    profile = PrecisionProfile((2, 1), name="mixed")  # fixed non-uniform
    if os.path.exists(METRICS_JSONL_PATH):  # the sink appends; start fresh
        os.remove(METRICS_JSONL_PATH)
    feed = MetricsFeed(capacity=4096, jsonl_path=METRICS_JSONL_PATH)
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=6, max_batch=4, max_wait=1.0, batch_buckets=(1, 2, 4),
        seq_buckets=(32,), continuous=True, pool_slots=4,
        profiles=[profile], metrics=feed,
    )
    eng.register_tier(Int8DigitalTier())

    tiers = (1, 4, "mixed", "int8")
    trace = make_trace(16, 4, 28, seed=13, tiers=tiers,
                       weights=(0.3, 0.2, 0.25, 0.25))
    req_keys = [jax.random.fold_in(jax.random.PRNGKey(31), i)
                for i in range(len(trace))]
    results, steady = {}, {}
    for replay in range(2):  # replay 0 is warmup (compiles)
        if replay == 1:
            eng.exe_cache.reset_stats()
            traces_before = eng.trace_count
        uid_of = {}
        for i, (prompt, k, gen) in enumerate(trace):
            # submit(tier=...) is the general form: uniform-K ints, profile
            # ids, and custom registered tiers all go through one knob
            uid_of[i] = eng.submit(prompt, tier=k, max_new_tokens=gen,
                                   key=req_keys[i], now=i * 1e-3)
        done = {}
        vt = len(trace) * 1e-3
        while eng.n_in_flight:
            done.update(eng.pump_step(now=vt, force=True))
        res = {i: done[uid] for i, uid in uid_of.items()}
        prev = results or res
        assert all(np.array_equal(res[i], prev[i]) for i in res), (
            "hybrid replay changed a request's tokens"
        )
        results = res
        if replay == 1:
            steady = {**eng.exe_cache.stats(),
                      "retraces": eng.trace_count - traces_before}

    # --- bit-identity: pooled tokens == solo re-serve, per domain ----------
    solo_ok = {}
    for label, pick in (("analog", "mixed"), ("digital", "int8")):
        i0 = next(i for i, (_, k, _) in enumerate(trace) if k == pick)
        prompt, _, gen = trace[i0]
        uid = eng.submit(prompt, tier=pick, max_new_tokens=gen,
                         key=req_keys[i0], now=0.0)
        solo = eng.flush()[uid]
        solo_ok[label] = bool(np.array_equal(solo, results[i0]))

    # --- honest per-tier pricing ------------------------------------------
    e = {str(t): float(eng.tier_energy_per_token(t)) for t in tiers}
    macs = float(total_macs(lm.energy_macs(cfg, 1)))
    int8_expected = DIGITAL_INT8_AJ_PER_MAC * macs
    tokens = {str(t): int(eng.stats["tier_tokens"].get(t, 0)) for t in tiers}
    feed.close()
    return {
        "backend": jax.default_backend(),
        "n_requests": len(trace),
        "tiers": [str(t) for t in tiers],
        "tier_tokens": tokens,
        "all_tiers_served": all(v > 0 for v in tokens.values()),
        "energy_per_token_aj": e,
        "int8_expected_aj": int8_expected,
        "int8_priced_from_digital_model": (
            abs(e["int8"] - int8_expected) <= 1e-6 * int8_expected
        ),
        "energy_ordering_ok": e["1"] < e["mixed"] < e["4"] < e["int8"],
        "solo_matches": solo_ok,
        "steady": steady,
        "metrics": {
            "jsonl_path": os.path.relpath(
                METRICS_JSONL_PATH, os.path.dirname(os.path.dirname(
                    os.path.abspath(__file__)))),
            "n_samples": len(feed),
            "tier_tokens_series": feed.tier_series("tokens"),
            "queue_depth_series": [s["queue_depth"] for s in feed.samples()],
        },
    }


# ---------------------------------------------------------------------------
# sharded: tensor-parallel serving across a device mesh
# ---------------------------------------------------------------------------


def _serve_replay(eng, trace, req_keys):
    """One replay of ``trace`` (continuous pump, virtual clock); returns
    (ordered token rows, tokens generated, wall seconds, decode row-slots)."""
    slots_before = eng.stats["decode_slot_steps"]
    tokens_before = eng.stats["tokens_generated"]
    t0 = time.perf_counter()
    uid_of = {}
    for i, (prompt, k, gen) in enumerate(trace):
        uid_of[i] = eng.submit(prompt, tier=k, max_new_tokens=gen,
                               key=req_keys[i], now=i * 1e-3)
    done = {}
    vt = len(trace) * 1e-3
    while eng.n_in_flight:
        done.update(eng.pump_step(now=vt, force=True))
    wall = time.perf_counter() - t0
    rows = [np.asarray(done[uid_of[i]]) for i in range(len(trace))]
    return (
        rows,
        eng.stats["tokens_generated"] - tokens_before,
        wall,
        eng.stats["decode_slot_steps"] - slots_before,
    )


@cache_json("serving_bench_sharded")
def sharded_smoke_bench():
    """One engine, one request stream, N tensor-parallel shards — and the
    exact same tokens.

    Serves ``granite_20b`` at reduced depth (``configs/shapes.py``
    ``reduced_depth``: 2 layers, /16 width, MQA layout and head_dim intact)
    across a host-device mesh (CI forces 8 CPU devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``). The engine
    keeps every jit-boundary array replicated; tensor parallelism lives
    inside ``analog_dot``'s shard_map, where each column shard salts its
    counter-based noise stream on its global tile coordinates — so the
    sharded engine's greedy tokens are asserted bit-identical to a
    single-device oracle engine (``backend="tile"``: the same stream the
    shards slice), per tier, including a non-uniform per-layer profile tier.

    The whole run is ONE engine driven through a mesh attach -> warm ->
    steady -> reshard -> warm -> steady episode: after each mesh's warmup,
    steady-state replays must run at a 100% executable-cache hit rate with
    zero retraces (the mesh fingerprint in every AOT key is what makes the
    reshard compile fresh entries exactly once). Records tokens/s and
    decode row-slots vs mesh size for the trajectory artifact.
    """
    n_dev = len(jax.devices())
    if n_dev < 2:
        raise RuntimeError(
            "sharded_smoke_bench needs >= 2 devices; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=8 (see "
            "tests/test_compress.py / launch/dryrun.py for the pattern)"
        )
    from repro.configs.granite_20b import CONFIG as GRANITE
    from repro.configs.shapes import reduced_depth
    from repro.launch.mesh import make_mesh_for_devices

    cfg = reduced_depth(
        GRANITE, n_layers=2, width_divisor=16,
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=64, dtype="float32",
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    profile = PrecisionProfile((2, 1), name="edge")
    # "tile" is the tiling-invariant stream TP shards slice; the oracle must
    # run it too (the legacy jax.random "jnp" path draws a different stream)
    a_cfg = AnalogConfig.shot(backend="tile")
    tiers = (1, 2, "edge")
    rng = np.random.default_rng(5)
    trace = []
    for i in range(8):
        length = int(rng.integers(6, 25))
        prompt = rng.integers(1, cfg.vocab_size, length)
        trace.append((prompt, tiers[i % len(tiers)], 4))
    req_keys = [jax.random.fold_in(jax.random.PRNGKey(41), i)
                for i in range(len(trace))]

    def make_engine(mesh):
        return ServingEngine(
            params, cfg, analog_cfg=a_cfg, energies=energies,
            max_gen=6, max_batch=4, max_wait=1.0, batch_buckets=(1, 2, 4),
            seq_buckets=(32,), continuous=True, pool_slots=4,
            profiles=[profile], mesh=mesh,
        )

    def measure(eng):
        """Warm replay (compiles), then a steady replay with reset stats."""
        rows, _, _, _ = _serve_replay(eng, trace, req_keys)
        eng.exe_cache.reset_stats()
        traces_before = eng.trace_count
        rows2, tokens, wall, slots = _serve_replay(eng, trace, req_keys)
        assert all(np.array_equal(a, b) for a, b in zip(rows, rows2)), (
            "replay changed a request's tokens"
        )
        cache = eng.exe_cache.stats()
        return rows, {
            "tokens_per_s": tokens / wall,
            "decode_slot_steps": int(slots),
            "hit_rate": cache["hit_rate"],
            "steady_misses": cache["misses"],
            "steady_retraces": eng.trace_count - traces_before,
            "cache_entries": cache["entries"],
        }

    # single-device oracle: same tile stream, no mesh
    oracle_rows, oracle_rec = measure(make_engine(None))

    mps = [mp for mp in (2, 4) if n_dev % mp == 0 and mp <= n_dev]
    per_mesh = {"1": dict(oracle_rec, model_parallel=1, tokens_match_oracle=True)}
    eng = None
    for mp in mps:  # ONE engine across the episode: attach -> serve -> reshard
        mesh = make_mesh_for_devices(n_dev, model_parallel=mp)
        if eng is None:
            eng = make_engine(mesh)
        else:
            eng.attach_mesh(mesh)  # drained reshard; AOT keys refingerprint
        rows, rec = measure(eng)
        rec["model_parallel"] = mp
        rec["tokens_match_oracle"] = bool(
            all(np.array_equal(a, b) for a, b in zip(oracle_rows, rows))
        )
        per_mesh[str(mp)] = rec

    sharded_rows = [per_mesh[str(mp)] for mp in mps]
    return {
        "backend": jax.default_backend(),
        "devices": n_dev,
        "model": cfg.name,
        "n_requests": len(trace),
        "tiers": [str(t) for t in tiers],
        "mesh_sizes": [1] + mps,
        "per_mesh": per_mesh,
        "sharded_equals_unsharded": all(
            r["tokens_match_oracle"] for r in sharded_rows
        ),
        "zero_steady_retraces": all(
            r["steady_retraces"] == 0 and r["steady_misses"] == 0
            for r in per_mesh.values()
        ),
        "steady_hit_rate": min(r["hit_rate"] for r in per_mesh.values()),
        "resharded": len(mps) > 1,
    }


# ---------------------------------------------------------------------------
# cluster smoke: replicated serving, health-checked failover mid-burst
# ---------------------------------------------------------------------------

#: per-replica MetricsFeed JSONL artifacts (uploaded by CI): one file per
#: replica of the faulted cluster episode, serving_metrics_r{rid}.jsonl
CLUSTER_JSONL_TMPL = os.path.join(PAPER_DIR, "serving_metrics_r{rid}.jsonl")
#: the faulted episode's crash schedule: replica 0 dies on this cluster round
CLUSTER_CRASH_ROUND = 4
#: detector thresholds for the smoke (rounds of the shared fault clock)
CLUSTER_SUSPECT_AFTER, CLUSTER_DEAD_AFTER = 2, 4
CLUSTER_BACKOFF_ROUNDS, CLUSTER_BACKOFF_JITTER = 1, 2
#: cluster-level energy/token ceiling for the governed episode (aJ/token):
#: between the K=2 and K=4 traffic mixes, so a K=4-heavy replica demotes
CLUSTER_BUDGET_AJ_FACTOR = 2.6


def _cluster_traffic(cfg, n, seed=11):
    """A mixed-tier burst: (prompt, tier, max_new) per request."""
    rng = np.random.default_rng(seed)
    return [
        (
            rng.integers(0, cfg.vocab_size, int(rng.integers(4, 28))),
            int(rng.choice(TIERS, p=TIER_WEIGHTS)),
            int(rng.integers(3, 7)),
        )
        for _ in range(n)
    ]


def _run_cluster_episode(cluster, traffic, *, dt=0.01, head=8, per_round=2):
    """Replay the burst on the virtual clock: ``head`` requests land up
    front, then ``per_round`` per pump round — the crash round hits with
    real queued AND pooled work on every replica. Returns (results keyed
    by cuid, per-cuid latency in seconds, final time)."""
    results, latency = {}, {}
    submitted, t = 0, 0.0
    arrivals = {}
    for p, tier, g in traffic[:head]:
        cuid = cluster.submit(p, tier=tier, max_new_tokens=g, now=t)
        arrivals[cuid] = t
        submitted = head
    rounds = 0
    while cluster.n_in_flight or submitted < len(traffic):
        t += dt
        for p, tier, g in traffic[submitted:submitted + per_round]:
            cuid = cluster.submit(p, tier=tier, max_new_tokens=g, now=t)
            arrivals[cuid] = t
            submitted += 1
        for cuid, res in cluster.pump_step(now=t).items():
            results[cuid] = res
            latency[cuid] = t - arrivals[cuid]
        rounds += 1
        assert rounds < 3000, "cluster episode hung"
    return results, latency, t


def _warm_cluster_engines(engines, cfg):
    """Pre-compile every executable any replica assignment can need: each
    tier at every prefill batch bucket (plus its decode/insert pair), so
    the measured failover episode is steady-state on every replica."""
    rng = np.random.default_rng(1)
    for eng in engines:
        t = 0.0
        for tier in TIERS:
            for bucket in (1, 2, 4):
                for _ in range(bucket):
                    eng.submit(
                        rng.integers(0, cfg.vocab_size, 8), tier=tier,
                        max_new_tokens=2, now=t,
                    )
                while eng.n_in_flight:
                    t += 0.01
                    eng.pump_step(now=t, force=True)
        eng.exe_cache.reset_stats()


@cache_json("serving_bench_cluster")
def cluster_smoke_bench():
    """Kill 1 of 3 replicas mid-burst and record the failover contract
    main() asserts: zero lost requests, failed-over streams bit-identical
    to the fault-free cluster (per-request stacked keys make tokens
    replica-independent), zero steady-state retraces on the survivors,
    p99 bounded by detection + backoff + one re-serve, and — in a second,
    governed episode — the cluster governor rebalancing the global power
    budget onto the survivor with demote-before-shed ordering intact."""
    cfg = ModelConfig(**dict(SMOKE_MODEL, name="serve-bench-cluster"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    shot = AnalogConfig.shot()

    def make_engine(rid=None, policy=None):
        feed = None
        if rid is not None:
            path = CLUSTER_JSONL_TMPL.format(rid=rid)
            if os.path.exists(path):  # the sink appends; start fresh
                os.remove(path)
            feed = MetricsFeed(capacity=4096, jsonl_path=path, replica_id=rid)
        return ServingEngine(
            params, cfg, analog_cfg=shot, energies=energies, max_gen=6,
            max_batch=4, max_wait=0.0, batch_buckets=(1, 2, 4),
            seq_buckets=(32,), continuous=True, pool_slots=4,
            k_ladder=TIERS, metrics=feed, policy=policy,
        )

    traffic = _cluster_traffic(cfg, 24)

    # --- A: fault-free cluster = the bit-identity oracle -------------------
    clean = ClusterRouter([make_engine() for _ in range(3)], seed=0)
    clean_results, clean_lat, _ = _run_cluster_episode(clean, traffic)
    assert clean.stats["failed"] == 0

    # --- B: the same burst with replica 0 crashing mid-burst ---------------
    engines = [make_engine(rid=r) for r in range(3)]
    _warm_cluster_engines(engines, cfg)
    traces_before = [e.trace_count for e in engines]
    cluster = ClusterRouter(
        engines, seed=0,
        suspect_after=CLUSTER_SUSPECT_AFTER, dead_after=CLUSTER_DEAD_AFTER,
        backoff_rounds=CLUSTER_BACKOFF_ROUNDS,
        backoff_jitter=CLUSTER_BACKOFF_JITTER,
        faults=(ReplicaCrash(replica=0, at=CLUSTER_CRASH_ROUND),),
    )
    results, lat, _ = _run_cluster_episode(cluster, traffic)
    failed_over = [
        c for c, e in cluster.journal.items() if e.failed_over
    ]
    token_rows = {
        c: r for c, r in results.items() if not isinstance(r, RequestFailure)
    }
    bit_identical = all(
        np.array_equal(np.asarray(r), np.asarray(clean_results[c]))
        for c, r in token_rows.items()
    )
    survivor_retraces = {
        r: engines[r].trace_count - traces_before[r] for r in (1, 2)
    }
    # principled p99 bound: an orphan waits out detection + backoff, then
    # re-serves from scratch — at most one clean max-latency serve more
    dt = 0.01
    detect_window = (
        CLUSTER_DEAD_AFTER + CLUSTER_BACKOFF_ROUNDS + CLUSTER_BACKOFF_JITTER
    ) * dt
    p99_bound = (
        float(np.percentile(list(clean_lat.values()), 99))
        + detect_window + max(clean_lat.values())
    )
    p99 = float(np.percentile(list(lat.values()), 99))
    failover = {
        "n_requests": len(traffic),
        "resolved": len(results),
        "lost": len(traffic) - len(results),
        "structured_failures": sum(
            isinstance(r, RequestFailure) for r in results.values()
        ),
        "failed_over": len(failed_over),
        "redispatched": cluster.stats["redispatched"],
        "dedup_tokens": cluster.stats["dedup_tokens"],
        "prefix_mismatches": cluster.stats["prefix_mismatches"],
        "duplicates_discarded": cluster.stats["duplicates_discarded"],
        "tokens_bit_identical": bool(bit_identical),
        "health": {str(r): s for r, s in cluster.health.items()},
        "survivor_retraces": {str(r): v for r, v in survivor_retraces.items()},
        "p99_s": p99,
        "p99_clean_s": float(np.percentile(list(clean_lat.values()), 99)),
        "p99_bound_s": p99_bound,
        "heartbeats": {
            str(h.rid): int(h.feed.heartbeat_step) for h in cluster.replicas
        },
        "jsonl_paths": [
            os.path.relpath(
                CLUSTER_JSONL_TMPL.format(rid=r),
                os.path.join(PAPER_DIR, "..", ".."),
            )
            for r in range(3)
        ],
        "replicas": cluster.replica_stats(),
    }

    # --- C: governed episode — rebalance the budget over the survivor ------
    # ceiling between E(K=2)=2*E(1) and E(K=4)=4*E(1): all-K=4 traffic
    # overruns it (demote pressure), the K=2 fallback fits under it
    budget = CLUSTER_BUDGET_AJ_FACTOR * _traffic_energy_per_token(
        cfg, energies, [(p, 1, g) for p, _k, g in traffic[:6]]
    )
    accs = {1: 0.80, 2: 0.90, 4: 0.97}
    policy = PolicyConfig(
        tiers=tuple(TierSpec(k, accs[k]) for k in TIERS),
        power_budget_aj=budget, min_dwell=2,
    )
    governed = ClusterRouter(
        [make_engine(policy=policy) for _ in range(2)], seed=0,
        suspect_after=CLUSTER_SUSPECT_AFTER, dead_after=CLUSTER_DEAD_AFTER,
        backoff_rounds=CLUSTER_BACKOFF_ROUNDS, backoff_jitter=0,
        power_budget_aj=budget,
        faults=(ReplicaCrash(replica=0, at=CLUSTER_CRASH_ROUND),),
    )
    heavy = [(p, 4, g) for p, _k, g in traffic]  # K=4 mix: demote pressure
    gresults, _glat, _ = _run_cluster_episode(governed, heavy)
    ordering_ok, demoted_total, shed_total = True, 0, 0
    for h in governed.replicas:
        policy_kinds = [
            e["policy_kind"] for e in h.engine.fault_log
            if e.get("kind") == "policy"
        ]
        demoted_total += h.engine.stats["demoted"]
        shed_total += h.engine.stats["shed"]
        if "shed_on" in policy_kinds:
            first_shed = policy_kinds.index("shed_on")
            ordering_ok &= "demote" in policy_kinds[:first_shed]
    governor = {
        "power_budget_aj": budget,
        "rebalances": governed.stats["rebalances"],
        "final_split": {
            str(r): v for r, v in governed.governor.split.items()
        },
        "survivor_budget_is_global": (
            abs(governed.governor.split.get(1, 0.0) - budget)
            <= 1e-6 * budget
        ),
        "demoted": demoted_total,
        "shed": shed_total,
        "demote_before_shed": bool(ordering_ok),
        "lost": len(heavy) - len(gresults),
        "structured_failures": sum(
            isinstance(r, RequestFailure) for r in gresults.values()
        ),
    }
    return {
        "backend": jax.default_backend(),
        "replicas": 3,
        "crash_round": CLUSTER_CRASH_ROUND,
        "failover": failover,
        "governor": governor,
    }


# ---------------------------------------------------------------------------


def _bench(model_kw, n_requests, gen, max_len, tiers=TIERS, weights=TIER_WEIGHTS):
    cfg = ModelConfig(**model_kw)
    params = init_params(jax.random.PRNGKey(0), cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    trace = make_trace(n_requests, gen, max_len, tiers=tiers, weights=weights)
    engine = run_engine(params, cfg, energies, trace, max_gen=gen)
    naive = run_naive(params, cfg, energies, trace, max_gen=gen)
    return {
        "backend": jax.default_backend(),
        "n_requests": n_requests,
        "gen_per_request": gen,
        "tiers": list(tiers),
        "engine": engine,
        "naive": naive,
        "throughput_speedup_x": engine["tokens_per_s"] / naive["tokens_per_s"],
        "steady_hit_rate": engine["cache"]["hit_rate"],
    }


@cache_json("serving_bench")
def serving_bench():
    out = _bench(MODEL, n_requests=48, gen=16, max_len=96)
    # continuous batching vs run-to-completion on heterogeneous budgets
    out["continuous"] = continuous_bench(MODEL, n_requests=48, max_len=32)
    return out


@cache_json("serving_bench_smoke")
def serving_bench_smoke():
    # two tiers + tight length range: groups fill even with few requests
    out = _bench(SMOKE_MODEL, n_requests=16, gen=6, max_len=48,
                 tiers=(1, 4), weights=(0.6, 0.4))
    # one stateful (non-dense) family through the same engine-vs-naive
    # harness: CI proof that length-aware prefill serves it retrace-free
    out["griffin"] = _bench(GRIFFIN_SMOKE_MODEL, n_requests=8, gen=4,
                            max_len=40, tiers=(1, 2), weights=(0.5, 0.5))
    # learned per-layer K profile served as a tier next to uniform K: the
    # paper's per-layer tradeoff (Fig. 5) live in the serving path
    out["profile"] = profile_smoke_bench()
    # continuous batching vs run-to-completion on heterogeneous budgets
    # (mixed 4/16/64 max_new_tokens), same replayed traffic + request keys
    out["continuous"] = continuous_bench(SMOKE_MODEL, n_requests=24, max_len=32)
    return out


def _write_trajectory(out, smoke: bool) -> str:
    """Write the repo-root machine-readable perf-trajectory record."""
    c = out["continuous"]
    n = out["naive"]

    def _mode(rec, cache, energy):
        m = {
            "tokens_per_s": rec["tokens_per_s"],
            "p50_ms": rec["p50_ms"],
            "p99_ms": rec["p99_ms"],
            "latency_semantics": rec["latency_semantics"],
            "hit_rate": cache["hit_rate"] if cache else None,
            "energy_per_token_aj": energy,
        }
        if cache is not None:  # full executable-cache counters, per mode
            m["cache"] = {k: cache[k] for k in
                          ("hits", "misses", "evictions", "entries")}
        return m

    # the naive row comes from the uniform-budget engine-vs-naive section;
    # batch_sync/continuous from the heterogeneous trace — see "traffic"
    record = {
        "bench": "serving",
        "schema": 1,
        "smoke": bool(smoke),
        "provenance": run_provenance(),
        "backend": out["backend"],
        "modes": {
            "naive": _mode(n, None, None),
            "batch_sync": _mode(
                c["batch_sync"], c["batch_sync"]["cache"],
                c["energy_per_token_aj"],
            ),
            "continuous": _mode(
                c["continuous"], c["continuous"]["cache"],
                c["energy_per_token_aj"],
            ),
        },
        "bucket_engine_speedup_x_vs_naive": out["throughput_speedup_x"],
        "continuous_speedup_x_vs_batch_sync": c["speedup_x"],
        "decode_slot_steps": c["decode_slot_steps"],
        "traffic": {
            "uniform": {"n_requests": out["n_requests"],
                        "gen_per_request": out["gen_per_request"]},
            "heterogeneous": {"n_requests": c["n_requests"], "gens": c["gens"],
                              "tokens_total": c["tokens_total"]},
        },
    }
    if "policy" in out:  # the SLA-governor frontier, machine-readable
        p = out["policy"]
        on, off = p["governor_on"], p["governor_off"]
        record["policy"] = {
            "slo": p["slo"],
            "burst_x": p["burst_x"],
            "tier_accuracy": p["tier_accuracy"],
            "frontier": {
                side: {
                    "energy_per_token_aj": rec["energy_per_token_aj"],
                    "burst_energy_per_token_aj": rec["burst_energy_per_token_aj"],
                    "p99": rec["p99"],
                    "realized_accuracy": rec["realized_accuracy"],
                    "timeouts": rec["timeouts"],
                    "rejected": rec["rejected"],
                    "shed": rec["shed"],
                }
                for side, rec in (("governor_on", on), ("governor_off", off))
            },
            "demoted": on["demoted"],
            "promoted_back": on["promoted_back"],
            "transitions": on["transitions"],
            "demote_before_shed": on["demote_before_shed"],
            "floor_violations": on["floor_violations"],
            "lost": p["lost"],
            "zero_steady_retraces": on["steady_retraces"] == 0,
            "online_retrim": p["online_retrim"],
        }
    if "hybrid" in out:  # analog + digital tiers in one engine, with the
        h = out["hybrid"]  # per-tier MetricsFeed time series
        record["hybrid"] = {
            "tiers": h["tiers"],
            "tier_tokens": h["tier_tokens"],
            "energy_per_token_aj": h["energy_per_token_aj"],
            "energy_ordering_ok": h["energy_ordering_ok"],
            "int8_priced_from_digital_model": h["int8_priced_from_digital_model"],
            "solo_matches": h["solo_matches"],
            "zero_steady_retraces": h["steady"]["retraces"] == 0,
            "hit_rate": h["steady"]["hit_rate"],
            "metrics": h["metrics"],
        }
    if "sharded" in out:  # tensor-parallel serving across a device mesh
        s = out["sharded"]
        record["sharded"] = {
            "devices": s["devices"],
            "model": s["model"],
            "tiers": s["tiers"],
            "mesh_sizes": s["mesh_sizes"],
            "per_mesh": {
                mp: {
                    "tokens_per_s": rec["tokens_per_s"],
                    "decode_slot_steps": rec["decode_slot_steps"],
                    "hit_rate": rec["hit_rate"],
                    "steady_retraces": rec["steady_retraces"],
                    "tokens_match_oracle": rec["tokens_match_oracle"],
                }
                for mp, rec in s["per_mesh"].items()
            },
            "sharded_equals_unsharded": s["sharded_equals_unsharded"],
            "zero_steady_retraces": s["zero_steady_retraces"],
            "steady_hit_rate": s["steady_hit_rate"],
            "resharded": s["resharded"],
        }
    if "cluster" in out:  # replicated failover contract, machine-readable
        cf, cg = out["cluster"]["failover"], out["cluster"]["governor"]
        record["cluster"] = {
            "replicas": out["cluster"]["replicas"],
            "crash_round": out["cluster"]["crash_round"],
            "lost": cf["lost"],
            "failed_over": cf["failed_over"],
            "redispatched": cf["redispatched"],
            "dedup_tokens": cf["dedup_tokens"],
            "prefix_mismatches": cf["prefix_mismatches"],
            "tokens_bit_identical": cf["tokens_bit_identical"],
            "survivor_retraces": cf["survivor_retraces"],
            "p99_s": cf["p99_s"],
            "p99_bound_s": cf["p99_bound_s"],
            "health": cf["health"],
            "rebalances": cg["rebalances"],
            "survivor_budget_is_global": cg["survivor_budget_is_global"],
            "demote_before_shed": cg["demote_before_shed"],
            "governed_lost": cg["lost"],
        }
    if "faults" in out:  # the fault-tolerance contract, machine-readable
        fi, fd = out["faults"]["inject"], out["faults"]["drift"]
        record["faults"] = {
            "resolved_once": fi["resolved_once"],
            "unaffected_bit_identical": fi["unaffected_bit_identical"],
            "retried_completed": fi["retried_completed"],
            "timeouts": fi["timeouts"],
            "slot_hygiene": fi["slot_hygiene"],
            "injected": fi["stats"],
            "drift_detected": fd["detected"],
            "drift_detect_within_clocks": fd["detect_within_clocks"],
            "drift_estimate": fd["detect_estimate"],
            "drift_events": 1 if fd["detected"] else 0,
            "drift_zero_retraces": fd["steady"]["retraces"] == 0,
            "recovered_in_band": fd["recovered_in_band"],
        }
    return atomic_write_json(TRAJECTORY_PATH, record)


def _print(out):
    e, n = out["engine"], out["naive"]
    print(f"backend={out['backend']} requests={out['n_requests']} "
          f"gen={out['gen_per_request']} tiers={out['tiers']}")
    print(f"{'':>8} {'tok/s':>9} {'p50_ms':>8} {'p99_ms':>9} {'retraces':>9}")
    print(f"{'engine':>8} {e['tokens_per_s']:>9.1f} {e['p50_ms']:>8.1f} "
          f"{e['p99_ms']:>9.1f} {e['steady_retraces']:>9}")
    print(f"{'naive':>8} {n['tokens_per_s']:>9.1f} {n['p50_ms']:>8.1f} "
          f"{n['p99_ms']:>9.1f} {n['steady_retraces']:>9}")
    print(f"speedup={out['throughput_speedup_x']:.2f}x "
          f"steady_hit_rate={out['steady_hit_rate']:.0%} "
          f"cache_entries={e['cache']['entries']}")
    print("(engine latency includes queueing/batching delay; naive latency "
          "is pure per-request serve time — compare tok/s head-to-head)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="tiny run for CI")
    ap.add_argument("--force", action="store_true", help="ignore cached JSON")
    ap.add_argument("--faults", action="store_true",
                    help="also run the fault-tolerance smoke (injected "
                         "faults, drift watchdog, graceful degradation)")
    ap.add_argument("--overload", action="store_true",
                    help="also replay a 3x overload burst with and without "
                         "the SLA-aware precision governor")
    ap.add_argument("--hybrid", action="store_true",
                    help="also serve int8 digital tiers next to uniform-K "
                         "and profile analog tiers in one engine, streaming "
                         "the per-tier MetricsFeed to a JSONL artifact")
    ap.add_argument("--sharded", action="store_true",
                    help="also serve tensor-parallel across a device mesh "
                         "(needs >= 2 devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8) and "
                         "assert sharded == unsharded tokens per tier")
    ap.add_argument("--cluster", action="store_true",
                    help="also run the replicated-cluster smoke: kill 1 of "
                         "3 replicas mid-burst and assert zero lost "
                         "requests, bit-identical failover tokens, zero "
                         "survivor retraces, and the rebalanced power "
                         "budget's demote-before-shed ordering")
    args = ap.parse_args()
    fn = serving_bench_smoke if args.smoke else serving_bench
    out = fn(force=args.force)
    if args.faults:
        out["faults"] = fault_smoke_bench(force=args.force)
    if args.overload:
        out["policy"] = overload_smoke_bench(force=args.force)
    if args.hybrid:
        out["hybrid"] = hybrid_smoke_bench(force=args.force)
    if args.sharded:
        out["sharded"] = sharded_smoke_bench(force=args.force)
    if args.cluster:
        out["cluster"] = cluster_smoke_bench(force=args.force)
    records = [("dense", out)]
    if "griffin" in out:
        records.append(("griffin", out["griffin"]))
    for label, rec in records:
        print(f"--- {label} ---")
        _print(rec)
        assert rec["steady_hit_rate"] == 1.0, (
            f"{label} engine re-traced in steady state"
        )
        assert rec["engine"]["steady_retraces"] == 0
    if "profile" in out:
        p = out["profile"]
        lr, un = p["learned"], p["uniform"]
        print("--- profile tier ---")
        print(f"learned K schedule {lr['repeats']} ({lr['segments']} scan "
              f"segment(s)) vs uniform K={un['k']}")
        print(f"energy/token {lr['energy_per_token_aj']:.0f} aJ vs "
              f"{un['energy_per_token_aj']:.0f} aJ "
              f"(-{p['energy_saving_pct']:.0f}%) at agreement "
              f"{lr['accuracy']:.3f} vs {un['accuracy']:.3f} "
              f"(floor {p['float_acc'] - 0.02:.3f})")
        print(f"steady: hit_rate={p['steady']['hit_rate']:.0%} "
              f"retraces={p['steady']['retraces']} "
              f"solo==batched: {p['solo_matches_batched']}")
        assert p["learned"]["non_uniform"], "profile search degenerated to uniform"
        assert p["accuracy_within_floor"], "profile broke the 2% accuracy floor"
        assert p["energy_saving_pct"] > 0, "profile tier saved no energy"
        assert p["steady"]["hit_rate"] == 1.0 and p["steady"]["misses"] == 0
        assert p["steady"]["retraces"] == 0, "profile serving re-traced"
        assert p["solo_matches_batched"], "profile batch changed a request's tokens"
    if "continuous" in out:
        c = out["continuous"]
        cs, cc = c["batch_sync"], c["continuous"]
        print("--- continuous batching (heterogeneous budgets "
              f"{c['gens']}, {c['n_requests']} requests) ---")
        print(f"{'':>12} {'tok/s':>9} {'p50_ms':>8} {'p99_ms':>9} "
              f"{'row-slots':>10} {'retraces':>9}")
        for label, rec in (("batch_sync", cs), ("continuous", cc)):
            print(f"{label:>12} {rec['tokens_per_s']:>9.1f} {rec['p50_ms']:>8.1f} "
                  f"{rec['p99_ms']:>9.1f} {rec['decode_slot_steps']:>10} "
                  f"{rec['steady_retraces']:>9}")
        print(f"speedup={c['speedup_x']:.2f}x "
              f"equal_outputs={c['equal_outputs']} "
              f"solo_matches={c['solo_matches']} "
              f"steady_hit_rate={cc['cache']['hit_rate']:.0%}")
        assert c["equal_outputs"], (
            "continuous decode changed a request's tokens vs batch-synchronous"
        )
        assert c["solo_matches"], "pooled tokens != solo run through the pool"
        assert cc["cache"]["hit_rate"] == 1.0 and cc["steady_retraces"] == 0, (
            "continuous engine re-traced in steady state"
        )
        assert c["decode_slot_steps"]["continuous"] < c["decode_slot_steps"]["batch_sync"], (
            "continuous decode dispatched no fewer row-slots than batch-sync"
        )
        assert c["speedup_x"] >= c["speedup_target_x"], (
            f"continuous steady throughput {c['speedup_x']:.2f}x < "
            f"{c['speedup_target_x']}x target (attempts: {c['speedup_attempts']})"
        )
    if "policy" in out:
        p = out["policy"]
        on, off = p["governor_on"], p["governor_off"]
        print(f"--- SLA governor ({p['burst_x']}x overload burst, "
              f"{p['n_requests']} requests, SLO {p['slo']:.0f}) ---")
        print(f"{'':>14} {'p99':>8} {'e/tok_aJ':>10} {'burst_e':>9} "
              f"{'acc':>6} {'timeout':>8} {'reject':>7} {'shed':>5}")
        for label, rec in (("governor_on", on), ("governor_off", off)):
            burst_e = rec["burst_energy_per_token_aj"]
            print(f"{label:>14} {rec['p99']:>8.1f} "
                  f"{rec['energy_per_token_aj']:>10.0f} "
                  f"{burst_e if burst_e is None else round(burst_e):>9} "
                  f"{rec['realized_accuracy']:>6.3f} {rec['timeouts']:>8} "
                  f"{rec['rejected']:>7} {rec['shed']:>5}")
        print(f"demoted={on['demoted']} promoted_back={on['promoted_back']} "
              f"transitions={on['transitions']} "
              f"final_mode={on['final_mode']} "
              f"lost on/off={p['lost']['on']}/{p['lost']['off']} "
              f"retraces={on['steady_retraces']}")
        rt = p["online_retrim"]
        print(f"online re-trim: {rt['trim']['repeats']} "
              f"(cost {rt['trim']['cost']:.0f} vs frozen "
              f"{rt['trim']['frozen_cost']:.0f}, {rt['trim']['n_evals']} "
              f"evals) repair: {rt['repair']['repeats']} "
              f"(repaired={rt['repair']['repaired']})")
        # the graceful-degradation contract, in shedding order
        assert on["demoted"] > 0, "the burst never engaged demotion"
        assert on["demote_before_shed"], "shedding engaged before demotion"
        assert on["p99"] is not None and on["p99"] <= p["slo"], (
            f"governor-on p99 {on['p99']} blew the SLO {p['slo']}"
        )
        assert p["lost"]["off"] > 0, (
            "the burst did not overload the governor-off engine: the "
            "comparison is vacuous"
        )
        assert p["lost"]["on"] < p["lost"]["off"], (
            f"governor lost no fewer requests ({p['lost']['on']} vs "
            f"{p['lost']['off']})"
        )
        assert on["burst_energy_per_token_aj"] < off["burst_energy_per_token_aj"], (
            "demotion did not cut burst energy/token"
        )
        assert on["floor_violations"] == 0 and off["floor_violations"] == 0, (
            "a request was served below its accuracy floor"
        )
        assert on["final_mode"] == "nominal", (
            f"governor never recovered after the drain: {on['final_mode']}"
        )
        assert on["steady_retraces"] == 0 and off["steady_retraces"] == 0, (
            "tier reassignment re-traced in steady state"
        )
        assert on["cache"]["hit_rate"] == 1.0
        assert rt["trim"]["feasible"] and rt["repair"]["feasible"]
        assert rt["trim"]["cost"] <= rt["trim"]["frozen_cost"], (
            "online re-trim made the frozen profile more expensive"
        )
    if "hybrid" in out:
        h = out["hybrid"]
        print(f"--- hybrid tiers ({h['n_requests']} requests over "
              f"{h['tiers']}) ---")
        print(f"{'tier':>8} {'tokens':>7} {'e/tok_aJ':>11}")
        for t in h["tiers"]:
            print(f"{t:>8} {h['tier_tokens'][t]:>7} "
                  f"{h['energy_per_token_aj'][t]:>11.0f}")
        print(f"steady: hit_rate={h['steady']['hit_rate']:.0%} "
              f"retraces={h['steady']['retraces']} "
              f"solo==pooled: {h['solo_matches']} "
              f"metrics_samples={h['metrics']['n_samples']}")
        assert h["all_tiers_served"], "a hybrid tier served no tokens"
        assert h["steady"]["hit_rate"] == 1.0 and h["steady"]["misses"] == 0
        assert h["steady"]["retraces"] == 0, (
            "mixed analog+digital traffic re-traced in steady state"
        )
        assert h["solo_matches"]["analog"] and h["solo_matches"]["digital"], (
            "pooled tokens != solo run in the hybrid engine"
        )
        assert h["int8_priced_from_digital_model"], (
            "the int8 tier was not priced from the digital cost model"
        )
        assert h["energy_ordering_ok"], (
            f"per-tier energy ordering broke: {h['energy_per_token_aj']}"
        )
        assert h["metrics"]["n_samples"] > 0, "the MetricsFeed never sampled"
    if "faults" in out:
        fi, fd = out["faults"]["inject"], out["faults"]["drift"]
        print("--- fault tolerance ---")
        print(f"storm: {fi['n_requests']} requests, {fi['n_affected']} "
              f"affected, {fi['timeouts']} timed out, stats={fi['stats']}")
        print(f"drift: nominal est {fd['nominal_estimate']:.3f}, detected "
              f"{fd['detected']} at est {fd['detect_estimate']:.3f} "
              f"({fd['detect_within_clocks']} clocks after onset), "
              f"promoted={fd['promoted']}, retraces={fd['steady']['retraces']}, "
              f"recovered est {fd['recovered_estimate']:.3f}")
        assert fi["stats"]["stalled_steps"] >= 1 \
            and fi["stats"]["exe_faults"] >= 1 \
            and fi["stats"]["poisoned_rows"] >= 1, (
            f"the fault storm left an injection site unexercised: {fi['stats']}"
        )
        assert fi["resolved_once"], "a request hung or resolved twice"
        assert fi["unaffected_bit_identical"], (
            "a fault leaked into an unaffected request's tokens"
        )
        assert fi["retried_completed"], "a retried request never completed"
        assert fi["timeouts"] >= 1, "the deadline request did not time out"
        assert fi["slot_hygiene"], "a decode slot leaked through the storm"
        assert fd["nominal_in_band"], "watchdog false-positive at nominal"
        assert fd["detected"], "watchdog missed the injected drift ramp"
        assert fd["detect_within_clocks"] <= 12, (
            f"drift detected {fd['detect_within_clocks']} clocks after onset "
            "(budget: 12)"
        )
        assert fd["promoted"], "drift response did not promote tiers"
        assert fd["steady"]["hit_rate"] == 1.0 and fd["steady"]["retraces"] == 0, (
            "the drift episode re-traced: the drift factor must stay a "
            "runtime operand"
        )
        assert fd["recovered_in_band"], "recalibration did not clear the drift"
    if "sharded" in out:
        s = out["sharded"]
        print(f"--- sharded serving ({s['model']}, {s['devices']} devices, "
              f"tiers {s['tiers']}) ---")
        print(f"{'mp':>4} {'tok/s':>9} {'row-slots':>10} {'hit_rate':>9} "
              f"{'retraces':>9} {'==oracle':>9}")
        for mp in s["mesh_sizes"]:
            rec = s["per_mesh"][str(mp)]
            print(f"{mp:>4} {rec['tokens_per_s']:>9.1f} "
                  f"{rec['decode_slot_steps']:>10} {rec['hit_rate']:>9.0%} "
                  f"{rec['steady_retraces']:>9} "
                  f"{str(rec['tokens_match_oracle']):>9}")
        print(f"sharded==unsharded: {s['sharded_equals_unsharded']} "
              f"resharded: {s['resharded']} "
              f"zero_steady_retraces: {s['zero_steady_retraces']}")
        assert s["sharded_equals_unsharded"], (
            "tensor-parallel serving changed a request's tokens vs the "
            "single-device oracle"
        )
        assert s["zero_steady_retraces"] and s["steady_hit_rate"] == 1.0, (
            "sharded serving re-traced in steady state (mesh fingerprint "
            "missing from an AOT key?)"
        )
        assert s["resharded"], "the episode never exercised a mesh resize"
    if "cluster" in out:
        cl = out["cluster"]
        cf, cg = cl["failover"], cl["governor"]
        print(f"--- replicated cluster ({cl['replicas']} replicas, crash "
              f"at round {cl['crash_round']}) ---")
        print(f"failover: {cf['n_requests']} requests, "
              f"{cf['failed_over']} orphaned, "
              f"{cf['redispatched']} re-dispatched, "
              f"{cf['dedup_tokens']} tokens deduped, lost={cf['lost']}, "
              f"health={cf['health']}")
        print(f"p99 {cf['p99_s'] * 1e3:.1f}ms (clean "
              f"{cf['p99_clean_s'] * 1e3:.1f}ms, bound "
              f"{cf['p99_bound_s'] * 1e3:.1f}ms) survivor_retraces="
              f"{cf['survivor_retraces']}")
        print(f"governor: budget {cg['power_budget_aj']:.0f} aJ/token, "
              f"{cg['rebalances']} rebalances, split {cg['final_split']}, "
              f"demoted={cg['demoted']} shed={cg['shed']}")
        assert cf["lost"] == 0 and cf["structured_failures"] == 0, (
            f"the crash lost requests: {cf['lost']} unresolved, "
            f"{cf['structured_failures']} structured failures"
        )
        assert cf["health"]["0"] == "dead" and cf["failed_over"] > 0, (
            "the crash was never detected or orphaned no work"
        )
        assert cf["prefix_mismatches"] == 0 and cf["tokens_bit_identical"], (
            "a failed-over request's tokens diverged from the fault-free "
            "cluster: per-request keys must make tokens replica-independent"
        )
        assert all(v == 0 for v in cf["survivor_retraces"].values()), (
            f"failover re-traced on a survivor: {cf['survivor_retraces']}"
        )
        assert cf["p99_s"] <= cf["p99_bound_s"], (
            f"failover p99 {cf['p99_s']:.3f}s exceeds the detection+backoff"
            f"+re-serve bound {cf['p99_bound_s']:.3f}s"
        )
        assert cg["rebalances"] >= 2, (
            "the cluster governor never rebalanced on membership change"
        )
        assert cg["survivor_budget_is_global"], (
            f"the survivor's ceiling is not the global budget: "
            f"{cg['final_split']}"
        )
        assert cg["demoted"] > 0, "the governed burst never engaged demotion"
        assert cg["demote_before_shed"], "shedding engaged before demotion"
        assert cg["lost"] == 0, "the governed episode lost requests"
    if "continuous" in out:
        path = _write_trajectory(out, smoke=args.smoke)
        print(f"perf trajectory written to {path}")


if __name__ == "__main__":
    main()
