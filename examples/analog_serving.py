"""Analog serving: the paper's deployment scenario as a serving client.

Two modes:

  default   — side-by-side digital vs analog generation on one batch: every
              matmul runs the analog path under shot noise with per-site
              energies; reports token agreement and optical energy/token.

  --traffic — replays a synthetic *mixed-precision* load through the
              bucket-batched serving engine (repro.serving): requests with
              random prompt lengths, heterogeneous decode budgets, and
              dynamic-precision tiers (K = 1/2/4 analog repeats) are
              tier-grouped, padded into power-of-two buckets, and served
              through AOT-compiled executables. Prints per-tier
              token/energy accounting and the executable-cache hit/miss
              counters (steady state re-traces nothing). Add --continuous
              to decode through persistent per-tier slot pools (in-flight
              admission, early retirement) instead of run-to-completion
              batches.

              admission, early retirement) instead of run-to-completion
              batches. Add --slo SECONDS to attach the SLA-aware precision
              governor: every request carries a latency SLO and a random
              accuracy floor, the middle of the replay arrives as a 3x
              burst, and the governor demotes/promotes precision tiers
              against live queue pressure (policy events are printed).
              Add --dashboard to attach the streaming MetricsFeed
              (serving/monitor.py) and render a compact per-tier dashboard
              — tokens/s, queue depth, pool occupancy — from the sampled
              ring after the replay (samples also stream to a JSONL file).

  --cluster — replicated serving: 3 engine replicas behind the
              ClusterRouter (repro.serving.cluster), replica 0 crashes
              mid-burst, the heartbeat detector declares it dead, and the
              request journal re-dispatches its queued + in-flight work to
              the survivors — re-served streams verified bit-identical to
              the prefixes already emitted (per-request PRNG keys make
              tokens replica-independent), nothing lost, nothing
              re-emitted.

Run:  PYTHONPATH=src python examples/analog_serving.py [--energy 10.0]
      PYTHONPATH=src python examples/analog_serving.py --traffic \
          [--requests 24] [--gen 8] [--continuous] [--slo 2.0] [--dashboard]
      PYTHONPATH=src python examples/analog_serving.py --cluster \
          [--requests 24] [--gen 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PHOTON_ENERGY_AJ, AnalogConfig, total_energy
from repro.models import (
    AnalogSpec,
    decode_step,
    energy_macs,
    init_energy_tree,
    init_params,
    prefill,
)
from repro.models.config import ModelConfig
from repro.data.pipeline import TokenTaskConfig, markov_batch
from repro.serving import (
    ClusterRouter,
    MetricsFeed,
    PolicyConfig,
    ReplicaCrash,
    ServingEngine,
    TierSpec,
    TimedOut,
)

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=1024, vocab_size=4096, attn_q_chunk=128,
    attn_kv_chunk=128, loss_chunk=128, dtype="float32",
)


def _trained_params():
    """Briefly pre-train on the Markov task (cached under /tmp)."""
    import os
    import tempfile

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import TrainConfig
    from repro.runtime.driver import DriverConfig, TrainDriver

    data = TokenTaskConfig(vocab_size=CFG.vocab_size, seq_len=128, global_batch=8, seed=7)
    ckpt = os.path.join(tempfile.gettempdir(), "repro_serve_demo")
    driver = TrainDriver(
        CFG, data, make_local_mesh(), ckpt_dir=ckpt,
        train_cfg=TrainConfig(lr=1e-3, opt_state_dtype="float32"),
        driver_cfg=DriverConfig(max_steps=80, ckpt_every=40, ckpt_async=False),
    )
    out = driver.run()
    if out["metrics"]:  # empty when a cached checkpoint already hit max_steps
        print(f"pre-trained to loss {out['metrics'][-1]['loss']:.3f}")
    else:
        print("restored pre-trained checkpoint")
    return out["state"]["params"]


def _tier_agreement(params, energies, ks):
    """Greedy-token-agreement accuracy stand-in per uniform-K tier: the
    metadata the governor's demotion floors are enforced against."""
    from repro.core import PrecisionProfile
    from repro.models import lm

    key = jax.random.PRNGKey(5)
    toks = jax.random.randint(key, (2, 32), 0, CFG.vocab_size)
    head = params["embed"].T if CFG.tie_embeddings else params["lm_head"]

    def greedy(analog):
        h, _ = lm.forward_hidden(
            params, {"tokens": toks}, CFG, mode="train", analog=analog
        )
        return np.asarray(jnp.argmax(jnp.matmul(h, head), axis=-1))

    ref = greedy(None)
    out = {}
    for k in ks:
        spec = AnalogSpec(
            cfg=AnalogConfig.shot(), energies=energies, key=key,
            profile=PrecisionProfile.uniform(k, CFG.n_layers),
        )
        out[k] = float((greedy(spec) == ref).mean())
    return out


def run_traffic(args, params):
    """Replay a mixed-precision load through the serving engine."""
    tiers, weights = (1, 2, 4), (0.5, 0.3, 0.2)
    profiles = []
    if args.profile:
        from repro.serving import PrecisionProfile

        schedule = tuple(int(k) for k in args.profile.split(","))
        profiles = [PrecisionProfile(schedule, name="cli")]
        # route a slice of traffic to the per-layer profile tier
        tiers, weights = (1, 2, 4, "cli"), (0.4, 0.25, 0.15, 0.2)
    energies = init_energy_tree(CFG, args.energy)
    policy, accs = None, {}
    if args.slo is not None:
        accs = _tier_agreement(params, energies, (1, 2, 4))
        print(f"tier agreement vs digital: "
              + ", ".join(f"K={k}: {a:.3f}" for k, a in sorted(accs.items())))
        policy = PolicyConfig(
            tiers=tuple(TierSpec(k, accs[k]) for k in (1, 2, 4)),
            demote_at=1.0, promote_at=0.25, shed_at=6.0, min_dwell=2,
        )
    seq_buckets = [32]
    while seq_buckets[-1] < args.prompt_len:
        seq_buckets.append(seq_buckets[-1] * 2)
    feed = None
    if args.dashboard:
        import os
        import tempfile

        feed = MetricsFeed(
            capacity=4096,
            jsonl_path=os.path.join(tempfile.gettempdir(),
                                    "repro_serving_metrics.jsonl"),
        )
    engine = ServingEngine(
        params, CFG, analog_cfg=AnalogConfig.shot(backend=args.backend),
        energies=energies, max_gen=args.gen, max_batch=8, max_wait=0.5,
        batch_buckets=(1, 2, 4, 8), seq_buckets=tuple(seq_buckets),
        profiles=profiles, continuous=args.continuous, policy=policy,
        metrics=feed,
    )
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        length = int(rng.integers(8, args.prompt_len + 1))
        k = rng.choice(np.asarray(tiers, dtype=object), p=weights)
        # heterogeneous decode budgets: where continuous batching pays off
        # (run-to-completion decodes every row to the batch max)
        gen = int(rng.choice([max(1, args.gen // 8), max(1, args.gen // 2), args.gen]))
        reqs.append((rng.integers(0, CFG.vocab_size, length),
                     k if isinstance(k, str) else int(k), gen))

    t0 = time.perf_counter()
    uid_tier, results = {}, {}
    t = 0.0
    for i, (prompt, k, gen) in enumerate(reqs):
        tier_kw = {"profile": k} if isinstance(k, str) else {"n_repeats": k}
        slo_kw = {}
        if args.slo is not None:
            # the middle third of the replay arrives as a 3x burst; each
            # request carries the SLO and a random accuracy floor
            t += 1e-3 / 3 if args.requests // 3 <= i < 2 * args.requests // 3 else 1e-3
            floor = (None, accs[2], accs[4])[rng.choice(3, p=(0.5, 0.3, 0.2))]
            slo_kw = {"target_latency": args.slo, "accuracy_floor": floor}
        else:
            t = i * 1e-3
        uid = engine.submit(prompt, max_new_tokens=gen, now=t, **tier_kw, **slo_kw)
        uid_tier[uid] = k
        results.update(engine.poll(now=t))
    while engine.n_in_flight:  # drain on the virtual clock (governor live)
        t += 1e-2
        results.update(
            engine.pump_step(now=t) if args.continuous else engine.poll(now=t)
        )
    wall = time.perf_counter() - t0
    timed_out = {u for u, r in results.items() if isinstance(r, TimedOut)}
    results = {u: r for u, r in results.items() if u not in timed_out}

    total_toks = sum(len(v) for v in results.values())
    print(f"replayed {args.requests} requests ({total_toks} tokens) "
          f"in {wall:.2f}s -> {total_toks / wall:.1f} tok/s "
          f"[backend={args.backend}]")
    for k in tiers:
        uids = [u for u, t in uid_tier.items() if t == k]
        toks = sum(len(results[u]) for u in uids if u in results)
        # true per-tier spend: sum_l K_l * E_l * MACs_l (lm_head is digital)
        e_tok = engine.tier_energy_per_token(k)
        label = f"K={k}" if not isinstance(k, str) else (
            f"profile {k}={list(engine.profiles[k].repeats)}"
        )
        print(f"  tier {label}: {len(uids):>3} requests, {toks:>4} tokens, "
              f"{e_tok / 1e6:.3f} pJ/token "
              f"({e_tok / PHOTON_ENERGY_AJ:.2e} photons)")
    cs = engine.cache_stats()
    print(f"executables: {cs['entries']} compiled ({cs['compile_s']:.1f}s), "
          f"{cs['hits']} hits / {cs['misses']} misses; batches="
          f"{engine.stats['batches']} padded_rows={engine.stats['padded_rows']}")
    if args.continuous:
        s = engine.stats
        active = s["active_slot_steps"] / max(1, s["decode_slot_steps"])
        print(f"continuous: {len(engine.pools)} tier pool(s) x "
              f"{engine.pool_slots} slots, {s['admitted']} admitted / "
              f"{s['retired']} retired in-flight, {s['decode_steps']} pool "
              f"steps ({s['decode_slot_steps']} row-slots, "
              f"{active:.0%} occupancy)")
    if engine.governor is not None:
        gov, s = engine.governor, engine.stats
        served = {}  # tokens by the tier each request was SERVED at
        for uid, toks in results.items():
            tier = engine.served_tiers.get(uid, uid_tier[uid])
            served[tier] = served.get(tier, 0) + len(toks)
        total = sum(served.values())
        blended = sum(
            n * engine.tier_energy_per_token(tier) for tier, n in served.items()
        ) / max(1, total)
        print(f"governor: mode={gov.mode} demoted={s['demoted']} "
              f"promoted_back={s['promoted_back']} shed={s['shed']} "
              f"timed_out={len(timed_out)} "
              f"transitions={s['policy_transitions']}")
        print(f"  served tier mix {dict(sorted(served.items(), key=str))} -> "
              f"blended {blended / 1e6:.3f} pJ/token")
        for e in gov.events:
            print(f"  [{e.kind:>8}] policy step {e.step} pressure="
                  f"{e.pressure:.2f} queue={e.queue_depth} moved={e.moved} "
                  f"{e.detail}")
    if feed is not None:
        _render_dashboard(feed, engine)
    sample = results[min(results)]
    print("sample tokens:", sample[:12].tolist())


def run_cluster(args, params):
    """Replicated serving demo: 3 data-parallel replicas behind a
    ClusterRouter, with replica 0 crashing mid-burst. The router's health
    detector discovers the death through the stalled MetricsFeed
    heartbeat, journal replay re-dispatches the orphaned requests to the
    survivors, and — because every request carries its own stacked PRNG
    key — the re-served streams are verified bit-identical against the
    prefixes the dead replica had already emitted (deduped, never
    re-emitted)."""
    energies = init_energy_tree(CFG, args.energy)
    seq_buckets = [32]
    while seq_buckets[-1] < args.prompt_len:
        seq_buckets.append(seq_buckets[-1] * 2)

    def make_engine():
        return ServingEngine(
            params, CFG, analog_cfg=AnalogConfig.shot(backend=args.backend),
            energies=energies, max_gen=args.gen, max_batch=4, max_wait=0.0,
            batch_buckets=(1, 2, 4), seq_buckets=tuple(seq_buckets),
            continuous=True, pool_slots=4, k_ladder=(1, 2, 4),
        )

    # the crash lands on round 1, while replica 0 still holds its share of
    # the up-front burst: queued rows re-dispatch, decoding rows re-serve
    cluster = ClusterRouter(
        [make_engine() for _ in range(3)], seed=0,
        suspect_after=2, dead_after=4, backoff_rounds=1, backoff_jitter=0,
        faults=(ReplicaCrash(replica=0, at=1),),
    )
    rng = np.random.default_rng(0)
    reqs = [
        (rng.integers(0, CFG.vocab_size, int(rng.integers(8, args.prompt_len + 1))),
         int(rng.choice((1, 2, 4), p=(0.5, 0.3, 0.2))),
         int(rng.choice([max(1, args.gen // 8), max(1, args.gen // 2)])))
        for _ in range(args.requests)
    ]
    t0 = time.perf_counter()
    results, t, submitted = {}, 0.0, 0
    # half the burst lands up front, the rest trickles in 2 per round —
    # the crash at round 4 hits with queued AND decoding work on replica 0
    for prompt, k, gen in reqs[: len(reqs) // 2]:
        cluster.submit(prompt, tier=k, max_new_tokens=gen, now=t)
        submitted += 1
    while cluster.n_in_flight or submitted < len(reqs):
        t += 1e-2
        for prompt, k, gen in reqs[submitted:submitted + 2]:
            cluster.submit(prompt, tier=k, max_new_tokens=gen, now=t)
            submitted += 1
        results.update(cluster.pump_step(now=t))
    wall = time.perf_counter() - t0

    s = cluster.stats
    total_toks = sum(len(v) for v in results.values())
    print(f"cluster: 3 replicas, crash injected at round 1; replayed "
          f"{len(reqs)} requests ({total_toks} tokens) in {wall:.2f}s")
    print(f"health: {cluster.health}")
    for ev in cluster.events:
        if ev["kind"] in ("crash_injected", "health", "failover"):
            desc = {
                "crash_injected": f"replica {ev.get('replica')} crashed",
                "health": (f"replica {ev.get('replica')} "
                           f"{ev.get('frm')} -> {ev.get('to')}: "
                           f"{ev.get('detail')}"),
                "failover": (f"replica {ev.get('replica')} orphaned "
                             f"{len(ev.get('uids', ()))} request(s); "
                             f"re-dispatch at round {ev.get('retry_round')}"),
            }[ev["kind"]]
            print(f"  [round {ev.get('round'):>3}] {desc}")
    print(f"failover: {s['failed_over']} orphaned, {s['redispatched']} "
          f"re-dispatched, {s['dedup_tokens']} already-streamed tokens "
          f"verified + deduped, {s['prefix_mismatches']} prefix mismatches")
    per = cluster.replica_stats()
    print(f"{'replica':>8} {'state':>8} {'heartbeat':>10} {'requests':>9} "
          f"{'tokens':>7}")
    for r in per:
        print(f"{r['replica_id']:>8} {r['state']:>8} "
              f"{r['heartbeat_step']:>10} {r['requests']:>9} "
              f"{r['tokens_generated']:>7}")
    lost = len(reqs) - len(results)
    assert lost == 0 and s["prefix_mismatches"] == 0, (
        f"failover contract broken: lost={lost} "
        f"mismatches={s['prefix_mismatches']}"
    )
    print(f"zero lost requests; every re-served stream bit-identical. "
          f"delivered={s['delivered']} failed={s['failed']}")


def _sparkline(values, width=48):
    """Unicode mini-chart of a numeric series (None plotted as 0)."""
    vals = [0.0 if v is None else float(v) for v in values]
    if len(vals) > width:  # downsample: mean over equal chunks
        step = len(vals) / width
        vals = [
            float(np.mean(vals[int(i * step):max(int(i * step) + 1,
                                                 int((i + 1) * step))]))
            for i in range(width)
        ]
    blocks = " .:-=+*#%@"
    hi = max(vals) or 1.0
    return "".join(blocks[min(len(blocks) - 1,
                              int(v / hi * (len(blocks) - 1)))] for v in vals)


def _render_dashboard(feed, engine):
    """Compact per-tier dashboard rendered from the MetricsFeed ring:
    token throughput per tier over pump steps, queue depth, and pool
    occupancy — the same samples the JSONL sink streams for offline
    dashboards."""
    samples = feed.samples()
    if not samples:
        print("dashboard: no samples recorded")
        return
    print(f"--- dashboard ({len(samples)} retained samples, "
          f"jsonl: {feed.jsonl_path}) ---")
    deltas = feed.tier_series("tokens_delta")
    for tier in sorted(deltas, key=str):
        series = deltas[tier]
        total = samples[-1]["tiers"][tier]["tokens"]
        e = samples[-1]["tiers"][tier]["energy_per_token_aj"]
        e_txt = "n/a" if e is None else f"{e / 1e6:.3f} pJ/tok"
        print(f"  tier {tier:>8} |{_sparkline(series)}| "
              f"{total:>5} tokens, {e_txt}")
    print(f"  queue depth   |{_sparkline([s['queue_depth'] for s in samples])}| "
          f"peak {max(s['queue_depth'] for s in samples)}")
    occ = [s["occupancy"] for s in samples]
    if any(occ):
        print(f"  pool occupancy|{_sparkline(occ)}| "
              f"peak {max(occ):.0%}")
    feed.close()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--energy", type=float, default=10.0, help="aJ per MAC")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--backend", default="auto", choices=["auto", "pallas", "jnp"],
                    help="matmul backend (pallas = fused kernel; interpret on CPU)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="dynamic-precision K: repeat each analog op K times "
                         "and average (fused in-kernel on pallas)")
    ap.add_argument("--traffic", action="store_true",
                    help="replay a mixed-precision load through the "
                         "bucket-batched serving engine")
    ap.add_argument("--continuous", action="store_true",
                    help="decode through persistent per-tier slot pools "
                         "(in-flight admission + early retirement) instead "
                         "of run-to-completion batches (--traffic mode)")
    ap.add_argument("--requests", type=int, default=24,
                    help="number of requests in --traffic mode")
    ap.add_argument("--slo", type=float, default=None,
                    help="per-request latency SLO in virtual seconds: attach "
                         "the SLA-aware precision governor, replay the middle "
                         "third as a 3x burst, and print policy events "
                         "(--traffic mode)")
    ap.add_argument("--profile", default=None,
                    help="comma-separated per-layer K schedule (e.g. 4,2,1,1)"
                         " served as its own precision tier in --traffic mode")
    ap.add_argument("--cluster", action="store_true",
                    help="replicated serving demo: 3 engine replicas behind "
                         "the ClusterRouter, replica 0 crashes mid-burst, "
                         "health-checked failover re-dispatches its requests "
                         "bit-identically to the survivors")
    ap.add_argument("--dashboard", action="store_true",
                    help="attach the streaming MetricsFeed and render a "
                         "compact per-tier dashboard (tokens/s, queue depth, "
                         "pool occupancy) after the replay; samples are also "
                         "streamed to a JSONL file (--traffic mode)")
    args = ap.parse_args()

    if args.cluster:
        run_cluster(args, _trained_params())
        return
    if args.traffic:
        run_traffic(args, _trained_params())
        return

    key = jax.random.PRNGKey(0)
    params = _trained_params()  # untrained logits are near-ties: noise flips argmax
    data = TokenTaskConfig(vocab_size=CFG.vocab_size, seq_len=args.prompt_len,
                           global_batch=args.batch, seed=11)
    prompts = jnp.asarray(markov_batch(data, 0)["tokens"])

    energies = init_energy_tree(CFG, args.energy)
    analog = AnalogSpec(
        cfg=AnalogConfig.shot(backend=args.backend), energies=energies, key=key,
        n_repeats=args.repeats,
    )
    cache_len = args.prompt_len + args.gen

    # --- analog and digital generations side by side ------------------------
    outs = {}
    for mode, aspec in (("digital", None), ("analog", analog)):
        cache, h_last = prefill(params, {"tokens": prompts}, CFG,
                                analog=aspec, cache_len=cache_len)
        from repro.models import lm
        logits = lm.logits_last(params, h_last, CFG)
        toks = []
        tok = jnp.argmax(logits[:, 0, 0], axis=-1)[:, None]
        step_fn = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, {"tokens": t}, pos, CFG, analog=aspec)
        )
        for i in range(args.gen):
            toks.append(tok)
            logits, cache = step_fn(params, cache, tok, args.prompt_len + i)
            tok = jnp.argmax(logits[:, 0, 0], axis=-1)[:, None]
        outs[mode] = jnp.concatenate(toks, axis=1)

    agree = float(jnp.mean(outs["digital"] == outs["analog"]))
    macs = energy_macs(CFG, 1)  # per generated token
    e_tot = float(total_energy(energies, macs)) * args.repeats
    print(f"generated {args.gen} tokens x {args.batch} sequences "
          f"[backend={args.backend}, K={args.repeats}]")
    print(f"digital vs analog token agreement: {agree:.1%} at {args.energy} aJ/MAC")
    print(f"optical energy per generated token: {e_tot/1e6:.3f} pJ "
          f"({e_tot / PHOTON_ENERGY_AJ:.2e} photons)")
    print("sample (digital):", outs["digital"][0, :12].tolist())
    print("sample (analog): ", outs["analog"][0, :12].tolist())


if __name__ == "__main__":
    main()
