"""Analog serving: batched prefill + autoregressive decode on a simulated
analog accelerator (the paper's deployment scenario, as a serving loop).

The model's every matmul runs through the analog execution path under shot
noise with per-site energies; the loop reports tokens/step agreement vs the
digital model and the optical energy per token (aJ) from the MAC accounting.

Run:  PYTHONPATH=src python examples/analog_serving.py [--energy 10.0]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import PHOTON_ENERGY_AJ, AnalogConfig, total_energy
from repro.models import (
    AnalogSpec,
    decode_step,
    energy_macs,
    init_energy_tree,
    init_params,
    prefill,
)
from repro.models.config import ModelConfig
from repro.data.pipeline import TokenTaskConfig, markov_batch

CFG = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=1024, vocab_size=4096, attn_q_chunk=128,
    attn_kv_chunk=128, loss_chunk=128, dtype="float32",
)


def _trained_params():
    """Briefly pre-train on the Markov task (cached under /tmp)."""
    import os
    import tempfile

    from repro.launch.mesh import make_local_mesh
    from repro.launch.steps import TrainConfig
    from repro.runtime.driver import DriverConfig, TrainDriver

    data = TokenTaskConfig(vocab_size=CFG.vocab_size, seq_len=128, global_batch=8, seed=7)
    ckpt = os.path.join(tempfile.gettempdir(), "repro_serve_demo")
    driver = TrainDriver(
        CFG, data, make_local_mesh(), ckpt_dir=ckpt,
        train_cfg=TrainConfig(lr=1e-3, opt_state_dtype="float32"),
        driver_cfg=DriverConfig(max_steps=80, ckpt_every=40, ckpt_async=False),
    )
    out = driver.run()
    if out["metrics"]:  # empty when a cached checkpoint already hit max_steps
        print(f"pre-trained to loss {out['metrics'][-1]['loss']:.3f}")
    else:
        print("restored pre-trained checkpoint")
    return out["state"]["params"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--energy", type=float, default=10.0, help="aJ per MAC")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--backend", default="auto", choices=["auto", "pallas", "jnp"],
                    help="matmul backend (pallas = fused kernel; interpret on CPU)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="dynamic-precision K: repeat each analog op K times "
                         "and average (fused in-kernel on pallas)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    params = _trained_params()  # untrained logits are near-ties: noise flips argmax
    data = TokenTaskConfig(vocab_size=CFG.vocab_size, seq_len=args.prompt_len,
                           global_batch=args.batch, seed=11)
    prompts = jnp.asarray(markov_batch(data, 0)["tokens"])

    energies = init_energy_tree(CFG, args.energy)
    analog = AnalogSpec(
        cfg=AnalogConfig.shot(backend=args.backend), energies=energies, key=key,
        n_repeats=args.repeats,
    )
    cache_len = args.prompt_len + args.gen

    # --- analog and digital generations side by side ------------------------
    outs = {}
    for mode, aspec in (("digital", None), ("analog", analog)):
        cache, h_last = prefill(params, {"tokens": prompts}, CFG,
                                analog=aspec, cache_len=cache_len)
        from repro.models import lm
        logits = lm.logits_last(params, h_last, CFG)
        toks = []
        tok = jnp.argmax(logits[:, 0, 0], axis=-1)[:, None]
        step_fn = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, {"tokens": t}, pos, CFG, analog=aspec)
        )
        for i in range(args.gen):
            toks.append(tok)
            logits, cache = step_fn(params, cache, tok, args.prompt_len + i)
            tok = jnp.argmax(logits[:, 0, 0], axis=-1)[:, None]
        outs[mode] = jnp.concatenate(toks, axis=1)

    agree = float(jnp.mean(outs["digital"] == outs["analog"]))
    macs = energy_macs(CFG, 1)  # per generated token
    e_tot = float(total_energy(energies, macs)) * args.repeats
    print(f"generated {args.gen} tokens x {args.batch} sequences "
          f"[backend={args.backend}, K={args.repeats}]")
    print(f"digital vs analog token agreement: {agree:.1%} at {args.energy} aJ/MAC")
    print(f"optical energy per generated token: {e_tot/1e6:.3f} microJ "
          f"({e_tot / PHOTON_ENERGY_AJ:.2e} photons)")
    print("sample (digital):", outs["digital"][0, :12].tolist())
    print("sample (analog): ", outs["analog"][0, :12].tolist())


if __name__ == "__main__":
    main()
