"""Eq.-14 calibration at LM scale: learn per-site energies of a frozen
transformer LM with the distributed calibrate step (the same jitted program
the dry-run lowers for the production mesh, here on the local mesh).

Shows the energy-NLL tradeoff and the learned per-layer-group allocations.

Run:  PYTHONPATH=src python examples/calibrate_lm.py [--target 2.0]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.core import AnalogConfig, avg_energy_per_mac, to_energy
from repro.core.energy import uniform_log_energies
from repro.data.pipeline import TokenTaskConfig, markov_batch
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_calibrate_step
from repro.models import energy_macs, init_params
from repro.models.config import ModelConfig
from repro.models.sharding import use_mesh
from repro.optim.adam import AdamConfig, adam_init

CFG = ModelConfig(
    name="calib-demo", family="dense", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=1024, vocab_size=4096, attn_q_chunk=128,
    attn_kv_chunk=128, loss_chunk=128, dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--target", type=float, default=2.0, help="aJ/MAC budget")
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    mesh = make_local_mesh()
    seq = 128
    data = TokenTaskConfig(vocab_size=CFG.vocab_size, seq_len=seq, global_batch=8, seed=7)

    with use_mesh(mesh):
        params = init_params(key, CFG)
        _, jit_for, aux = make_calibrate_step(
            CFG, mesh, analog_cfg=AnalogConfig.shot(), seq_len=seq,
            target_e_per_mac=args.target, lam=20.0, lr=0.05,
        )
        macs = aux["macs"]
        log_e = uniform_log_energies(macs, 4.0 * args.target)
        opt = adam_init(log_e, AdamConfig(lr=0.05))

        batch0 = markov_batch(data, 0)
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch0.items()}
        step = jit_for(specs)

        for i in range(args.steps):
            batch = markov_batch(data, i)
            log_e, opt, m = step(log_e, opt, params, batch, jax.random.fold_in(key, i))
            if i % 10 == 0 or i == args.steps - 1:
                e = to_energy(log_e)
                print(f"step {i:>3}: nll {float(m['nll']):.4f}  "
                      f"avg E/MAC {float(avg_energy_per_mac(e, macs)):.3f} aJ")

    e = to_energy(log_e)
    print("\nlearned per-group allocations (aJ/MAC), group 0:")
    for site, v in sorted(e["groups"].items()):
        print(f"  {site:<12} {[round(float(x), 2) for x in jnp.atleast_1d(v)[:4]]}")
    print(f"  lm_head      {float(e['lm_head']):.2f}")


if __name__ == "__main__":
    main()
