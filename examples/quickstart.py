"""Quickstart: the paper's mechanism in one file.

1. Analog matmuls under shot / thermal / weight noise (Eqs. 9-11),
2. the redundant-coding law (noise std ~ 1/sqrt(E)),
3. learning per-layer energies with the Eq.-14 penalty on a tiny frozen MLP,
4. dynamic vs uniform accuracy at the same energy budget.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AnalogConfig,
    CalibConfig,
    analog_dot,
    avg_energy_per_mac,
    dense_site_macs,
    eval_accuracy,
    learn_energies,
    site_key,
    to_energy,
    uniform_log_energies,
)
from repro.data import make_tabular_dataset

key = jax.random.PRNGKey(0)

# --- 1. analog matmuls -------------------------------------------------------
x = jax.random.normal(key, (4, 64))
w = jax.random.normal(jax.random.fold_in(key, 1), (64, 32)) * 0.2
clean = x @ w
for name, cfg in [
    ("shot    (2 aJ/MAC)", AnalogConfig.shot()),
    ("thermal (sigma=.01)", AnalogConfig.thermal(0.01)),
    ("weight  (sigma=.1) ", AnalogConfig.weight(0.1)),
]:
    y = analog_dot(x, w, cfg=cfg, energy=jnp.asarray(2.0), key=key)
    print(f"{name}: mean|err| = {float(jnp.abs(y - clean).mean()):.4f}")

# --- 2. redundant coding: noise ~ 1/sqrt(E) ---------------------------------
cfg = AnalogConfig.shot()
for e in (1.0, 4.0, 16.0):
    ys = jax.vmap(lambda k: analog_dot(x, w, cfg=cfg, energy=jnp.asarray(e), key=k))(
        jax.random.split(key, 64)
    )
    print(f"E = {e:5.1f} aJ/MAC -> noise std {float(jnp.std(ys - clean[None])):.4f}")

# --- 3. learn per-layer energies on a frozen model (Eq. 14) -----------------
print("\ntraining a small MLP on a synthetic task ...")
dims = [32, 64, 64, 8]
xd, yd = make_tabular_dataset(4096, dim=32, n_classes=8, depth=2, seed=3)
xd, yd = jnp.asarray(xd), jnp.asarray(yd)
sizes = list(zip(dims[:-1], dims[1:]))
params = [
    jax.random.normal(k, s) / np.sqrt(s[0])
    for k, s in zip(jax.random.split(key, 3), sizes)
]


def loss_fn(p, xb, yb):
    h = xb
    for i, wi in enumerate(p):
        h = h @ wi
        if i < len(p) - 1:
            h = jax.nn.relu(h)
    logp = jax.nn.log_softmax(h)
    return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))


opt = jax.jit(lambda p, xb, yb: jax.tree.map(lambda w_, g: w_ - 0.5 * g, p, jax.grad(loss_fn)(p, xb, yb)))
for _ in range(1200):
    params = opt(params, xd[:3072], yd[:3072])


def apply_fn(energies, xb, k):
    h = xb
    for i, wi in enumerate(params):
        h = analog_dot(h, wi, cfg=cfg, energy=energies[f"l{i}"],
                       key=site_key(jax.random.fold_in(k, i), f"l{i}"))
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


macs = {f"l{i}": dense_site_macs(1, a, b, per_channel=False) for i, (a, b) in enumerate(sizes)}
test = [(xd[3072:], yd[3072:])]
batches = [(xd[i : i + 256], yd[i : i + 256]) for i in range(0, 3072, 256)]

target = 0.1  # aJ/MAC
uniform = to_energy(uniform_log_energies(macs, target))
acc_uni = eval_accuracy(apply_fn, uniform, test, key=key, n_noise_samples=16)

energies, diag = learn_energies(
    apply_fn, macs, batches, key=key, target_e_per_mac=target,
    cfg=CalibConfig(lam=20.0, lr=0.05, steps=200, init_mult=4.0),
)
acc_dyn = eval_accuracy(apply_fn, energies, test, key=key, n_noise_samples=16)

print(f"\nbudget {target} aJ/MAC:")
print(f"  uniform  precision: acc = {acc_uni:.3f}")
print(f"  dynamic  precision: acc = {acc_dyn:.3f} "
      f"(achieved {diag['avg_e_per_mac']:.3f} aJ/MAC)")
print("  learned allocations (aJ/MAC):",
      {k: round(float(v), 3) for k, v in energies.items()})
print("\n-> the middle layer tolerates more noise; the first/last layers get "
      "the energy (paper Fig. 6).")
