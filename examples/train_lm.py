"""End-to-end training driver example.

Trains a transformer LM on the deterministic synthetic Markov task through
the full production stack: sharded train step (TP+SP rules on a local mesh),
fault-tolerant driver, atomic checkpoints, straggler monitoring.

Default is a ~10M-param model for a quick CPU demo; ``--model 100m`` selects
a ~100M-param config (same code path, the few-hundred-step run the
deliverable describes — budget ~1-2h on this CPU container; on a real TPU
slice it is minutes).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--model 10m]
"""
import argparse
import os
import tempfile

from repro.data.pipeline import TokenTaskConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import TrainConfig
from repro.models.config import ModelConfig
from repro.runtime.driver import DriverConfig, TrainDriver

MODELS = {
    "10m": ModelConfig(
        name="demo-10m", family="dense", n_layers=4, d_model=256, n_heads=8,
        n_kv_heads=4, d_ff=1024, vocab_size=4096, attn_q_chunk=128,
        attn_kv_chunk=128, loss_chunk=128,
    ),
    "100m": ModelConfig(
        name="demo-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_ff=3072, vocab_size=32768, attn_q_chunk=256,
        attn_kv_chunk=256, loss_chunk=256,
    ),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="10m", choices=sorted(MODELS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = MODELS[args.model]
    print(f"model {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    data = TokenTaskConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        global_batch=args.batch, seed=7,
    )
    ckpt_dir = args.ckpt_dir or os.path.join(tempfile.gettempdir(), f"repro_{cfg.name}")
    driver = TrainDriver(
        cfg, data, make_local_mesh(),
        ckpt_dir=ckpt_dir,
        train_cfg=TrainConfig(lr=3e-4, opt_state_dtype="float32"),
        driver_cfg=DriverConfig(
            max_steps=args.steps, ckpt_every=50, ckpt_async=True, log_every=10
        ),
    )
    out = driver.run()
    print("step  loss    step_time")
    for m in out["metrics"]:
        print(f"{m['step']:>5} {m['loss']:.4f}  {m['dt']*1e3:.0f} ms")
    print(f"checkpoints in {ckpt_dir}; straggler flags: {len(driver.monitor.flags)}")


if __name__ == "__main__":
    main()
