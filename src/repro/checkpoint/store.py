"""Sharded, atomic, async checkpointing (msgpack + zstd, zlib fallback).

Layout: <dir>/step_<N>/shard_<i>.ckpt + MANIFEST (written last). A
checkpoint is valid iff its MANIFEST exists and checksums match — writers
stage into a temp dir and rename, so readers never observe partial state.
``CheckpointManager`` adds async save (background thread), retention, and
restore-latest-valid (skipping corrupt/incomplete checkpoints, as after a
mid-save node failure).

``reshard`` re-commits a restored (host) tree onto any mesh/sharding — the
elastic-scaling path: train on 512 chips, restore onto 256, or re-balance
after shrinking the data axis.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import zlib

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib
    zstandard = None

PyTree = Any
_MANIFEST = "MANIFEST.json"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
_CODEC = "zstd" if zstandard is not None else "zlib"


def _compress(data: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(data)
    return zlib.compress(data, 6)


def _decompress(blob: bytes) -> bytes:
    """Codec is detected from the frame magic, so a checkpoint written with
    either codec restores on any host that has the matching decoder."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but zstandard is not installed"
            )
        return zstandard.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return "/".join(out)


def _tree_to_records(tree: PyTree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    rec = {}
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        # bfloat16 has no portable msgpack dtype: ship as uint16 view
        dt = str(arr.dtype)
        if dt == "bfloat16":
            payload = arr.view(np.uint16).tobytes()
        else:
            payload = arr.tobytes()
        rec[_path_str(path)] = {
            "dtype": dt,
            "shape": list(arr.shape),
            "data": payload,
        }
    return rec


def _records_to_leaves(rec: dict) -> dict:
    out = {}
    for k, v in rec.items():
        dt = v["dtype"]
        if dt == "bfloat16":
            arr = np.frombuffer(v["data"], np.uint16).reshape(v["shape"]).view(jnp.bfloat16)
        else:
            arr = np.frombuffer(v["data"], np.dtype(dt)).reshape(v["shape"])
        out[k] = arr
    return out


def save_checkpoint(directory: str, step: int, tree: PyTree, *, shard_id: int = 0) -> str:
    """Atomic save: stage -> fsync -> rename; MANIFEST written last."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    stage = tempfile.mkdtemp(prefix=".stage_", dir=directory)
    try:
        rec = _tree_to_records(tree)
        blob = _compress(msgpack.packb(rec, use_bin_type=True))
        shard_name = f"shard_{shard_id:05d}.ckpt"
        with open(os.path.join(stage, shard_name), "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "shards": {shard_name: hashlib.sha256(blob).hexdigest()},
            "format": f"msgpack+{_CODEC}/v1",
        }
        with open(os.path.join(stage, _MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(stage, final)
        return final
    except BaseException:
        shutil.rmtree(stage, ignore_errors=True)
        raise


def _valid(ckpt_dir: str) -> bool:
    mpath = os.path.join(ckpt_dir, _MANIFEST)
    if not os.path.exists(mpath):
        return False
    try:
        manifest = json.load(open(mpath))
        for shard, digest in manifest["shards"].items():
            blob = open(os.path.join(ckpt_dir, shard), "rb").read()
            if hashlib.sha256(blob).hexdigest() != digest:
                return False
        return True
    except Exception:
        return False


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and _valid(os.path.join(directory, name)):
            steps.append(int(name[5:]))
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str, step: Optional[int] = None, template: Optional[PyTree] = None
) -> Tuple[int, PyTree]:
    """Returns (step, tree). With a ``template``, the flat record dict is
    re-folded into the template's structure (leaves host numpy arrays)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {directory}")
    ckpt_dir = os.path.join(directory, f"step_{step:09d}")
    manifest = json.load(open(os.path.join(ckpt_dir, _MANIFEST)))
    rec: dict = {}
    for shard in manifest["shards"]:
        blob = open(os.path.join(ckpt_dir, shard), "rb").read()
        rec.update(msgpack.unpackb(_decompress(blob), raw=False))
    leaves = _records_to_leaves(rec)
    if template is None:
        return step, leaves
    flat = jax.tree_util.tree_flatten_with_path(template)
    out = [leaves[_path_str(p)] for p, _ in flat[0]]
    return step, jax.tree_util.tree_unflatten(flat[1], out)


def reshard(tree: PyTree, shardings: PyTree) -> PyTree:
    """Commit a (host or device) tree onto target shardings — the elastic
    re-scale path. Works across mesh shapes/sizes."""
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)


class CheckpointManager:
    """Async save + retention + restore-latest-valid."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._lock = threading.Lock()
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: PyTree, *, blocking: bool = True) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            with self._lock:
                save_checkpoint(self.directory, step, host_tree)
                self._gc()

        if blocking:
            work()
        else:
            self.wait()
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, template: PyTree) -> Optional[Tuple[int, PyTree]]:
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None
        return restore_checkpoint(self.directory, step, template)

    def _gc(self) -> None:
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(n[5:])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and _valid(os.path.join(self.directory, n))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)
