"""Architecture registry: the 10 assigned archs + the paper's own models.

``get_config(name)`` / ``get_smoke_config(name)`` / ``ARCHS``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.shapes import SHAPES, ShapeSpec, input_specs, shape_applicable
from repro.models.config import ModelConfig

#: assigned pool (exact ids from the assignment) -> module name
ARCHS: Dict[str, str] = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "grok-1-314b": "grok_1_314b",
    "granite-3-8b": "granite_3_8b",
    "qwen2.5-32b": "qwen2_5_32b",
    "granite-20b": "granite_20b",
    "qwen2.5-14b": "qwen2_5_14b",
    "musicgen-large": "musicgen_large",
    "internvl2-2b": "internvl2_2b",
    "xlstm-1.3b": "xlstm_1_3b",
    "recurrentgemma-2b": "recurrentgemma_2b",
}

#: paper-validation extras (not in the dry-run pool)
EXTRA_ARCHS: Dict[str, str] = {
    "bert-base": "bert_base",
}


def _module(name: str):
    mod = ARCHS.get(name) or EXTRA_ARCHS.get(name)
    if mod is None:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS) + sorted(EXTRA_ARCHS)}")
    return importlib.import_module(f"repro.configs.{mod}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).smoke_config()


def list_archs() -> List[str]:
    return list(ARCHS)


__all__ = [
    "ARCHS",
    "EXTRA_ARCHS",
    "SHAPES",
    "ShapeSpec",
    "get_config",
    "get_smoke_config",
    "input_specs",
    "list_archs",
    "shape_applicable",
]
