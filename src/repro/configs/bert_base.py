"""bert-base (the paper's own NLP model, §VI Table IV): 12L d_model=768
12H d_ff=3072 vocab=30522, GELU. Used by the paper-validation benchmarks
(shot-noise analog inference + Eq.-14 calibration); not part of the assigned
dry-run pool (encoder-only: no decode shapes)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="bert-base",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=30522,
    mlp_type="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="bert-smoke", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256, mlp_type="gelu",
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
