"""granite-20b [dense]: 52L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — code model. [arXiv:2405.04324; hf]

d_ff = 4*d and the MQA layout match the GPTBigCode-style granite-20b-code:
GELU MLP (a SwiGLU reading of d_ff would give ~28B params, not 20B).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite20-smoke", family="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=256, vocab_size=256,
        mlp_type="gelu", attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
