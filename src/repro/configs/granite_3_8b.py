"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    mlp_type="swiglu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite3-smoke", family="dense", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mlp_type="swiglu", attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
