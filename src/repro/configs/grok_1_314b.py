"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2. [hf:xai-org/grok-1; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131072,
    mlp_type="swiglu",  # grok-1 experts are gated 3-matrix MLPs (~309B of the 314B)
    n_experts=8,
    top_k=2,
    moe_every=1,
    capacity_factor=1.25,
    moe_ff_split=2,  # 16 virtual experts shard the 16-wide data axis
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok1-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, mlp_type="swiglu",
        n_experts=4, top_k=2, moe_every=1, capacity_factor=2.0,
        moe_group_size=64, attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
