"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2. [arXiv:2404.16821; hf]

The InternViT frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (256 tokens) prepended to the text stream; the
model owns the InternLM2-style decoder backbone.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    mlp_type="swiglu",
    frontend="patch",
    n_frontend_tokens=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mlp_type="swiglu", frontend="patch", n_frontend_tokens=8,
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
