"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Interpretation (DESIGN.md §Arch-applicability): 400B total / 17B active with
the given dims requires interleaved MoE (every 2nd layer) + 1 shared expert,
matching the public Llama-4 description; all-layers MoE would be ~790B.
Resulting totals: ~397B params, ~17B active.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    mlp_type="swiglu",
    n_experts=128,
    top_k=1,
    moe_every=2,
    n_shared_experts=1,
    capacity_factor=1.25,
    rope_theta=500000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke", family="moe", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, mlp_type="swiglu",
        n_experts=4, top_k=1, moe_every=2, n_shared_experts=1,
        capacity_factor=2.0, moe_group_size=64,
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
