"""musicgen-large [audio]: 48L d_model=2048 32H (MHA kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens (4 codebooks, delay pattern).
[arXiv:2306.05284; hf]

Frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings (the EnCodec + codebook-embedding sum); the model owns the
transformer backbone + 4 parallel codebook heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="dense",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="gelu",
    frontend="frames",
    n_codebooks=4,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=64,
        mlp_type="gelu", frontend="frames", n_codebooks=4,
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
