"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1000000.0,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen14-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        mlp_type="swiglu", qkv_bias=True,
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
