"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000 — RG-LRU + local attention, 1 attn : 2 recurrent.
[arXiv:2402.19427; hf]

26 layers = 8 x (rec, rec, attn) + 2 trailing recurrent layers. Local
attention window 2048. Sub-quadratic: runs the long_500k shape (RG-LRU state
+ bounded attention window).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="griffin",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_type="swiglu",
    tie_embeddings=True,
    rnn_width=2560,
    conv_width=4,
    local_window=2048,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rgemma-smoke", family="griffin", n_layers=8, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
        mlp_type="swiglu", rnn_width=64, conv_width=4, local_window=32,
        attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
