"""Assigned input-shape set (LM transformer shapes) and input_specs().

  train_4k     seq_len=4096    global_batch=256   (training      -> train_step)
  prefill_32k  seq_len=32768   global_batch=32    (inference     -> prefill_step)
  decode_32k   seq_len=32768   global_batch=128   (decode        -> decode_step,
                                                   one token, KV cache of 32768)
  long_500k    seq_len=524288  global_batch=1     (long-context decode; only
                                                   sub-quadratic archs)

``input_specs`` returns weak-type-correct ShapeDtypeStructs — no allocation —
matching the batch dicts the step functions consume. Modality frontends are
stubs per the assignment: "frames" provides precomputed frame embeddings,
"patch" provides precomputed patch embeddings.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (SSM/hybrid); pure
    full-attention archs skip it (recorded, per the assignment spec)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k dense KV decode is out of the sub-quadratic regime"
    return True, ""


def reduced_depth(
    cfg: ModelConfig, *, n_layers: int, width_divisor: int = 1, **overrides
) -> ModelConfig:
    """Depth- (and optionally width-) reduced variant of a paper config.

    Keeps the architecture's identity — family, MQA/GQA layout, head_dim,
    MLP type, d_ff/d_model ratio — while shrinking it to host-device scale:
    ``n_layers`` replaces the depth outright, and ``width_divisor`` divides
    d_model / d_ff / n_heads / vocab_size (head_dim is preserved, so the
    attention geometry survives the shrink). This is how the serving bench
    demonstrates ``granite_20b`` tensor-parallel on a forced-host-device CPU
    mesh without allocating 20B replicated parameters. Extra ``overrides``
    pass through to ``dataclasses.replace`` (e.g. chunk sizes for short
    sequences).
    """
    if n_layers < 1:
        raise ValueError(f"n_layers must be >= 1, got {n_layers}")
    if width_divisor < 1:
        raise ValueError(f"width_divisor must be >= 1, got {width_divisor}")
    wd = int(width_divisor)
    changes = dict(
        name=f"{cfg.name}-L{n_layers}" + (f"-w{wd}" if wd > 1 else ""),
        n_layers=int(n_layers),
        d_model=max(1, cfg.d_model // wd),
        d_ff=max(1, cfg.d_ff // wd),
        n_heads=max(1, cfg.n_heads // wd),
        n_kv_heads=max(1, min(cfg.n_kv_heads, cfg.n_heads // wd)),
        vocab_size=max(2, cfg.vocab_size // wd),
        head_dim=cfg.head_dim,
    )
    changes.update(overrides)
    return dataclasses.replace(cfg, **changes)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Model-input stand-ins for one (arch x shape) cell."""
    b, t = shape.global_batch, shape.seq_len
    i32, cdt = jnp.int32, cfg.compute_dtype
    d = cfg.d_model

    if shape.kind == "decode":
        if cfg.frontend == "frames":
            batch = {"embeds": _sds((b, 1, d), cdt)}
        else:
            batch = {"tokens": _sds((b, 1), i32)}
        return batch

    if cfg.frontend == "frames":
        batch = {"embeds": _sds((b, t, d), cdt)}
        labels = _sds((b, t, cfg.n_codebooks), i32)
    elif cfg.frontend == "patch":
        p = cfg.n_frontend_tokens
        batch = {
            "patch_embeds": _sds((b, p, d), cdt),
            "tokens": _sds((b, t - p), i32),
        }
        labels = _sds((b, t), i32)
    else:
        batch = {"tokens": _sds((b, t), i32)}
        labels = _sds((b, t), i32)

    if shape.kind == "train":
        batch["labels"] = labels
    return batch
