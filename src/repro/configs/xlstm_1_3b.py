"""xlstm-1.3b [ssm]: 48L d_model=2048 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks at 7:1 (one sLSTM per 8 blocks; xLSTM[7:1]). [arXiv:2405.04517;
unverified]

d_ff=0: blocks carry their own expansion (no separate MLP). Sub-quadratic:
runs the long_500k shape (constant-size matrix/scalar memory states).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    slstm_ratio=8,
    sharding_profile="dp",  # 1.3B: TP16 is collective-bound and OOMs on recurrence residuals
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="xlstm", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=0, vocab_size=256,
        slstm_ratio=2, attn_q_chunk=32, attn_kv_chunk=32, loss_chunk=32,
    )
