"""Core: the paper's contribution as composable JAX modules.

  noise      - analog noise models (Eqs. 3-5, 9-11)
  precision  - noise-bits analysis (Eqs. 6-8, Tables I/III)
  analog     - the analog_dot execution primitive + AnalogConfig
  energy     - energy accounting + Eq.-14 log-penalty
  redundant  - K-repeat redundant coding (Fig. 3): fused hot path + oracles
  calibrate  - Eq.-14 energy learning (frozen weights)
  search     - min-energy binary search (<2% degradation) + the greedy
               per-layer repeat-profile search
  profile    - frozen per-layer K-repeat schedules (learn -> freeze -> serve)
"""
from repro.core.analog import (
    PER_CHANNEL,
    PER_LAYER,
    AnalogConfig,
    SiteQuant,
    analog_conv2d,
    analog_dot,
    fold_key,
    key_batch,
    raw_key,
    site_key,
)
from repro.core.calibrate import (
    CalibConfig,
    eval_accuracy,
    eval_profile_accuracy,
    learn_energies,
    softmax_xent,
)
from repro.core.energy import (
    DIGITAL_BF16_AJ_PER_MAC,
    DIGITAL_INT8_AJ_PER_MAC,
    apply_repeats,
    avg_energy_per_mac,
    dense_site_macs,
    log_energy_penalty,
    repeat_total_energy,
    to_energy,
    total_energy,
    total_macs,
    uniform_log_energies,
)
from repro.core.noise import PHOTON_ENERGY_AJ, SHOT, THERMAL, WEIGHT, NoiseSpec
from repro.core.precision import noise_bits, noise_var_from_bits, thermal_noise_bits
from repro.core.profile import DEFAULT_K_LEVELS, PrecisionProfile, coalesce_runs
from repro.core.search import (
    ProfileSearchResult,
    SearchResult,
    min_energy_search,
    online_repeat_profile_search,
    repeat_profile_search,
)

__all__ = [
    "AnalogConfig",
    "CalibConfig",
    "NoiseSpec",
    "PER_CHANNEL",
    "PER_LAYER",
    "PHOTON_ENERGY_AJ",
    "SHOT",
    "THERMAL",
    "WEIGHT",
    "DEFAULT_K_LEVELS",
    "DIGITAL_BF16_AJ_PER_MAC",
    "DIGITAL_INT8_AJ_PER_MAC",
    "PrecisionProfile",
    "ProfileSearchResult",
    "SearchResult",
    "SiteQuant",
    "analog_conv2d",
    "apply_repeats",
    "coalesce_runs",
    "analog_dot",
    "fold_key",
    "key_batch",
    "raw_key",
    "avg_energy_per_mac",
    "dense_site_macs",
    "eval_accuracy",
    "eval_profile_accuracy",
    "learn_energies",
    "log_energy_penalty",
    "min_energy_search",
    "online_repeat_profile_search",
    "repeat_profile_search",
    "repeat_total_energy",
    "noise_bits",
    "noise_var_from_bits",
    "site_key",
    "softmax_xent",
    "thermal_noise_bits",
    "to_energy",
    "total_energy",
    "total_macs",
    "uniform_log_energies",
]
