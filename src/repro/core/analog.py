"""The analog matmul execution primitive (paper §II-C, §IV).

``analog_dot`` is the single choke-point through which every matmul in every
model runs. In ``digital`` mode it performs (optionally fake-quantized)
ordinary matmuls; in ``analog`` mode it simulates the noisy accelerator:

    quantize inputs/weights  ->  MAC array (x @ w)  ->  physical noise
    scaled by 1/sqrt(E)      ->  requantize output to 8 bits

Per the paper's Appendix A:
  * thermal/weight noise: digital 8-bit I/O (per-channel weights, per-tensor
    activations, percentile clipping for thermal), output requantized to 8b.
  * shot noise: continuous-valued inputs and weights (neuromorphic regime).

Energies may be scalar (per-layer) or per-output-channel vectors (§V).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import noise as noise_lib
from repro.core.noise import NoiseSpec
from repro.kernels.dispatch import TP_AXIS, fused_dot, resolve_backend, tile_dot
from repro.quant.affine import QuantParams, fake_quant

Array = jax.Array

PER_LAYER = "per_layer"
PER_CHANNEL = "per_channel"


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AnalogConfig:
    """Static configuration of the simulated analog accelerator."""

    mode: str = dataclasses.field(metadata=dict(static=True), default="digital")
    noise: NoiseSpec = NoiseSpec()
    granularity: str = dataclasses.field(metadata=dict(static=True), default=PER_LAYER)
    weight_bits: Optional[float] = dataclasses.field(metadata=dict(static=True), default=8.0)
    act_bits: Optional[float] = dataclasses.field(metadata=dict(static=True), default=8.0)
    out_bits: Optional[float] = dataclasses.field(metadata=dict(static=True), default=8.0)
    #: snap energies to integer multiples of a quantum (photons / K repeats).
    discrete_energy: bool = dataclasses.field(metadata=dict(static=True), default=False)
    energy_quantum: float = dataclasses.field(
        metadata=dict(static=True), default=noise_lib.PHOTON_ENERGY_AJ
    )
    #: execution backend: "auto" picks the fused Pallas kernel when shape /
    #: platform permit (see kernels/dispatch.py), "pallas"/"jnp"/"tile"
    #: force a path ("tile" = the pure-jnp oracle with Pallas-identical
    #: counter-based noise — the stream tensor-parallel shards slice).
    backend: str = dataclasses.field(metadata=dict(static=True), default="auto")
    #: legacy alias for backend="pallas" (kept for existing configs/tests).
    use_kernel: bool = dataclasses.field(metadata=dict(static=True), default=False)

    def __post_init__(self):
        if self.mode not in ("digital", "analog"):
            raise ValueError(f"bad mode {self.mode!r}")
        if self.granularity not in (PER_LAYER, PER_CHANNEL):
            raise ValueError(f"bad granularity {self.granularity!r}")
        if self.backend not in ("auto", "pallas", "jnp", "tile"):
            raise ValueError(f"bad backend {self.backend!r}")

    @classmethod
    def shot(cls, **kw) -> "AnalogConfig":
        """Shot-noise configuration: continuous I/O (paper §VI-A)."""
        kw.setdefault("noise", NoiseSpec(kind=noise_lib.SHOT))
        return cls(
            mode="analog", weight_bits=None, act_bits=None, out_bits=None, **kw
        )

    @classmethod
    def thermal(cls, sigma_t: float = 0.01, **kw) -> "AnalogConfig":
        kw.setdefault("noise", NoiseSpec(kind=noise_lib.THERMAL, sigma=sigma_t))
        return cls(mode="analog", **kw)

    @classmethod
    def weight(cls, sigma_w: float = 0.1, **kw) -> "AnalogConfig":
        kw.setdefault("noise", NoiseSpec(kind=noise_lib.WEIGHT, sigma=sigma_w))
        return cls(mode="analog", **kw)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SiteQuant:
    """Calibrated quantizers for one matmul site.

    ``wqp``: per-channel weight quantizer (ranges shaped (1, M)).
    ``xqp``: per-tensor activation quantizer (scalar ranges).
    ``oqp``: per-tensor output quantizer (layer l+1 range, scalar).
    """

    wqp: Optional[QuantParams] = None
    xqp: Optional[QuantParams] = None
    oqp: Optional[QuantParams] = None


def key_batch(key: Optional[jax.Array]) -> Optional[int]:
    """Leading batch size of a *stacked* key array, or None for a single key.

    A stacked key carries one independent PRNG stream per request row (the
    serving engine's per-request noise isolation): raw uint32 keys stack to
    (B, 2), typed keys to (B,). Every fold/draw maps over the leading axis.
    """
    if key is None:
        return None
    try:
        typed = jnp.issubdtype(key.dtype, jax.dtypes.prng_key)
    except AttributeError:  # very old jax: only raw uint32 keys exist
        typed = False
    base_ndim = 0 if typed else 1
    if key.ndim == base_ndim:
        return None
    if key.ndim == base_ndim + 1:
        return key.shape[0]
    raise ValueError(f"bad key shape {key.shape}")


def fold_key(key: jax.Array, data) -> jax.Array:
    """``jax.random.fold_in`` that maps over stacked per-request keys."""
    if key_batch(key) is None:
        return jax.random.fold_in(key, data)
    return jax.vmap(lambda k: jax.random.fold_in(k, data))(key)


def raw_key(key: jax.Array) -> jax.Array:
    """Normalize a (possibly typed) PRNG key to raw uint32 data — the
    stackable, ShapeDtypeStruct-able form the serving engine traffics in."""
    try:
        if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
            return jax.random.key_data(key)
    except AttributeError:  # very old jax: only raw uint32 keys exist
        pass
    return key


def collapse_keys(key: jax.Array, valid: Optional[jax.Array] = None) -> jax.Array:
    """XOR-fold a stacked (B, ...) key array into ONE batch-level raw key.

    Expert-batched MoE matmuls mix tokens from every request in shared
    capacity buffers, so per-request noise streams are physically meaningless
    there; those sites instead draw a single stream from this batch-level
    key. Deterministic and order-invariant in the batch, but (necessarily)
    dependent on the set of *real* keys sharing the batch. Single keys pass
    through unchanged.

    ``valid`` (B,) bool: rows marked False — batch-padding rows in a bucket
    batch — fold the XOR identity (0) instead of their key, so the collapsed
    key depends only on the real requests. Without this, identical real
    traffic served at different batch-pad counts would XOR in a different
    number of pad keys and draw different expert noise.
    """
    if key_batch(key) is None:
        return key
    raw = raw_key(key)
    if valid is not None:
        mask = jnp.reshape(valid, (raw.shape[0],) + (1,) * (raw.ndim - 1))
        raw = jnp.where(mask, raw, jnp.zeros_like(raw))
    return jax.lax.reduce(raw, raw.dtype.type(0), jax.lax.bitwise_xor, (0,))


def site_key(key: jax.Array, site: str) -> jax.Array:
    """Deterministic per-site RNG stream derived from a stable name hash.

    Stacked per-request keys fold elementwise: every request keeps its own
    stream for the site."""
    h = int.from_bytes(hashlib.blake2s(site.encode(), digest_size=4).digest(), "little")
    return fold_key(key, h)


def _w_range(sq: SiteQuant, w: Array) -> Array:
    """Per-output-channel weight range (1, M) or from data if uncalibrated."""
    if sq is not None and sq.wqp is not None:
        return (sq.wqp.x_max - sq.wqp.x_min).astype(jnp.float32)
    lo = jnp.min(w, axis=0, keepdims=True)
    hi = jnp.max(w, axis=0, keepdims=True)
    return (hi - lo).astype(jnp.float32)


def _x_range(sq: SiteQuant, x: Array) -> Array:
    if sq is not None and sq.xqp is not None:
        return (sq.xqp.x_max - sq.xqp.x_min).astype(jnp.float32)
    return (jnp.max(x) - jnp.min(x)).astype(jnp.float32)


def _maybe_sharded_analog_dot(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    energy: Array,
    key: jax.Array,
    sq: Optional[SiteQuant],
    n_repeats: int,
) -> Optional[Array]:
    """Column-parallel analog matmul through shard_map, or None to fall back.

    Each tensor-parallel shard holds columns ``[r * n_local, (r+1) * n_local)``
    of the weight and draws its noise with the matching global column offset,
    so (Threefry being counter-based) it computes exactly its tile of the
    unsharded "tile"/Pallas stream — the gathered output is bit-identical to
    the single-device oracle at every K and per-layer profile. Only the
    output N dim is sharded (the contracting dim stays whole: no psum, no
    cross-device rounding) and the gather back to replicated is pure data
    movement, so bit-identity is exact, not approximate.

    Falls back (returns None) when there is no active tensor-parallel mesh,
    when the resolved backend is not tiling-invariant ("jnp"), or when the
    operands don't fit the column-parallel contract (calibrated quantizers,
    per-channel energies, N not divisible by the shard count).
    """
    from repro.kernels.dispatch import active_mesh

    mesh = active_mesh()
    if mesh is None:
        return None
    tp = int(dict(mesh.shape).get(TP_AXIS, 1))
    if tp <= 1:
        return None
    if sq is not None or w.ndim != 2 or w.shape[1] % tp != 0:
        return None
    if jnp.ndim(energy) != 0:
        return None  # per-channel energy columns would need co-sharding
    n_local = w.shape[1] // tp
    backend = resolve_backend(cfg, x.shape, (w.shape[0], n_local))
    if backend not in ("tile", "pallas"):
        return None

    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.kernels import ops as kernel_ops

    kb = key_batch(key)
    if kb is not None and (x.ndim < 2 or x.shape[0] != kb):
        raise ValueError(
            f"stacked key batch {kb} does not match x leading dim {x.shape}"
        )
    kraw = raw_key(key)
    e_arr = jnp.asarray(energy, jnp.float32)
    mm = kernel_ops.analog_matmul if backend == "pallas" else (
        kernel_ops.analog_matmul_reference
    )

    def shard(xs, ws, ks, es):
        col0 = jax.lax.axis_index(TP_AXIS) * n_local

        def one(xr, kr):
            return mm(
                xr, ws, energy=es, key=kr, cfg=cfg, sq=None,
                n_repeats=n_repeats, offsets=(0, col0),
            )

        if kb is None:
            return one(xs, ks)
        return jax.vmap(one)(xs, ks)

    out_spec = P(*([None] * (x.ndim - 1)), TP_AXIS)
    y = shard_map(
        shard,
        mesh=mesh,
        in_specs=(P(), P(None, TP_AXIS), P(), P()),
        out_specs=out_spec,
        check_rep=False,
    )(x, w, kraw, e_arr)
    # Gather the column shards back to replicated: everything outside
    # analog_dot (residual adds, caches, AOT argument shardings) stays
    # replicated, which is what lets executables survive mesh resize.
    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, P()))


def analog_dot(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    energy: Optional[Array] = None,
    key: Optional[jax.Array] = None,
    sq: Optional[SiteQuant] = None,
    precision=None,
    n_repeats: int = 1,
) -> Array:
    """Noisy (or digital) matmul ``(..., K) @ (K, M) -> (..., M)``.

    ``energy``: scalar (per-layer) or (M,) per-channel energy/MAC; required in
    analog mode. ``key``: PRNG key for the noise draw; required in analog mode.
    ``n_repeats``: static K-repeat redundancy (paper §IV): run the op K times
    at ``energy`` each and average. On the Pallas backend the repeats are
    averaged in-register inside the fused kernel (one matmul pass, one x/w
    HBM read); on the jnp path the statistically identical single draw at
    ``K * energy`` is used. Total energy spent is ``K * energy`` either way.
    """
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"contract mismatch {x.shape} @ {w.shape}")
    if n_repeats < 1:
        raise ValueError(f"n_repeats must be >= 1, got {n_repeats}")
    if cfg.mode == "analog" and energy is not None and key is not None:
        # Tensor-parallel path: under an active mesh with a model axis > 1,
        # run the matmul column-sharded through shard_map — checked before
        # the stacked-key vmap so ONE shard_map wraps the whole batch.
        y = _maybe_sharded_analog_dot(
            x, w, cfg=cfg, energy=energy, key=key, sq=sq, n_repeats=n_repeats
        )
        if y is not None:
            return y
    kb = key_batch(key)
    if kb is not None:
        # Stacked per-request keys: one independent noise stream per leading
        # row. Each row's draw is identical to running that row alone, so a
        # request's output never depends on what else shares its batch (the
        # serving engine's batching-invariance contract).
        if x.ndim < 2 or x.shape[0] != kb:
            raise ValueError(
                f"stacked key batch {kb} does not match x leading dim {x.shape}"
            )
        return jax.vmap(
            lambda xr, kr: analog_dot(
                xr, w, cfg=cfg, energy=energy, key=kr, sq=sq,
                precision=precision, n_repeats=n_repeats,
            )
        )(x, key)
    k_dim, m_dim = w.shape
    compute_dtype = jnp.float32 if cfg.mode == "analog" else x.dtype

    if cfg.mode == "digital":
        if cfg.weight_bits is not None and sq is not None and sq.wqp is not None:
            w = fake_quant(w, sq.wqp)
        if cfg.act_bits is not None and sq is not None and sq.xqp is not None:
            x = fake_quant(x, sq.xqp)
        y = jnp.matmul(x, w.astype(x.dtype), precision=precision)
        if cfg.out_bits is not None and sq is not None and sq.oqp is not None:
            y = fake_quant(y, sq.oqp)
        return y

    if energy is None or key is None:
        raise ValueError("analog mode requires energy and key")
    backend = resolve_backend(cfg, x.shape, w.shape)
    if backend == "pallas":
        return fused_dot(
            x, w, cfg=cfg, energy=energy, key=key, sq=sq, n_repeats=n_repeats
        )
    if backend == "tile":
        return tile_dot(
            x, w, cfg=cfg, energy=energy, key=key, sq=sq, n_repeats=n_repeats
        )

    x = x.astype(compute_dtype)
    w = w.astype(compute_dtype)
    energy = jnp.asarray(energy, jnp.float32)
    if cfg.discrete_energy:
        from repro.quant.affine import ste_snap_levels

        energy = ste_snap_levels(energy, cfg.energy_quantum)
    if n_repeats > 1:
        # K repeats at E averaged == one draw at K*E (noise in quadrature);
        # the explicit-K oracle forms live in core/redundant.py.
        energy = energy * n_repeats

    # --- input/weight quantization (digital-I/O architectures) -------------
    if cfg.weight_bits is not None and sq is not None and sq.wqp is not None:
        w_q = fake_quant(w, sq.wqp)
    else:
        w_q = w
    if cfg.act_bits is not None and sq is not None and sq.xqp is not None:
        x_q = fake_quant(x, sq.xqp)
    else:
        x_q = x

    kind = cfg.noise.kind
    if kind == noise_lib.WEIGHT:
        w_rng = _w_range(sq, w_q)  # (1, M)
        w_noisy = noise_lib.perturb_weights(key, w_q, w_rng, cfg.noise.sigma, energy)
        y = jnp.matmul(x_q, w_noisy, precision=precision)
    elif kind == noise_lib.THERMAL:
        y = jnp.matmul(x_q, w_q, precision=precision)
        std = noise_lib.thermal_noise_std(
            k_dim, _w_range(sq, w_q), _x_range(sq, x_q), cfg.noise.sigma, energy
        )
        y = y + noise_lib.sample_output_noise(key, y.shape, std)
    elif kind == noise_lib.SHOT:
        y = jnp.matmul(x_q, w_q, precision=precision)
        # eps-safe norms: ||.|| has a NaN gradient at exactly zero, and MoE
        # capacity padding produces all-zero input rows
        w_col = jnp.sqrt(jnp.sum(w_q * w_q, axis=0, keepdims=True) + 1e-20)
        x_row = jnp.sqrt(jnp.sum(x_q * x_q, axis=-1, keepdims=True) + 1e-20)
        std = noise_lib.shot_noise_std(
            w_col, x_row, k_dim, energy, cfg.noise.photon_energy_aj
        )
        y = y + noise_lib.sample_output_noise(key, y.shape, std)
    elif kind == noise_lib.NONE:
        y = jnp.matmul(x_q, w_q, precision=precision)
    else:  # pragma: no cover - NoiseSpec validates kinds
        raise ValueError(kind)

    # --- output requantization (paper App. A: requantize to 8 bits) --------
    if cfg.out_bits is not None and sq is not None and sq.oqp is not None:
        y = fake_quant(y, sq.oqp)
    return y


def analog_conv2d(
    x: Array,
    kernel: Array,
    *,
    cfg: AnalogConfig,
    stride: int = 1,
    padding: str = "SAME",
    energy: Optional[Array] = None,
    key: Optional[jax.Array] = None,
    sq: Optional[SiteQuant] = None,
) -> Array:
    """Convolution as an im2col matmul (paper §II-A, [25]) through analog_dot.

    ``x``: (B, H, W, Cin); ``kernel``: (kh, kw, Cin, Cout).
    """
    kh, kw, cin, cout = kernel.shape
    patches = jax.lax.conv_general_dilated_patches(
        x.astype(jnp.float32),
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # (B, Ho, Wo, kh*kw*cin) with feature order (cin, kh, kw)
    w_mat = jnp.transpose(kernel, (2, 0, 1, 3)).reshape(kh * kw * cin, cout)
    return analog_dot(patches, w_mat, cfg=cfg, energy=energy, key=key, sq=sq)
