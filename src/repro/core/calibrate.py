"""Learning optimal precision-energy tradeoffs (paper §V, Eq. 14).

Optimizes per-site (or per-channel) energies of a *frozen* pre-trained model
by SGD on

    L(E) = E_{(x,y), xi} [ -log p(y | x, xi; theta, E) ]
           + lambda * max(log E_tot(E) - log E_max, 0)

with the reparameterization trick (noise enters as N(0,1) inputs scaled by
the differentiable std) and straight-through estimators through rounding.
Energies are parameterized in log-space; Adam with lr=0.01 per Appendix A.
"""
from __future__ import annotations

import dataclasses
import weakref
from typing import Callable, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.energy import (
    EnergyTree,
    MacTree,
    apply_repeats,
    avg_energy_per_mac,
    log_energy_penalty,
    to_energy,
    uniform_log_energies,
)
from repro.optim.adam import AdamConfig, adam_init, adam_update

Array = jax.Array
#: noisy forward: (energies, inputs, rng) -> logits
ApplyFn = Callable[[EnergyTree, Array, jax.Array], Array]


@dataclasses.dataclass(frozen=True)
class CalibConfig:
    """Hyperparameters from paper Appendix A."""

    lam: float = 2.0  # 2 for shot noise; 8 for thermal/weight
    lr: float = 0.01
    steps: int = 200
    discrete: bool = False
    quantum: float = 1.0
    #: initial uniform energy/MAC as a multiple of the target (start from a
    #: low-noise regime and let the penalty pull energy down).
    init_mult: float = 8.0


def softmax_xent(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def learn_energies(
    apply_fn: ApplyFn,
    macs: MacTree,
    batches: Sequence[Tuple[Array, Array]],
    *,
    key: jax.Array,
    target_e_per_mac: float,
    cfg: CalibConfig = CalibConfig(),
    init_log_e: Optional[EnergyTree] = None,
    loss_fn: Callable[[Array, Array], Array] = softmax_xent,
) -> Tuple[EnergyTree, dict]:
    """Runs the Eq.-14 optimization; returns (energies, diagnostics).

    ``batches`` is cycled for ``cfg.steps`` gradient steps (paper: 4% of the
    training set for one epoch; insensitivity to calibration size noted in
    Appendix A).
    """
    if init_log_e is None:
        log_e = uniform_log_energies(macs, cfg.init_mult * target_e_per_mac)
    else:
        log_e = jax.tree.map(jnp.asarray, init_log_e)

    def objective(log_e, x, y, k):
        e = to_energy(log_e, discrete=cfg.discrete, quantum=cfg.quantum)
        logits = apply_fn(e, x, k)
        nll = loss_fn(logits, y)
        pen = log_energy_penalty(e, macs, target_e_per_mac, cfg.lam)
        return nll + pen, nll

    grad_fn = jax.jit(jax.value_and_grad(objective, has_aux=True))
    opt_cfg = AdamConfig(lr=cfg.lr)
    opt_state = adam_init(log_e, opt_cfg)
    jit_update = jax.jit(lambda g, s, p: adam_update(g, s, p, opt_cfg))

    losses = []
    for step in range(cfg.steps):
        x, y = batches[step % len(batches)]
        k = jax.random.fold_in(key, step)
        (loss, nll), grads = grad_fn(log_e, x, y, k)
        log_e, opt_state = jit_update(grads, opt_state, log_e)
        losses.append(float(nll))

    energies = to_energy(log_e, discrete=cfg.discrete, quantum=cfg.quantum)
    diag = {
        "final_nll": losses[-1] if losses else float("nan"),
        "avg_e_per_mac": float(avg_energy_per_mac(energies, macs)),
        "log_e": log_e,
        "nll_trace": losses,
    }
    return energies, diag


def eval_accuracy(
    apply_fn: ApplyFn,
    energies: EnergyTree,
    batches: Iterable[Tuple[Array, Array]],
    *,
    key: jax.Array,
    n_noise_samples: int = 1,
) -> float:
    """Top-1 accuracy of the noisy model, averaged over noise draws.

    The noise draws run as a single jitted forward per batch with the keys
    folded in-device — vmapped across samples when ``n_noise_samples`` is
    small, ``lax.map``-ed (one forward's activation memory, any sample
    count) when large — not a Python loop of per-sample dispatches.
    Per-sample keys are ``fold_in(fold_in(key, batch), sample)`` exactly as
    the loop formulation drew them, and both mappings evaluate each key's
    draw bit-identically to a solo call — so results match the loop for
    every ``n_noise_samples``, including the n=1 base case.
    """
    n_correct = _eval_fn(apply_fn, n_noise_samples)
    correct = 0
    total = 0
    for bi, (x, y) in enumerate(batches):
        correct += int(n_correct(energies, x, y, jax.random.fold_in(key, bi)))
        total += int(y.size) * n_noise_samples
    return correct / max(total, 1)


def eval_profile_accuracy(
    apply_fn: ApplyFn,
    energies: EnergyTree,
    repeats,
    batches: Iterable[Tuple[Array, Array]],
    *,
    key: jax.Array,
    n_noise_samples: int = 1,
) -> float:
    """Accuracy of the noisy model under a per-layer repeat schedule.

    ``repeats`` is a pytree matching ``energies`` (site -> K). Serving layer
    ``l`` at ``K_l`` repeats averages K_l draws at energy ``E_l`` — in
    distribution (and bit-exactly on the jnp backend, which folds K into a
    single draw at ``K * E``) identical to evaluating at the scaled energies.
    That makes profile evaluation a pure ``eval_accuracy`` reuse: one jitted
    executable per schedule, cached like any other allocation, and the exact
    semantics ``repeat_profile_search`` needs for its accuracy floor.
    """
    scaled = apply_repeats(energies, repeats)
    return eval_accuracy(
        apply_fn, scaled, batches, key=key, n_noise_samples=n_noise_samples
    )


def noise_rms(
    apply_fn: ApplyFn,
    energies: EnergyTree,
    x: Array,
    reference: Array,
    *,
    key: jax.Array,
    n_noise_samples: int = 4,
) -> float:
    """RMS residual of the noisy forward against a clean reference output,
    averaged over ``n_noise_samples`` independent noise draws.

    This is the drift watchdog's observable: every noise model's std is
    proportional to ``1/sqrt(E)`` (Eqs. 9-11), so a global noise-scale
    drift factor ``d`` moves this RMS (to first order) linearly in ``d`` —
    the ratio of a live probe's RMS to the RMS measured at registration
    time estimates the realized drift. Energies are runtime arguments of
    one cached jitted executable per ``(apply_fn, n_noise_samples)``, so
    periodic probing never retraces; per-sample keys are
    ``fold_in(key, sample)``, matching ``eval_accuracy``'s draw scheme.
    """
    rms = _rms_fn(apply_fn, n_noise_samples)
    return float(rms(energies, x, reference, key))


#: apply_fn -> {n_noise_samples: jitted counter}. Weak keys: the jitted
#: executable (and the params the closure captures) die with the apply_fn,
#: instead of pinning every model ever evaluated.
_EVAL_FNS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _eval_fn(apply_fn: ApplyFn, n_noise_samples: int):
    """The jitted S-sample correct-count, cached per (apply_fn, S) so
    repeated evals of one model (every min_energy_search probe) trace once."""
    per_fn = _EVAL_FNS.setdefault(apply_fn, {})
    if n_noise_samples in per_fn:
        return per_fn[n_noise_samples]
    # the closure must not hold apply_fn strongly (a value->key reference
    # would keep the weak-keyed entry alive forever); tracing only happens
    # while a caller holds apply_fn, so the weakref is always live then
    fn_ref = weakref.ref(apply_fn)

    @jax.jit
    def n_correct(energies, x, y, batch_key):
        apply = fn_ref()
        assert apply is not None
        keys = jax.vmap(lambda s: jax.random.fold_in(batch_key, s))(
            jnp.arange(n_noise_samples)
        )

        def fwd(k):
            return apply(energies, x, k)

        if n_noise_samples <= 8:
            logits = jax.vmap(fwd)(keys)  # (S, B, C)
        else:
            logits = jax.lax.map(fwd, keys)
        pred = jnp.argmax(logits, axis=-1)
        return jnp.sum(pred == y[None, :])

    per_fn[n_noise_samples] = n_correct
    return n_correct


#: apply_fn -> {n_noise_samples: jitted RMS probe} — same weak-key scheme
#: as _EVAL_FNS (the watchdog holds its engine's apply fn for its lifetime).
_RMS_FNS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _rms_fn(apply_fn: ApplyFn, n_noise_samples: int):
    per_fn = _RMS_FNS.setdefault(apply_fn, {})
    if n_noise_samples in per_fn:
        return per_fn[n_noise_samples]
    fn_ref = weakref.ref(apply_fn)

    @jax.jit
    def rms(energies, x, reference, key):
        apply = fn_ref()
        assert apply is not None
        keys = jax.vmap(lambda s: jax.random.fold_in(key, s))(
            jnp.arange(n_noise_samples)
        )

        def resid(k):
            return (apply(energies, x, k) - reference).astype(jnp.float32)

        if n_noise_samples <= 8:
            r = jax.vmap(resid)(keys)
        else:
            r = jax.lax.map(resid, keys)
        return jnp.sqrt(jnp.mean(jnp.square(r)))

    per_fn[n_noise_samples] = rms
    return rms
