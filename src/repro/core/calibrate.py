"""Learning optimal precision-energy tradeoffs (paper §V, Eq. 14).

Optimizes per-site (or per-channel) energies of a *frozen* pre-trained model
by SGD on

    L(E) = E_{(x,y), xi} [ -log p(y | x, xi; theta, E) ]
           + lambda * max(log E_tot(E) - log E_max, 0)

with the reparameterization trick (noise enters as N(0,1) inputs scaled by
the differentiable std) and straight-through estimators through rounding.
Energies are parameterized in log-space; Adam with lr=0.01 per Appendix A.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.energy import (
    EnergyTree,
    MacTree,
    avg_energy_per_mac,
    log_energy_penalty,
    to_energy,
    uniform_log_energies,
)
from repro.optim.adam import AdamConfig, adam_init, adam_update

Array = jax.Array
#: noisy forward: (energies, inputs, rng) -> logits
ApplyFn = Callable[[EnergyTree, Array, jax.Array], Array]


@dataclasses.dataclass(frozen=True)
class CalibConfig:
    """Hyperparameters from paper Appendix A."""

    lam: float = 2.0  # 2 for shot noise; 8 for thermal/weight
    lr: float = 0.01
    steps: int = 200
    discrete: bool = False
    quantum: float = 1.0
    #: initial uniform energy/MAC as a multiple of the target (start from a
    #: low-noise regime and let the penalty pull energy down).
    init_mult: float = 8.0


def softmax_xent(logits: Array, labels: Array) -> Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def learn_energies(
    apply_fn: ApplyFn,
    macs: MacTree,
    batches: Sequence[Tuple[Array, Array]],
    *,
    key: jax.Array,
    target_e_per_mac: float,
    cfg: CalibConfig = CalibConfig(),
    init_log_e: Optional[EnergyTree] = None,
    loss_fn: Callable[[Array, Array], Array] = softmax_xent,
) -> Tuple[EnergyTree, dict]:
    """Runs the Eq.-14 optimization; returns (energies, diagnostics).

    ``batches`` is cycled for ``cfg.steps`` gradient steps (paper: 4% of the
    training set for one epoch; insensitivity to calibration size noted in
    Appendix A).
    """
    if init_log_e is None:
        log_e = uniform_log_energies(macs, cfg.init_mult * target_e_per_mac)
    else:
        log_e = jax.tree.map(jnp.asarray, init_log_e)

    def objective(log_e, x, y, k):
        e = to_energy(log_e, discrete=cfg.discrete, quantum=cfg.quantum)
        logits = apply_fn(e, x, k)
        nll = loss_fn(logits, y)
        pen = log_energy_penalty(e, macs, target_e_per_mac, cfg.lam)
        return nll + pen, nll

    grad_fn = jax.jit(jax.value_and_grad(objective, has_aux=True))
    opt_cfg = AdamConfig(lr=cfg.lr)
    opt_state = adam_init(log_e, opt_cfg)
    jit_update = jax.jit(lambda g, s, p: adam_update(g, s, p, opt_cfg))

    losses = []
    for step in range(cfg.steps):
        x, y = batches[step % len(batches)]
        k = jax.random.fold_in(key, step)
        (loss, nll), grads = grad_fn(log_e, x, y, k)
        log_e, opt_state = jit_update(grads, opt_state, log_e)
        losses.append(float(nll))

    energies = to_energy(log_e, discrete=cfg.discrete, quantum=cfg.quantum)
    diag = {
        "final_nll": losses[-1] if losses else float("nan"),
        "avg_e_per_mac": float(avg_energy_per_mac(energies, macs)),
        "log_e": log_e,
        "nll_trace": losses,
    }
    return energies, diag


def eval_accuracy(
    apply_fn: ApplyFn,
    energies: EnergyTree,
    batches: Iterable[Tuple[Array, Array]],
    *,
    key: jax.Array,
    n_noise_samples: int = 1,
) -> float:
    """Top-1 accuracy of the noisy model, averaged over noise draws."""
    fwd = jax.jit(apply_fn)
    correct = 0
    total = 0
    for bi, (x, y) in enumerate(batches):
        for s in range(n_noise_samples):
            k = jax.random.fold_in(jax.random.fold_in(key, bi), s)
            logits = fwd(energies, x, k)
            pred = jnp.argmax(logits, axis=-1)
            correct += int(jnp.sum(pred == y))
            total += int(y.size)
    return correct / max(total, 1)
