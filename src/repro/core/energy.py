"""Energy accounting and the Eq.-14 penalty objective.

Energies are learned in log-space (``E = exp(log_e)``): the noise std scales
as ``1/sqrt(E)`` so positivity is structural, and the paper's own observation
that "energy allocations change by orders of magnitude during training"
(§V, motivation for the log-penalty) makes log-space the natural chart.

MAC counts ``n_mac`` are per-example (batch-independent); the budget is
expressed as a target *average energy/MAC* so batch factors cancel.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.quant.affine import ste_snap_levels

Array = jax.Array
EnergyTree = Dict[str, Array]  # site name -> scalar (per-layer) or (C,) (per-channel)
MacTree = Dict[str, Array]  # site name -> per-example MACs, same shape as energy leaf

# Digital per-MAC cost constants, in aJ/MAC, for pricing digital execution
# tiers next to the analog energy tree in one honest ledger. Anchored to the
# classic CMOS survey numbers (Horowitz, ISSCC'14: ~0.2 pJ per 8-bit MAC and
# ~1 pJ per fp16-class MAC at 45 nm) scaled ~6-7x down for a modern ~7 nm
# node. Order-of-magnitude constants by design: the point is that digital
# MACs sit 2-3 decades above the analog array's tens of aJ/MAC, not any
# particular process corner — pass a measured value to a DigitalTier to pin
# a real device.
DIGITAL_INT8_AJ_PER_MAC = 30_000.0  # 30 fJ/MAC: int8 multiply-accumulate
DIGITAL_BF16_AJ_PER_MAC = 120_000.0  # 120 fJ/MAC: bf16 multiply-accumulate


def to_energy(log_e: EnergyTree, *, discrete: bool = False, quantum: float = 1.0) -> EnergyTree:
    """Map log-parameters to positive energies; optionally snap to discrete
    redundancy levels (photon counts / repeat counts) with an STE (paper §V:
    'rounding the energy/MAC to the nearest quantized energy level during
    training using the STE'). Discrete levels are >= 1 quantum."""

    def one(le):
        e = jnp.exp(le)
        if discrete:
            e = ste_snap_levels(e, quantum)
        return e

    return jax.tree.map(one, log_e)


def total_energy(energies: EnergyTree, macs: MacTree) -> Array:
    """E_tot = sum_l E^(l) * n_mac^(l)  (per example). Works on any pytree
    pair with matching structure (flat site dicts or nested LM energy trees)."""
    prods = jax.tree.map(
        lambda e, m: jnp.sum(jnp.asarray(e, jnp.float32) * jnp.asarray(m, jnp.float32)),
        energies,
        macs,
    )
    return jnp.sum(jnp.stack(jax.tree.leaves(prods)))


def total_macs(macs: MacTree) -> Array:
    leaves = [jnp.sum(jnp.asarray(m, jnp.float32)) for m in jax.tree.leaves(macs)]
    return jnp.sum(jnp.stack(leaves))


def avg_energy_per_mac(energies: EnergyTree, macs: MacTree) -> Array:
    return total_energy(energies, macs) / total_macs(macs)


def apply_repeats(energies: EnergyTree, repeats) -> EnergyTree:
    """Scale each site's energy by its repeat count K.

    Serving a site at K repeats spends ``K * E`` per MAC (the K draws average
    to noise / sqrt(K)); the scaled tree is both what honest accounting sees
    and — on the jnp backend, which folds K into the energy of a single draw
    — bit-exactly what evaluation sees. ``repeats`` is any pytree matching
    ``energies`` whose leaves broadcast against the energy leaves (scalars,
    per-layer vectors, or the stacked trees from ``lm.profile_repeat_tree``).
    """
    return jax.tree.map(
        lambda e, k: jnp.asarray(e, jnp.float32) * jnp.asarray(k, jnp.float32),
        energies,
        repeats,
    )


def repeat_total_energy(energies: EnergyTree, macs: MacTree, repeats) -> Array:
    """True served energy ``sum_l K_l * E_l * MACs_l`` (per example) of a
    per-layer repeat schedule over a per-site energy allocation."""
    return total_energy(apply_repeats(energies, repeats), macs)


def log_energy_penalty(
    energies: EnergyTree, macs: MacTree, target_e_per_mac: float, lam: float
) -> Array:
    """Eq. 14 penalty: lam * max(log(E_tot) - log(E_max), 0) with
    ``E_max = target_e_per_mac * total_macs``."""
    e_tot = total_energy(energies, macs)
    budget = jnp.asarray(target_e_per_mac, jnp.float32) * total_macs(macs)
    return lam * jnp.maximum(jnp.log(e_tot) - jnp.log(budget), 0.0)


def uniform_log_energies(macs: MacTree, e_per_mac: float) -> EnergyTree:
    """Uniform allocation: every site (and channel) at the same energy/MAC."""
    le = float(jnp.log(jnp.asarray(e_per_mac, jnp.float32)))
    return jax.tree.map(lambda m: jnp.full(jnp.shape(m), le, jnp.float32), macs)


def dense_site_macs(
    batch_elems: int, k: int, m: int, *, per_channel: bool
) -> Array:
    """Per-example MACs of a dense site computing (B..., K) @ (K, M).

    ``batch_elems`` counts output vectors per example (e.g. seq len for an LM
    token stream, or 1 for a plain MLP). Per-layer: scalar B*K*M.
    Per-channel: (M,) vector of B*K each."""
    if per_channel:
        return jnp.full((m,), float(batch_elems * k), jnp.float32)
    return jnp.asarray(float(batch_elems) * k * m, jnp.float32)


def describe(energies: EnergyTree, macs: MacTree) -> Tuple[Array, Array]:
    """(total energy, average energy/MAC) convenience pair for logging."""
    return total_energy(energies, macs), avg_energy_per_mac(energies, macs)
