"""Analog noise models (paper §II-C Eqs. 3-5 and §IV Eqs. 9-11).

Each model maps a clean dot product ``y = x @ w`` to a noisy sample, with the
noise standard deviation scaled by ``1/sqrt(E)`` where ``E`` is the per-layer
(or per-output-channel) energy/MAC allocated via redundant coding (§IV).

Units:
  * thermal / weight noise: ``E`` is a relative, unitless quantity (paper §IV).
  * shot noise: ``E`` is physical optical energy per MAC in attojoules (aJ);
    ``photons/MAC = E / E_photon`` with ``E_photon = hc/lambda = 0.128 aJ``
    at lambda = 1.55um (paper §VI-A: "photon energy of 128 zJ").
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

PLANCK_J_S = 6.62607015e-34
LIGHTSPEED_M_S = 2.99792458e8
DEFAULT_WAVELENGTH_M = 1.55e-6
#: photon energy at 1.55um in attojoules (1 aJ = 1e-18 J): hc/lambda = 0.128 aJ.
PHOTON_ENERGY_AJ = PLANCK_J_S * LIGHTSPEED_M_S / DEFAULT_WAVELENGTH_M * 1e18

THERMAL = "thermal"
WEIGHT = "weight"
SHOT = "shot"
NONE = "none"
KINDS = (NONE, THERMAL, WEIGHT, SHOT)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class NoiseSpec:
    """Which physical noise source limits the analog accelerator.

    ``sigma`` is the engineering free parameter: sigma_t for thermal noise
    (paper Appendix A: 0.01) or sigma_w for weight noise (0.1). Unused for
    shot noise, where the physics (photon statistics) fixes the scale.
    """

    kind: str = dataclasses.field(metadata=dict(static=True), default=NONE)
    sigma: float = dataclasses.field(metadata=dict(static=True), default=0.01)
    photon_energy_aj: float = dataclasses.field(
        metadata=dict(static=True), default=PHOTON_ENERGY_AJ
    )

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown noise kind {self.kind!r}; expected one of {KINDS}")


def thermal_noise_std(
    n_macs: Array, w_range: Array, x_range: Array, sigma_t: float, energy: Array
) -> Array:
    """Eq. 9 noise std: sqrt(N) * (Wmax-Wmin) * (xmax-xmin) * sigma_t / sqrt(E).

    Broadcasts: ``w_range`` may be per-output-channel (per-channel weight
    quantization, Appendix A), ``energy`` scalar or per-channel.
    """
    n = jnp.asarray(n_macs, jnp.float32)
    return jnp.sqrt(n) * w_range * x_range * sigma_t / jnp.sqrt(energy)


def weight_noise_std(w_range: Array, sigma_w: float, energy: Array) -> Array:
    """Eq. 10 per-weight perturbation std: (Wmax-Wmin) * sigma_w / sqrt(E)."""
    return w_range * sigma_w / jnp.sqrt(energy)


def shot_noise_std(
    w_col_norms: Array,
    x_row_norms: Array,
    n_macs: Array,
    energy_aj: Array,
    photon_energy_aj: float = PHOTON_ENERGY_AJ,
) -> Array:
    """Eq. 11 noise std: ||W_i||2 ||x||2 / sqrt(N * photons_per_mac).

    ``w_col_norms``: L2 norm over the contracting axis per output channel,
    shape broadcastable to the output's channel axis. ``x_row_norms``: L2 norm
    of each input vector, shape = batch dims + (1,). ``energy_aj`` is optical
    energy per MAC in aJ (scalar or per-channel).
    """
    photons = jnp.asarray(energy_aj, jnp.float32) / photon_energy_aj
    n = jnp.asarray(n_macs, jnp.float32)
    return w_col_norms * x_row_norms / jnp.sqrt(n * photons)


def sample_output_noise(
    key: jax.Array, shape: tuple, std: Array, dtype=jnp.float32
) -> Array:
    """Reparameterized additive Gaussian output noise: std * N(0, 1).

    ``std`` broadcasts against ``shape`` (e.g. per-channel on the last axis).
    The reparameterization trick (paper §V, [55]) makes the result
    differentiable w.r.t. ``std`` and hence w.r.t. the energies.
    """
    xi = jax.random.normal(key, shape, dtype=dtype)
    return xi * std


def perturb_weights(
    key: jax.Array, w: Array, w_range: Array, sigma_w: float, energy: Array
) -> Array:
    """Eq. 10: elementwise Gaussian weight-read noise, std per Eq. 10.

    ``w_range``/``energy`` broadcast per output channel (last axis of ``w``).
    """
    std = weight_noise_std(w_range, sigma_w, energy)
    xi = jax.random.normal(key, w.shape, dtype=jnp.float32)
    return w.astype(jnp.float32) + xi * std


def noise_variance_for_layer(
    spec: NoiseSpec,
    *,
    n_macs: Array,
    energy: Array,
    w_range: Optional[Array] = None,
    x_range: Optional[Array] = None,
    w_col_norms: Optional[Array] = None,
    x_row_norm_sq_mean: Optional[Array] = None,
) -> Array:
    """Analytic Var(eps_a) of the layer output under each noise model.

    Used by the noise-bits analysis (§III). For weight noise the output
    variance of ``sum_j (W_ij + xi_j r sigma/sqrt(E)) x_j`` is
    ``(r sigma)^2/E * ||x||^2``; we take the mean squared input norm.
    """
    if spec.kind == THERMAL:
        return thermal_noise_std(n_macs, w_range, x_range, spec.sigma, energy) ** 2
    if spec.kind == WEIGHT:
        per_w_var = weight_noise_std(w_range, spec.sigma, energy) ** 2
        return per_w_var * x_row_norm_sq_mean
    if spec.kind == SHOT:
        photons = energy / spec.photon_energy_aj
        return (w_col_norms**2) * x_row_norm_sq_mean / (n_macs * photons)
    return jnp.zeros(())
