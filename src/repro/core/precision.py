"""Noise bits: the analog-noise <-> bit-precision equivalence (paper §III).

``B_eps = log2( range / sqrt(12 * Var(eps_a)) + 1 )``          (Eq. 7)

and its explicit thermal-noise form (Eq. 8). Also provides the inverse map
(bits -> equivalent noise variance) used to replicate Table I: evaluate a
network under analog noise, compute per-layer noise bits, then re-evaluate
with noise removed but activations quantized to those (fractional) bit counts.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def noise_bits(out_range: Array, noise_var: Array) -> Array:
    """Eq. 7: number of bits whose quantization noise variance equals
    ``noise_var`` for a uniform quantizer spanning ``out_range``."""
    out_range = jnp.asarray(out_range, jnp.float32)
    noise_var = jnp.maximum(jnp.asarray(noise_var, jnp.float32), 1e-30)
    return jnp.log2(out_range / jnp.sqrt(12.0 * noise_var) + 1.0)


def noise_var_from_bits(out_range: Array, bits: Array) -> Array:
    """Inverse of Eq. 7 == quantization-noise variance of a B-bit uniform
    quantizer (Eq. 6): ``(range / (2^B - 1))^2 / 12``."""
    n_bins = 2.0 ** jnp.asarray(bits, jnp.float32) - 1.0
    delta = jnp.asarray(out_range, jnp.float32) / jnp.maximum(n_bins, 1e-9)
    return delta * delta / 12.0


def thermal_noise_bits(
    out_range: Array,
    n_macs: Array,
    w_range: Array,
    x_range: Array,
    sigma_t: float,
    energy: Array = 1.0,
) -> Array:
    """Eq. 8 (extended with dynamic energy, §VI Table III): noise bits of a
    layer under thermal noise. ``out_range`` is the (l+1) activation range;
    ``w_range``/``x_range`` are the layer-(l) weight/input ranges."""
    n = jnp.asarray(n_macs, jnp.float32)
    denom = (
        sigma_t
        * jnp.asarray(w_range, jnp.float32)
        * jnp.asarray(x_range, jnp.float32)
        * jnp.sqrt(12.0 * n)
        / jnp.sqrt(jnp.asarray(energy, jnp.float32))
    )
    return jnp.log2(jnp.asarray(out_range, jnp.float32) / jnp.maximum(denom, 1e-30) + 1.0)


def empirical_noise_var(clean: Array, noisy: Array) -> Array:
    """Monte-Carlo Var(eps_a) estimate over a layer (paper defines the noise
    distribution over the entire layer, §III)."""
    err = (noisy.astype(jnp.float32) - clean.astype(jnp.float32)).reshape(-1)
    return jnp.mean(err * err)


def snr_noise_bits(snr: Array) -> Array:
    """The SNR connection (paper §III): B = log2(sqrt(SNR) + 1) under a
    uniform signal assumption. Provided for the comparison discussed in-text;
    NOT used for Table I (signal distributions are not uniform)."""
    return jnp.log2(jnp.sqrt(jnp.asarray(snr, jnp.float32)) + 1.0)


def average_bits(
    per_layer_bits: dict, per_layer_macs: Optional[dict] = None, *, weighted: bool = False
) -> Array:
    """Average noise-bits across layers.

    Default (``weighted=False``): the plain unweighted mean over layers —
    the form the paper reports as Table I 'Average Bits'.

    ``weighted=True``: the MAC-weighted mean ``sum_l B_l * n_l / sum_l n_l``
    with ``n_l = sum(per_layer_macs[l])`` — the honest aggregate when layers
    differ by orders of magnitude in MAC count (profile energy reporting:
    a tiny head at high precision shouldn't drag the average like a giant
    FFN would). Requires ``per_layer_macs`` covering every layer in
    ``per_layer_bits``.
    """
    vals = jnp.stack(
        [jnp.asarray(per_layer_bits[k], jnp.float32).mean() for k in per_layer_bits]
    )
    if not weighted:
        return jnp.mean(vals)
    if per_layer_macs is None:
        raise ValueError("weighted=True requires per_layer_macs")
    w = jnp.stack(
        [jnp.sum(jnp.asarray(per_layer_macs[k], jnp.float32)) for k in per_layer_bits]
    )
    return jnp.sum(vals * w) / jnp.sum(w)
