"""Per-layer precision profiles: frozen, servable K-repeat schedules.

The paper's headline method learns the precision of each layer of a frozen
pre-trained model (§V-VI, up to 89% energy reduction for ResNet50). At
serving time the per-layer knob is the repeat count ``K_l``: layer ``l``
runs its analog matmuls K_l times at its per-site energies and averages
(noise / sqrt(K_l) at K_l x energy, fused in-kernel on the Pallas backend).

A :class:`PrecisionProfile` freezes one such schedule so it can be passed
around as a value: learned once (``repro.core.search.repeat_profile_search``),
saved to JSON, registered with the serving engine as a tier, and hashed into
AOT executable cache keys. A uniform schedule is the degenerate single-K
profile — serving code treats it exactly like the classic ``n_repeats=K``
tier.

K is *static* in the fused kernel (baked into the trace), so a profile is a
tuple of Python ints, never a traced array: the model's layer scan is
segmented into contiguous same-K runs at trace time (``models/lm.py``).
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

#: default ladder of repeat counts a profile search may assign per layer.
DEFAULT_K_LEVELS = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class PrecisionProfile:
    """A frozen per-layer repeat schedule ``K_l`` for a specific model.

    ``repeats[l]`` is the repeat count of model layer ``l`` (``cfg.n_layers``
    entries; for multi-layer scan groups each sublayer keeps its own entry —
    ``models/lm.py`` maps layers onto scan groups). All entries are positive
    Python ints: K is static in the fused kernel, so schedules are trace-time
    constants, never traced arrays.

    ``coalesce=False`` disables merging contiguous same-K layers into shared
    scan segments — every scan group then runs as its own segment. That is
    the *unrolled-loop test oracle* for the segmented scan; serving always
    keeps the default.

    ``accuracy`` is optional metadata: the schedule's measured accuracy
    proxy from the search eval that learned it
    (``repro.core.search.repeat_profile_search`` /
    ``eval_profile_accuracy``). The serving policy reads it to enforce
    per-request accuracy floors when demoting under overload. It is NOT
    part of the profile's identity (``cache_key`` ignores it — the trace
    depends only on the repeats).
    """

    repeats: Tuple[int, ...]
    name: str = "profile"
    coalesce: bool = True
    accuracy: Optional[float] = None

    def __post_init__(self):
        reps = tuple(int(k) for k in self.repeats)
        if not reps:
            raise ValueError("a profile needs at least one layer")
        if any(k < 1 for k in reps):
            raise ValueError(f"repeat counts must be >= 1, got {reps}")
        object.__setattr__(self, "repeats", reps)
        if not self.name:
            raise ValueError("a profile needs a non-empty name")
        if self.accuracy is not None:
            object.__setattr__(self, "accuracy", float(self.accuracy))

    # -- shape ---------------------------------------------------------------

    @property
    def n_layers(self) -> int:
        return len(self.repeats)

    @property
    def is_uniform(self) -> bool:
        return len(set(self.repeats)) == 1

    @property
    def max_k(self) -> int:
        return max(self.repeats)

    @classmethod
    def uniform(cls, k: int, n_layers: int, name: Optional[str] = None) -> "PrecisionProfile":
        """The degenerate single-K profile (the classic ``n_repeats`` tier)."""
        return cls(
            repeats=(int(k),) * n_layers,
            name=name if name is not None else f"uniform-{int(k)}",
        )

    # -- identity ------------------------------------------------------------

    def cache_key(self):
        """Hashable identity for AOT executable cache keys.

        Uniform profiles key as the bare int K so they share executables with
        classic ``n_repeats=K`` tiers (the degenerate case really is the same
        trace); non-uniform schedules key on the full repeat tuple. The
        unrolled-oracle form is trace-distinct and tagged so it never aliases
        the coalesced executable.
        """
        if self.is_uniform and self.coalesce:
            return int(self.repeats[0])
        key: tuple = tuple(self.repeats)
        if not self.coalesce:
            key = ("unrolled",) + key
        return key

    # -- persistence (the freeze step of learn -> freeze -> serve) -----------

    def to_json(self) -> dict:
        obj = {"name": self.name, "repeats": list(self.repeats)}
        if self.accuracy is not None:
            obj["accuracy"] = self.accuracy
        return obj

    @classmethod
    def from_json(cls, obj: dict) -> "PrecisionProfile":
        return cls(
            repeats=tuple(obj["repeats"]),
            name=obj.get("name", "profile"),
            accuracy=obj.get("accuracy"),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "PrecisionProfile":
        with open(path) as f:
            return cls.from_json(json.load(f))


def coalesce_runs(
    rows: Sequence, coalesce: bool = True
) -> List[Tuple[int, int, object]]:
    """Split ``rows`` into contiguous equal-value runs: [(start, stop, row)].

    The segmentation primitive of the profile-aware layer scan: scan groups
    whose K-row matches their neighbour share one trace segment. With
    ``coalesce=False`` every row is its own run (the unrolled oracle).
    """
    runs: List[Tuple[int, int, object]] = []
    start = 0
    for i in range(1, len(rows) + 1):
        if i == len(rows) or rows[i] != rows[start] or not coalesce:
            runs.append((start, i, rows[start]))
            start = i
    return runs
