"""Redundant coding: dynamic precision by repeating operations (paper §IV).

Three physically distinct but statistically equivalent mechanisms:

  * time averaging   — accumulate the same op over K clock cycles (Fig. 3a)
  * spatial averaging— K device copies encode the same weights (Fig. 3b/3c)
  * the continuous idealization used for learning — noise std / sqrt(E)

This module implements the explicit K-repeat forms so tests can verify the
1/sqrt(K) law that justifies the continuous ``E`` parameterization used by
``analog_dot`` (signals add linearly, noise adds in quadrature).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.analog import AnalogConfig, SiteQuant, analog_dot
from repro.quant.affine import ste_snap_levels

Array = jax.Array


def time_averaged_dot(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    base_energy: Array,
    key: jax.Array,
    k_repeats: int,
    sq: SiteQuant | None = None,
) -> Array:
    """Fig. 3a: run the op for K clock cycles at base energy and average.

    Statistically identical to a single draw at energy ``K * base_energy``.
    """

    def one(i):
        return analog_dot(
            x, w, cfg=cfg, energy=base_energy, key=jax.random.fold_in(key, i), sq=sq
        )

    draws = jax.vmap(one)(jnp.arange(k_repeats))
    return jnp.mean(draws, axis=0)


def spatial_averaged_dot(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    base_energy: Array,
    key: jax.Array,
    k_repeats: int,
    sq: SiteQuant | None = None,
) -> Array:
    """Fig. 3b: compute ``[W; W; ...] . [x, x, ...] / K`` on one big array.

    The MAC count grows K-fold (energy K * base), and independent per-copy
    noise averages out. For output-additive noise (thermal/shot) the paper's
    K-column construction is equivalent to K independent draws averaged; we
    build it explicitly for weight noise, where each spatial copy of W reads
    independent device noise.
    """
    k_dim, m_dim = w.shape
    w_tiled = jnp.concatenate([w] * k_repeats, axis=0)  # (K*k, M)
    x_tiled = jnp.concatenate([x] * k_repeats, axis=-1)  # (..., K*k)
    y = analog_dot(
        x_tiled, w_tiled, cfg=cfg, energy=base_energy, key=key, sq=sq
    )
    return y / float(k_repeats)


def discrete_levels(energy: Array, quantum: float) -> Array:
    """Round energies to integer redundancy levels with an STE (paper §V)."""
    return ste_snap_levels(energy, quantum)
