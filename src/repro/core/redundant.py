"""Redundant coding: dynamic precision by repeating operations (paper §IV).

Three physically distinct but statistically equivalent mechanisms:

  * time averaging   — accumulate the same op over K clock cycles (Fig. 3a)
  * spatial averaging— K device copies encode the same weights (Fig. 3b/3c)
  * the continuous idealization used for learning — noise std / sqrt(E)

The public ``time_averaged_dot`` / ``spatial_averaged_dot`` entry points run
the FUSED execution path: a single ``analog_dot`` with ``n_repeats=K``, which
the backend dispatch lowers either to the fused Pallas kernel (K noise draws
averaged in-register, one matmul pass, one x/w HBM read) or to the jnp
single-draw-at-``K*E`` equivalent. The ``*_explicit`` forms materialize the
O(K) computation the hardware physically performs — K matmuls over K clock
cycles, or a K-fold tiled crossbar — and exist as test oracles for the
1/sqrt(K) law that justifies both the fusion and the continuous ``E``
parameterization (signals add linearly, noise adds in quadrature).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.analog import AnalogConfig, SiteQuant, analog_dot
from repro.quant.affine import ste_snap_levels

Array = jax.Array


def time_averaged_dot(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    base_energy: Array,
    key: jax.Array,
    k_repeats: int,
    sq: SiteQuant | None = None,
) -> Array:
    """Fig. 3a: run the op for K clock cycles at base energy and average.

    Fused: one ``analog_dot`` with ``n_repeats=K`` — statistically identical
    to the explicit K-draw average (and to a single draw at ``K * base``),
    at 1/K the matmul cost and HBM traffic of the explicit form.
    """
    return analog_dot(
        x, w, cfg=cfg, energy=base_energy, key=key, sq=sq, n_repeats=k_repeats
    )


def spatial_averaged_dot(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    base_energy: Array,
    key: jax.Array,
    k_repeats: int,
    sq: SiteQuant | None = None,
) -> Array:
    """Fig. 3b: K spatial device copies of W, averaged.

    Statistically identical to time averaging (independent per-copy noise
    averages the same way regardless of whether the copies are laid out in
    time or space), so the fused path serves both; the physical K-column
    construction lives in ``spatial_averaged_dot_explicit``.
    """
    return analog_dot(
        x, w, cfg=cfg, energy=base_energy, key=key, sq=sq, n_repeats=k_repeats
    )


def time_averaged_dot_explicit(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    base_energy: Array,
    key: jax.Array,
    k_repeats: int,
    sq: SiteQuant | None = None,
) -> Array:
    """Test oracle: the physical K-cycle form — K independent draws, averaged.

    O(K) matmuls and O(K) noise tensors; the fused path must match this
    distribution (mean AND variance) for every noise kind.
    """

    def one(i):
        return analog_dot(
            x, w, cfg=cfg, energy=base_energy, key=jax.random.fold_in(key, i), sq=sq
        )

    draws = jax.vmap(one)(jnp.arange(k_repeats))
    return jnp.mean(draws, axis=0)


def spatial_averaged_dot_explicit(
    x: Array,
    w: Array,
    *,
    cfg: AnalogConfig,
    base_energy: Array,
    key: jax.Array,
    k_repeats: int,
    sq: SiteQuant | None = None,
) -> Array:
    """Test oracle: compute ``[x, x, ...] . [W; W; ...] / K`` on one big array.

    The MAC count grows K-fold (energy K * base), and independent per-copy
    noise averages out. For output-additive noise (thermal/shot) the paper's
    K-column construction is equivalent to K independent draws averaged; we
    build it explicitly for weight noise, where each spatial copy of W reads
    independent device noise. The K-fold tiled operands are exactly the HBM
    cost the fused kernel avoids.
    """
    w_tiled = jnp.tile(w, (k_repeats, 1))  # (K*k, M)
    x_tiled = jnp.tile(x, (1,) * (x.ndim - 1) + (k_repeats,))  # (..., K*k)
    y = analog_dot(
        x_tiled, w_tiled, cfg=cfg, energy=base_energy, key=key, sq=sq
    )
    return y / float(k_repeats)


def discrete_levels(energy: Array, quantum: float) -> Array:
    """Round energies to integer redundancy levels with an STE (paper §V)."""
    return ste_snap_levels(energy, quantum)
