"""Binary search for the minimum energy/MAC at bounded accuracy loss.

Paper §VI-A: "we determine the minimum average energy/MAC for which the
accuracy does not degrade below floating point accuracy by 2% (within 0.1%)
by performing a binary search on the target energy/MAC."
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable, Optional, Tuple


@dataclasses.dataclass
class SearchResult:
    min_e_per_mac: float  # smallest feasible target found
    accuracy: float  # accuracy achieved at that target
    achieved_e_per_mac: float  # actual average E/MAC (may undershoot target)
    trace: list  # [(target, acc, achieved)] per bisection step
    artifact: object = None  # energies (or whatever make_fn returns) at best


def min_energy_search(
    make_fn: Callable[[float], Tuple[object, float]],
    acc_fn: Callable[[object], float],
    *,
    float_acc: float,
    max_degradation: float = 0.02,
    acc_tol: float = 0.001,
    lo: float = 1e-3,
    hi: float = 1e3,
    max_iters: int = 12,
) -> SearchResult:
    """Bisect (in log space) the smallest target energy/MAC meeting the
    accuracy floor ``float_acc - max_degradation``.

    ``make_fn(target) -> (artifact, achieved_e_per_mac)`` builds an energy
    allocation for the target (uniform assignment, or a full Eq.-14
    calibration run). ``acc_fn(artifact) -> accuracy`` evaluates it.
    Terminates early once the achieved accuracy is within ``acc_tol`` of the
    floor (paper's "within 0.1%").

    Warm starts: when ``make_fn`` accepts an ``init`` keyword, each probe
    after the first feasible one receives the best feasible probe's artifact
    (its energy allocation / log_e) as ``init``. Successive bisection targets
    are close together, so a calibration-backed make_fn converges in far
    fewer Eq.-14 steps starting from the neighbouring optimum. The probe
    *decisions* (feasible / infeasible) and the bisection trajectory are
    unchanged for make_fns that ignore ``init``.
    """
    floor = float_acc - max_degradation
    trace = []
    best: Optional[tuple] = None  # (target, acc, achieved, artifact)
    try:
        takes_init = "init" in inspect.signature(make_fn).parameters
    except (TypeError, ValueError):  # builtins / C callables: no plumbing
        takes_init = False

    def probe(target: float):
        nonlocal best
        if takes_init:
            artifact, achieved = make_fn(target, init=best[3] if best else None)
        else:
            artifact, achieved = make_fn(target)
        acc = acc_fn(artifact)
        trace.append((target, acc, achieved))
        if acc >= floor and (best is None or achieved < best[2]):
            best = (target, acc, achieved, artifact)
        return acc

    # Ensure the bracket actually brackets feasibility.
    acc_hi = probe(hi)
    if acc_hi < floor:
        return SearchResult(math.inf, acc_hi, math.inf, trace, None)
    acc_lo = probe(lo)
    if acc_lo >= floor:
        _, acc, achieved, art = best
        return SearchResult(lo, acc, achieved, trace, art)

    llo, lhi = math.log(lo), math.log(hi)
    for _ in range(max_iters):
        mid = math.exp(0.5 * (llo + lhi))
        acc = probe(mid)
        if acc >= floor:
            lhi = math.log(mid)
            if acc - floor <= acc_tol:  # inside the paper's 0.1% window
                break
        else:
            llo = math.log(mid)

    assert best is not None
    target, acc, achieved, art = best
    return SearchResult(target, acc, achieved, trace, art)
