"""Binary search for the minimum energy/MAC at bounded accuracy loss.

Paper §VI-A: "we determine the minimum average energy/MAC for which the
accuracy does not degrade below floating point accuracy by 2% (within 0.1%)
by performing a binary search on the target energy/MAC."
"""
from __future__ import annotations

import dataclasses
import inspect
import math
from typing import Callable, Optional, Tuple


@dataclasses.dataclass
class SearchResult:
    min_e_per_mac: float  # smallest feasible target found
    accuracy: float  # accuracy achieved at that target
    achieved_e_per_mac: float  # actual average E/MAC (may undershoot target)
    trace: list  # [(target, acc, achieved)] per bisection step
    artifact: object = None  # energies (or whatever make_fn returns) at best


def min_energy_search(
    make_fn: Callable[[float], Tuple[object, float]],
    acc_fn: Callable[[object], float],
    *,
    float_acc: float,
    max_degradation: float = 0.02,
    acc_tol: float = 0.001,
    lo: float = 1e-3,
    hi: float = 1e3,
    max_iters: int = 12,
) -> SearchResult:
    """Bisect (in log space) the smallest target energy/MAC meeting the
    accuracy floor ``float_acc - max_degradation``.

    ``make_fn(target) -> (artifact, achieved_e_per_mac)`` builds an energy
    allocation for the target (uniform assignment, or a full Eq.-14
    calibration run). ``acc_fn(artifact) -> accuracy`` evaluates it.
    Terminates early once the achieved accuracy is within ``acc_tol`` of the
    floor (paper's "within 0.1%").

    Warm starts: when ``make_fn`` accepts an ``init`` keyword, each probe
    after the first feasible one receives the best feasible probe's artifact
    (its energy allocation / log_e) as ``init``. Successive bisection targets
    are close together, so a calibration-backed make_fn converges in far
    fewer Eq.-14 steps starting from the neighbouring optimum. The probe
    *decisions* (feasible / infeasible) and the bisection trajectory are
    unchanged for make_fns that ignore ``init``.
    """
    floor = float_acc - max_degradation
    trace = []
    best: Optional[tuple] = None  # (target, acc, achieved, artifact)
    try:
        takes_init = "init" in inspect.signature(make_fn).parameters
    except (TypeError, ValueError):  # builtins / C callables: no plumbing
        takes_init = False

    def probe(target: float):
        nonlocal best
        if takes_init:
            artifact, achieved = make_fn(target, init=best[3] if best else None)
        else:
            artifact, achieved = make_fn(target)
        acc = acc_fn(artifact)
        trace.append((target, acc, achieved))
        if acc >= floor and (best is None or achieved < best[2]):
            best = (target, acc, achieved, artifact)
        return acc

    # Ensure the bracket actually brackets feasibility.
    acc_hi = probe(hi)
    if acc_hi < floor:
        return SearchResult(math.inf, acc_hi, math.inf, trace, None)
    acc_lo = probe(lo)
    if acc_lo >= floor:
        # Both bracket probes are feasible. Report the best feasible probe
        # *whole*: a calibration-backed make_fn can undershoot its target, so
        # the hi probe may have achieved less energy than the lo probe — in
        # which case (target, acc, achieved, artifact) must all come from hi,
        # never a mix of the two probes' fields.
        target, acc, achieved, art = best
        return SearchResult(target, acc, achieved, trace, art)

    llo, lhi = math.log(lo), math.log(hi)
    for _ in range(max_iters):
        mid = math.exp(0.5 * (llo + lhi))
        acc = probe(mid)
        if acc >= floor:
            lhi = math.log(mid)
            if acc - floor <= acc_tol:  # inside the paper's 0.1% window
                break
        else:
            llo = math.log(mid)

    assert best is not None
    target, acc, achieved, art = best
    return SearchResult(target, acc, achieved, trace, art)


# ===========================================================================
# per-layer repeat-count profiles (paper §V-VI: learn each layer's precision)
# ===========================================================================


@dataclasses.dataclass
class ProfileSearchResult:
    """Outcome of :func:`repeat_profile_search` (and its online variant)."""

    repeats: Tuple[int, ...]  # the learned per-layer K schedule
    accuracy: float  # accuracy achieved by that schedule
    cost: float  # sum_l K_l * w_l (w = per-layer energy weight)
    uniform_cost: float  # cost of the uniform max-K schedule (the baseline)
    feasible: bool  # False: the starting schedule itself missed the floor
    trace: list  # [(repeats, acc)] per evaluated schedule
    n_evals: int = 0
    #: online variant only: the frozen schedule missed the floor at the
    #: live statistics and had to be raised before descent
    repaired: bool = False


def repeat_profile_search(
    acc_fn: Callable[[Tuple[int, ...]], float],
    *,
    n_layers: int,
    float_acc: float,
    max_degradation: float = 0.02,
    k_levels: Tuple[int, ...] = (1, 2, 4, 8),
    weights: Optional[Tuple[float, ...]] = None,
    init: Optional[Tuple[int, ...]] = None,
) -> ProfileSearchResult:
    """Greedy per-layer descent of the repeat schedule ``K_l`` subject to the
    paper's accuracy floor ``float_acc - max_degradation``.

    ``acc_fn(repeats) -> accuracy`` evaluates a candidate schedule (serving
    at K repeats equals one draw at K x energy on the jnp path, so
    ``repro.core.calibrate.eval_profile_accuracy`` is the usual adapter).
    ``weights[l]`` is layer ``l``'s energy cost per unit K (``E_l * MACs_l``)
    — it orders the descent (largest savings first) and prices the result;
    defaults to all-ones.

    Starting from the uniform max level (or ``init`` — e.g. the schedule
    learned at a neighbouring accuracy floor, the profile analogue of
    ``min_energy_search``'s warm starts), the search repeatedly lowers the
    single layer whose step down the level ladder saves the most energy
    while keeping the accuracy floor, until no single-layer decrement is
    feasible. Evaluations are memoized; the search is deterministic for a
    deterministic ``acc_fn``.
    """
    levels = tuple(sorted(set(int(k) for k in k_levels)))
    if not levels or levels[0] < 1:
        raise ValueError(f"bad k_levels {k_levels!r}")
    w = tuple(float(x) for x in (weights or (1.0,) * n_layers))
    if len(w) != n_layers:
        raise ValueError(f"{len(w)} weights for {n_layers} layers")
    start = tuple(int(k) for k in (init or (levels[-1],) * n_layers))
    if len(start) != n_layers or any(k not in levels for k in start):
        raise ValueError(f"init {start!r} is not on the {levels} ladder")
    floor = float_acc - max_degradation

    trace: list = []
    memo: dict = {}

    def evaluate(reps: Tuple[int, ...]) -> float:
        if reps not in memo:
            memo[reps] = float(acc_fn(reps))
            trace.append((reps, memo[reps]))
        return memo[reps]

    def cost(reps: Tuple[int, ...]) -> float:
        return float(sum(k * wl for k, wl in zip(reps, w)))

    # the savings baseline is always uniform max-K, even when a warm-start
    # init begins the descent below it
    uniform_cost = cost((levels[-1],) * n_layers)
    cur = start
    acc = evaluate(cur)
    if acc < floor:
        return ProfileSearchResult(
            cur, acc, cost(cur), uniform_cost, False, trace, len(memo)
        )

    improved = True
    while improved:
        improved = False
        moves = []  # (savings, layer, lowered schedule)
        for l in range(n_layers):
            idx = levels.index(cur[l])
            if idx == 0:
                continue
            cand = cur[:l] + (levels[idx - 1],) + cur[l + 1 :]
            moves.append((w[l] * (cur[l] - levels[idx - 1]), l, cand))
        # biggest energy saving first; layer index breaks ties deterministically
        for _, _, cand in sorted(moves, key=lambda m: (-m[0], m[1])):
            cand_acc = evaluate(cand)
            if cand_acc >= floor:
                cur, acc, improved = cand, cand_acc, True
                break

    return ProfileSearchResult(
        cur, acc, cost(cur), uniform_cost, True, trace, len(memo)
    )


# ===========================================================================
# online re-trim: repair + descend from a frozen serving profile
# ===========================================================================


class _BudgetExhausted(Exception):
    """Internal: the online eval budget ran out mid-search."""


class _BudgetedAccFn:
    """Memoizing, budget-bounded wrapper around a live ``acc_fn``.

    Memo hits are free; only genuinely new schedule evaluations consume
    the budget (an online eval against live traffic costs real probe
    compute/energy, a memo lookup does not). The memo doubles as the
    combined eval trace — dict insertion order IS eval order.
    """

    def __init__(self, acc_fn, max_evals: Optional[int]):
        self.acc_fn = acc_fn
        self.max_evals = max_evals
        self.memo: dict = {}

    def __call__(self, reps) -> float:
        reps = tuple(reps)
        if reps in self.memo:
            return self.memo[reps]
        if self.max_evals is not None and len(self.memo) >= self.max_evals:
            raise _BudgetExhausted()
        self.memo[reps] = float(self.acc_fn(reps))
        return self.memo[reps]


def online_repeat_profile_search(
    acc_fn: Callable[[Tuple[int, ...]], float],
    *,
    frozen,
    float_acc: float,
    max_degradation: float = 0.02,
    k_levels: Tuple[int, ...] = (1, 2, 4, 8),
    weights: Optional[Tuple[float, ...]] = None,
    max_evals: Optional[int] = None,
) -> ProfileSearchResult:
    """Re-trim a frozen serving profile against *live* statistics, between
    serving epochs, under a bounded eval budget.

    The offline search (:func:`repeat_profile_search`) learns a schedule
    once against a calibration set; a deployed engine then watches the
    world move — the noise floor drifts (``NoiseDriftWatchdog``), the
    traffic mix shifts the per-layer energy weights, the realized accuracy
    proxy walks. This variant closes that loop: ``acc_fn`` should evaluate
    candidates against the live statistics (e.g. ``eval_profile_accuracy``
    at the engine's *effective* drifted energies over a traffic-weighted
    probe batch) and ``weights`` should price layers by live spend.

    ``frozen`` is the currently-served schedule (a ``PrecisionProfile`` or
    a repeat tuple) — the warm start. Two phases:

    1. **Repair** (upward): if the frozen schedule misses the floor at the
       live stats, greedily raise one layer at a time — cheapest increment
       first, accepting the first candidate that restores feasibility,
       else the best-accuracy probe — until feasible (or the ladder tops
       out: ``feasible=False``, serve the watchdog's K-promotion instead).
    2. **Descent**: delegate to :func:`repeat_profile_search` warm-started
       from the (repaired) schedule, trimming layers the live traffic
       shows are over-provisioned.

    ``max_evals`` bounds total *new* ``acc_fn`` evaluations (memo hits are
    free). On exhaustion the cheapest feasible schedule seen so far is
    returned; if none is known, the frozen schedule itself comes back with
    ``feasible=False`` — serving keeps its vetted profile rather than
    adopting an unvetted one. Deterministic for a deterministic
    ``acc_fn``; ``repaired`` records whether phase 1 had to act.
    """
    reps0 = tuple(
        int(k) for k in (frozen.repeats if hasattr(frozen, "repeats") else frozen)
    )
    n_layers = len(reps0)
    levels = tuple(sorted(set(int(k) for k in k_levels)))
    if not levels or levels[0] < 1:
        raise ValueError(f"bad k_levels {k_levels!r}")
    if any(k not in levels for k in reps0):
        raise ValueError(f"frozen schedule {reps0!r} is not on the {levels} ladder")
    w = tuple(float(x) for x in (weights or (1.0,) * n_layers))
    if len(w) != n_layers:
        raise ValueError(f"{len(w)} weights for {n_layers} layers")
    if max_evals is not None and max_evals < 1:
        raise ValueError(f"max_evals must be >= 1, got {max_evals}")
    floor = float_acc - max_degradation
    budget = _BudgetedAccFn(acc_fn, max_evals)

    def cost(reps: Tuple[int, ...]) -> float:
        return float(sum(k * wl for k, wl in zip(reps, w)))

    uniform_cost = cost((levels[-1],) * n_layers)

    def result(reps, acc, feasible, repaired):
        return ProfileSearchResult(
            reps, acc, cost(reps), uniform_cost, feasible,
            list(budget.memo.items()), len(budget.memo), repaired,
        )

    def best_known_feasible():
        feas = [(cost(r), r, a) for r, a in budget.memo.items() if a >= floor]
        if not feas:
            return None
        c, reps, acc = min(feas, key=lambda t: (t[0], t[1]))
        return reps, acc

    # phase 1: repair upward until the live floor holds again
    cur = reps0
    repaired = False
    try:
        acc = budget(cur)
        while acc < floor:
            moves = []  # (increment cost, layer, raised schedule)
            for l in range(n_layers):
                idx = levels.index(cur[l])
                if idx == len(levels) - 1:
                    continue
                cand = cur[:l] + (levels[idx + 1],) + cur[l + 1 :]
                moves.append((w[l] * (levels[idx + 1] - cur[l]), l, cand))
            if not moves:
                # ladder topped out everywhere and still infeasible: the
                # live floor is unreachable by repeats alone
                return result(cur, acc, False, repaired)
            repaired = True
            # cheapest increment first; take the first feasible candidate,
            # else the best-accuracy probe (ties broken by layer index)
            moves.sort(key=lambda m: (m[0], m[1]))
            best_cand, best_acc = None, -float("inf")
            for _c, _l, cand in moves:
                a = budget(cand)
                if a >= floor:
                    best_cand, best_acc = cand, a
                    break
                if a > best_acc:
                    best_cand, best_acc = cand, a
            cur, acc = best_cand, best_acc
    except _BudgetExhausted:
        known = best_known_feasible()
        if known is not None:
            return result(known[0], known[1], True, repaired)
        return result(reps0, budget.memo.get(reps0, float("nan")), False, repaired)

    # phase 2: descend from the (repaired) schedule — the offline greedy,
    # warm-started, sharing the memo and the remaining eval budget
    try:
        res = repeat_profile_search(
            budget, n_layers=n_layers, float_acc=float_acc,
            max_degradation=max_degradation, k_levels=levels,
            weights=w, init=cur,
        )
        return result(res.repeats, res.accuracy, True, repaired)
    except _BudgetExhausted:
        known = best_known_feasible()
        assert known is not None  # `cur` itself is feasible and memoized
        return result(known[0], known[1], True, repaired)
