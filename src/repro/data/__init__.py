from repro.data.pipeline import DataPipeline, TokenTaskConfig, markov_batch
from repro.data.synthetic import (
    make_entailment_dataset,
    make_image_dataset,
    make_tabular_dataset,
)

__all__ = [
    "DataPipeline",
    "TokenTaskConfig",
    "make_entailment_dataset",
    "make_image_dataset",
    "make_tabular_dataset",
    "markov_batch",
]
