"""Deterministic synthetic data pipeline with background prefetch.

Properties a real cluster pipeline needs and tests assert:
  * deterministic: batch(step) is a pure function of (seed, step, rank) —
    restart-from-checkpoint replays identical data, and a run with failures
    reproduces a run without them bit-exactly.
  * sharded: each data-parallel rank draws a disjoint slice of the global
    batch (rank folded into the counter), so hosts never exchange data.
  * prefetched: a daemon thread keeps a bounded queue of upcoming batches.

The token task is a learnable first-order Markov chain over the vocab (so
example trainings show real loss decrease, not noise-fitting).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenTaskConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 4  # next-token candidates per state (task difficulty)


def _chain(cfg: TokenTaskConfig) -> np.ndarray:
    """Fixed random transition table: (vocab, branching) candidate successors."""
    rng = np.random.default_rng(cfg.seed ^ 0xC0FFEE)
    return rng.integers(0, cfg.vocab_size, size=(cfg.vocab_size, cfg.branching))


_CHAIN_CACHE: Dict[tuple, np.ndarray] = {}


def markov_batch(
    cfg: TokenTaskConfig, step: int, rank: int = 0, world: int = 1
) -> Dict[str, np.ndarray]:
    """Batch for one (step, rank): tokens (b, T) and next-token labels."""
    key = (cfg.vocab_size, cfg.branching, cfg.seed)
    if key not in _CHAIN_CACHE:
        _CHAIN_CACHE[key] = _chain(cfg)
    chain = _CHAIN_CACHE[key]
    assert cfg.global_batch % world == 0
    b = cfg.global_batch // world
    rng = np.random.default_rng((cfg.seed, step, rank))
    toks = np.empty((b, cfg.seq_len + 1), np.int32)
    toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
    choices = rng.integers(0, cfg.branching, size=(b, cfg.seq_len))
    for t in range(cfg.seq_len):
        toks[:, t + 1] = chain[toks[:, t], choices[:, t]]
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:].copy()}


class DataPipeline:
    """Prefetching iterator over markov_batch(step) with restart support."""

    def __init__(
        self,
        cfg: TokenTaskConfig,
        start_step: int = 0,
        rank: int = 0,
        world: int = 1,
        prefetch: int = 2,
    ):
        self.cfg = cfg
        self.rank = rank
        self.world = world
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self):
        step = self._step
        while not self._stop.is_set():
            batch = markov_batch(self.cfg, step, self.rank, self.world)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step, batch = self._q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
