"""Deterministic synthetic datasets for the paper-validation experiments.

The container is offline (no ImageNet/GLUE), so the paper's models are
replaced by small networks trained on procedurally generated tasks that are
non-trivially learnable — the dynamic-precision claims we validate are about
*energy-accuracy tradeoffs of a frozen trained model under analog noise*,
which these tasks exercise exactly (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_image_dataset(
    n: int, *, n_classes: int = 10, size: int = 16, channels: int = 3, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional structured images: each class has a fixed random
    frequency signature + spatial pattern; samples add noise and random
    phase/amplitude jitter. CNN-learnable but not linearly separable from
    raw pixels at high noise."""
    rng = np.random.default_rng(seed)
    # per-class: mixture of 3 2-D sinusoid patterns + a blob location.
    # Classes are deliberately close (narrow frequency band, shared phases,
    # strong per-sample jitter + pixel noise) so a small CNN lands around
    # 85-95% — leaving headroom for noise-induced degradation.
    freqs = rng.uniform(1.0, 2.2, size=(n_classes, 3, 2))
    phases = rng.uniform(0, 2 * np.pi, size=(n_classes, 3))
    blob = rng.uniform(0.3, 0.7, size=(n_classes, 2))
    labels = rng.integers(0, n_classes, size=n)
    yy, xx = np.mgrid[0:size, 0:size] / size
    imgs = np.empty((n, size, size, channels), np.float32)
    for i in range(n):
        c = labels[i]
        jit = rng.normal(0, 0.35, size=3)
        img = np.zeros((size, size), np.float32)
        for k in range(3):
            img += (1.0 + jit[k]) * np.sin(
                2 * np.pi * (freqs[c, k, 0] * xx + freqs[c, k, 1] * yy) + phases[c, k]
            )
        bx, by = blob[c] + rng.normal(0, 0.08, size=2)
        img += 1.0 * np.exp(-(((xx - bx) ** 2 + (yy - by) ** 2) / 0.02))
        img = img[..., None] * np.array([1.0, 0.8, 0.6], np.float32)
        img += rng.normal(0, 1.0, size=img.shape)
        imgs[i] = img
    return imgs.astype(np.float32), labels.astype(np.int32)


def make_entailment_dataset(
    n: int, *, vocab: int = 64, seq_len: int = 24, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """MNLI-style 3-way task over token pairs (premise, hypothesis).

    Rule: hypothesis tokens drawn from the premise's "topic set" ->
    entail(0); from the complementary set -> contradict(1); mixed ->
    neutral(2). Requires cross-segment attention to solve.
    """
    rng = np.random.default_rng(seed)
    half = seq_len // 2
    n_topics = 8
    per = (vocab - 4) // n_topics
    topic_words = rng.permutation(vocab - 4)[: n_topics * per].reshape(n_topics, per)
    toks = np.empty((n, seq_len), np.int32)
    labels = rng.integers(0, 3, size=n).astype(np.int32)
    sep = vocab - 1
    for i in range(n):
        t = rng.integers(0, n_topics)
        other = (t + 1 + rng.integers(0, n_topics - 1)) % n_topics
        prem = rng.choice(topic_words[t], size=half - 1)
        if labels[i] == 0:
            hyp = rng.choice(topic_words[t], size=half)
        elif labels[i] == 1:
            hyp = rng.choice(topic_words[other], size=half)
        else:
            k = half // 2
            hyp = np.concatenate(
                [rng.choice(topic_words[t], size=k), rng.choice(topic_words[other], size=half - k)]
            )
            rng.shuffle(hyp)
        toks[i] = np.concatenate([prem, [sep], hyp])
    return toks, labels


def make_tabular_dataset(
    n: int, *, dim: int = 32, n_classes: int = 8, depth: int = 3, seed: int = 0
) -> Tuple[np.ndarray, np.ndarray]:
    """MLP task: labels from a fixed random teacher MLP (depth layers) over
    gaussian inputs — learnable to high accuracy, nonlinear."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, dim)).astype(np.float32)
    h = x
    for _ in range(depth):
        w = rng.normal(size=(h.shape[1], dim)).astype(np.float32) / np.sqrt(h.shape[1])
        h = np.tanh(h @ w)
    w_out = rng.normal(size=(dim, n_classes)).astype(np.float32)
    labels = np.argmax(h @ w_out, axis=-1).astype(np.int32)
    return x, labels
