"""Pallas TPU kernels for the perf-critical analog-simulation hot spots.

  analog_matmul  - fused quant -> matmul -> K-repeat noise -> requant
                   (paper §IV). The dynamic-precision repeat-average is
                   computed in-register: K independent Threefry gaussian
                   tiles (salted by repeat index) are averaged inside the
                   kernel, so the op costs ONE matmul pass and one x/w HBM
                   read regardless of K — the K-fold tiled operands and the
                   K HBM-resident noise tensors of the unfused form never
                   exist.
  prng           - counter-based Threefry-2x32 + Box-Muller (in-register
                   noise); ``repeat_averaged_gaussian_tile`` is the shared
                   kernel/oracle contract for K-repeat draws.
  ref            - pure-jnp oracles with bit-identical noise draws (any
                   BlockSpec tiling, any K).
  ops            - jit'd public wrappers.
  dispatch       - backend resolution: "auto" routes analog matmuls to this
                   kernel on TPU for large-enough shapes, to the jnp path
                   otherwise; "pallas"/"jnp" force a path. ``analog_dot``
                   and every model hook call through it.
"""
from repro.kernels.dispatch import fused_dot, resolve_backend
from repro.kernels.ops import analog_matmul, analog_matmul_reference, prepare_operands

__all__ = [
    "analog_matmul",
    "analog_matmul_reference",
    "fused_dot",
    "prepare_operands",
    "resolve_backend",
]
