"""Pallas TPU kernels for the perf-critical analog-simulation hot spots.

  analog_matmul  - fused quant -> matmul -> noise -> requant (paper §IV)
  prng           - counter-based Threefry-2x32 + Box-Muller (in-register noise)
  ref            - pure-jnp oracles with bit-identical noise draws
  ops            - jit'd public wrappers
"""
from repro.kernels.ops import analog_matmul, analog_matmul_reference, prepare_operands

__all__ = ["analog_matmul", "analog_matmul_reference", "prepare_operands"]
