"""Fused analog-matmul Pallas TPU kernel.

One kernel fuses the entire simulated analog pipeline of paper §IV:

    fake-quant(x)  ->  fake-quant(w) per-channel  ->  [weight-read noise]
    ->  MXU matmul accumulate (f32)  ->  [output noise, std = row x col]
    ->  affine requantization of the output

Noise is generated *inside* the kernel from a counter-based Threefry PRNG
keyed on global element indices — the (M, N) gaussian tensor never exists in
HBM. Block sizes are MXU-aligned (multiples of 128) and sized so the working
set (x, w, out tiles) fits VMEM.

Noise kinds (static):
  * "output": additive gaussian with std[i, j] = row_scale[i] * col_scale[j].
    Covers thermal (row=1) and shot (row=||x_i||) — scales precomputed in
    ops.py from the calibrated ranges / energies.
  * "weight": per-weight gaussian with std[j] = wnoise_scale[j] (Eq. 10),
    drawn per (k, j) — identical draw for every row-tile i, as in a single
    physical read of the crossbar.
  * "none": plain (optionally quantized) matmul.

Dynamic precision (static ``n_repeats``): the paper's K-repeat redundancy
(§IV, Fig. 3) — run the analog op K times at base energy and average — is
fused into the kernel. Because the matmul is linear in its operands, the
average of K noisy products equals the clean product plus the *averaged*
noise, so the kernel draws K independent gaussian tiles per output/weight
tile (salted by repeat index), averages them in-register, and applies them
in a SINGLE matmul pass: one x/w HBM read and one y write regardless of K.
The K-fold tiled operands of the explicit form never exist.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import prng

Array = jax.Array

DEFAULT_BLOCK = (256, 256, 512)  # (bm, bn, bk)


def _fake_quant(v: Array, delta: Array, zp: Array, bins: Array) -> Array:
    """Affine fake-quant; delta/zp/bins broadcast (scalars or per-channel)."""
    code = jnp.round(v / delta) + zp
    code = jnp.clip(code, 0.0, bins)
    return (code - zp) * delta


def _kernel(
    x_ref,
    w_ref,
    rs_ref,
    cs_ref,
    wq_ref,
    sc_ref,
    seed_ref,
    out_ref,
    *,
    noise_kind: str,
    nk: int,
    block: tuple,
    k_total: int,
    quant_x: bool,
    quant_w: bool,
    quant_out: bool,
    n_repeats: int,
):
    bm, bn, bk = block
    ti = pl.program_id(0)
    tj = pl.program_id(1)
    tk = pl.program_id(2)
    sc = sc_ref[...]  # (1, 8) f32 scalars
    seed = seed_ref[...]  # (1, 4) uint32: key words + global tile origin
    k0, k1 = seed[0, 0], seed[0, 1]
    # Global origin of this call's operands in the unsharded problem: a
    # tensor-parallel shard offsets its noise counters so it draws exactly
    # its tile of the global stream ((0, 0) for whole-array calls).
    row0, col0 = seed[0, 2], seed[0, 3]

    @pl.when(tk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    xb = x_ref[...].astype(jnp.float32)
    wb = w_ref[...].astype(jnp.float32)

    if k_total % bk != 0:
        # Mask the K-tail: out-of-bounds block regions are undefined (NaN in
        # interpret mode) and must not feed the accumulation.
        k_idx = jax.lax.broadcasted_iota(jnp.int32, (bm, bk), 1) + tk * bk
        xb = jnp.where(k_idx < k_total, xb, 0.0)
        wk_idx = jax.lax.broadcasted_iota(jnp.int32, (bk, bn), 0) + tk * bk
        wb = jnp.where(wk_idx < k_total, wb, 0.0)

    if quant_x:
        xb = _fake_quant(xb, sc[0, 0], sc[0, 1], sc[0, 2])
    if quant_w:
        wd = wq_ref[0:1, :]  # (1, bn) per-channel delta
        wz = wq_ref[1:2, :]
        wbins = wq_ref[2:3, :]
        wb = _fake_quant(wb, wd, wz, wbins)
    if noise_kind == "weight":
        # std per column lives in cs; counter = (global k, global j); the
        # salt decorrelates this stream from the output-noise stream. With
        # n_repeats > 1 the K independent device reads are averaged here in
        # VMEM — the (K*k, N) tiled weight array never exists.
        xi = prng.repeat_averaged_gaussian_tile(
            k0 ^ jnp.uint32(prng.WEIGHT_STREAM_SALT),
            k1,
            jnp.asarray(tk * bk, jnp.uint32),
            col0 + jnp.asarray(tj * bn, jnp.uint32),
            (bk, bn),
            n_repeats,
        )
        wb = wb + cs_ref[...] * xi

    out_ref[...] += jnp.dot(xb, wb, preferred_element_type=jnp.float32)

    @pl.when(tk == nk - 1)
    def _finish():
        y = out_ref[...]
        if noise_kind == "output":
            # K repeat draws averaged in-register: one matmul pass, zero
            # extra HBM traffic for the dynamic-precision redundancy.
            xi = prng.repeat_averaged_gaussian_tile(
                k0,
                k1,
                row0 + jnp.asarray(ti * bm, jnp.uint32),
                col0 + jnp.asarray(tj * bn, jnp.uint32),
                (bm, bn),
                n_repeats,
            )
            y = y + rs_ref[...] * cs_ref[...] * xi
        if quant_out:
            y = _fake_quant(y, sc[0, 3], sc[0, 4], sc[0, 5])
        out_ref[...] = y


def analog_matmul_raw(
    x: Array,
    w: Array,
    row_scale: Array,
    col_scale: Array,
    wq: Array,
    scalars: Array,
    seed: Array,
    *,
    noise_kind: str = "output",
    quant_x: bool = False,
    quant_w: bool = False,
    quant_out: bool = False,
    n_repeats: int = 1,
    block: tuple = DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
) -> Array:
    """Low-level entry: shapes (M,K) @ (K,N) -> (M,N).

    row_scale: (M, 1) f32; col_scale: (1, N) f32; wq: (3, N) f32 rows =
    (delta, zp, bins); scalars: (1, 8) f32 = (xd, xz, xbins, od, oz, obins,
    0, 0); seed: (1, 4) uint32 = (k0, k1, row0, col0) — key words plus the
    global tile origin of this call in the unsharded problem (tensor-parallel
    shards offset their noise counters; whole-array calls pass (0, 0)).
    ``n_repeats`` (static): average K independent noise draws in-register —
    the fused form of the paper's K-repeat redundancy, with noise std scaled
    by 1/sqrt(K).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert n_repeats >= 1, n_repeats
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    bm, bn, bk = block
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))

    kern = functools.partial(
        _kernel,
        noise_kind=noise_kind,
        nk=grid[2],
        block=(bm, bn, bk),
        k_total=k,
        quant_x=quant_x,
        quant_w=quant_w,
        quant_out=quant_out,
        n_repeats=n_repeats,
    )
    kwargs = {}
    if not interpret:  # TPU compiler hints
        try:
            from jax.experimental.pallas import tpu as pltpu

            params_cls = getattr(pltpu, "CompilerParams", None) or getattr(
                pltpu, "TPUCompilerParams"
            )
            kwargs["compiler_params"] = params_cls(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )
        except Exception:  # pragma: no cover - hint only
            pass

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((3, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, 8), lambda i, j, kk: (0, 0)),
            pl.BlockSpec((1, 4), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
        **kwargs,
    )(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        row_scale.astype(jnp.float32),
        col_scale.astype(jnp.float32),
        wq.astype(jnp.float32),
        scalars.astype(jnp.float32),
        seed.astype(jnp.uint32),
    )
