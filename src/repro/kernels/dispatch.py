"""Backend dispatch: route analog matmuls to the fused Pallas kernel or jnp.

``analog_dot`` (and through it every model hook) calls ``resolve_backend``
to decide where a matmul executes:

  * ``cfg.backend == "pallas"`` — always the fused kernel (interpret mode on
    CPU, compiled on TPU). Also selected by the legacy ``use_kernel=True``.
  * ``cfg.backend == "tile"`` — the pure-jnp *tile oracle*: identical math
    and counter-based noise draws to the Pallas kernel (kernels/ref.py), no
    Pallas. This is the stream tensor-parallel sharding slices — a shard
    salted on its global tile coordinates draws exactly its tile of it — so
    it is also what "auto" picks on CPU whenever a tensor-parallel mesh is
    active (sharded == unsharded stays bit-exact there).
  * ``cfg.backend == "jnp"`` — always the legacy pure-jnp path
    (jax.random-based noise; NOT tiling-invariant, never sharded).
  * ``cfg.backend == "auto"`` (default) — the fused kernel when it is the
    faster choice: analog mode, running on a TPU, and every matmul dimension
    at least ``MIN_PALLAS_DIM`` (MXU tiles are 128-aligned; smaller problems
    gain nothing from the fusion and interpret-mode Pallas on CPU is a
    correctness vehicle, not a fast path). Under an active tensor-parallel
    mesh the per-shard (local) N decides the threshold and the non-TPU /
    small-shape fallback is "tile" instead of "jnp". Everything else falls
    back to the jnp path, bit-compatible with pre-dispatch behavior.

Mesh awareness: resolution consults the ambient logical mesh
(``models/sharding.use_mesh``) — ``active_tp()`` below — and is memoized on
(config, shapes, platform, tp), so with bucketed serving it still resolves
once per bucket per mesh shape.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

Array = jax.Array

AUTO = "auto"
PALLAS = "pallas"
JNP = "jnp"
TILE = "tile"
BACKENDS = (AUTO, PALLAS, JNP, TILE)

#: smallest dimension for which "auto" picks the Pallas kernel.
MIN_PALLAS_DIM = 128

#: mesh axis tensor-parallel matmul shards live on (launch/mesh.py).
TP_AXIS = "model"


def active_tp() -> int:
    """Tensor-parallel shard count of the ambient logical mesh (1 = none)."""
    # Lazy import: core/kernels must not import repro.models at module time.
    from repro.models import sharding as shardlib

    mesh = shardlib.get_mesh()
    if mesh is None:
        return 1
    return int(dict(mesh.shape).get(TP_AXIS, 1))


def active_mesh():
    """The ambient logical mesh, or None (see models/sharding.use_mesh)."""
    from repro.models import sharding as shardlib

    return shardlib.get_mesh()


def resolve_backend(cfg, x_shape: tuple, w_shape: tuple) -> str:
    """Resolve the execution backend for one ``(..., K) @ (K, N)`` matmul.

    Returns ``"pallas"``, ``"tile"``, or ``"jnp"`` (never ``"auto"``).
    Static: depends only on the config, operand *shapes*, platform, and the
    ambient mesh's tensor-parallel factor, so it is jit/vmap safe.

    Memoized on (config, shapes, platform, tp): the serving engine's
    bucketing bounds the distinct shape set, so steady-state serving
    resolves once per bucket, not once per analog_dot call.
    """
    return _resolve_cached(
        cfg, tuple(x_shape), tuple(w_shape), jax.default_backend(), active_tp()
    )


@functools.lru_cache(maxsize=4096)
def _resolve_cached(
    cfg, x_shape: tuple, w_shape: tuple, platform: str, tp: int
) -> str:
    backend = getattr(cfg, "backend", AUTO)
    if backend == PALLAS or (backend == AUTO and getattr(cfg, "use_kernel", False)):
        return PALLAS
    if backend == TILE:
        return TILE
    if backend == JNP:
        return JNP
    if cfg.mode != "analog":
        return JNP
    fallback = TILE if tp > 1 else JNP
    if platform != "tpu":
        return fallback
    m = int(np.prod(x_shape[:-1], dtype=np.int64)) if len(x_shape) > 1 else 1
    k = x_shape[-1]
    n = w_shape[-1]
    if tp > 1 and n % tp == 0:
        n = n // tp  # the per-shard problem is what the kernel sees
    if min(m, k, n) < MIN_PALLAS_DIM:
        return fallback
    return PALLAS


def fused_dot(
    x: Array, w: Array, *, cfg, energy, key, sq=None, n_repeats: int = 1
) -> Array:
    """The Pallas hot path: fused quant -> matmul -> K-repeat noise -> requant."""
    from repro.kernels import ops as kernel_ops

    return kernel_ops.analog_matmul(
        x, w, energy=energy, key=key, cfg=cfg, sq=sq, n_repeats=n_repeats
    )


def tile_dot(
    x: Array, w: Array, *, cfg, energy, key, sq=None, n_repeats: int = 1
) -> Array:
    """The tile oracle: Pallas-identical math + noise draws, pure jnp."""
    from repro.kernels import ops as kernel_ops

    return kernel_ops.analog_matmul_reference(
        x, w, energy=energy, key=key, cfg=cfg, sq=sq, n_repeats=n_repeats
    )
