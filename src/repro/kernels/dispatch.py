"""Backend dispatch: route analog matmuls to the fused Pallas kernel or jnp.

``analog_dot`` (and through it every model hook) calls ``resolve_backend``
to decide where a matmul executes:

  * ``cfg.backend == "pallas"`` — always the fused kernel (interpret mode on
    CPU, compiled on TPU). Also selected by the legacy ``use_kernel=True``.
  * ``cfg.backend == "jnp"`` — always the pure-jnp path.
  * ``cfg.backend == "auto"`` (default) — the fused kernel when it is the
    faster choice: analog mode, running on a TPU, and every matmul dimension
    at least ``MIN_PALLAS_DIM`` (MXU tiles are 128-aligned; smaller problems
    gain nothing from the fusion and interpret-mode Pallas on CPU is a
    correctness vehicle, not a fast path). Everything else falls back to the
    jnp oracle path, which stays bit-compatible with pre-dispatch behavior.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

Array = jax.Array

AUTO = "auto"
PALLAS = "pallas"
JNP = "jnp"
BACKENDS = (AUTO, PALLAS, JNP)

#: smallest dimension for which "auto" picks the Pallas kernel.
MIN_PALLAS_DIM = 128


def resolve_backend(cfg, x_shape: tuple, w_shape: tuple) -> str:
    """Resolve the execution backend for one ``(..., K) @ (K, N)`` matmul.

    Returns ``"pallas"`` or ``"jnp"`` (never ``"auto"``). Static: depends
    only on the config and operand *shapes*, so it is jit/vmap safe.

    Memoized on (config, shapes, platform): the serving engine's bucketing
    bounds the distinct shape set, so steady-state serving resolves once per
    bucket, not once per analog_dot call.
    """
    return _resolve_cached(cfg, tuple(x_shape), tuple(w_shape), jax.default_backend())


@functools.lru_cache(maxsize=4096)
def _resolve_cached(cfg, x_shape: tuple, w_shape: tuple, platform: str) -> str:
    backend = getattr(cfg, "backend", AUTO)
    if backend == PALLAS or (backend == AUTO and getattr(cfg, "use_kernel", False)):
        return PALLAS
    if backend == JNP:
        return JNP
    if cfg.mode != "analog":
        return JNP
    if platform != "tpu":
        return JNP
    m = int(np.prod(x_shape[:-1], dtype=np.int64)) if len(x_shape) > 1 else 1
    k = x_shape[-1]
    n = w_shape[-1]
    if min(m, k, n) < MIN_PALLAS_DIM:
        return JNP
    return PALLAS


def fused_dot(
    x: Array, w: Array, *, cfg, energy, key, sq=None, n_repeats: int = 1
) -> Array:
    """The Pallas hot path: fused quant -> matmul -> K-repeat noise -> requant."""
    from repro.kernels import ops as kernel_ops

    return kernel_ops.analog_matmul(
        x, w, energy=energy, key=key, cfg=cfg, sq=sq, n_repeats=n_repeats
    )
