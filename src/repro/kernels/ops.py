"""Public jit'd wrappers around the fused analog-matmul kernel.

``prepare_operands`` maps the high-level (AnalogConfig, SiteQuant, energy,
key) description onto the kernel's raw operands — precomputed noise scale
vectors, per-channel quantizer vectors, scalar pack, PRNG seed — so the same
preparation feeds both the Pallas kernel and the pure-jnp oracle.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import noise as noise_lib
from repro.kernels import prng
from repro.kernels.analog_matmul import DEFAULT_BLOCK, analog_matmul_raw
from repro.kernels.ref import analog_matmul_ref_raw

Array = jax.Array


def _ranges(sq, w, x) -> Tuple[Array, Array]:
    if sq is not None and sq.wqp is not None:
        w_rng = (sq.wqp.x_max - sq.wqp.x_min).astype(jnp.float32).reshape(1, -1)
    else:
        w_rng = (jnp.max(w, axis=0) - jnp.min(w, axis=0)).reshape(1, -1)
    if sq is not None and sq.xqp is not None:
        x_rng = (sq.xqp.x_max - sq.xqp.x_min).astype(jnp.float32)
    else:
        x_rng = jnp.max(x) - jnp.min(x)
    return w_rng, jnp.asarray(x_rng, jnp.float32)


def prepare_operands(
    x2d: Array, w: Array, *, energy, key, cfg, sq=None, offsets=(0, 0)
) -> dict:
    """Compute raw kernel operands from the analog execution description.

    ``offsets = (row0, col0)`` is the global tile origin of this call's
    operands in the unsharded problem: a tensor-parallel shard holding
    columns ``[col0, col0 + n)`` of the full weight passes its column offset
    so the counter-based noise it draws is exactly its tile of the global
    stream (the whole-array call at ``(0, 0)`` is unchanged). Offsets may be
    traced values (e.g. ``axis_index * n_local`` inside ``shard_map``).
    """
    m, k = x2d.shape
    _, n = w.shape
    energy = jnp.asarray(energy, jnp.float32)
    if cfg.discrete_energy:
        from repro.quant.affine import ste_snap_levels

        energy = ste_snap_levels(energy, cfg.energy_quantum)
    e_col = jnp.broadcast_to(energy.reshape(1, -1), (1, n))

    kind = cfg.noise.kind
    ones_row = jnp.ones((m, 1), jnp.float32)
    if kind == noise_lib.THERMAL:
        w_rng, x_rng = _ranges(sq, w, x2d)
        col = noise_lib.thermal_noise_std(k, w_rng, x_rng, cfg.noise.sigma, e_col)
        row = ones_row
        noise_kind = "output"
    elif kind == noise_lib.SHOT:
        w_col = jnp.linalg.norm(w.astype(jnp.float32), axis=0).reshape(1, -1)
        photons = e_col / cfg.noise.photon_energy_aj
        col = w_col / jnp.sqrt(jnp.float32(k) * photons)
        row = jnp.linalg.norm(x2d.astype(jnp.float32), axis=-1, keepdims=True)
        noise_kind = "output"
    elif kind == noise_lib.WEIGHT:
        w_rng, _ = _ranges(sq, w, x2d)
        col = noise_lib.weight_noise_std(w_rng, cfg.noise.sigma, e_col)
        row = ones_row
        noise_kind = "weight"
    else:
        col = jnp.zeros((1, n), jnp.float32)
        row = ones_row
        noise_kind = "none"

    quant_w = cfg.weight_bits is not None and sq is not None and sq.wqp is not None
    quant_x = cfg.act_bits is not None and sq is not None and sq.xqp is not None
    quant_out = cfg.out_bits is not None and sq is not None and sq.oqp is not None

    if quant_w:
        wd = jnp.broadcast_to(sq.wqp.delta.reshape(1, -1), (1, n))
        wz = jnp.broadcast_to(sq.wqp.zero_point.reshape(1, -1), (1, n))
        wb = jnp.broadcast_to(jnp.reshape(sq.wqp.n_bins, (1, 1)), (1, n))
        wq = jnp.concatenate([wd, wz, wb], axis=0)
    else:
        wq = jnp.ones((3, n), jnp.float32)

    def _sq_scalars(qp):
        if qp is None:
            return jnp.ones(()), jnp.zeros(()), jnp.ones(())
        return (
            jnp.reshape(qp.delta, ()),
            jnp.reshape(qp.zero_point, ()),
            jnp.reshape(qp.n_bins, ()),
        )

    xd, xz, xb = _sq_scalars(sq.xqp if (quant_x and sq) else None)
    od, oz, ob = _sq_scalars(sq.oqp if (quant_out and sq) else None)
    scalars = jnp.stack([xd, xz, xb, od, oz, ob, jnp.zeros(()), jnp.zeros(())]).reshape(1, 8)

    k0, k1 = prng.key_to_words(key)
    row0 = jnp.asarray(offsets[0], jnp.int32).astype(jnp.uint32).reshape(())
    col0 = jnp.asarray(offsets[1], jnp.int32).astype(jnp.uint32).reshape(())
    seed = jnp.stack([k0, k1, row0, col0]).reshape(1, 4)

    return dict(
        x=x2d,
        w=w,
        row_scale=row,
        col_scale=col,
        wq=wq,
        scalars=scalars,
        seed=seed,
        noise_kind=noise_kind,
        quant_x=quant_x,
        quant_w=quant_w,
        quant_out=quant_out,
    )


def analog_matmul(
    x: Array,
    w: Array,
    *,
    energy,
    key,
    cfg,
    sq=None,
    n_repeats: int = 1,
    block: tuple = DEFAULT_BLOCK,
    interpret: Optional[bool] = None,
    offsets=(0, 0),
) -> Array:
    """Fused analog matmul for arbitrary batch dims: (..., K) @ (K, N).

    ``n_repeats``: static K-repeat redundancy (paper §IV) fused into the
    kernel — one matmul pass whose noise is the in-register average of K
    independent draws at the given (base) energy. ``offsets``: global
    (row0, col0) tile origin for tensor-parallel shards (see
    ``prepare_operands``).
    """
    batch_shape = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    ops = prepare_operands(
        x2d, w, energy=energy, key=key, cfg=cfg, sq=sq, offsets=offsets
    )
    kind = ops.pop("noise_kind")
    qx, qw, qo = ops.pop("quant_x"), ops.pop("quant_w"), ops.pop("quant_out")
    y = analog_matmul_raw(
        ops["x"],
        ops["w"],
        ops["row_scale"],
        ops["col_scale"],
        ops["wq"],
        ops["scalars"],
        ops["seed"],
        noise_kind=kind,
        quant_x=qx,
        quant_w=qw,
        quant_out=qo,
        n_repeats=n_repeats,
        block=block,
        interpret=interpret,
    )
    return y.reshape(*batch_shape, w.shape[1])


def analog_matmul_reference(
    x: Array, w: Array, *, energy, key, cfg, sq=None, n_repeats: int = 1, offsets=(0, 0)
) -> Array:
    """Oracle with identical noise draws (pure jnp, no Pallas)."""
    batch_shape = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    ops = prepare_operands(
        x2d, w, energy=energy, key=key, cfg=cfg, sq=sq, offsets=offsets
    )
    kind = ops.pop("noise_kind")
    qx, qw, qo = ops.pop("quant_x"), ops.pop("quant_w"), ops.pop("quant_out")
    y = analog_matmul_ref_raw(
        ops["x"],
        ops["w"],
        ops["row_scale"],
        ops["col_scale"],
        ops["wq"],
        ops["scalars"],
        ops["seed"],
        noise_kind=kind,
        quant_x=qx,
        quant_w=qw,
        quant_out=qo,
        n_repeats=n_repeats,
    )
    return y.reshape(*batch_shape, w.shape[1])
