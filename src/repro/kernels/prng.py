"""Counter-based PRNG (Threefry-2x32, 20 rounds) + Box-Muller gaussians.

Pure ``jnp`` uint32 arithmetic, so the SAME code traces both inside Pallas
kernel bodies (register-resident noise generation — no HBM traffic for the
noise tensor) and in the pure-jnp oracle (`kernels/ref.py`), giving bit-exact
kernel-vs-reference parity.

Counter convention: one gaussian per output element, counter words =
(global_row_index, global_col_index), key words = derived from the JAX PRNG
key (+ a salt to decorrelate weight-noise draws from output-noise draws).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_ROT_A = (13, 15, 26, 6)
_ROT_B = (17, 29, 16, 24)
_PARITY = 0x1BD11BDA  # python int: jnp constants can't be closure-captured in Pallas
#: salt xored into the key for the weight-noise stream.
WEIGHT_STREAM_SALT = 0x9E3779B9
#: multiplier folded into the key word per repeat index (K-repeat averaging).
REPEAT_STREAM_MULT = 0x85EBCA6B


def _rotl(x: Array, d: int) -> Array:
    return (x << jnp.uint32(d)) | (x >> jnp.uint32(32 - d))


def _rounds(x0: Array, x1: Array, rots) -> tuple[Array, Array]:
    for d in rots:
        x0 = x0 + x1
        x1 = _rotl(x1, d)
        x1 = x1 ^ x0
    return x0, x1


def threefry2x32(k0: Array, k1: Array, c0: Array, c1: Array) -> tuple[Array, Array]:
    """Full 20-round Threefry-2x32. All args uint32, broadcastable."""
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(c0, jnp.uint32)
    x1 = jnp.asarray(c1, jnp.uint32)
    ks2 = k0 ^ k1 ^ jnp.uint32(_PARITY)

    x0 = x0 + k0
    x1 = x1 + k1
    x0, x1 = _rounds(x0, x1, _ROT_A)
    x0 = x0 + k1
    x1 = x1 + ks2 + jnp.uint32(1)
    x0, x1 = _rounds(x0, x1, _ROT_B)
    x0 = x0 + ks2
    x1 = x1 + k0 + jnp.uint32(2)
    x0, x1 = _rounds(x0, x1, _ROT_A)
    x0 = x0 + k0
    x1 = x1 + k1 + jnp.uint32(3)
    x0, x1 = _rounds(x0, x1, _ROT_B)
    x0 = x0 + k1
    x1 = x1 + ks2 + jnp.uint32(4)
    x0, x1 = _rounds(x0, x1, _ROT_A)
    x0 = x0 + ks2
    x1 = x1 + k0 + jnp.uint32(5)
    return x0, x1


def bits_to_unit_open(bits: Array) -> Array:
    """uint32 -> float32 in (0, 1]: 1 - (bits >> 8) * 2^-24."""
    u = (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)
    return jnp.float32(1.0) - u


def bits_to_unit_halfopen(bits: Array) -> Array:
    """uint32 -> float32 in [0, 1)."""
    return (bits >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(2.0**-24)


def counter_gaussian(k0: Array, k1: Array, c0: Array, c1: Array) -> Array:
    """One standard gaussian per (c0, c1) counter pair via Box-Muller."""
    b0, b1 = threefry2x32(k0, k1, c0, c1)
    u1 = bits_to_unit_open(b0)  # (0, 1] so log() is finite
    u2 = bits_to_unit_halfopen(b1)
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(u1))
    theta = jnp.float32(2.0 * 3.14159265358979) * u2
    return r * jnp.cos(theta)


def gaussian_tile(
    k0: Array, k1: Array, row0: Array, col0: Array, shape: tuple[int, int]
) -> Array:
    """Gaussian tile for global element indices [row0:row0+m, col0:col0+n).

    Pure function of the *global* indices — independent of how the output is
    tiled, which is what makes kernel and oracle agree for any BlockSpec.
    """
    m, n = shape
    r0 = jnp.asarray(row0, jnp.int32).astype(jnp.uint32)
    c0 = jnp.asarray(col0, jnp.int32).astype(jnp.uint32)
    rows = jax.lax.broadcasted_iota(jnp.uint32, (m, n), 0) + r0
    cols = jax.lax.broadcasted_iota(jnp.uint32, (m, n), 1) + c0
    return counter_gaussian(k0, k1, rows, cols)


def repeat_key(k1: Array, r: int) -> Array:
    """Second key word for repeat stream ``r`` of a K-repeat averaged op.

    ``r`` is a static Python int. ``r = 0`` returns ``k1`` unchanged, so the
    K=1 stream coincides bit-for-bit with the single-draw stream.
    """
    return jnp.asarray(k1, jnp.uint32) ^ jnp.uint32((r * REPEAT_STREAM_MULT) & 0xFFFFFFFF)


def repeat_averaged_gaussian_tile(
    k0: Array,
    k1: Array,
    row0: Array,
    col0: Array,
    shape: tuple[int, int],
    n_repeats: int,
) -> Array:
    """Mean of ``n_repeats`` independent gaussian tiles, one per repeat stream.

    This is the in-register noise of the fused dynamic-precision kernel
    (paper §IV: repeat the analog op K times and average -> std / sqrt(K)).
    The sequential accumulation order (r = 0..K-1) and the final
    ``float32(1/K)`` scale are part of the contract: the Pallas kernel and the
    pure-jnp oracle both call this function, which is what makes their
    repeat-averaged draws bit-exact for any output tiling.
    """
    xi = gaussian_tile(k0, k1, row0, col0, shape)
    for r in range(1, n_repeats):
        xi = xi + gaussian_tile(k0, repeat_key(k1, r), row0, col0, shape)
    if n_repeats > 1:
        xi = xi * jnp.float32(1.0 / n_repeats)
    return xi


def key_to_words(key: jax.Array) -> tuple[Array, Array]:
    """JAX PRNG key (typed or raw uint32 pair) -> two uint32 key words."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    else:
        data = key
    data = data.reshape(-1).astype(jnp.uint32)
    if data.size == 1:
        return jnp.uint32(0), data[0]
    return data[0], data[1]
