"""Pure-jnp oracle for the fused analog-matmul kernel.

Implements the same math as ``analog_matmul.py`` on full arrays — including
the identical counter-based gaussians keyed on *global* element indices and
the identical K-repeat averaged draws (``n_repeats``) — so the tests can
assert elementwise agreement for any BlockSpec tiling and any K. This file
contains no Pallas.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import prng

Array = jax.Array


def _fake_quant(v, delta, zp, bins):
    code = jnp.round(v / delta) + zp
    code = jnp.clip(code, 0.0, bins)
    return (code - zp) * delta


def analog_matmul_ref_raw(
    x: Array,
    w: Array,
    row_scale: Array,
    col_scale: Array,
    wq: Array,
    scalars: Array,
    seed: Array,
    *,
    noise_kind: str = "output",
    quant_x: bool = False,
    quant_w: bool = False,
    quant_out: bool = False,
    n_repeats: int = 1,
) -> Array:
    m, k = x.shape
    _, n = w.shape
    sc = scalars.astype(jnp.float32)
    seed = seed.astype(jnp.uint32)
    k0, k1 = seed[0, 0], seed[0, 1]
    # Global tile origin of this operand block in the unsharded problem.
    # (0, 0) for a whole-array call; a tensor-parallel shard passes its
    # column offset so it draws exactly its tile of the global noise stream.
    row0, col0 = seed[0, 2], seed[0, 3]
    x = x.astype(jnp.float32)
    w = w.astype(jnp.float32)

    if quant_x:
        x = _fake_quant(x, sc[0, 0], sc[0, 1], sc[0, 2])
    if quant_w:
        w = _fake_quant(w, wq[0:1, :], wq[1:2, :], wq[2:3, :])
    if noise_kind == "weight":
        xi = prng.repeat_averaged_gaussian_tile(
            k0 ^ jnp.uint32(prng.WEIGHT_STREAM_SALT), k1, 0, col0, (k, n), n_repeats
        )
        w = w + col_scale.astype(jnp.float32) * xi

    y = jnp.dot(x, w, preferred_element_type=jnp.float32)

    if noise_kind == "output":
        xi = prng.repeat_averaged_gaussian_tile(k0, k1, row0, col0, (m, n), n_repeats)
        y = y + row_scale.astype(jnp.float32) * col_scale.astype(jnp.float32) * xi
    if quant_out:
        y = _fake_quant(y, sc[0, 3], sc[0, 4], sc[0, 5])
    return y
