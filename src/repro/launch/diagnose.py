import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
# ^ before any jax import (same contract as dryrun.py).

"""HLO diagnosis: the tool behind every §Perf iteration.

Lowers one (arch x shape x mesh) cell and prints the top collectives
(scan-multiplied, with replica-group sizes) and the largest tensors in the
module — the two lists that localize sharding pathologies (full-array
gathers, per-iteration all-reduces, hoisted f32 stacks).

Usage:
  python -m repro.launch.diagnose --arch grok-1-314b --shape train_4k [--mesh multi]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    import jax

    from repro.configs import SHAPES, get_config, input_specs
    from repro.launch.hlo_analysis import (
        _group_size,
        _nbytes,
        multipliers,
        parse_computations,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import TrainConfig, make_decode_step, make_prefill_step, make_train_step
    from repro.models import lm
    from repro.models.sharding import use_mesh
    from repro.optim.adam import adam_init

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    with use_mesh(mesh):
        batch_specs = input_specs(cfg, shape)
        p_specs = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        if shape.kind == "train":
            tcfg = TrainConfig()
            _, jit_for, _ = make_train_step(cfg, mesh, tcfg)
            o_specs = jax.eval_shape(lambda p: adam_init(p, tcfg.adam()), p_specs)
            compiled = jit_for(batch_specs).lower(p_specs, o_specs, batch_specs).compile()
        elif shape.kind == "prefill":
            _, jit_for, _ = make_prefill_step(cfg, mesh, cache_len=shape.seq_len)
            compiled = jit_for(batch_specs).lower(p_specs, batch_specs, None, None).compile()
        else:
            _, jit_for, _ = make_decode_step(cfg, mesh)
            c_specs = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len)
            )
            compiled = (
                jit_for(batch_specs, shape.seq_len)
                .lower(p_specs, c_specs, batch_specs, shape.seq_len - 1, None, None)
                .compile()
            )

    comps = parse_computations(compiled.as_text())
    mult = multipliers(comps)
    colls, temps = [], []
    kinds = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0)
        for op in comp.order:
            nb = _nbytes(op.type_text)
            if op.kind in kinds:
                colls.append((m * nb, op.kind, nb, m, _group_size(op.args_text, mesh.size),
                              op.type_text[:48], cname[:40]))
            if nb > 1e9:
                temps.append((nb, op.kind, op.type_text[:60], cname[:40]))

    print(f"== top collectives ({args.arch} {args.shape} {args.mesh}) ==")
    for tot, kind, nb, m, g, t, c in sorted(colls, reverse=True)[: args.top]:
        print(f"{kind:14s} {nb/1e6:9.1f}MB x{m:6.0f} = {tot/1e9:8.1f}GB g={g:3d} {t:48s} {c}")
    print("== largest tensors ==")
    seen = set()
    for nb, kind, t, c in sorted(temps, reverse=True):
        if (kind, t) in seen:
            continue
        seen.add((kind, t))
        print(f"{nb/1e9:6.1f}GB {kind:18s} {t} in {c}")
        if len(seen) >= args.top:
            break


if __name__ == "__main__":
    main()
