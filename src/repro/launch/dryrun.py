import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init). Only this launcher forces 512 host devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
single-pod (16,16) and multi-pod (2,16,16) production meshes, and record
memory_analysis / cost_analysis / scan-corrected HLO stats as JSON
artifacts for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--jobs 2] [--skip-existing]
  python -m repro.launch.dryrun --summarize   # print the cell table
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "artifacts", "dryrun")


def _artifact_path(arch, shape, mesh_name, variant=""):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    sfx = f"__{variant}" if variant else ""
    return os.path.join(ARTIFACT_DIR, f"{arch}__{shape}__{mesh_name}{sfx}.json")


def run_cell(arch: str, shape_name: str, mesh_name: str, analog: str = "none",
             microbatch: int = 1, causal_skip: bool = False,
             kv_dtype: str = None, profile: str = None,
             capacity_factor: float = None, int8_weights: bool = False) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, input_specs, shape_applicable, SHAPES
    from repro.core.analog import AnalogConfig
    from repro.launch import hlo_analysis, roofline
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (
        TrainConfig,
        make_calibrate_step,
        make_decode_step,
        make_prefill_step,
        make_train_step,
    )
    from repro.models import lm
    from repro.models.sharding import use_mesh

    import dataclasses as _dc

    cfg = get_config(arch)
    if causal_skip:
        cfg = _dc.replace(cfg, causal_skip=True)
    if profile:
        cfg = _dc.replace(cfg, sharding_profile=profile)
    if capacity_factor:
        cfg = _dc.replace(cfg, capacity_factor=capacity_factor)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "skipped", "reason": why,
        }

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    n_dev = mesh.size
    cache_bytes = None
    analog_cfg = None
    if analog == "shot":
        analog_cfg = AnalogConfig.shot()

    t0 = time.time()
    with use_mesh(mesh):
        batch_specs = input_specs(cfg, shape)
        p_specs = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
        params_bytes = None
        if int8_weights:
            from repro.quant.weights import quantize_params

            p_specs = jax.eval_shape(quantize_params, p_specs)
            import math as _m

            params_bytes = sum(
                _m.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(p_specs)
            )
        key_spec = jax.ShapeDtypeStruct((2,), jnp.uint32)

        if shape.kind == "train":
            tcfg = TrainConfig(microbatches=microbatch)
            _, jit_for, _ = make_train_step(cfg, mesh, tcfg)
            from repro.optim.adam import adam_init

            o_specs = jax.eval_shape(lambda p: adam_init(p, tcfg.adam()), p_specs)
            jitted = jit_for(batch_specs)
            lowered = jitted.lower(p_specs, o_specs, batch_specs)
        elif shape.kind == "prefill":
            _, jit_for, _ = make_prefill_step(
                cfg, mesh, cache_len=shape.seq_len, analog_cfg=analog_cfg,
                param_tree=p_specs if int8_weights else None,
            )
            jitted = jit_for(batch_specs)
            e_specs = (
                jax.eval_shape(lambda: lm.init_energy_tree(cfg, 1.0))
                if analog_cfg is not None
                else None
            )
            lowered = jitted.lower(p_specs, batch_specs, e_specs, key_spec if analog_cfg else None)
        else:  # decode
            _, jit_for, _ = make_decode_step(
                cfg, mesh, analog_cfg=analog_cfg,
                param_tree=p_specs if int8_weights else None,
            )
            jitted = jit_for(batch_specs, shape.seq_len)
            cache_dt = jnp.dtype(kv_dtype) if kv_dtype else None
            c_specs = jax.eval_shape(
                lambda: lm.init_cache(cfg, shape.global_batch, shape.seq_len, dtype=cache_dt)
            )
            e_specs = (
                jax.eval_shape(lambda: lm.init_energy_tree(cfg, 1.0))
                if analog_cfg is not None
                else None
            )
            pos = shape.seq_len - 1
            lowered = jitted.lower(
                p_specs, c_specs, batch_specs, pos, e_specs, key_spec if analog_cfg else None
            )
            import math as _math

            cache_bytes = sum(
                _math.prod(l.shape) * l.dtype.itemsize
                for l in jax.tree.leaves(c_specs)
            )
        lower_s = time.time() - t0

        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    mem = compiled.memory_analysis()
    cost = dict(compiled.cost_analysis() or {})
    hlo_text = compiled.as_text()
    stats = hlo_analysis.analyze(hlo_text, n_dev)
    rt = roofline.terms(
        cfg,
        shape,
        n_dev,
        hlo_dot_flops=stats.dot_flops,
        collective_link_bytes=stats.total_collective_bytes,
        cache_bytes_global=cache_bytes,
        param_bytes_global=params_bytes,
    )

    per_dev_bytes = {
        "argument": getattr(mem, "argument_size_in_bytes", 0),
        "output": getattr(mem, "output_size_in_bytes", 0),
        "temp": getattr(mem, "temp_size_in_bytes", 0),
        "alias": getattr(mem, "alias_size_in_bytes", 0),
    }
    peak = per_dev_bytes["argument"] + per_dev_bytes["temp"] + per_dev_bytes["output"] - per_dev_bytes["alias"]
    art = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "analog": analog,
        "microbatch": microbatch,
        "causal_skip": causal_skip,
        "kv_dtype": kv_dtype,
        "profile": profile,
        "capacity_factor": capacity_factor,
        "int8_weights": int8_weights,
        "status": "ok",
        "n_devices": n_dev,
        "step_kind": shape.kind,
        "lower_s": round(lower_s, 2),
        "compile_s": round(compile_s, 2),
        "memory_analysis": per_dev_bytes,
        "peak_bytes_per_device": peak,
        "fits_16gb": bool(peak < roofline.V5E["hbm_bytes"]),
        "cost_analysis_raw": {
            k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost
        },
        "hlo": {
            "dot_flops_per_device": stats.dot_flops,
            "collective_link_bytes_per_device": stats.total_collective_bytes,
            "collective_bytes_by_kind": stats.collective_bytes,
            "collective_counts": stats.n_collectives,
        },
        "roofline": rt.as_dict(),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return art


CELL_ANALOG_EXTRAS = [
    # (arch, shape) cells additionally lowered with analog shot-noise serving
    ("granite-3-8b", "decode_32k"),
    ("llama4-maverick-400b-a17b", "decode_32k"),
]


def all_cells(meshes):
    from repro.configs import ARCHS, SHAPES

    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            for m in meshes:
                cells.append((arch, shape, m, "none"))
    for arch, shape in CELL_ANALOG_EXTRAS:
        for m in meshes:
            cells.append((arch, shape, m, "shot"))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--analog", default="none", choices=["none", "shot"])
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--kv-dtype", default=None)
    ap.add_argument("--profile", default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--int8-weights", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--summarize", action="store_true")
    args = ap.parse_args()

    if args.summarize:
        summarize()
        return

    if args.all:
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        cells = all_cells(meshes)
        if args.skip_existing:
            cells = [
                c for c in cells
                if not os.path.exists(_artifact_path(c[0], c[1], c[2], c[3] if c[3] != "none" else ""))
            ]
        print(f"running {len(cells)} cells with {args.jobs} workers")

        def run_sub(cell):
            arch, shape, mesh_name, analog = cell
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape, "--mesh", mesh_name,
                "--analog", analog,
            ]
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True, timeout=7200)
            dt = time.time() - t0
            status = "OK" if r.returncode == 0 else "FAIL"
            print(f"[{status}] {arch} {shape} {mesh_name} {analog} ({dt:.0f}s)")
            if r.returncode != 0:
                print(r.stderr[-2000:])
            return r.returncode

        with ThreadPoolExecutor(max_workers=args.jobs) as ex:
            codes = list(ex.map(run_sub, cells))
        print(f"done: {codes.count(0)}/{len(codes)} ok")
        sys.exit(0 if all(c == 0 for c in codes) else 1)

    art = run_cell(args.arch, args.shape, args.mesh, args.analog,
                   microbatch=args.microbatch, causal_skip=args.causal_skip,
                   kv_dtype=args.kv_dtype, profile=args.profile,
                   capacity_factor=args.capacity_factor,
                   int8_weights=args.int8_weights)
    variant = args.analog if args.analog != "none" else ""
    if args.tag:
        variant = (variant + "_" if variant else "") + args.tag
    path = _artifact_path(args.arch, args.shape, args.mesh, variant)
    with open(path, "w") as f:
        json.dump(art, f, indent=2)
    if art["status"] == "ok":
        print(f"{args.arch} {args.shape} {args.mesh}: compile {art['compile_s']}s, "
              f"peak/dev {art['peak_bytes_per_device']/1e9:.2f} GB, fits={art['fits_16gb']}")
        print("memory_analysis:", art["memory_analysis"])
        print("cost_analysis:", art["cost_analysis_raw"])
        r = art["roofline"]
        print(f"roofline: compute {r['compute_s']:.4f}s memory {r['memory_s']:.4f}s "
              f"collective {r['collective_s']:.4f}s dominant={r['dominant']} "
              f"useful_ratio={r['useful_ratio']:.3f}")
    else:
        print(f"SKIPPED: {art['reason']}")


def summarize():
    rows = []
    for name in sorted(os.listdir(ARTIFACT_DIR)):
        if name.endswith(".json"):
            rows.append(json.load(open(os.path.join(ARTIFACT_DIR, name))))
    cols = "arch shape mesh analog status compile_s peak_GB fits dominant useful"
    print(cols)
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']} {r['shape']} {r['mesh']} - SKIP ({r['reason'][:40]})")
            continue
        rf = r["roofline"]
        print(
            f"{r['arch']} {r['shape']} {r['mesh']} {r.get('analog','none')} ok "
            f"{r['compile_s']} {r['peak_bytes_per_device']/1e9:.2f} {r['fits_16gb']} "
            f"{rf['dominant']} {rf['useful_ratio']:.3f}"
        )


if __name__ == "__main__":
    main()
