"""Scan-aware HLO text analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, not
(trip count) times — our layer stacks, attention chunking and loss chunking
are all ``lax.scan``s, so raw cost numbers undercount by 10-100x. This module
parses the *post-optimization* HLO text instead:

  * builds the computation call graph (while bodies, fusions, calls),
  * extracts each while loop's trip count from its condition computation,
  * propagates execution multipliers down the graph,
  * sums dot FLOPs (2 * prod(result shape) * contracted size) and
    collective bytes (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) weighted by those multipliers.

All numbers are PER-DEVICE (the HLO is the SPMD-partitioned module). Ring
factors convert collective sizes into per-device link bytes:
  all-reduce 2(g-1)/g * size | all-gather, reduce-scatter, all-to-all
  (g-1)/g * size | collective-permute 1 * size.
Validated against cost_analysis on unrolled (scan-free) modules in tests.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")


def _parse_shape(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All (dtype, dims) found in a type string (handles tuples)."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(x) for x in m.group(2).split(",") if x)
        out.append((dt, dims))
    return out


def _nbytes(text: str) -> int:
    return sum(
        _DTYPE_BYTES[dt] * int(math.prod(dims)) for dt, dims in _parse_shape(text)
    )


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    type_text: str
    args_text: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[Op]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        # computation headers start at column 0 and end with '{'
        if (line.startswith("%") or line.startswith("ENTRY")) and stripped.endswith("{"):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = Computation(m.group(1), {}, [])
                comps[cur.name] = cur
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = cur
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(3), m.group(2), m.group(4))
            cur.ops[op.name] = op
            cur.order.append(op)
    return comps


def _called(args_text: str, key: str) -> List[str]:
    """computation names referenced as key=%name (or to_apply/calls etc.)."""
    return re.findall(rf"{key}=%?([\w\.\-]+)", args_text)


def _const_value(op: Op) -> Optional[int]:
    m = re.search(r"^(\d+)\)", op.args_text)
    return int(m.group(1)) if m else None


def _trip_count(cond: Computation, comps: Dict[str, Computation]) -> int:
    """Scan conditions compare the loop counter against the trip count.

    Resolve the CONSTANT OPERAND of the root comparison (possibly through a
    wrapping fusion); fall back to the max s32 constant in the computation.
    """
    root = cond.order[-1] if cond.order else None
    if root is not None:
        # operands of the root (compare or fusion-of-compare)
        for name in re.findall(r"%([\w\.\-]+)", root.args_text):
            op = cond.ops.get(name)
            if op is not None and op.kind == "constant":
                v = _const_value(op)
                if v is not None and v > 0:
                    return v
    best = 1
    for op in cond.order:
        if op.kind == "constant":
            v = _const_value(op)
            if v is not None:
                best = max(best, v)
    return best


def multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """Execution-count multiplier per computation (entry = 1)."""
    mult: Dict[str, float] = defaultdict(float)
    entry = comps.get("__entry__")
    if entry is None:
        return {}
    mult[entry.name] = 1.0
    # propagate in passes (call graph is a DAG; few levels deep)
    for _ in range(16):
        changed = False
        for cname, comp in comps.items():
            if cname == "__entry__" or mult[cname] == 0.0:
                continue
            m = mult[cname]
            for op in comp.order:
                if op.kind == "while":
                    bodies = _called(op.args_text, "body")
                    conds = _called(op.args_text, "condition")
                    trip = (
                        _trip_count(comps[conds[0]], comps)
                        if conds and conds[0] in comps
                        else 1
                    )
                    for b in bodies:
                        new = m * trip
                        if abs(mult[b] - new) > 1e-9:
                            mult[b] = new
                            changed = True
                else:
                    for key in ("calls", "to_apply", "branch_computations"):
                        for c in _called(op.args_text, key):
                            if c in comps and abs(mult[c] - m) > 1e-9 and mult[c] < m:
                                mult[c] = m
                                changed = True
        if not changed:
            break
    return dict(mult)


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _link_bytes(kind: str, result_bytes: float, g: int) -> float:
    """Per-device link bytes (ring algorithms) given the HLO *result* size.

    all-reduce: in==out==S, ring = 2S(g-1)/g.
    all-gather: out=S is the gathered tensor; ring receives S(g-1)/g.
    reduce-scatter: out=S is the scattered shard; input is S*g; ring moves
      S*(g-1) per device.
    all-to-all: out=S; each device exchanges S(g-1)/g.
    collective-permute: S.
    """
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    return result_bytes


def _group_size(args_text: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", args_text)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", args_text)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _operand_shape(op: Op, comp: Computation) -> Optional[str]:
    """Type text of the first operand.

    Handles both HLO print dialects: typed operands
    (``dot(f32[16,16]{1,0} %x, ...)``) carry the shape inline; untyped
    (``dot(%x, ...)``) require a lookup in the same computation.
    """
    m = re.match(r"\s*(\w+\[[\d,]*\]\S*)\s", op.args_text)
    if m and _parse_shape(m.group(1)):
        return m.group(1)
    m = re.match(r"\s*%?([\w\.\-]+)", op.args_text)
    if m and m.group(1) in comp.ops:
        return comp.ops[m.group(1)].type_text
    return None


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    collective_bytes: Dict[str, float]  # per-kind, ring-factored link bytes
    collective_raw_bytes: Dict[str, float]  # per-kind, plain operand bytes
    n_collectives: Dict[str, int]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo: str, total_devices: int) -> HloStats:
    comps = parse_computations(hlo)
    mult = multipliers(comps)
    dot_flops = 0.0
    coll = defaultdict(float)
    coll_raw = defaultdict(float)
    n_coll = defaultdict(int)

    for cname, comp in comps.items():
        if cname == "__entry__":
            continue
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in comp.order:
            if op.kind == "dot":
                out_elems = sum(math.prod(d) for _, d in _parse_shape(op.type_text))
                # contracted size from lhs shape and contracting dims
                lhs_t = _operand_shape(op, comp)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.args_text)
                csize = 1
                if lhs_t and cdims:
                    shapes = _parse_shape(lhs_t)
                    if shapes:
                        dims = shapes[0][1]
                        for ci in cdims.group(1).split(","):
                            if ci:
                                csize *= dims[int(ci)]
                dot_flops += m * 2.0 * out_elems * csize
            elif op.kind in _COLLECTIVES:
                g = _group_size(op.args_text, total_devices)
                if g <= 1:
                    continue
                size = _nbytes(op.type_text)
                in_size = size / g if op.kind == "all-gather" else size
                coll_raw[op.kind] += m * in_size
                coll[op.kind] += m * _link_bytes(op.kind, size, g)
                n_coll[op.kind] += 1
    return HloStats(
        dot_flops=dot_flops,
        collective_bytes=dict(coll),
        collective_raw_bytes=dict(coll_raw),
        n_collectives=dict(n_coll),
    )
