"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (smoke tests, benches) sees the real single device.

Topology: one TPU v5e pod = 16x16 = 256 chips. Single-pod mesh is
("data", "model") = (16, 16); the multi-pod mesh adds a leading "pod" axis
(DCN between pods): ("pod", "data", "model") = (2, 16, 16) = 512 chips.
TP ("model") stays intra-pod on ICI; batch/ZeRO sharding spans pod x data.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto
    AxisType = None


def _mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # make_mesh predates the axis_types kwarg
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh_for_devices(n_devices: int, model_parallel: int = 1) -> Mesh:
    """Elastic helper: an (n/model, model) mesh over however many devices the
    runtime currently has (used by the fault-tolerance / resize paths)."""
    assert n_devices % model_parallel == 0, (n_devices, model_parallel)
    return _mesh((n_devices // model_parallel, model_parallel), ("data", "model"))


def make_local_mesh() -> Mesh:
    """1-device mesh with production axis names: smoke tests exercise the
    exact sharded code paths with every constraint a no-op."""
    return _mesh((1, 1), ("data", "model"))
