"""Roofline terms for TPU v5e from dry-run artifacts.

Hardware constants (per chip): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI, 16 GB HBM.

Three terms per (arch x shape x mesh), all in seconds per step:

  compute    = HLO_dot_FLOPs_per_device / peak_FLOPs
  memory     = HBM_traffic_per_device / hbm_bw
  collective = HLO_collective_link_bytes_per_device / link_bw

Sources: FLOPs and collective bytes come from the scan-corrected HLO parse
(launch/hlo_analysis.py — raw cost_analysis counts scan bodies once, see
EXPERIMENTS.md §Methodology). HBM traffic uses an analytic per-step model
(weights/optimizer/cache/activation-boundary traffic; formulas below),
cross-checked against cost_analysis 'bytes accessed' on scan-free smoke
modules. MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (fwd-only).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.shapes import ShapeSpec
from repro.models.config import ModelConfig

V5E = dict(
    peak_flops=197e12,  # bf16
    hbm_bw=819e9,
    link_bw=50e9,
    hbm_bytes=16e9,
)


def matmul_param_count(cfg: ModelConfig, active_only: bool = True) -> int:
    """Params that participate in matmuls (embedding gather excluded;
    lm_head included — tied or not, the logits matmul runs)."""
    n = cfg.active_param_count() if active_only else cfg.param_count()
    n -= cfg.vocab_size * cfg.d_model  # embed gather is not a matmul
    if cfg.tie_embeddings:
        n += cfg.vocab_size * cfg.d_model * cfg.n_codebooks  # logits matmul
    return n


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global useful FLOPs per step: 6·N·D train, 2·N·D forward-only."""
    n = matmul_param_count(cfg)
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def attention_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Global attention score+value FLOPs (excluded from 6ND; reported so
    the useful-ratio denominator is honest for long sequences). Causal
    factor 1/2; window caps the context; train multiplies by 3 (bwd ~ 2x).
    """
    if cfg.family == "xlstm":
        return 0.0
    b, t = shape.global_batch, shape.seq_len
    n_attn = cfg.n_layers
    window = None
    if cfg.family == "griffin":
        n_attn = cfg.n_layers // len(cfg.griffin_pattern)
        window = cfg.local_window
    hd, qh = cfg.head_dim, cfg.n_heads
    if shape.kind == "decode":
        ctx = min(t, window) if window else t
        return 4.0 * b * qh * hd * ctx * n_attn
    ctx_per_q = (min(t, window) if window else t) / 2.0
    mult = 3.0 if shape.kind == "train" else 1.0
    return mult * 4.0 * b * t * qh * hd * ctx_per_q * n_attn


def analytic_hbm_traffic(
    cfg: ModelConfig, shape: ShapeSpec, n_chips: int, opt_bytes_per_param: float = 4.0,
    cache_bytes_global: float = None, param_bytes_global: float = None,
) -> float:
    """Per-device HBM bytes per step (documented coarse model).

    train:  params: read fwd + read remat-fwd + read bwd (3x)
            grads:  write + read (2x)
            opt:    m,v read+write (4x at state dtype) + param write
            acts:   per layer-group boundary (B_loc, T, d) x 2B x
                    (fwd write + bwd read + remat write) = 3x
    prefill: params 1x + cache write + act boundary 1x
    decode:  params 1x (weight streaming dominates) + cache read + write
    """
    p_bytes = (param_bytes_global if param_bytes_global is not None
               else cfg.param_count() * 2.0)  # bf16 default
    # dense params shard on "model" (16) only; MoE expert weights (the bulk)
    # span experts x ff = all chips
    p_ways = n_chips if cfg.family == "moe" else min(n_chips, 16)
    p_shard = p_bytes / p_ways
    b_loc = max(shape.global_batch / max(n_chips / 16, 16), 1)  # batch over data axis
    d = cfg.d_model
    g = cfg.n_layers  # boundary per layer (scan group boundaries are finer; upper bound)
    act_boundary = b_loc * shape.seq_len * d * 2.0 * g

    if shape.kind == "train":
        opt = cfg.param_count() / n_chips * opt_bytes_per_param  # ZeRO-1: /all chips
        return 3.0 * p_shard + 2.0 * p_shard + opt + p_shard + 3.0 * act_boundary

    cache = (cache_bytes_global if cache_bytes_global is not None
             else _cache_bytes(cfg, shape)) / n_chips
    if shape.kind == "prefill":
        return p_shard + cache + act_boundary
    # decode: read whole cache + write one slot; stream all (active... all
    # resident) weights once; activations negligible
    return p_shard + cache + b_loc * d * 2.0 * g


def _cache_bytes(cfg: ModelConfig, shape: ShapeSpec) -> float:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family in ("dense", "moe"):
        return 2.0 * b * s * cfg.n_kv_heads * cfg.head_dim * 2.0 * cfg.n_layers
    if cfg.family == "griffin":
        n_attn = cfg.n_layers // len(cfg.griffin_pattern)
        n_rec = cfg.n_layers - n_attn
        w = min(s, cfg.local_window)
        attn = 2.0 * b * w * cfg.n_kv_heads * cfg.head_dim * 2.0 * n_attn
        rec = b * cfg.rnn_width * 4.0 * n_rec
        return attn + rec
    # xlstm: matrix memories
    g, m = cfg.n_layers // cfg.slstm_ratio, cfg.slstm_ratio - 1
    hd = cfg.d_model // cfg.n_heads
    c_state = g * m * b * cfg.n_heads * hd * hd * 4.0
    return c_state + g * b * cfg.d_model * 4.0 * 4


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float
    attention_flops_global: float
    hlo_flops_per_device: float
    useful_ratio: float
    dominant: str

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


def terms(
    cfg: ModelConfig,
    shape: ShapeSpec,
    n_chips: int,
    *,
    hlo_dot_flops: float,
    collective_link_bytes: float,
    cache_bytes_global: float = None,
    param_bytes_global: float = None,
) -> RooflineTerms:
    compute_s = hlo_dot_flops / V5E["peak_flops"]
    memory_s = analytic_hbm_traffic(
        cfg, shape, n_chips, cache_bytes_global=cache_bytes_global,
        param_bytes_global=param_bytes_global,
    ) / V5E["hbm_bw"]
    collective_s = collective_link_bytes / V5E["link_bw"]
    mf = model_flops(cfg, shape)
    af = attention_flops(cfg, shape)
    useful = (mf + af) / max(n_chips * hlo_dot_flops, 1.0)
    doms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(doms, key=doms.get)
    return RooflineTerms(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        model_flops_global=mf,
        attention_flops_global=af,
        hlo_flops_per_device=hlo_dot_flops,
        useful_ratio=useful,
        dominant=dominant,
    )
