"""Jitted, sharded step functions: train / prefill / decode / calibrate.

Each ``make_*`` returns (jitted_fn, shardings) where shardings carry the
NamedShardings for every argument/output — the same objects the dry-run
lowers against and the live trainer commits arrays to.

Distribution features:
  * TP on "model" via the logical-axis rules (params + activations)
  * DP on ("pod","data") for the batch
  * ZeRO-1: Adam moments sharded on ("pod","data") on top of TP (XLA turns
    the update into reduce-scatter(grads) -> sharded update -> all-gather)
  * remat per layer group (models' lax.scan bodies)
  * optional int8 + error-feedback gradient compression (numerics of a
    compressed DP all-reduce; see optim/compress.py)
  * analog serving/calibration: decode and calibrate steps accept per-site
    energies (the paper's dynamic precision as a first-class feature)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.analog import AnalogConfig
from repro.core.energy import log_energy_penalty, to_energy, total_macs
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.sharding import PROFILES, named_sharding, spec, tree_shardings, use_mesh, use_rules, zero1_axes
from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.optim.clip import clip_by_global_norm

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    opt_state_dtype: str = "bfloat16"  # bf16 moments: fits 400B on 256 chips
    grad_compression: Optional[str] = None  # None | "int8_ef"
    #: gradient-accumulation microbatches per step (activation peak / m)
    microbatches: int = 1

    def adam(self) -> AdamConfig:
        return AdamConfig(
            lr=self.lr,
            b1=self.b1,
            b2=self.b2,
            weight_decay=self.weight_decay,
            state_dtype=jnp.dtype(self.opt_state_dtype),
        )


def _replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def train_rules(cfg: ModelConfig) -> dict:
    return PROFILES[cfg.sharding_profile]


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules=None, spec_tree=None) -> PyTree:
    """Param shardings; with ``spec_tree`` (e.g. an int8-quantized param
    spec tree), Int8Weight subtrees get (q: weight spec, scale:
    shape-filtered spec)."""
    axes = lm.param_axes(cfg)
    with use_mesh(mesh):
        if spec_tree is None:
            return tree_shardings(axes, lm.param_shapes(cfg), mesh, rules=rules)
        from repro.quant.weights import Int8Weight

        def one(ax, node):
            if isinstance(node, Int8Weight):
                return Int8Weight(
                    q=named_sharding(ax, mesh, rules, shape=node.q.shape),
                    scale=named_sharding(ax, mesh, rules, shape=node.scale.shape),
                )
            return named_sharding(ax, mesh, rules, shape=node.shape)

        return jax.tree.map(
            one, axes, spec_tree, is_leaf=lambda x: isinstance(x, tuple)
        )


def opt_shardings(cfg: ModelConfig, mesh: Mesh, rules=None) -> Any:
    """AdamState shardings: ZeRO-1 (moments get an extra ("pod","data")
    shard on their first replicated axis)."""
    axes = lm.param_axes(cfg)
    shapes = lm.param_shapes(cfg)
    z_axes = jax.tree.map(zero1_axes, axes, is_leaf=lambda x: isinstance(x, tuple))
    with use_mesh(mesh):
        moments = tree_shardings(z_axes, shapes, mesh, rules=rules)
    return AdamState(step=_replicated(mesh), mu=moments, nu=moments)


def batch_shardings(batch_specs: dict, mesh: Mesh, rules=None) -> dict:
    with use_mesh(mesh):
        return {
            k: named_sharding(ax, mesh, rules, shape=batch_specs[k].shape)
            for k, ax in lm.batch_axes(batch_specs).items()
        }


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, cache_len: int) -> PyTree:
    with use_mesh(mesh):
        c_specs = jax.eval_shape(lambda: lm.init_cache(cfg, batch, cache_len))
        return tree_shardings(lm.cache_axes(cfg), c_specs, mesh)


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig = TrainConfig()):
    adam_cfg = tcfg.adam()
    rules = train_rules(cfg)

    def step(params, opt_state, batch):
        with use_rules(rules):
            m = tcfg.microbatches
            if m > 1:
                micro = jax.tree.map(
                    lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch
                )

                def mb_body(carry, mb):
                    loss_acc, g_acc = carry
                    loss, grads = jax.value_and_grad(
                        lambda p: lm.train_loss(p, mb, cfg)
                    )(params)
                    g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                    return (loss_acc + loss, g_acc), None

                g0 = jax.tree.map(lambda p: jnp.zeros_like(p), params)
                (loss, grads), _ = jax.lax.scan(mb_body, (jnp.zeros(()), g0), micro)
                loss = loss / m
                grads = jax.tree.map(lambda g: g / m, grads)
            else:
                loss, grads = jax.value_and_grad(
                    lambda p: lm.train_loss(p, batch, cfg)
                )(params)
            if tcfg.grad_compression == "int8_ef":
                from repro.optim.compress import ef_int8_roundtrip

                grads = ef_int8_roundtrip(grads)
            grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
            new_params, new_opt = adam_update(grads, opt_state, params, adam_cfg)
            metrics = {"loss": loss, "grad_norm": gnorm}
            return new_params, new_opt, metrics

    p_sh = param_shardings(cfg, mesh, rules)
    o_sh = opt_shardings(cfg, mesh, rules)

    def jit_for(batch_specs):
        b_sh = batch_shardings(batch_specs, mesh, rules)
        return jax.jit(
            step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, _replicated(mesh)),
            donate_argnums=(0, 1),
        )

    return step, jit_for, dict(params=p_sh, opt=o_sh)


def make_opt_init(cfg: ModelConfig, mesh: Mesh, tcfg: TrainConfig = TrainConfig()):
    adam_cfg = tcfg.adam()
    rules = train_rules(cfg)
    o_sh = opt_shardings(cfg, mesh, rules)
    return jax.jit(
        functools.partial(adam_init, cfg=adam_cfg),
        in_shardings=(param_shardings(cfg, mesh, rules),),
        out_shardings=o_sh,
    )


# ---------------------------------------------------------------------------
# serving (prefill + decode), optionally analog
# ---------------------------------------------------------------------------


def make_prefill_step(
    cfg: ModelConfig,
    mesh: Mesh,
    cache_len: Optional[int] = None,
    analog_cfg: Optional[AnalogConfig] = None,
    param_tree=None,
):
    def step(params, batch, energies, key):
        analog = None
        if analog_cfg is not None:
            analog = lm.AnalogSpec(cfg=analog_cfg, energies=energies, key=key)
        cache, h_last = lm.prefill(params, batch, cfg, analog=analog, cache_len=cache_len)
        logits = lm.logits_last(params, h_last, cfg)
        return cache, logits

    p_sh = param_shardings(cfg, mesh, spec_tree=param_tree)

    def jit_for(batch_specs):
        b_sh = batch_shardings(batch_specs, mesh)
        b = next(iter(batch_specs.values())).shape[0]
        c_sh = cache_shardings(cfg, mesh, b, cache_len)
        with use_mesh(mesh):
            logits_sh = named_sharding(
                ("batch", None, None, None), mesh,
                shape=(b, 1, cfg.n_codebooks, cfg.vocab_size),
            )
        return jax.jit(
            step,
            in_shardings=(p_sh, b_sh, _replicated(mesh), _replicated(mesh)),
            out_shardings=(c_sh, logits_sh),
        )

    return step, jit_for, dict(params=p_sh)


def make_decode_step(
    cfg: ModelConfig, mesh: Mesh, analog_cfg: Optional[AnalogConfig] = None,
    param_tree=None,
):
    def step(params, cache, batch, pos, energies, key):
        analog = None
        if analog_cfg is not None:
            analog = lm.AnalogSpec(cfg=analog_cfg, energies=energies, key=key)
        logits, new_cache = lm.decode_step(params, cache, batch, pos, cfg, analog=analog)
        return logits, new_cache

    p_sh = param_shardings(cfg, mesh, spec_tree=param_tree)

    def jit_for(batch_specs, cache_len):
        b_sh = batch_shardings(batch_specs, mesh)
        b = next(iter(batch_specs.values())).shape[0]
        c_sh = cache_shardings(cfg, mesh, b, cache_len)
        with use_mesh(mesh):
            logits_sh = named_sharding(
                ("batch", None, None, None), mesh,
                shape=(b, 1, cfg.n_codebooks, cfg.vocab_size),
            )
        return jax.jit(
            step,
            in_shardings=(p_sh, c_sh, b_sh, _replicated(mesh), _replicated(mesh), _replicated(mesh)),
            out_shardings=(logits_sh, c_sh),
            donate_argnums=(1,),
        )

    return step, jit_for, dict(params=p_sh)


# ---------------------------------------------------------------------------
# calibrate (paper Eq. 14 at LM scale): learn energies, weights frozen
# ---------------------------------------------------------------------------


def make_calibrate_step(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    analog_cfg: AnalogConfig,
    seq_len: int,
    target_e_per_mac: float,
    lam: float = 2.0,
    lr: float = 0.01,
):
    macs = lm.energy_macs(cfg, seq_len)
    adam_cfg = AdamConfig(lr=lr)

    def step(log_e, opt_state, params, batch, key):
        def loss_fn(le):
            e = to_energy(le)
            aspec = lm.AnalogSpec(cfg=analog_cfg, energies=e, key=key)
            nll = lm.train_loss(params, batch, cfg, analog=aspec)
            pen = log_energy_penalty(e, macs, target_e_per_mac, lam)
            return nll + pen, nll

        (loss, nll), grads = jax.value_and_grad(loss_fn, has_aux=True)(log_e)
        new_log_e, new_opt = adam_update(grads, opt_state, log_e, adam_cfg)
        return new_log_e, new_opt, {"loss": loss, "nll": nll}

    p_sh = param_shardings(cfg, mesh)
    rep = _replicated(mesh)
    e_sh = jax.tree.map(lambda _: rep, lm.init_energy_tree(cfg, 1.0))
    o_sh = AdamState(step=rep, mu=e_sh, nu=e_sh)

    def jit_for(batch_specs):
        b_sh = batch_shardings(batch_specs, mesh)
        return jax.jit(
            step,
            in_shardings=(e_sh, o_sh, p_sh, b_sh, rep),
            out_shardings=(e_sh, o_sh, rep),
            donate_argnums=(0, 1),
        )

    return step, jit_for, dict(energies=e_sh, opt=o_sh, params=p_sh, macs=macs)
