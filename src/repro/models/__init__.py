from repro.models.config import ModelConfig
from repro.models.lm import (
    AnalogSpec,
    decode_step,
    energy_macs,
    forward_hidden,
    init_cache,
    init_energy_tree,
    init_params,
    param_axes,
    param_specs,
    prefill,
    train_loss,
)

__all__ = [
    "AnalogSpec",
    "ModelConfig",
    "decode_step",
    "energy_macs",
    "forward_hidden",
    "init_cache",
    "init_energy_tree",
    "init_params",
    "param_axes",
    "param_specs",
    "prefill",
    "train_loss",
]
