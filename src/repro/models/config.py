"""Model configuration for all supported architecture families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # "dense" | "moe" | "xlstm" | "griffin"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    mlp_type: str = "swiglu"  # "swiglu" | "gelu"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 1
    moe_every: int = 1  # 2 => dense/MoE interleaved (llama4-style)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 256
    #: split each expert's FF dim into `moe_ff_split` "virtual experts" so
    #: the (virtual) expert count divides the mesh "data" axis (grok: 8
    #: experts -> 16 virtual). Exact for gated/linear MLPs: ff splits are
    #: independent through the activation; down-proj partial sums are summed
    #: by the combine einsum.
    moe_ff_split: int = 1

    # --- griffin (RecurrentGemma) -------------------------------------------
    rnn_width: Optional[int] = None  # lru width; default d_model
    conv_width: int = 4
    local_window: int = 2048
    #: layers per scan group: (recurrent, recurrent, attention)
    griffin_pattern: Tuple[str, ...] = ("rec", "rec", "attn")

    # --- xlstm ---------------------------------------------------------------
    slstm_ratio: int = 8  # one sLSTM per `slstm_ratio` blocks (7:1 -> 8)

    # --- frontends (stubs per assignment spec) -------------------------------
    frontend: str = "none"  # "none" | "patch" (VLM) | "frames" (audio)
    n_frontend_tokens: int = 256  # prefix length for "patch"
    n_codebooks: int = 1  # output heads (musicgen: 4)

    # --- attention impl -------------------------------------------------------
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    #: python-unrolled causal chunk skipping (exact-causal FLOPs) vs masked scan
    causal_skip: bool = False
    sliding_window: Optional[int] = None  # window for plain transformer attn

    # --- training -------------------------------------------------------------
    remat: bool = True
    loss_chunk: int = 1024
    #: "tp" (Megatron TP+SP on "model") | "dp" (replicated weights, batch
    #: over the whole mesh — right for small models where TP is
    #: collective-bound); applies to train_step, serving always uses "tp".
    sharding_profile: str = "tp"

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "griffin" and self.rnn_width is None:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_vocab(self) -> int:
        """Embedding/LM-head rows padded to a multiple of 16 so the vocab dim
        shards on the 16-wide "model" mesh axis (Megatron-style vocab
        padding; logical vocab_size is unchanged, pad logits are masked)."""
        return -(-self.vocab_size // 16) * 16

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def sub_quadratic(self) -> bool:
        """Whether long-context (500k) decode is supported: SSM/hybrid only."""
        return self.family in ("xlstm", "griffin")

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ---------------

    def param_count(self) -> int:
        d, ff, hd = self.d_model, self.d_ff, self.head_dim
        qh, kh = self.n_heads, self.n_kv_heads
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size * self.n_codebooks  # lm head(s)
        if self.family in ("dense", "moe"):
            attn = d * qh * hd + 2 * d * kh * hd + qh * hd * d
            if self.qkv_bias:
                attn += (qh + 2 * kh) * hd
            mlp_mats = 3 if self.mlp_type == "swiglu" else 2
            dense_mlp = mlp_mats * d * ff
            per_norms = 2 * d
            if self.family == "dense":
                n += self.n_layers * (attn + dense_mlp + per_norms)
            else:
                n_moe = self.n_layers // self.moe_every
                n_dense = self.n_layers - n_moe
                moe = self.n_experts * mlp_mats * d * ff + d * self.n_experts
                moe += self.n_shared_experts * mlp_mats * d * ff
                n += self.n_layers * (attn + per_norms)
                n += n_dense * dense_mlp + n_moe * moe
        elif self.family == "griffin":
            rw = self.rnn_width
            # branch projections + RG-LRU gate matrices + conv + out proj
            rec = 2 * d * rw + 2 * rw * rw + rw * d + 3 * rw + self.conv_width * rw + rw
            attn = d * qh * hd + 2 * d * kh * hd + qh * hd * d
            mlp = 3 * d * ff
            n_attn = self.n_layers // len(self.griffin_pattern)
            n_rec = self.n_layers - n_attn
            n += n_rec * (rec + mlp + 2 * d) + n_attn * (attn + mlp + 2 * d)
        elif self.family == "xlstm":
            # mLSTM block: z/q/k/v/o projections + per-head gates
            mlstm = 5 * d * d + 2 * d * self.n_heads + 2 * d
            hd_m = d // self.n_heads
            # sLSTM: W (d,4d) + block-diag R (4,H,hd,hd) + out proj
            slstm = 4 * d * d + 4 * self.n_heads * hd_m * hd_m + d * d + 2 * d
            n_s = self.n_layers // self.slstm_ratio
            n_m = self.n_layers - n_s
            n += n_m * mlstm + n_s * slstm
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top_k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        mlp_mats = 3 if self.mlp_type == "swiglu" else 2
        n_moe = self.n_layers // self.moe_every
        inactive = n_moe * (self.n_experts - self.top_k) * mlp_mats * d * ff
        return int(self.param_count() - inactive)
