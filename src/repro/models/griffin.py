"""Griffin / RecurrentGemma blocks: RG-LRU recurrence + local attention.

The RG-LRU (Real-Gated Linear Recurrent Unit, arXiv:2402.19427):

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    log a_t = -c * r_t * softplus(Lambda)   (a = sigmoid(Lambda)^(c r_t))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The diagonal linear recurrence is evaluated with an associative scan
(O(log T) depth) for training/prefill, and as a single fused update for
decode. The temporal-mixing block is: [gate branch: GELU(W_g x)] *
[recurrent branch: conv1d(W_x x) -> RG-LRU] -> out projection.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.hooks import MatmulHook
from repro.models.sharding import constrain

Array = jax.Array
LRU_C = 8.0


def rg_lru_coeffs(xr: Array, p: Dict[str, Array], hook: MatmulHook) -> Tuple[Array, Array]:
    """(a, beta*gated_input) coefficients per position.

    xr: (B, T, R) post-conv recurrent-branch activations.
    """
    r = jax.nn.sigmoid(hook("rec_a", xr, p["w_a"]).astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(hook("rec_i", xr, p["w_i"]).astype(jnp.float32) + p["b_i"])
    log_a = -LRU_C * r * jax.nn.softplus(p["lambda"].astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * xr.astype(jnp.float32)


def rg_lru_scan(a: Array, b: Array, h0: Optional[Array] = None) -> Array:
    """h_t = a_t h_{t-1} + b_t via associative scan over axis 1 (time)."""
    if h0 is not None:
        # fold the carried state into the first step
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def causal_conv1d(
    x: Array, w: Array, b: Array, state: Optional[Array] = None,
    lengths: Optional[Array] = None,
) -> Tuple[Array, Array]:
    """Depthwise causal conv along time. x: (B, T, R); w: (cw, R); b: (R,).

    ``state``: (B, cw-1, R) trailing inputs from the previous segment.
    ``lengths``: (B,) per-row true lengths for right-padded batches — the
    returned state then holds each row's last ``cw-1`` *real* inputs (rows
    shorter than ``cw-1`` backfill from the zero/previous state), so decode
    resumes as if the padding never existed. Conv taps never cross the length
    boundary for real outputs (causality); pad-position outputs are garbage
    the caller must mask. Returns (y, new_state)."""
    cw = w.shape[0]
    bsz, t, r = x.shape
    if state is None:
        state = jnp.zeros((bsz, cw - 1, r), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, T+cw-1, R)
    y = jnp.zeros((bsz, t, r), jnp.float32)
    for i in range(cw):
        y = y + xp[:, i : i + t].astype(jnp.float32) * w[i].astype(jnp.float32)
    y = y + b.astype(jnp.float32)
    if lengths is None:
        new_state = xp[:, t:]  # last cw-1 inputs
    else:
        # xp index L..L+cw-2 == x positions L-cw+1..L-1 (state region if < 0)
        idx = jnp.asarray(lengths)[:, None] + jnp.arange(cw - 1)[None, :]
        new_state = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y.astype(x.dtype), new_state


def recurrent_mix(
    x: Array,
    p: Dict[str, Array],
    hook: MatmulHook,
    *,
    h0: Optional[Array] = None,
    conv_state: Optional[Array] = None,
    pad_mask: Optional[Array] = None,
    lengths: Optional[Array] = None,
) -> Tuple[Array, Array, Array]:
    """The Griffin recurrent temporal-mixing block.

    x: (B, T, d). Returns (y (B,T,d), h_last (B,R), conv_state (B,cw-1,R)).

    ``pad_mask`` (B, T) / ``lengths`` (B,): right-padded batches. Pad steps
    become the scan identity (a=1, b=0) so the carried state passes through
    them untouched and ``h_last`` is exactly each row's state after its last
    *real* token; the conv state is gathered at the length boundary. Outputs
    at pad positions are garbage the caller must never read.
    """
    gate = jax.nn.gelu(hook("rec_gate", x, p["w_gate"]).astype(jnp.float32))
    xr = hook("rec_in", x, p["w_x"])  # (B, T, R)
    xr = constrain(xr, "batch", "seq", "rnn")
    xr, conv_state = causal_conv1d(
        xr, p["conv_w"], p["conv_b"], conv_state, lengths=lengths
    )
    a, b = rg_lru_coeffs(xr, p, hook)
    if pad_mask is not None:
        # identity carry at pad steps: exact in fp (h*1.0 + 0.0 == h)
        a = jnp.where(pad_mask[..., None], 1.0, a)
        b = jnp.where(pad_mask[..., None], 0.0, b)
    h = rg_lru_scan(a, b, h0)  # (B, T, R) f32
    h_last = h[:, -1]
    y = (h * gate).astype(x.dtype)
    y = hook("rec_out", y, p["w_out"])
    return y, h_last, conv_state


def recurrent_decode(
    x: Array,
    p: Dict[str, Array],
    hook: MatmulHook,
    h0: Array,
    conv_state: Array,
) -> Tuple[Array, Array, Array]:
    """Single-token recurrent step. x: (B, 1, d)."""
    gate = jax.nn.gelu(hook("rec_gate", x, p["w_gate"]).astype(jnp.float32))
    xr = hook("rec_in", x, p["w_x"])
    xr, conv_state = causal_conv1d(xr, p["conv_w"], p["conv_b"], conv_state)
    a, b = rg_lru_coeffs(xr, p, hook)
    h = a[:, 0] * h0 + b[:, 0]  # (B, R)
    y = (h[:, None] * gate).astype(x.dtype)
    y = hook("rec_out", y, p["w_out"])
    return y, h, conv_state
