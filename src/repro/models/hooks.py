"""Matmul hooks: the seam where the paper's analog execution plugs into
every model. Digital training uses the default hook; analog serving and
Eq.-14 calibration pass an AnalogHook carrying per-site energies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.analog import (
    AnalogConfig,
    analog_dot,
    collapse_keys,
    fold_key,
    site_key,
)

Array = jax.Array


class MatmulHook:
    """Digital execution: plain matmuls (bf16/f32 per model dtype)."""

    def __call__(self, site: str, x: Array, w: Array) -> Array:
        return jnp.matmul(x, w.astype(x.dtype))

    def batched(self, site: str, x: Array, w: Array) -> Array:
        """Expert-batched matmul: (E, ..., K) @ (E, K, M)."""
        return jnp.einsum("e...k,ekm->e...m", x, w.astype(x.dtype))


@dataclasses.dataclass
class AnalogHook(MatmulHook):
    """Analog execution with per-site energies (paper §IV-V).

    ``energies`` maps site name -> scalar / (M,) per-channel / (E,) or (E, M)
    for expert-batched sites. All leaves are for the *current layer* (callers
    slice stacked (L, ...) energy trees inside their layer scan).

    ``key`` may be a single PRNG key or a *stacked* (B, ...) array of
    per-request keys (one per batch row, the serving engine's noise
    isolation): every site then draws an independent stream per row, so a
    request's output is invariant to what else shares its batch. For
    expert-batched sites, stacked keys are XOR-folded into one batch-level
    stream (``collapse_keys``) — MoE capacity buffers mix tokens from
    different requests inside one matmul, so per-request noise isolation is
    physically meaningless there and analog MoE serving is reproducible
    per batch composition rather than per request.

    Execution routes through the backend dispatch in ``analog_dot``: under
    ``cfg.backend = "pallas"`` (or "auto" on TPU with large enough shapes)
    every site runs the fused Pallas kernel — quant, matmul, K-repeat noise
    averaging and requant in one pass. ``n_repeats`` is the serving-time
    dynamic-precision knob: K repeats at the per-site energies, averaged
    in-register by the kernel (noise / sqrt(K) at zero extra HBM traffic).
    K is static in the trace, so per-layer K schedules (PrecisionProfile)
    reach this hook as one segment-constant int per layer — the layer scan in
    ``models/lm.py`` is segmented into same-K runs rather than threading a
    traced repeat array through here.

    ``valid`` (B,) bool marks the *real* rows of a stacked-key bucket batch
    (False = batch-padding row, length 0). It only affects expert-batched
    sites: pad rows fold the XOR identity into the batch-level stream, so
    the same real traffic draws the same expert noise at any pad count.

    ``noise_scale`` models hardware noise drift: a (traced) scalar factor
    multiplying the effective noise std at *every* site. All three noise
    models have std proportional to ``1/sqrt(E)`` (core/noise.py
    Eqs. 9-11), so scaling the std by ``d`` is realized exactly as serving
    at energies ``E / d**2`` — a runtime value on both backends (energy is
    a fused-kernel operand), which is what lets the serving engine drift
    the noise floor without retracing. ``None`` (the default) is the
    bit-identical nominal path.
    """

    cfg: AnalogConfig
    energies: Dict[str, Array]
    key: jax.Array
    n_repeats: int = 1
    valid: Optional[Array] = None
    noise_scale: Optional[Array] = None

    def _site_energy(self, site: str) -> Array:
        e = self.energies[site]
        if self.noise_scale is not None:
            # std ~ 1/sqrt(E): a noise-std drift factor d IS E -> E / d^2
            e = e / jnp.square(self.noise_scale)
        return e

    def __call__(self, site: str, x: Array, w: Array) -> Array:
        e = self._site_energy(site)
        k = site_key(self.key, site)
        y = analog_dot(x, w, cfg=self.cfg, energy=e, key=k, n_repeats=self.n_repeats)
        return y.astype(x.dtype)

    def batched(self, site: str, x: Array, w: Array) -> Array:
        # expert buffers mix requests: one batch-level stream (pad rows inert)
        key = collapse_keys(self.key, self.valid)
        e = self._site_energy(site)
        n_e = w.shape[0]
        e = jnp.broadcast_to(jnp.atleast_1d(e), (n_e,) + jnp.shape(e)[1:])
        keys = jax.random.split(site_key(key, site), n_e)

        def one(xe, we, ee, ke):
            return analog_dot(
                xe, we, cfg=self.cfg, energy=ee, key=ke, n_repeats=self.n_repeats
            )

        y = jax.vmap(one)(x, w, e, keys)
        return y.astype(x.dtype)


@dataclasses.dataclass
class PrefixHook(MatmulHook):
    """Namespaces an inner hook's site names (repeated sublayers per group)."""

    inner: MatmulHook
    prefix: str

    def __call__(self, site: str, x: Array, w: Array) -> Array:
        return self.inner(f"{self.prefix}{site}", x, w)

    def batched(self, site: str, x: Array, w: Array) -> Array:
        return self.inner.batched(f"{self.prefix}{site}", x, w)


def hook_for_layer(
    analog_cfg: Optional[AnalogConfig],
    layer_energies: Optional[Dict[str, Array]],
    key: Optional[jax.Array],
    layer_idx,
    *,
    n_repeats: int = 1,
    valid: Optional[Array] = None,
    noise_scale: Optional[Array] = None,
) -> MatmulHook:
    """Hook for one layer: ``n_repeats`` is that layer's K (a static int —
    per-layer schedules arrive pre-sliced from the segmented scan), ``valid``
    the bucket batch's real-row mask, ``noise_scale`` the drift factor on
    every site's noise std (see AnalogHook)."""
    if analog_cfg is None or layer_energies is None:
        return MatmulHook()
    lk = fold_key(key, layer_idx)
    return AnalogHook(
        cfg=analog_cfg, energies=layer_energies, key=lk, n_repeats=n_repeats,
        valid=valid, noise_scale=noise_scale,
    )
