"""Shared model layers: norms, RoPE, attention (chunked online-softmax,
local-window, decode), MLPs, embeddings, chunked cross-entropy.

All attention paths are pure jnp (XLA SPMD-compatible); score/value matmuls
run in f32. Memory never materializes a full (T, S) score matrix for long
sequences: training/prefill attention scans over KV chunks with an online
softmax (flash-attention dataflow expressed in XLA), and an optional
python-unrolled ``causal_skip`` mode performs exact-causal work by slicing
the KV prefix per query chunk.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.hooks import MatmulHook
from repro.models.sharding import constrain

Array = jax.Array
NEG_INF = -1e30


def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope_tables(positions: Array, head_dim: int, theta: float) -> Tuple[Array, Array]:
    """cos/sin tables for given positions; shapes (..., T, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., T, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: (B, T, H, D); cos/sin: (B, T, half) or (T, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# attention
# --------------------------------------------------------------------------


def _chunk(x: Array, size: int, axis: int) -> Array:
    """(.., N, ..) -> (n_chunks, .., size, ..) moving chunk axis to front."""
    n = x.shape[axis] // size
    new_shape = x.shape[:axis] + (n, size) + x.shape[axis + 1 :]
    x = x.reshape(new_shape)
    return jnp.moveaxis(x, axis, 0)


def _online_block(
    carry, qc: Array, kc: Array, vc: Array, mask: Array, scale: float
):
    """One (q-chunk x kv-chunk) online-softmax update.

    qc: (B, Tq, KH, G, D); kc/vc: (B, Tk, KH, D); mask: (Tq, Tk) bool.
    carry = (m, l, acc): (B, KH, G, Tq), (B, KH, G, Tq), (B, Tq, KH, G, D).
    """
    m, l, acc = carry
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qc.astype(jnp.float32), kc.astype(jnp.float32)
    ) * scale
    s = jnp.where(mask[None, None, None, :, :], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vc.astype(jnp.float32))
    acc_new = acc * jnp.moveaxis(corr, -1, 1)[..., None] + pv
    return (m_new, l_new, acc_new)


def _mask_for(iq, jk, q_chunk, kv_chunk, q_offset, causal, window):
    qp = jnp.arange(q_chunk) + iq * q_chunk + q_offset
    kp = jnp.arange(kv_chunk) + jk * kv_chunk
    m = jnp.ones((q_chunk, kv_chunk), bool)
    if causal:
        m &= qp[:, None] >= kp[None, :]
    if window is not None:
        m &= (qp[:, None] - kp[None, :]) < window
    return m


def _flash_fwd(q5, k, v, cfg: tuple):
    """q5: (B, T, KH, G, D); k/v: (B, S, KH, D).
    Returns (out5 (B,T,KH,G,D) f32, lse (B,KH,G,T) f32)."""
    q_chunk, kv_chunk, causal, window, q_offset, causal_skip = cfg
    b, t, kh, g, d = q5.shape
    s = k.shape[1]
    nq, nk = t // q_chunk, s // kv_chunk
    scale = 1.0 / (d**0.5)
    qs = _chunk(q5, q_chunk, 1)
    ks = _chunk(k, kv_chunk, 1)
    vs = _chunk(v, kv_chunk, 1)

    def run_q_chunk(iq, qc, ks_sub, vs_sub, jk_idx):
        init = (
            jnp.full((b, kh, g, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, kh, g, q_chunk), jnp.float32),
            jnp.zeros((b, q_chunk, kh, g, d), jnp.float32),
        )

        def inner(carry, xs):
            kc, vc, jk = xs
            mask = _mask_for(iq, jk, q_chunk, kv_chunk, q_offset, causal, window)
            return _online_block(carry, qc, kc, vc, mask, scale), None

        (m, l, acc), _ = jax.lax.scan(inner, init, (ks_sub, vs_sub, jk_idx))
        out = acc / jnp.maximum(l, 1e-30)[..., None].swapaxes(1, 3).swapaxes(2, 3)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B, KH, G, qc)
        return out, lse

    if causal_skip and causal and window is None:
        # triangular scan: ONE scan over only the valid (iq, jk) block pairs
        # (exact-causal FLOPs), carrying the online-softmax state of every
        # query chunk as a stack — constant buffers, no python unrolling.
        pairs = [
            (iq, jk)
            for iq in range(nq)
            for jk in range(max(1, min(nk, -(-((iq + 1) * q_chunk + int(q_offset)) // kv_chunk))))
        ]
        iq_idx = jnp.asarray([p[0] for p in pairs], jnp.int32)
        jk_idx = jnp.asarray([p[1] for p in pairs], jnp.int32)

        def pair_body(carry, xs):
            m_st, l_st, acc_st = carry  # stacks over q chunks
            iq, jk = xs
            qc = jnp.take(qs, iq, axis=0)
            kc = jnp.take(ks, jk, axis=0)
            vc = jnp.take(vs, jk, axis=0)
            mask = _mask_for(iq, jk, q_chunk, kv_chunk, q_offset, causal, window)
            blk = (
                jnp.take(m_st, iq, axis=0),
                jnp.take(l_st, iq, axis=0),
                jnp.take(acc_st, iq, axis=0),
            )
            m_n, l_n, acc_n = _online_block(blk, qc, kc, vc, mask, scale)
            return (
                m_st.at[iq].set(m_n),
                l_st.at[iq].set(l_n),
                acc_st.at[iq].set(acc_n),
            ), None

        init = (
            jnp.full((nq, b, kh, g, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((nq, b, kh, g, q_chunk), jnp.float32),
            jnp.zeros((nq, b, q_chunk, kh, g, d), jnp.float32),
        )
        (m_st, l_st, acc_st), _ = jax.lax.scan(pair_body, init, (iq_idx, jk_idx))
        out = acc_st / jnp.maximum(l_st, 1e-30)[..., None].swapaxes(2, 4).swapaxes(3, 4)
        lse = m_st + jnp.log(jnp.maximum(l_st, 1e-30))
    else:

        def outer(_, xs):
            qc, iq = xs
            return None, run_q_chunk(iq, qc, ks, vs, jnp.arange(nk))

        _, (out, lse) = jax.lax.scan(outer, None, (qs, jnp.arange(nq)))

    out = jnp.moveaxis(out, 0, 1).reshape(b, t, kh, g, d)
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, kh, g, t)
    return out, lse


def _flash_bwd_impl(q5, k, v, out, lse, do, cfg: tuple):
    """Two-pass flash backward: recompute p per block from (q,k,lse)."""
    q_chunk, kv_chunk, causal, window, q_offset, causal_skip = cfg
    b, t, kh, g, d = q5.shape
    s = k.shape[1]
    nq, nk = t // q_chunk, s // kv_chunk
    scale = 1.0 / (d**0.5)

    qs = _chunk(q5, q_chunk, 1)  # (nq, B, qc, KH, G, D)
    ks = _chunk(k, kv_chunk, 1)
    vs = _chunk(v, kv_chunk, 1)
    dos = _chunk(do, q_chunk, 1)  # (nq, B, qc, KH, G, D)
    lses = _chunk(jnp.moveaxis(lse, 3, 1), q_chunk, 1)  # (nq, B, qc, KH, G)
    # delta_i = sum_d do * out (per query)
    delta = jnp.sum(do * out, axis=-1)  # (B, T, KH, G)
    deltas = _chunk(delta, q_chunk, 1)  # (nq, B, qc, KH, G)

    # pass 2 contracts the (possibly sequence-sharded) q dim inside a scan —
    # gather q/do/lse/delta to full sequence once, or the partitioner emits
    # an all-reduce per (q-chunk x kv-chunk) block.
    def _full_seq(x):
        return constrain(x, None, "batch", *([None] * (x.ndim - 2)))

    qs_f, dos_f = _full_seq(qs), _full_seq(dos)
    lses_f, deltas_f = _full_seq(lses), _full_seq(deltas)

    def p_block(qc, kc, lse_c, iq, jk):
        sblk = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qc.astype(jnp.float32), kc.astype(jnp.float32)
        ) * scale
        mask = _mask_for(iq, jk, q_chunk, kv_chunk, q_offset, causal, window)
        sblk = jnp.where(mask[None, None, None], sblk, NEG_INF)
        # lse_c: (B, qc, KH, G) -> (B, KH, G, qc, 1)
        l5 = jnp.moveaxis(lse_c, 1, 3)[..., None]
        return jnp.exp(sblk - l5)

    # pass 1: dq per q chunk (scan over kv chunks inside)
    def dq_chunk(_, xs):
        qc, doc, lse_c, dlt, iq = xs

        def inner(acc, ys):
            kc, vc, jk = ys
            p = p_block(qc, kc, lse_c, iq, jk)  # (B,KH,G,qc,kc)
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc.astype(jnp.float32), vc.astype(jnp.float32))
            ds = p * (dp - jnp.moveaxis(dlt, 1, 3)[..., None])
            acc = acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc.astype(jnp.float32)) * scale
            return acc, None

        acc0 = jnp.zeros((b, q_chunk, kh, g, d), jnp.float32)
        acc, _ = jax.lax.scan(inner, acc0, (ks, vs, jnp.arange(nk)))
        return None, acc

    _, dq = jax.lax.scan(dq_chunk, None, (qs, dos, lses, deltas, jnp.arange(nq)))
    dq = jnp.moveaxis(dq, 0, 1).reshape(b, t, kh, g, d)

    # pass 2: dk/dv per kv chunk (scan over q chunks inside)
    def dkv_chunk(_, xs):
        kc, vc, jk = xs

        def inner(carry, ys):
            dk_c, dv_c = carry
            qc, doc, lse_c, dlt, iq = ys
            p = p_block(qc, kc, lse_c, iq, jk)
            dv_c = dv_c + jnp.einsum("bhgqk,bqhgd->bkhd", p, doc.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk", doc.astype(jnp.float32), vc.astype(jnp.float32))
            ds = p * (dp - jnp.moveaxis(dlt, 1, 3)[..., None])
            dk_c = dk_c + jnp.einsum("bhgqk,bqhgd->bkhd", ds, qc.astype(jnp.float32)) * scale
            return (dk_c, dv_c), None

        z = jnp.zeros((b, kv_chunk, kh, d), jnp.float32)
        (dk_c, dv_c), _ = jax.lax.scan(
            inner, (z, z), (qs_f, dos_f, lses_f, deltas_f, jnp.arange(nq))
        )
        return None, (dk_c, dv_c)

    _, (dk, dv) = jax.lax.scan(dkv_chunk, None, (ks, vs, jnp.arange(nk)))
    dk = jnp.moveaxis(dk, 0, 1).reshape(b, s, kh, d)
    dv = jnp.moveaxis(dv, 0, 1).reshape(b, s, kh, d)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_attention(q5, k, v, cfg: tuple):
    out, _ = _flash_fwd(q5, k, v, cfg)
    return out


def _flash_vjp_fwd(q5, k, v, cfg):
    out, lse = _flash_fwd(q5, k, v, cfg)
    return out, (q5, k, v, out, lse)


def _flash_vjp_bwd(cfg, res, do):
    q5, k, v, out, lse = res
    dq, dk, dv = _flash_bwd_impl(q5, k, v, out, lse, do.astype(jnp.float32), cfg)
    return dq.astype(q5.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_attention.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    q_chunk: int,
    kv_chunk: int,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    causal_skip: bool = False,
) -> Array:
    """Flash attention in pure XLA; q: (B, T, H, D), k/v: (B, S, KH, D).

    Forward scans KV chunks with an online softmax; the custom VJP saves only
    (q, k, v, out, logsumexp) and recomputes score blocks in the backward
    (two passes: dq, then dk/dv) — O(T) residual memory instead of the
    O(T^2/chunk) a scan-of-blocks autodiff would retain.

    ``causal_skip=True`` unrolls the query-chunk loop in Python and slices
    only the needed KV prefix per chunk: exact-causal FLOPs at the cost of a
    larger (but static) HLO.
    """
    b, t, h, d = q.shape
    _, s, kh, _ = k.shape
    g = h // kh
    q_chunk = min(q_chunk, t)
    kv_chunk = min(kv_chunk, s)
    while t % q_chunk:  # largest divisor not exceeding the requested chunk
        q_chunk -= 1
    while s % kv_chunk:
        kv_chunk -= 1
    cfg = (q_chunk, kv_chunk, bool(causal), window, int(q_offset), bool(causal_skip))
    q5 = q.reshape(b, t, kh, g, d)
    out = _flash_attention(q5, k, v, cfg)
    return out.reshape(b, t, h, d).astype(q.dtype)


def local_attention(
    q: Array, k: Array, v: Array, *, window: int, q_offset=0
) -> Array:
    """Sliding-window causal attention with linear cost: chunk size = window,
    each query chunk attends to (previous, current) key chunks only."""
    b, t, h, d = q.shape
    if t <= window or t % window:
        # short or non-aligned sequences: masked chunked path (correct, and
        # only quadratic within the actual sequence length)
        return chunked_attention(
            q, k, v, q_chunk=min(t, window), kv_chunk=min(k.shape[1], window),
            causal=True, window=window, q_offset=q_offset,
        )
    g = h // k.shape[2]
    scale = 1.0 / (d**0.5)
    nq = t // window
    q5 = q.reshape(b, t, k.shape[2], g, d)
    outs = []
    for iq in range(nq):
        q_lo = iq * window
        k_lo = max(0, q_lo - window)
        qc = q5[:, q_lo : q_lo + window]
        kc = k[:, k_lo : q_lo + window]
        vc = v[:, k_lo : q_lo + window]
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qc.astype(jnp.float32), kc.astype(jnp.float32)
        ) * scale
        qp = jnp.arange(window) + q_lo + q_offset
        kp = jnp.arange(kc.shape[1]) + k_lo
        mask = (qp[:, None] >= kp[None, :]) & ((qp[:, None] - kp[None, :]) < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        outs.append(jnp.einsum("bhgqk,bkhd->bqhgd", p, vc.astype(jnp.float32)))
    out = jnp.concatenate(outs, axis=1).reshape(b, t, h, d)
    return out.astype(q.dtype)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    pos: Array,
    slot_pos: Optional[Array] = None,
    window: Optional[int] = None,
) -> Array:
    """Single-token attention against a KV cache.

    q: (B, 1, H, D); caches: (B, S, KH, D); pos: scalar/(B,) current position.
    ``slot_pos``: (S,) or (B, S) absolute position of each cache slot (ring
    buffers); defaults to arange(S). Softmax reductions over the cache S axis
    work under SPMD sequence-sharding of the cache (XLA inserts the
    all-reduce for max/sum -> distributed flash-decode).
    """
    b, _, h, d = q.shape
    _, s, kh, _ = k_cache.shape
    g = h // kh
    scale = 1.0 / (d**0.5)
    if slot_pos is None:
        slot_pos = jnp.arange(s)
    if slot_pos.ndim == 1:
        slot_pos = jnp.broadcast_to(slot_pos[None, :], (b, s))
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (b,))[:, None]

    q5 = q.reshape(b, kh, g, d)
    scores = jnp.einsum(
        "bhgd,bshd->bhgs", q5.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    valid = (slot_pos <= pos_b) & (slot_pos >= 0)
    if window is not None:
        valid &= (pos_b - slot_pos) < window
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------


def mlp(x: Array, p: dict, mlp_type: str, hook: MatmulHook, prefix: str = "mlp") -> Array:
    if mlp_type == "swiglu":
        gate = hook(f"{prefix}_gate", x, p["w_gate"])
        up = hook(f"{prefix}_up", x, p["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:  # gelu
        h = hook(f"{prefix}_in", x, p["w_in"])
        if "b_in" in p:
            h = h + p["b_in"].astype(h.dtype)
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, "batch", "seq", "mlp")
    y = hook(f"{prefix}_out", x=h, w=p["w_down"])
    if "b_out" in p:
        y = y + p["b_out"].astype(y.dtype)
    return y


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------


def chunked_xent(
    h: Array,
    lm_head: Array,
    labels: Array,
    *,
    chunk: int,
    n_codebooks: int = 1,
    vocab: int,
    hook: Optional[MatmulHook] = None,
    ignore_label: int = -1,
) -> Array:
    """Mean token NLL without materializing full (B, T, V) logits.

    h: (B, T, d); lm_head: (d, n_codebooks * vocab_padded) — pad columns
    beyond ``vocab`` are masked out of the logsumexp;
    labels: (B, T) or (B, T, n_codebooks).
    """
    b, t, d = h.shape
    hook = hook or MatmulHook()
    chunk = min(chunk, t)
    while t % chunk:
        chunk -= 1
    n = t // chunk
    vocab_padded = lm_head.shape[-1] // n_codebooks
    if labels.ndim == 2:
        labels = labels[..., None]
    hs = _chunk(h, chunk, 1)  # (n, B, chunk, d)
    ls = _chunk(labels, chunk, 1)  # (n, B, chunk, cb)

    @jax.checkpoint  # recompute logits in bwd: O(B*chunk*V) residuals -> 0
    def chunk_nll(hc, lc):
        logits = hook("lm_head", hc, lm_head).astype(jnp.float32)
        logits = logits.reshape(b, chunk, n_codebooks, vocab_padded)
        logits = constrain(logits, "batch", None, None, "vocab")
        if vocab_padded != vocab:
            pad_mask = jnp.arange(vocab_padded) < vocab
            logits = jnp.where(pad_mask, logits, NEG_INF)
        logz = jax.nn.logsumexp(logits, axis=-1)
        lbl = jnp.clip(lc, 0, vocab - 1)
        gold = jnp.take_along_axis(logits, lbl[..., None], axis=-1)[..., 0]
        mask = (lc != ignore_label).astype(jnp.float32)
        return jnp.sum((logz - gold) * mask), jnp.sum(mask)

    def body(carry, xs):
        tot, cnt = carry
        hc, lc = xs
        t_, c_ = chunk_nll(hc, lc)
        return (tot + t_, cnt + c_), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls))
    return tot / jnp.maximum(cnt, 1.0)
