"""Unified language model covering all assigned architecture families.

Parameters are nested dicts with layer-stacked leaves; the layer stack runs
under ``jax.lax.scan`` (per-group), optionally rematerialized. Families:

  dense    - pre-norm transformer, GQA/MQA, SwiGLU or GELU MLP
  moe      - transformer where every ``moe_every``-th layer's MLP is a
             GShard-style MoE (+ optional shared experts); grok-1 = every
             layer, llama4 = interleaved
  griffin  - RecurrentGemma: scan groups of (rec, rec, local-attention)
  xlstm    - scan groups of (slstm_ratio-1) mLSTM blocks + 1 sLSTM block

Frontends per the assignment spec are stubs: "frames" (musicgen) consumes
precomputed frame embeddings; "patch" (internvl) consumes precomputed patch
embeddings concatenated before the token stream.

Every matmul routes through a MatmulHook: digital by default, or an
AnalogHook carrying per-site energies (paper §IV-V) for analog serving and
Eq.-14 calibration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.analog import AnalogConfig, fold_key
from repro.core.energy import apply_repeats, total_energy
from repro.core.profile import PrecisionProfile, coalesce_runs
from repro.models import griffin as griffin_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.config import ModelConfig
from repro.models.hooks import MatmulHook, PrefixHook, hook_for_layer
from repro.models.layers import (
    apply_rope,
    chunked_attention,
    chunked_xent,
    decode_attention,
    local_attention,
    mlp,
    rms_norm,
    rope_tables,
)
from repro.models.sharding import constrain

Array = jax.Array
PyTree = Any


@dataclasses.dataclass
class AnalogSpec:
    """Analog execution request for a forward pass.

    ``n_repeats`` is the serving-time dynamic-precision knob (paper §IV):
    every matmul site runs K-repeat averaged at its per-site energy, fused
    in-kernel on the Pallas backend (noise / sqrt(K), no extra HBM traffic).

    ``key`` may be a single PRNG key, or a stacked (B, ...) array of
    per-request keys (one per batch row): every site then draws an
    independent noise stream per row, the serving engine's guarantee that a
    request's tokens don't depend on its batch-mates.

    ``profile`` is the per-layer form of the same knob (paper §V-VI): a
    frozen ``PrecisionProfile`` assigning each layer its own K_l. It
    overrides ``n_repeats`` (which must stay 1 when set). K is static in the
    fused kernel, so the layer scan is *segmented* into contiguous same-K
    runs — layers sharing K share one trace, distinct-K segments get their
    own — identically for prefill and decode.

    ``noise_scale`` is an optional (traced) scalar drift factor on every
    site's noise std — hardware noise-floor drift as a *runtime* operand
    (std ~ 1/sqrt(E), so it reaches the kernels as energies / scale**2; see
    AnalogHook). ``None`` is the bit-identical nominal path.
    """

    cfg: AnalogConfig
    energies: PyTree  # from init_energy_tree
    key: jax.Array
    n_repeats: int = 1
    profile: Optional[PrecisionProfile] = None
    noise_scale: Optional[Array] = None


# ===========================================================================
# parameter construction
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class Leaf:
    shape: tuple
    axes: tuple
    scale: float = 1.0


def _attn_leaves(cfg: ModelConfig, lead: tuple, lead_axes: tuple) -> Dict[str, Leaf]:
    d, hd = cfg.d_model, cfg.head_dim
    qh, kh = cfg.n_heads, cfg.n_kv_heads
    s = d**-0.5
    leaves = {
        "wq": Leaf(lead + (d, qh * hd), lead_axes + (None, "heads"), s),
        "wk": Leaf(lead + (d, kh * hd), lead_axes + (None, "kv_heads"), s),
        "wv": Leaf(lead + (d, kh * hd), lead_axes + (None, "kv_heads"), s),
        "wo": Leaf(lead + (qh * hd, d), lead_axes + ("heads", None), (qh * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        leaves["bq"] = Leaf(lead + (qh * hd,), lead_axes + ("heads",), 0.0)
        leaves["bk"] = Leaf(lead + (kh * hd,), lead_axes + ("kv_heads",), 0.0)
        leaves["bv"] = Leaf(lead + (kh * hd,), lead_axes + ("kv_heads",), 0.0)
    return leaves


def _mlp_leaves(cfg: ModelConfig, lead: tuple, lead_axes: tuple) -> Dict[str, Leaf]:
    d, ff = cfg.d_model, cfg.d_ff
    s = d**-0.5
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": Leaf(lead + (d, ff), lead_axes + (None, "mlp"), s),
            "w_up": Leaf(lead + (d, ff), lead_axes + (None, "mlp"), s),
            "w_down": Leaf(lead + (ff, d), lead_axes + ("mlp", None), ff**-0.5),
        }
    return {
        "w_in": Leaf(lead + (d, ff), lead_axes + (None, "mlp"), s),
        "b_in": Leaf(lead + (ff,), lead_axes + ("mlp",), 0.0),
        "w_down": Leaf(lead + (ff, d), lead_axes + ("mlp", None), ff**-0.5),
        "b_out": Leaf(lead + (d,), lead_axes + (None,), 0.0),
    }


def _moe_leaves(cfg: ModelConfig, lead: tuple, lead_axes: tuple) -> Dict[str, Leaf]:
    d, e = cfg.d_model, cfg.n_experts * cfg.moe_ff_split
    ff = cfg.d_ff // cfg.moe_ff_split
    s = d**-0.5
    leaves = {"router": Leaf(lead + (d, cfg.n_experts), lead_axes + (None, None), s)}
    ea = lead_axes + ("experts",)
    el = lead + (e,)
    if cfg.mlp_type == "swiglu":
        leaves["w_gate"] = Leaf(el + (d, ff), ea + ("expert_embed", "expert_mlp"), s)
        leaves["w_up"] = Leaf(el + (d, ff), ea + ("expert_embed", "expert_mlp"), s)
        leaves["w_down"] = Leaf(el + (ff, d), ea + ("expert_mlp", "expert_embed"), ff**-0.5)
    else:
        leaves["w_in"] = Leaf(el + (d, ff), ea + ("expert_embed", "expert_mlp"), s)
        leaves["w_down"] = Leaf(el + (ff, d), ea + ("expert_mlp", "expert_embed"), ff**-0.5)
    if cfg.n_shared_experts:
        leaves["shared"] = _mlp_leaves(cfg, lead, lead_axes)  # type: ignore
    return leaves


def _rec_leaves(cfg: ModelConfig, lead: tuple, lead_axes: tuple) -> Dict[str, Leaf]:
    d, r, cw = cfg.d_model, cfg.rnn_width, cfg.conv_width
    s = d**-0.5
    return {
        "w_gate": Leaf(lead + (d, r), lead_axes + (None, "rnn"), s),
        "w_x": Leaf(lead + (d, r), lead_axes + (None, "rnn"), s),
        "w_a": Leaf(lead + (r, r), lead_axes + ("rnn", None), r**-0.5),
        "b_a": Leaf(lead + (r,), lead_axes + (None,), 0.0),
        "w_i": Leaf(lead + (r, r), lead_axes + ("rnn", None), r**-0.5),
        "b_i": Leaf(lead + (r,), lead_axes + (None,), 0.0),
        "lambda": Leaf(lead + (r,), lead_axes + (None,), 1.0),
        "conv_w": Leaf(lead + (cw, r), lead_axes + ("conv", "rnn"), cw**-0.5),
        "conv_b": Leaf(lead + (r,), lead_axes + ("rnn",), 0.0),
        "w_out": Leaf(lead + (r, d), lead_axes + ("rnn", None), r**-0.5),
    }


def _mlstm_leaves(cfg: ModelConfig, lead: tuple, lead_axes: tuple) -> Dict[str, Leaf]:
    d, h = cfg.d_model, cfg.n_heads
    s = d**-0.5
    return {
        "w_z": Leaf(lead + (d, d), lead_axes + (None, "rnn"), s),
        "w_q": Leaf(lead + (d, d), lead_axes + (None, "rnn"), s),
        "w_k": Leaf(lead + (d, d), lead_axes + (None, "rnn"), s),
        "w_v": Leaf(lead + (d, d), lead_axes + (None, "rnn"), s),
        "w_o": Leaf(lead + (d, d), lead_axes + ("rnn", None), s),
        "w_gates": Leaf(lead + (d, 2 * h), lead_axes + (None, None), s),
        "b_gates": Leaf(lead + (2 * h,), lead_axes + (None,), 0.0),
        "norm": Leaf(lead + (d,), lead_axes + (None,), 0.0),
        "ln": Leaf(lead + (d,), lead_axes + (None,), 0.0),
    }


def _slstm_leaves(cfg: ModelConfig, lead: tuple, lead_axes: tuple) -> Dict[str, Leaf]:
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    s = d**-0.5
    return {
        "w_x": Leaf(lead + (d, 4 * d), lead_axes + (None, "rnn"), s),
        "b": Leaf(lead + (4 * d,), lead_axes + (None,), 0.0),
        "r": Leaf(lead + (4, h, hd, hd), lead_axes + (None, "heads", None, None), hd**-0.5),
        "w_o": Leaf(lead + (d, d), lead_axes + (None, None), s),
        "ln": Leaf(lead + (d,), lead_axes + (None,), 0.0),
    }


def group_structure(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, layers_per_group) of the layer scan."""
    if cfg.family in ("dense", "moe"):
        per = cfg.moe_every if cfg.family == "moe" else 1
        return cfg.n_layers // per, per
    if cfg.family == "griffin":
        return cfg.n_layers // len(cfg.griffin_pattern), len(cfg.griffin_pattern)
    if cfg.family == "xlstm":
        return cfg.n_layers // cfg.slstm_ratio, cfg.slstm_ratio
    raise ValueError(cfg.family)


def param_leaves(cfg: ModelConfig) -> Dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    g, per = group_structure(cfg)
    lead, la = (g,), ("layers",)
    tree: Dict[str, Any] = {"final_ln": Leaf((d,), (None,), 0.0)}
    if not cfg.tie_embeddings:
        tree["lm_head"] = Leaf((d, v * cfg.n_codebooks), (None, "vocab"), d**-0.5)
    if cfg.frontend != "frames":
        tree["embed"] = Leaf((v, d), ("vocab", None), 0.02)

    blocks: Dict[str, Any] = {}
    if cfg.family in ("dense", "moe"):
        for i in range(per):
            blocks[f"ln1_{i}"] = Leaf(lead + (d,), la + (None,), 0.0)
            blocks[f"ln2_{i}"] = Leaf(lead + (d,), la + (None,), 0.0)
            blocks[f"attn{i}"] = _attn_leaves(cfg, lead, la)
            is_moe = cfg.family == "moe" and i == per - 1
            if is_moe:
                blocks["moe"] = _moe_leaves(cfg, lead, la)
            else:
                blocks[f"mlp{i}"] = _mlp_leaves(cfg, lead, la)
    elif cfg.family == "griffin":
        for i, kind in enumerate(cfg.griffin_pattern):
            blocks[f"ln1_{i}"] = Leaf(lead + (d,), la + (None,), 0.0)
            blocks[f"ln2_{i}"] = Leaf(lead + (d,), la + (None,), 0.0)
            if kind == "rec":
                blocks[f"rec{i}"] = _rec_leaves(cfg, lead, la)
            else:
                blocks[f"attn{i}"] = _attn_leaves(cfg, lead, la)
            blocks[f"mlp{i}"] = _mlp_leaves(cfg, lead, la)
        tail = cfg.n_layers - g * per
        if tail:
            tl, tla = (tail,), ("layers",)
            tree["tail"] = {
                "ln1": Leaf(tl + (d,), tla + (None,), 0.0),
                "ln2": Leaf(tl + (d,), tla + (None,), 0.0),
                "rec": _rec_leaves(cfg, tl, tla),
                "mlp": _mlp_leaves(cfg, tl, tla),
            }
    elif cfg.family == "xlstm":
        m = per - 1
        blocks["mlstm"] = _mlstm_leaves(cfg, (g, m), ("layers", "stack"))
        blocks["slstm"] = _slstm_leaves(cfg, lead, la)
    tree["blocks"] = blocks
    return tree


def _is_leaf(x):
    return isinstance(x, Leaf)


def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    leaves, treedef = jax.tree.flatten(param_leaves(cfg), is_leaf=_is_leaf)
    keys = jax.random.split(key, len(leaves))

    def make(leaf: Leaf, k):
        if leaf.scale == 0.0:
            return jnp.zeros(leaf.shape, cfg.compute_dtype)
        x = jax.random.normal(k, leaf.shape, jnp.float32) * leaf.scale
        return x.astype(cfg.compute_dtype)

    return treedef.unflatten([make(l, k) for l, k in zip(leaves, keys)])


def param_axes(cfg: ModelConfig) -> PyTree:
    return jax.tree.map(lambda l: l.axes, param_leaves(cfg), is_leaf=_is_leaf)


def param_shapes(cfg: ModelConfig) -> PyTree:
    return jax.tree.map(lambda l: l.shape, param_leaves(cfg), is_leaf=_is_leaf)


def param_specs(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStructs (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, cfg.compute_dtype),
        param_leaves(cfg),
        is_leaf=_is_leaf,
    )


# ===========================================================================
# energies (paper: per-layer / per-expert energy allocations)
# ===========================================================================


def group_sites(cfg: ModelConfig) -> Dict[str, tuple]:
    """Analog matmul sites within one scan group -> energy leaf suffix."""
    sites: Dict[str, tuple] = {}
    _, per = group_structure(cfg)
    if cfg.family in ("dense", "moe"):
        for i in range(per):
            for s in ("q", "k", "v", "o"):
                sites[f"attn{i}_{s}"] = ()
            is_moe = cfg.family == "moe" and i == per - 1
            if is_moe:
                sites["router"] = ()
                names = ("moe_gate", "moe_up", "moe_down") if cfg.mlp_type == "swiglu" else ("moe_in", "moe_down")
                for s in names:
                    sites[s] = (cfg.n_experts * cfg.moe_ff_split,)
                if cfg.n_shared_experts:
                    for s in ("moe_shared_gate", "moe_shared_up", "moe_shared_out"):
                        sites[s] = ()
            else:
                names = (
                    (f"mlp{i}_gate", f"mlp{i}_up", f"mlp{i}_out")
                    if cfg.mlp_type == "swiglu"
                    else (f"mlp{i}_in", f"mlp{i}_out")
                )
                for s in names:
                    sites[s] = ()
    elif cfg.family == "griffin":
        for i, kind in enumerate(cfg.griffin_pattern):
            if kind == "rec":
                for s in ("rec_gate", "rec_in", "rec_a", "rec_i", "rec_out"):
                    sites[f"{kind}{i}_{s}"] = ()
            else:
                for s in ("q", "k", "v", "o"):
                    sites[f"attn{i}_{s}"] = ()
            for s in (f"mlp{i}_gate", f"mlp{i}_up", f"mlp{i}_out"):
                sites[s] = ()
    elif cfg.family == "xlstm":
        m = per - 1
        for s in ("mlstm_z", "mlstm_q", "mlstm_k", "mlstm_v", "mlstm_o"):
            sites[s] = (m,)
        for s in ("slstm_wx", "slstm_o"):
            sites[s] = ()
    return sites


def init_energy_tree(cfg: ModelConfig, e0: float) -> PyTree:
    g, per = group_structure(cfg)
    tree = {
        "groups": {
            s: jnp.full((g,) + suf, float(e0), jnp.float32)
            for s, suf in group_sites(cfg).items()
        },
        "lm_head": jnp.asarray(float(e0), jnp.float32),
    }
    if cfg.family == "griffin":
        tail = cfg.n_layers - g * per
        if tail:
            tail_sites = [
                "rec0_rec_gate", "rec0_rec_in", "rec0_rec_a", "rec0_rec_i",
                "rec0_rec_out", "mlp0_gate", "mlp0_up", "mlp0_out",
            ]
            tree["tail"] = {s: jnp.full((tail,), float(e0), jnp.float32) for s in tail_sites}
    return tree


def energy_macs(cfg: ModelConfig, seq_len: int) -> PyTree:
    """Per-example MAC counts mirroring init_energy_tree's structure.

    Used by the Eq.-14 energy accounting at LM scale: E_tot = sum E * macs.
    """
    g, per = group_structure(cfg)
    d, ff, hd = cfg.d_model, cfg.d_ff, cfg.head_dim
    qh, kh, t = cfg.n_heads, cfg.n_kv_heads, seq_len
    r = cfg.rnn_width or d
    e = cfg.n_experts

    def site_macs(site: str, suffix: tuple):
        base = None
        if "_q" in site or site.endswith("_o"):
            base = t * d * qh * hd
        if "_k" in site or "_v" in site:
            base = t * d * kh * hd
        if "mlp" in site or "shared" in site:
            base = t * d * ff
        if site == "router":
            base = t * d * e
        if site.startswith("moe_") and "shared" not in site:
            base = (t * cfg.top_k / e) * d * ff  # expected per-expert load
        if "rec_gate" in site or "rec_in" in site:
            base = t * d * r
        if "rec_a" in site or "rec_i" in site:
            base = t * r * r
        if "rec_out" in site:
            base = t * r * d
        if site.startswith("mlstm"):
            base = t * d * d
        if site == "slstm_wx":
            base = t * d * 4 * d
        if site == "slstm_o":
            base = t * d * d
        assert base is not None, site
        return jnp.full((g,) + suffix, float(base), jnp.float32)

    tree = {
        "groups": {s: site_macs(s, suf) for s, suf in group_sites(cfg).items()},
        "lm_head": jnp.asarray(float(t * d * cfg.vocab_size * cfg.n_codebooks), jnp.float32),
    }
    if cfg.family == "griffin":
        tail = cfg.n_layers - g * per
        if tail:
            tree["tail"] = {
                s: jnp.full((tail,), float(site_macs(s, ())[0]), jnp.float32)
                for s in init_energy_tree(cfg, 1.0)["tail"]
            }
    return tree


# ===========================================================================
# precision profiles (paper §V-VI: per-layer K schedules on the LM stack)
# ===========================================================================


def group_site_subs(cfg: ModelConfig) -> Dict[str, object]:
    """Analog site -> sublayer index within one scan group.

    Mirrors ``group_sites``. The value is the 0-based sublayer a site belongs
    to (profiles assign K per *layer*, i.e. per sublayer of a scan group), or
    the sentinel ``"stack"`` for the xlstm mLSTM sites whose energy leaves
    carry their own leading (m,) stack dim — there the per-sublayer Ks map
    onto that dim directly.
    """
    subs: Dict[str, object] = {}
    _, per = group_structure(cfg)
    for site in group_sites(cfg):
        if cfg.family == "xlstm":
            subs[site] = "stack" if site.startswith("mlstm") else per - 1
        elif site == "router" or site.startswith("moe_"):
            subs[site] = per - 1  # the MoE sublayer closes its scan group
        else:
            # attn{i}_*, mlp{i}_*, rec{i}_*: the embedded index is the sublayer
            digits = "".join(c for c in site.split("_")[0] if c.isdigit())
            subs[site] = int(digits)
    return subs


def profile_rows(cfg: ModelConfig, profile: PrecisionProfile):
    """Validate a profile against the model; split it onto the scan layout.

    Returns ``(rows, tail_ks)``: ``rows[i]`` is the K-tuple of scan group
    ``i``'s sublayers (length ``per``), ``tail_ks`` the per-layer Ks of the
    griffin tail layers that run outside the group scan (empty otherwise).
    Profiles are indexed by *model layer*: ``repeats[l]`` belongs to layer
    ``l`` in stack order, so ``len(repeats)`` must equal ``cfg.n_layers``.
    """
    if profile.n_layers != cfg.n_layers:
        raise ValueError(
            f"profile {profile.name!r} has {profile.n_layers} layers but "
            f"model {cfg.name!r} has {cfg.n_layers}"
        )
    g, per = group_structure(cfg)
    reps = profile.repeats
    rows = [tuple(reps[i * per : (i + 1) * per]) for i in range(g)]
    tail_ks = list(reps[g * per :])
    return rows, tail_ks


def profile_repeat_tree(cfg: ModelConfig, profile: PrecisionProfile) -> PyTree:
    """Per-site repeat factors matching ``init_energy_tree``'s structure.

    Each leaf broadcasts against the corresponding energy leaf and carries
    that site's K_l along the stacked layer dim; the lm_head (served
    digitally by ``logits_last``) stays at 1. Feed to
    ``repro.core.energy.apply_repeats`` / ``repeat_total_energy`` for the
    true served energy ``sum_l K_l * E_l * MACs_l``.
    """
    rows, tail_ks = profile_rows(cfg, profile)
    g, per = group_structure(cfg)
    rows_arr = jnp.asarray(rows, jnp.float32).reshape(g, per)
    subs = group_site_subs(cfg)
    groups = {}
    for site, suf in group_sites(cfg).items():
        if subs[site] == "stack":
            k = rows_arr[:, : per - 1]  # (g, m) aligns with the (m,) suffix
        else:
            k = rows_arr[:, subs[site]].reshape((g,) + (1,) * len(suf))
        groups[site] = k
    tree = {"groups": groups, "lm_head": jnp.asarray(1.0, jnp.float32)}
    if tail_ks:
        tail_sites = init_energy_tree(cfg, 1.0)["tail"]
        tree["tail"] = {
            s: jnp.asarray(tail_ks, jnp.float32) for s in tail_sites
        }
    return tree


def profile_token_energy(cfg: ModelConfig, energies: PyTree, profile: PrecisionProfile) -> float:
    """True serving energy per generated token: ``sum_l K_l * E_l * MACs_l``
    over the model's analog sites (decode = one token, seq_len 1)."""
    macs = energy_macs(cfg, 1)
    scaled = apply_repeats(energies, profile_repeat_tree(cfg, profile))
    return float(total_energy(scaled, macs))


# ===========================================================================
# forward
# ===========================================================================


def _cache_store(cache, new, slot):
    """Write a one-token KV slab into the cache at ``slot``.

    ``slot`` scalar: uniform position for the whole batch (the classic
    decode path, a dynamic_update_slice). ``slot`` (B,): per-row slots — the
    serving engine batches requests with different prompt lengths, so each
    row writes (and later attends) at its own position. Both forms update
    one slot per row in place; neither rewrites the cache.
    """
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice(cache, new.astype(cache.dtype), (0, slot, 0, 0))
    rows = jnp.arange(cache.shape[0])
    return cache.at[rows, slot].set(new[:, 0].astype(cache.dtype))


def _attn_sublayer(
    x,
    p,
    cfg: ModelConfig,
    hook: MatmulHook,
    prefix: str,
    *,
    rope,
    mode: str,
    cache=None,
    pos=None,
    window=None,
    cache_len=None,
    lengths=None,
):
    b, t, d = x.shape
    hd, qh, kh = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    cos, sin = rope
    q = hook(f"{prefix}_q", x, p["wq"])
    k = hook(f"{prefix}_k", x, p["wk"])
    v = hook(f"{prefix}_v", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = q.reshape(b, t, qh, hd)
    k = k.reshape(b, t, kh, hd)
    v = v.reshape(b, t, kh, hd)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    if mode != "decode":
        # sequence-parallel attention: queries stay sequence-sharded, K/V are
        # gathered to full sequence (cheap: KV bytes << activations), and GQA
        # expands to MHA locally — flash attention then runs with ZERO
        # collectives and no head-count divisibility constraints.
        seq_ax = "act_seq" if mode == "train" else "seq"
        q = constrain(q, "batch", seq_ax, None, None)
        k = constrain(k, "batch", None, None, None)
        v = constrain(v, "batch", None, None, None)
        k_gqa, v_gqa = k, v  # un-expanded KV for the prefill cache
        if qh != kh:
            k = jnp.repeat(k, qh // kh, axis=2)
            v = jnp.repeat(v, qh // kh, axis=2)

    new_cache = None
    if mode == "decode":
        k_cache, v_cache = cache  # (B, S, KH, hd)
        s_len = k_cache.shape[1]
        pos_arr = jnp.asarray(pos)
        if window is not None:
            slot = pos_arr % window
            k_cache = _cache_store(k_cache, k, slot)
            v_cache = _cache_store(v_cache, v, slot)
            base = jnp.arange(s_len)
            if pos_arr.ndim == 0:
                slot_pos = jnp.where(
                    base <= slot, pos_arr - slot + base, pos_arr - slot - s_len + base
                )
            else:  # per-row positions: (B, S) slot->absolute-position map
                off, wrap = (pos_arr - slot)[:, None], (pos_arr - slot - s_len)[:, None]
                slot_pos = jnp.where(base[None, :] <= slot[:, None], off + base, wrap + base)
            out = decode_attention(q, k_cache, v_cache, pos, slot_pos=slot_pos, window=window)
        else:
            k_cache = _cache_store(k_cache, k, pos_arr)
            v_cache = _cache_store(v_cache, v, pos_arr)
            k_cache = constrain(k_cache, "batch", "kv_seq", "kv_heads", None)
            v_cache = constrain(v_cache, "batch", "kv_seq", "kv_heads", None)
            out = decode_attention(q, k_cache, v_cache, pos)
        new_cache = (k_cache, v_cache)
    else:
        if window is not None:
            out = local_attention(q, k, v, window=window)
        else:
            out = chunked_attention(
                q,
                k,
                v,
                q_chunk=cfg.attn_q_chunk,
                kv_chunk=cfg.attn_kv_chunk,
                causal=True,
                causal_skip=cfg.causal_skip,
                window=cfg.sliding_window,
            )
        if mode == "prefill":
            if window is not None:
                w = window
                # ring size matches init_cache: a cache shorter than the
                # window never wraps (all positions < cache_len), so decode's
                # slot = pos % w stays linear there
                ring = w if cache_len is None else min(cache_len, w)
                if lengths is not None:
                    # per-row ring: slot s holds the row's most recent REAL
                    # position p < L with p % w == s; slots whose p is
                    # negative (row shorter than the window) are zeroed and
                    # stay masked at decode until overwritten
                    start = jnp.asarray(lengths)[:, None] - w  # (B, 1)
                    slots = jnp.arange(ring)[None, :]
                    if ring == w:
                        p_abs = start + jnp.mod(slots - start, w)  # (B, ring)
                    else:  # ring == cache_len > t: linear layout, slot == pos
                        p_abs = jnp.broadcast_to(slots, (b, ring))
                    p_abs = jnp.where(p_abs < jnp.asarray(lengths)[:, None], p_abs, -1)
                    idx = jnp.clip(p_abs, 0, t - 1)[..., None, None]
                    ok = (p_abs >= 0)[..., None, None]
                    kc = jnp.where(ok, jnp.take_along_axis(k_gqa, idx, axis=1), 0)
                    vc = jnp.where(ok, jnp.take_along_axis(v_gqa, idx, axis=1), 0)
                elif t >= ring:
                    # ring layout: slot s holds position p with p % w == s
                    kc = jnp.roll(k_gqa[:, -ring:], t % ring, axis=1)
                    vc = jnp.roll(v_gqa[:, -ring:], t % ring, axis=1)
                else:
                    kc = jnp.pad(k_gqa, ((0, 0), (0, ring - t), (0, 0), (0, 0)))
                    vc = jnp.pad(v_gqa, ((0, 0), (0, ring - t), (0, 0), (0, 0)))
                new_cache = (kc.astype(cfg.compute_dtype), vc.astype(cfg.compute_dtype))
            else:
                kc, vc = k_gqa, v_gqa
                if cache_len is not None and cache_len > t:
                    pad = ((0, 0), (0, cache_len - t), (0, 0), (0, 0))
                    kc, vc = jnp.pad(kc, pad), jnp.pad(vc, pad)
                kc = constrain(kc.astype(cfg.compute_dtype), "batch", "kv_seq", None, None)
                vc = constrain(vc.astype(cfg.compute_dtype), "batch", "kv_seq", None, None)
                new_cache = (kc, vc)
    if mode != "decode":
        out = constrain(out, "batch", "act_seq" if mode == "train" else "seq", None, None)
    y = hook(f"{prefix}_o", out.reshape(b, t, qh * hd), p["wo"])
    return y, new_cache


def _transformer_group(
    x, gp, cfg, hook_fn, *, rope, mode, cache, pos, cache_len=None,
    pad_mask=None, lengths=None,
):
    """One scan group of the dense/moe families. cache: dict of per-sublayer
    entries with leading dim `per` (or None). ``hook_fn(i)`` builds sublayer
    ``i``'s matmul hook — per-layer precision profiles give each sublayer its
    own (static) repeat count, so hooks are constructed per sublayer."""
    _, per = group_structure(cfg)
    new_cache = {"k": [], "v": []}
    for i in range(per):
        hook = hook_fn(i)
        h = rms_norm(x, gp[f"ln1_{i}"], cfg.norm_eps)
        sub_cache = None
        if cache is not None:
            sub_cache = (cache["k"][i], cache["v"][i])
        y, upd = _attn_sublayer(
            h, gp[f"attn{i}"], cfg, hook, f"attn{i}",
            rope=rope, mode=mode, cache=sub_cache, pos=pos,
            window=cfg.sliding_window, cache_len=cache_len, lengths=lengths,
        )
        x = x + y
        if upd is not None:
            new_cache["k"].append(upd[0])
            new_cache["v"].append(upd[1])
        h = rms_norm(x, gp[f"ln2_{i}"], cfg.norm_eps)
        is_moe = cfg.family == "moe" and i == per - 1
        if is_moe:
            y = moe_lib.moe_block(h, gp["moe"], cfg, hook, pad_mask=pad_mask)
        else:
            y = mlp(h, gp[f"mlp{i}"], cfg.mlp_type, hook, prefix=f"mlp{i}")
        x = x + y
        # sequence-parallel residual stream at sublayer boundaries (train):
        # decode/prefill keep seq unsharded (T=1 or cache-driven layouts)
        x = constrain(x, "batch", "act_seq" if mode == "train" else "seq", None)
    if not new_cache["k"]:
        new_cache = None
    else:
        new_cache = {
            "k": jnp.stack(new_cache["k"]),
            "v": jnp.stack(new_cache["v"]),
        }
    return x, new_cache


def _griffin_group(
    x, gp, cfg, hook_fn, *, rope, mode, cache, pos, pattern, tail=False,
    cache_len=None, pad_mask=None, lengths=None,
):
    """``hook_fn(i)`` -> sublayer ``i``'s matmul hook (per-layer K)."""
    new_cache = {}
    for i, kind in enumerate(pattern):
        sfx = "" if tail else f"_{i}"
        ln1 = gp["ln1" + sfx] if tail else gp[f"ln1_{i}"]
        ln2 = gp["ln2" + sfx] if tail else gp[f"ln2_{i}"]
        rec_p = gp["rec"] if tail else gp.get(f"rec{i}")
        mlp_p = gp["mlp"] if tail else gp[f"mlp{i}"]
        hook = hook_fn(i)

        def sublayer(x, i=i, kind=kind, ln1=ln1, ln2=ln2, rec_p=rec_p,
                     mlp_p=mlp_p, hook=hook):
            out_cache = {}
            h = rms_norm(x, ln1, cfg.norm_eps)
            if kind == "rec":
                rec_hook = PrefixHook(hook, f"rec{i}_")
                h0 = cache[f"h{i}"] if cache is not None else None
                cs = cache[f"conv{i}"] if cache is not None else None
                if mode == "decode":
                    y, h_new, cs_new = griffin_lib.recurrent_decode(h, rec_p, rec_hook, h0, cs)
                else:
                    y, h_new, cs_new = griffin_lib.recurrent_mix(
                        h, rec_p, rec_hook, h0=h0, conv_state=cs,
                        pad_mask=pad_mask, lengths=lengths,
                    )
                if mode in ("decode", "prefill"):
                    out_cache[f"h{i}"] = h_new
                    out_cache[f"conv{i}"] = cs_new
            else:
                sub_cache = (cache[f"k{i}"], cache[f"v{i}"]) if cache is not None else None
                y, upd = _attn_sublayer(
                    h, gp[f"attn{i}"], cfg, hook, f"attn{i}",
                    rope=rope, mode=mode, cache=sub_cache, pos=pos,
                    window=cfg.local_window, cache_len=cache_len, lengths=lengths,
                )
                if upd is not None:
                    out_cache[f"k{i}"] = upd[0]
                    out_cache[f"v{i}"] = upd[1]
            x = x + y
            h = rms_norm(x, ln2, cfg.norm_eps)
            x = x + mlp(h, mlp_p, cfg.mlp_type, hook, prefix=f"mlp{i}")
            x = constrain(x, "batch", "act_seq" if mode == "train" else "seq", None)
            return x, out_cache

        if mode == "train" and cfg.remat and len(pattern) > 1:
            sublayer = jax.checkpoint(sublayer)  # per-sublayer remat
        x, out_cache = sublayer(x)
        new_cache.update(out_cache)
    return x, (new_cache or None)


def _xlstm_group(x, gp, cfg, hook_fn, *, mode, cache, group_idx, pad_mask=None):
    """hook_fn(sub_idx_or_None) -> hook for an inner layer."""
    _, per = group_structure(cfg)
    m = per - 1
    new_cache = {}

    def mlstm_one(j, xj, st):
        pj = jax.tree.map(lambda a: a[j], gp["mlstm"])
        h = rms_norm(xj, pj["ln"], cfg.norm_eps)
        y, st_new = xlstm_lib.mlstm_block(
            h, pj, hook_fn(j), n_heads=cfg.n_heads,
            chunk=min(cfg.attn_kv_chunk, 512), state=st,
            decode=(mode == "decode"), pad_mask=pad_mask,
        )
        out = xj + y
        out = constrain(out, "batch", "act_seq" if mode == "train" else "seq", None)
        return out, st_new

    if mode == "train" and cfg.remat:
        # per-sublayer remat: a group holds `per` layers; the group-level
        # remat alone would retain every sublayer's recurrence residuals
        mlstm_one = jax.checkpoint(mlstm_one, static_argnums=(0,))

    states = cache or {}
    c_list, n_list, m_list = [], [], []
    for j in range(m):
        st = None
        if cache is not None:
            st = (states["C"][j], states["n"][j], states["m"][j])
        x, st_new = mlstm_one(j, x, st)
        if mode in ("decode", "prefill"):
            c_list.append(st_new[0])
            n_list.append(st_new[1])
            m_list.append(st_new[2])
    if c_list:
        new_cache["C"] = jnp.stack(c_list)
        new_cache["n"] = jnp.stack(n_list)
        new_cache["m"] = jnp.stack(m_list)

    h = rms_norm(x, gp["slstm"]["ln"], cfg.norm_eps)
    st = None
    if cache is not None:
        st = (states["sc"], states["sn"], states["sh"], states["sm"])
    y, st_new = xlstm_lib.slstm_block(
        h, gp["slstm"], hook_fn(None), n_heads=cfg.n_heads,
        state=st, decode=(mode == "decode"), pad_mask=pad_mask,
    )
    x = x + y
    if mode in ("decode", "prefill"):
        new_cache["sc"], new_cache["sn"], new_cache["sh"], new_cache["sm"] = st_new
    return x, (new_cache or None)


def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token/frontend embedding -> (h (B,T,d), positions (T,))."""
    if cfg.frontend == "frames":
        h = batch["embeds"].astype(cfg.compute_dtype)
    elif cfg.frontend == "patch":
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = jnp.concatenate(
            [batch["patch_embeds"].astype(cfg.compute_dtype), tok.astype(cfg.compute_dtype)],
            axis=1,
        )
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    t = h.shape[1]
    return constrain(h, "batch", "seq", None), jnp.arange(t)


def _maybe_dequant(tree):
    """Dequantize Int8Weight leaves (int8 weight-streaming serving): called
    per layer-slice inside the scan so the bf16 copy is a fused transient —
    int8 is what streams from HBM."""
    from repro.quant.weights import Int8Weight, dequantize_params

    if any(isinstance(l, Int8Weight) for l in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, Int8Weight))):
        return dequantize_params(tree)
    return tree


def _run_stack(
    params, h, cfg: ModelConfig, *, mode, cache, pos, positions, analog,
    cache_len=None, lengths=None,
):
    """Scan over layer groups; returns (h, new_cache).

    ``lengths`` (B,): per-row true lengths for right-padded bucket batches.
    In prefill/train, positions >= length are pad: windowed ring caches are
    gathered from each row's last real tokens, recurrent (griffin/xlstm)
    scans treat pad steps as identity, and MoE routing drops pad tokens. In
    decode, a row with length 0 is batch padding (its token is masked out of
    MoE capacity; other families keep pad rows isolated by construction).
    """
    g, per = group_structure(cfg)
    rope = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    a_cfg = analog.cfg if analog is not None else None
    a_key = analog.key if analog is not None else None
    a_rep = getattr(analog, "n_repeats", 1) if analog is not None else 1
    a_scale = getattr(analog, "noise_scale", None) if analog is not None else None
    profile = getattr(analog, "profile", None) if analog is not None else None
    if profile is not None and a_rep != 1:
        raise ValueError(
            f"AnalogSpec carries both n_repeats={a_rep} and profile "
            f"{profile.name!r}; a profile is the per-layer form of the same "
            "knob and overrides n_repeats, which must stay 1"
        )
    energies = analog.energies["groups"] if analog is not None else None

    pad_mask = None
    valid_rows = None
    if lengths is not None:
        lengths = jnp.asarray(lengths)
        # real-row mask for batch-level noise folds (MoE expert sites):
        # length-0 batch-padding rows fold the XOR identity, so real traffic
        # draws the same expert noise at any pad count
        valid_rows = lengths > 0
        if mode == "decode":
            pad_mask = (lengths == 0)[:, None]  # (B, 1): batch-padding rows
        else:
            pad_mask = jnp.arange(h.shape[1])[None, :] >= lengths[:, None]

    def make_group_fwd(k_row):
        """Group forward at a static per-sublayer repeat row ``k_row``
        (length ``per``) — uniform serving passes one constant row; profile
        serving builds one of these per same-K scan segment."""

        def group_fwd(h, gp, g_cache, g_energies, idx):
            gp = _maybe_dequant(gp)
            if cfg.family == "xlstm":
                def hook_fn(sub):
                    le = None
                    if g_energies is not None:
                        le = {
                            k: (v[sub] if (sub is not None and v.ndim > 0 and k.startswith("mlstm")) else v)
                            for k, v in g_energies.items()
                        }
                    k_rep = k_row[sub] if sub is not None else k_row[per - 1]
                    return hook_for_layer(
                        a_cfg, le, a_key, idx, n_repeats=k_rep, valid=valid_rows,
                        noise_scale=a_scale,
                    )

                return _xlstm_group(
                    h, gp, cfg, hook_fn, mode=mode, cache=g_cache, group_idx=idx,
                    pad_mask=pad_mask,
                )

            def hook_fn(i):
                return hook_for_layer(
                    a_cfg, g_energies, a_key, idx, n_repeats=k_row[i],
                    valid=valid_rows, noise_scale=a_scale,
                )

            if cfg.family == "griffin":
                return _griffin_group(
                    h, gp, cfg, hook_fn, rope=rope, mode=mode, cache=g_cache,
                    pos=pos, pattern=cfg.griffin_pattern, cache_len=cache_len,
                    pad_mask=pad_mask, lengths=lengths,
                )
            return _transformer_group(
                h, gp, cfg, hook_fn, rope=rope, mode=mode, cache=g_cache, pos=pos,
                cache_len=cache_len, pad_mask=pad_mask, lengths=lengths,
            )

        if cfg.remat and mode == "train":
            group_fwd = jax.checkpoint(group_fwd, static_argnums=(), prevent_cse=False)
        return group_fwd

    def make_body(k_row):
        group_fwd = make_group_fwd(k_row)

        def body(h, xs):
            gp, g_cache, g_energies, idx = xs
            h, new_cache = group_fwd(h, gp, g_cache, g_energies, idx)
            return h, new_cache

        return body

    xs = (
        params["blocks"],
        cache["groups"] if cache is not None else None,
        energies,
        jnp.arange(g),
    )
    if profile is None:
        h, new_group_cache = jax.lax.scan(make_body((a_rep,) * per), h, xs)
        tail_ks = None
    else:
        # segmented scan: contiguous scan groups sharing a K-row share one
        # trace; distinct-K segments each get their own (K is static in the
        # fused kernel). Group indices stay global (xs carries arange(g)), so
        # every layer's noise stream is identical to the unsegmented scan.
        rows, tail_ks = profile_rows(cfg, profile)
        parts = []
        for start, stop, k_row in coalesce_runs(rows, coalesce=profile.coalesce):
            seg_xs = jax.tree.map(lambda a: a[start:stop], xs)
            h, seg_cache = jax.lax.scan(make_body(k_row), h, seg_xs)
            parts.append(seg_cache)
        if not parts:  # g == 0 (every layer in the griffin tail): empty scan
            h, new_group_cache = jax.lax.scan(make_body((1,) * per), h, xs)
        elif len(parts) == 1 or parts[0] is None:
            new_group_cache = parts[0]
        else:
            new_group_cache = jax.tree.map(
                lambda *a: jnp.concatenate(a, axis=0), *parts
            )

    new_cache = {"groups": new_group_cache} if new_group_cache is not None else None

    # griffin tail layers (outside the group scan)
    if cfg.family == "griffin" and "tail" in params:
        tail_n = params["tail"]["ln1"].shape[0]
        tail_cache = []
        for j in range(tail_n):
            tp = _maybe_dequant(jax.tree.map(lambda a: a[j], params["tail"]))
            t_cache = None
            if cache is not None:
                t_cache = jax.tree.map(lambda a: a[j], cache["tail"])
            t_energies = (
                jax.tree.map(lambda a: a[j], analog.energies["tail"])
                if analog is not None
                else None
            )
            tail_k = tail_ks[j] if tail_ks is not None else a_rep
            hook = hook_for_layer(
                a_cfg, t_energies, a_key, g * per + j, n_repeats=tail_k,
                valid=valid_rows, noise_scale=a_scale,
            )
            h, tc = _griffin_group(
                h, tp, cfg, lambda i, hook=hook: hook, rope=rope, mode=mode,
                cache=t_cache, pos=pos, pattern=("rec",), tail=True,
                cache_len=cache_len, pad_mask=pad_mask, lengths=lengths,
            )
            if tc is not None:
                tail_cache.append({"h0": tc["h0"], "conv0": tc["conv0"]})
        if tail_cache and new_cache is not None:
            new_cache["tail"] = jax.tree.map(lambda *a: jnp.stack(a), *tail_cache)
    return h, new_cache


def forward_hidden(
    params, batch, cfg: ModelConfig, *, mode="train", cache=None, pos=None,
    analog=None, cache_len=None, lengths=None,
):
    h, positions = _embed_inputs(params, batch, cfg)
    h, new_cache = _run_stack(
        params, h, cfg, mode=mode, cache=cache, pos=pos, positions=positions,
        analog=analog, cache_len=cache_len, lengths=lengths,
    )
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    return h, new_cache


def _lm_head(params, cfg: ModelConfig) -> Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return _maybe_dequant(params["lm_head"])


def train_loss(params, batch, cfg: ModelConfig, analog=None) -> Array:
    h, _ = forward_hidden(params, batch, cfg, mode="train", analog=analog)
    hook = MatmulHook()
    if analog is not None:
        from repro.models.hooks import AnalogHook

        hook = AnalogHook(
            cfg=analog.cfg,
            energies={"lm_head": analog.energies["lm_head"]},
            key=fold_key(analog.key, 0x1A57),
            noise_scale=getattr(analog, "noise_scale", None),
        )
    return chunked_xent(
        h,
        _lm_head(params, cfg),
        batch["labels"],
        chunk=cfg.loss_chunk,
        n_codebooks=cfg.n_codebooks,
        vocab=cfg.vocab_size,
        hook=hook,
    )


def logits_last(params, h_last, cfg: ModelConfig) -> Array:
    """(B, 1, d) -> (B, 1, n_codebooks, V) (vocab padding sliced off)."""
    b = h_last.shape[0]
    logits = jnp.matmul(h_last, _lm_head(params, cfg).astype(h_last.dtype))
    logits = logits.reshape(b, 1, cfg.n_codebooks, cfg.padded_vocab)
    return logits[..., : cfg.vocab_size]


# ===========================================================================
# cache init / prefill / decode
# ===========================================================================


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None) -> PyTree:
    dtype = dtype or cfg.compute_dtype
    g, per = group_structure(cfg)
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    groups: Dict[str, Array] = {}
    if cfg.family in ("dense", "moe"):
        s = cache_len if cfg.sliding_window is None else min(cache_len, cfg.sliding_window)
        groups["k"] = jnp.zeros((g, per, batch, s, kh, hd), dtype)
        groups["v"] = jnp.zeros((g, per, batch, s, kh, hd), dtype)
    elif cfg.family == "griffin":
        r, cw, w = cfg.rnn_width, cfg.conv_width, cfg.local_window
        for i, kind in enumerate(cfg.griffin_pattern):
            if kind == "rec":
                groups[f"h{i}"] = jnp.zeros((g, batch, r), jnp.float32)
                groups[f"conv{i}"] = jnp.zeros((g, batch, cw - 1, r), dtype)
            else:
                s = min(cache_len, w)
                groups[f"k{i}"] = jnp.zeros((g, batch, s, kh, hd), dtype)
                groups[f"v{i}"] = jnp.zeros((g, batch, s, kh, hd), dtype)
    elif cfg.family == "xlstm":
        m = per - 1
        d, h_ = cfg.d_model, cfg.n_heads
        hd_ = d // h_
        groups["C"] = jnp.zeros((g, m, batch, h_, hd_, hd_), jnp.float32)
        groups["n"] = jnp.zeros((g, m, batch, h_, hd_), jnp.float32)
        groups["m"] = jnp.full((g, m, batch, h_), -1e30, jnp.float32)
        groups["sc"] = jnp.zeros((g, batch, d), jnp.float32)
        groups["sn"] = jnp.zeros((g, batch, d), jnp.float32)
        groups["sh"] = jnp.zeros((g, batch, d), jnp.float32)
        groups["sm"] = jnp.full((g, batch, d), -1e30, jnp.float32)
    cache = {"groups": groups}
    if cfg.family == "griffin" and cfg.n_layers % len(cfg.griffin_pattern):
        tail = cfg.n_layers % len(cfg.griffin_pattern)
        cache["tail"] = {
            "h0": jnp.zeros((tail, batch, cfg.rnn_width), jnp.float32),
            "conv0": jnp.zeros((tail, batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
        }
    return cache


def cache_axes(cfg: ModelConfig) -> PyTree:
    """Logical sharding axes mirroring init_cache's structure.

    Transformer KV caches shard (batch -> data, sequence -> model): the
    decode softmax then runs as a distributed flash-decode (XLA inserts the
    max/sum all-reduces over the sequence shards). Griffin window caches are
    small (window 2048) — batch-sharded only. xLSTM matrix memories shard
    batch and the value dim.
    """
    g, per = group_structure(cfg)
    groups: Dict[str, tuple] = {}
    if cfg.family in ("dense", "moe"):
        ax = ("layers", None, "batch", "kv_seq", None, None)
        groups["k"] = ax
        groups["v"] = ax
    elif cfg.family == "griffin":
        for i, kind in enumerate(cfg.griffin_pattern):
            if kind == "rec":
                groups[f"h{i}"] = ("layers", "batch", "rnn")
                groups[f"conv{i}"] = ("layers", "batch", None, "rnn")
            else:
                groups[f"k{i}"] = ("layers", "batch", None, None, None)
                groups[f"v{i}"] = ("layers", "batch", None, None, None)
    elif cfg.family == "xlstm":
        groups["C"] = ("layers", "stack", "batch", None, None, "rnn")
        groups["n"] = ("layers", "stack", "batch", None, None)
        groups["m"] = ("layers", "stack", "batch", None)
        for s in ("sc", "sn", "sh", "sm"):
            groups[s] = ("layers", "batch", "rnn")
    axes = {"groups": groups}
    if cfg.family == "griffin" and cfg.n_layers % len(cfg.griffin_pattern):
        axes["tail"] = {
            "h0": ("layers", "batch", "rnn"),
            "conv0": ("layers", "batch", None, "rnn"),
        }
    return axes


def scatter_cache_rows(cfg: ModelConfig, dst: PyTree, src: PyTree, slot_ids) -> PyTree:
    """Scatter ``src`` cache rows into ``dst`` pool slots (continuous-batching
    admission): every leaf of a freshly prefilled cache (batch ``b``) is
    written into the persistent decode pool's cache (batch ``slots``) at
    ``slot_ids`` (b,) along its batch dim. Both trees must share ``cache_len``
    (the engine prefills at the pool's cache length, so layer/seq layouts
    already match); the batch axis of each leaf is located via
    ``cache_axes``. Out-of-range slot ids (>= slots) are dropped — the
    engine points prefill batch-padding rows at ``slots`` so they never
    land anywhere. Runs under jit: admission is a device-side scatter, the
    cache never round-trips through the host.
    """
    axes_leaves = jax.tree.leaves(
        cache_axes(cfg), is_leaf=lambda x: isinstance(x, tuple)
    )
    dst_leaves, treedef = jax.tree.flatten(dst)
    src_leaves = jax.tree.leaves(src)
    assert len(dst_leaves) == len(src_leaves) == len(axes_leaves)
    slot_ids = jnp.asarray(slot_ids)
    out = []
    for d, s, ax in zip(dst_leaves, src_leaves, axes_leaves):
        b_ax = ax.index("batch")
        dm = jnp.moveaxis(d, b_ax, 0)
        sm = jnp.moveaxis(s, b_ax, 0)
        dm = dm.at[slot_ids].set(sm.astype(dm.dtype), mode="drop")
        out.append(jnp.moveaxis(dm, 0, b_ax))
    return treedef.unflatten(out)


def batch_axes(batch: dict) -> dict:
    """Logical axes for a batch dict (tokens/embeds/labels/patch_embeds)."""
    out = {}
    for k, v in batch.items():
        nd = v.ndim if hasattr(v, "ndim") else len(v.shape)
        out[k] = ("batch",) + (None,) * (nd - 1)
    return out


def prefill(params, batch, cfg: ModelConfig, analog=None, cache_len=None, lengths=None):
    """Run the prompt; returns (cache, last_hidden (B,1,d)).

    ``lengths`` (B,): per-row true prompt lengths for bucket-padded batches —
    the last hidden is gathered at each row's final *real* token, and pad
    positions are inert in every family's state: global causal attention
    masks them for real queries by construction; windowed ring caches gather
    each row's last real `w` tokens; griffin/xlstm recurrences treat pad
    steps as identity (state carries through exactly); MoE routing drops pad
    tokens from expert capacity. A length of 0 marks a batch-padding row
    (zero state, outputs garbage-but-isolated).
    """
    h, cache = forward_hidden(
        params, batch, cfg, mode="prefill", analog=analog, cache_len=cache_len,
        lengths=lengths,
    )
    if lengths is None:
        return cache, h[:, -1:]
    idx = jnp.clip(jnp.asarray(lengths) - 1, 0, h.shape[1] - 1)[:, None, None]
    h_last = jnp.take_along_axis(h, jnp.broadcast_to(idx, (h.shape[0], 1, h.shape[2])), axis=1)
    return cache, h_last


def decode_step(params, cache, batch, pos, cfg: ModelConfig, analog=None, lengths=None):
    """One token step. batch: {"tokens": (B,1)} or {"embeds": (B,1,d)}.
    ``pos``: position of the new token — scalar, or (B,) per-row positions
    (bucket-batched serving: requests with different prompt lengths decode
    together, each row at its own position). ``lengths`` (B,): per-row true
    prompt lengths; a row with length 0 is batch padding, masked out of MoE
    expert capacity (all other ops are row-independent, so pad rows can't
    touch real rows regardless). Returns (logits, new_cache)."""
    if cfg.frontend == "patch" and "patch_embeds" not in batch:
        # decode consumes plain tokens after the image prefix
        h = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cfg.compute_dtype)
    else:
        h, _ = _embed_inputs(params, batch, cfg)
    pos = jnp.asarray(pos)
    positions = pos[:, None] if pos.ndim else jnp.full((h.shape[0], 1), pos)
    h, new_cache = _run_stack(
        params, h, cfg, mode="decode", cache=cache, pos=pos,
        positions=positions, analog=analog, lengths=lengths,
    )
    h = rms_norm(h, params["final_ln"], cfg.norm_eps)
    return logits_last(params, h, cfg), new_cache
