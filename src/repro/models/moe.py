"""Mixture-of-Experts block: GShard-style grouped einsum dispatch with
capacity-factor token dropping, top-k routing, optional shared experts.

Tokens are processed in small groups so the one-hot dispatch/combine tensors
stay tiny relative to expert compute. Experts shard on the "model" mesh axis
(expert parallelism); XLA inserts the all-to-all at the dispatch einsum
boundary (visible in the dry-run collective analysis).

Analog integration: expert matmuls run through ``hook.batched`` with
per-expert energies — expert granularity is the paper's "per-channel"
idea lifted to MoE (§V: "energy can also be allocated at a finer scale").
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.hooks import MatmulHook
from repro.models.layers import mlp
from repro.models.sharding import constrain

Array = jax.Array


def router_topk(logits: Array, top_k: int):
    """probs/ids of the top-k experts; weights renormalized over the k."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, top_k)  # (..., k)
    if top_k > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    return gate_vals, ids


def make_dispatch(
    ids: Array, gate_vals: Array, n_experts: int, capacity: int,
    valid: Array | None = None,
) -> tuple[Array, Array]:
    """GShard dispatch/combine tensors.

    ids/gate_vals: (G, S, k). Returns (dispatch (G,S,E,C) bool-ish,
    combine (G,S,E,C) f32). Earlier routing slots get capacity priority.

    ``valid``: (G, S) bool — tokens marked False (bucket padding in serving)
    are dropped from routing entirely: they occupy no expert capacity, shift
    no real token's queue position, and their combine weights are zero.
    Masking router *logits* alone cannot do this (a softmax over masked
    logits still tops-k somewhere), so padding is excluded here at dispatch.
    """
    g, s, k = ids.shape
    counts = jnp.zeros((g, n_experts), jnp.int32)
    combine = jnp.zeros((g, s, n_experts, capacity), jnp.float32)
    for slot in range(k):
        onehot = jax.nn.one_hot(ids[..., slot], n_experts, dtype=jnp.int32)  # (G,S,E)
        if valid is not None:
            onehot = onehot * valid.astype(jnp.int32)[..., None]
        # position of each token within its expert queue (exclusive cumsum)
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts[:, None, :]
        keep = (pos < capacity) & (onehot > 0)
        pos_oh = jax.nn.one_hot(pos, capacity, dtype=jnp.float32)  # (G,S,E,C)
        disp_slot = pos_oh * keep[..., None].astype(jnp.float32)
        combine = combine + disp_slot * gate_vals[..., slot][..., None, None]
        counts = counts + jnp.sum(onehot * keep.astype(jnp.int32), axis=1)
    dispatch = (combine > 0.0).astype(jnp.float32)
    return dispatch, combine


def moe_block(
    x: Array,
    p: Dict[str, Array],
    cfg: ModelConfig,
    hook: MatmulHook,
    pad_mask: Array | None = None,
) -> Array:
    """x: (B, T, d) -> (B, T, d).

    ``pad_mask`` (B, T): True marks bucket-padding tokens (serving). They are
    excluded from expert dispatch — no capacity consumed, zero output — so a
    real token's routing depends only on the real tokens sharing its group.
    """
    b, t, d = x.shape
    n_tok = b * t
    gs = min(cfg.moe_group_size, n_tok)
    while n_tok % gs:  # largest divisor of n_tok not exceeding the target
        gs -= 1
    g = n_tok // gs
    e = cfg.n_experts
    k = cfg.top_k
    cap = max(1, int(-(-gs * k * cfg.capacity_factor // e)))

    xg = constrain(x.reshape(g, gs, d), "tokens", None, None)
    # route on the (B, T, d) layout, not the grouped one: rowwise-identical
    # math, but the leading dim stays the batch so stacked per-request noise
    # keys (serving) vmap per request — router noise is request-isolated
    logits = hook("router", x, p["router"]).reshape(g, gs, e)  # (G, S, E)
    logits = constrain(logits, "tokens", None, None)
    gate_vals, ids = router_topk(logits, k)
    valid = None if pad_mask is None else jnp.logical_not(pad_mask).reshape(g, gs)
    dispatch, combine = make_dispatch(ids, gate_vals, e, cap, valid=valid)
    if cfg.moe_ff_split > 1:
        # virtual experts: route each token to all ff-splits of its expert;
        # the combine sum then adds the down-proj partials (exact).
        dispatch = jnp.repeat(dispatch, cfg.moe_ff_split, axis=2)
        combine = jnp.repeat(combine, cfg.moe_ff_split, axis=2)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    # token-major dispatch (fully local: every operand is G-sharded), THEN an
    # explicit reshard to the expert-major layout — the all-to-all boundary
    # of expert parallelism. Emitting the expert-major einsum directly makes
    # the SPMD partitioner all-gather the whole token array instead.
    xe = jnp.einsum("gsd,gsec->gecd", xg, dispatch)
    # no-op forward; in backward this forces the cotangent back to token
    # sharding BEFORE the dispatch-einsum VJP (otherwise the mismatched
    # batch-dim shardings make the partitioner replicate the whole tensor)
    xe = constrain(xe, "tokens", None, None, None)
    xe = jnp.moveaxis(xe, 1, 0)  # (E, G, C, d)
    # two-step reshard: (1) swap the data-axis owner G->E while keeping G on
    # (pod, model) (an all-to-all), (2) gather G over "model" only — G keeps
    # its "pod" shard and E stays sliced. A one-step constraint makes the
    # partitioner all-gather the full expert-major tensor before slicing E.
    xe = constrain(xe, "experts", "tokens_pm", None, None)
    xe = constrain(xe, "experts", "pod_tokens", None, "expert_embed")

    if cfg.mlp_type == "swiglu":
        gate = hook.batched("moe_gate", xe, p["w_gate"])
        up = hook.batched("moe_up", xe, p["w_up"])
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = hook.batched("moe_in", xe, p["w_in"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    h = constrain(h, "experts", "pod_tokens", None, "expert_mlp")
    ye = hook.batched("moe_down", h, p["w_down"])  # (E, G, C, d)
    # reverse path: reduce-scatter G onto "model", all-to-all E->G on "data"
    ye = constrain(ye, "experts", "tokens_pm", None, None)
    ye = constrain(jnp.moveaxis(ye, 0, 1), "tokens", None, None, None)

    y = jnp.einsum("gecd,gsec->gsd", ye, combine)
    y = y.reshape(b, t, d)

    if cfg.n_shared_experts:
        y = y + mlp(x, p["shared"], cfg.mlp_type, hook, prefix="moe_shared")
    return y


def aux_load_balance_loss(logits: Array, ids: Array, n_experts: int) -> Array:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e (for training)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    p_mean = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    top1 = ids[..., 0].reshape(-1)
    f = jnp.mean(jax.nn.one_hot(top1, n_experts, dtype=jnp.float32), axis=0)
    return n_experts * jnp.sum(f * p_mean)
