"""Logical-axis sharding: params and activations are annotated with logical
axis names; a rule table maps them onto physical mesh axes.

Physical meshes (launch/mesh.py):
  single-pod: ("data", "model") = (16, 16)
  multi-pod:  ("pod", "data", "model") = (2, 16, 16)

The default rules implement TP on "model" (heads / mlp / vocab / experts),
DP on ("pod","data") for batch, ZeRO-1 optimizer-state sharding on
("pod","data") stacked on top of the param's own TP sharding, and KV-cache
sequence sharding on "model" for large decode caches.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    # Megatron-style sequence parallelism: the residual stream is kept
    # sequence-sharded on "model" at layer boundaries (remat saves 1/16 the
    # activations; XLA inserts all-gather/reduce-scatter at the transitions
    # into/out of attention and TP matmuls).
    "act_seq": "model",
    # MoE token groups: the (batch x seq) reshape inherits the full product
    # sharding; named so dispatch/combine einsums stay local and the
    # expert-major reshard is an explicit all-to-all boundary. The _pm/_pod
    # stages keep the "pod" component in place during the expert reshard —
    # without them the multi-pod partitioner gathers the full token array.
    "tokens": ("pod", "data", "model"),
    "tokens_pm": ("pod", "model"),
    "pod_tokens": ("pod",),
    "kv_seq": "model",  # decode-cache sequence dim (distributed flash-decode)
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    # experts span the data axis and expert-ff the model axis, so MoE weights
    # shard over ALL chips (llama4's 387B of experts cannot live on 16): the
    # token->expert boundary becomes an all-to-all across "data". When the
    # expert count does not divide "data" (grok: 8 experts on 16), the
    # shape-aware resolver falls back to sharding the expert d_model dim
    # ("expert_embed") over "data" instead — 2D expert tensor parallelism.
    "experts": "data",
    "expert_mlp": "model",
    "expert_embed": "data",
    "capacity": None,
    "layers": None,
    "rnn": "model",  # xLSTM / RG-LRU feature dim
    "conv": None,
    "window": None,
    "stack": None,
    "zero": ("pod", "data"),  # extra axis for ZeRO-1 optimizer states
    None: None,
}

#: pure data parallelism: small models (~<4B) replicate weights and put the
#: whole mesh behind the batch; ZeRO-1 shards optimizer state over all chips.
DP_RULES = {
    **{k: None for k in DEFAULT_RULES},
    "batch": ("pod", "data", "model"),
    "zero": ("pod", "data", "model"),
}

#: serving: every logical axis replicated. The serving engine keeps all
#: jit-boundary arrays (params, decode caches, tokens, keys) replicated so
#: AOT executables survive mesh resize, and tensor parallelism lives ONLY
#: inside analog_dot's shard_map (column-parallel matmul shards whose
#: counter-based noise is salted on global tile coordinates). Under these
#: rules the model code's constrain() calls resolve to replication, so the
#: decode cache is never sequence-sharded out from under the pools.
SERVING_RULES = {k: None for k in DEFAULT_RULES}

PROFILES = {"tp": DEFAULT_RULES, "dp": DP_RULES, "serving": SERVING_RULES}

_state = threading.local()


def set_mesh(mesh: Optional[Mesh]) -> None:
    _state.mesh = mesh


def get_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def set_rules(rules: Optional[dict]) -> None:
    _state.rules = rules


def get_rules() -> dict:
    return getattr(_state, "rules", None) or DEFAULT_RULES


@contextlib.contextmanager
def use_rules(rules: Optional[dict]):
    prev = getattr(_state, "rules", None)
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = get_mesh()
    prev_rules = getattr(_state, "rules", None)
    set_mesh(mesh)
    if rules is not None:
        set_rules(rules)
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        set_mesh(prev)
        set_rules(prev_rules)


def _candidates(axis: Optional[str], rules: dict, mesh: Mesh):
    phys = rules.get(axis, None)
    if phys is None:
        return ()
    if isinstance(phys, str):
        phys = (phys,)
    return tuple(a for a in phys if a in mesh.axis_names)


def spec(
    names: Sequence[Optional[str]],
    rules: Optional[dict] = None,
    mesh=None,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Logical axis names -> PartitionSpec under the current mesh.

    Shape-aware: a mesh axis is only assigned to a dim if (a) the dim size is
    divisible by the (product of) mesh axis sizes — jit argument shardings
    require exact divisibility — and (b) the mesh axis is not already used by
    an earlier dim of the same tensor (conflict resolution in dim order,
    which is what lets grok's 8 experts fall back to 2D d_model sharding).
    Tuples degrade to their longest feasible prefix. Without a shape, no
    divisibility filtering is applied.
    """
    mesh = mesh or get_mesh()
    rules = rules or get_rules()
    if mesh is None:
        return P()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    used: set = set()
    out = []
    for i, n in enumerate(names):
        cand = tuple(a for a in _candidates(n, rules, mesh) if a not in used)
        chosen = None
        if cand:
            if shape is None:
                chosen = cand
            else:
                dim = shape[i]
                for k in range(len(cand), 0, -1):
                    prefix = cand[:k]
                    prod = 1
                    for a in prefix:
                        prod *= sizes[a]
                    if prod > 1 and dim % prod == 0:
                        chosen = prefix
                        break
        if chosen:
            used.update(chosen)
            out.append(chosen if len(chosen) > 1 else chosen[0])
        else:
            out.append(None)
    return P(*out)


def constrain(x: jax.Array, *names: Optional[str], rules: Optional[dict] = None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    mesh = get_mesh()
    if mesh is None or mesh.size == 1:
        return x
    s = NamedSharding(mesh, spec(names, rules, mesh, shape=x.shape))
    return jax.lax.with_sharding_constraint(x, s)


def named_sharding(
    names: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    rules=None,
    shape: Optional[Sequence[int]] = None,
):
    mesh = mesh or get_mesh()
    if mesh is None:
        raise ValueError("no mesh active")
    return NamedSharding(mesh, spec(names, rules, mesh, shape=shape))


def tree_shardings(axes_tree, shapes_tree=None, mesh: Optional[Mesh] = None, rules=None):
    """Map a tree of logical-axis tuples (+ optional matching shapes tree)
    to a tree of NamedShardings."""
    mesh = mesh or get_mesh()
    is_leaf = lambda x: isinstance(x, tuple)
    if shapes_tree is None:
        return jax.tree.map(
            lambda names: named_sharding(names, mesh, rules), axes_tree, is_leaf=is_leaf
        )
    return jax.tree.map(
        lambda names, sds: named_sharding(
            names, mesh, rules, shape=getattr(sds, "shape", sds)
        ),
        axes_tree,
        shapes_tree,
        is_leaf=is_leaf,
    )


def zero1_axes(axes: Tuple[Optional[str], ...]) -> Tuple[Optional[str], ...]:
    """Optimizer-state axes for a param: add 'zero' sharding on the largest
    still-replicated dim (ZeRO-1). Prefers the first None axis of rank>=1."""
    if not axes:
        return axes
    out = list(axes)
    for i, a in enumerate(out):
        if a is None:
            out[i] = "zero"
            return tuple(out)
    return tuple(out)
