"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, sequential) with stabilized exponential gating.

mLSTM recurrence per head (state C: (dk, dv), n: (dk,), m: scalar):

    m_t = max(logf_t + m_{t-1}, logi_t)
    C_t = exp(logf_t + m_{t-1} - m_t) C_{t-1} + exp(logi_t - m_t) k_t v_t^T
    n_t = exp(logf_t + m_{t-1} - m_t) n_{t-1} + exp(logi_t - m_t) k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))

evaluated CHUNKWISE: sequential lax.scan over chunks carrying (C, n, m, b_end)
with quadratic intra-chunk attention — the standard linear-attention chunked
dataflow (memory O(T*d + dk*dv) instead of O(T*dk*dv)).

sLSTM uses a sequential scan over time with block-diagonal (per-head)
recurrent weights and the same m-stabilized exponential gates.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.hooks import MatmulHook

Array = jax.Array


def mlstm_chunkwise(
    q: Array,
    k: Array,
    v: Array,
    log_i: Array,
    log_f: Array,
    *,
    chunk: int,
    state: Optional[Tuple[Array, Array, Array]] = None,
) -> Tuple[Array, Tuple[Array, Array, Array]]:
    """q,k,v: (B, T, H, D); log_i/log_f: (B, T, H) (pre-activation gates,
    log_i = i_tilde, log_f = logsigmoid(f_tilde)). Returns (h, final_state)
    with state = (C (B,H,D,D), n (B,H,D), m (B,H))."""
    b, t, h, d = q.shape
    chunk = min(chunk, t)
    while t % chunk:  # largest divisor not exceeding the requested chunk
        chunk -= 1
    nc = t // chunk
    scale = 1.0 / (d**0.5)

    def resh(x):  # (B,T,H,...) -> (nc, B, chunk, H, ...)
        x = x.reshape((b, nc, chunk) + x.shape[2:])
        return jnp.moveaxis(x, 1, 0)

    qs, ks, vs = resh(q.astype(jnp.float32) * scale), resh(k.astype(jnp.float32)), resh(
        v.astype(jnp.float32)
    )
    lis, lfs = resh(log_i.astype(jnp.float32)), resh(log_f.astype(jnp.float32))

    if state is None:
        c0 = jnp.zeros((b, h, d, d), jnp.float32)
        n0 = jnp.zeros((b, h, d), jnp.float32)
        m0 = jnp.full((b, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = (s.astype(jnp.float32) for s in state)

    idx = jnp.arange(chunk)
    causal = idx[:, None] >= idx[None, :]  # (chunk, chunk)

    @jax.checkpoint  # recompute intra-chunk tensors in bwd; save carries only
    def body(carry, xs):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, li, lf = xs  # (B, chunk, H, ...)
        bcum = jnp.cumsum(lf, axis=1)  # (B, chunk, H) inclusive cumsum of logf
        b_end = bcum[:, -1]  # (B, H)

        # log weight of source j seen from target i: bcum_i - bcum_j + li_j
        # stabilizer per target i:
        src = -bcum + li  # (B, chunk, H): -b_j + logi_j
        src_max = jax.lax.cummax(src, axis=1)  # running max over j<=i
        m_intra = bcum + src_max  # (B, chunk, H)
        m_inter = bcum + m_prev[:, None, :]  # (B, chunk, H)
        m_i = jnp.maximum(m_intra, m_inter)

        # intra-chunk
        logw = (
            bcum[:, :, None, :] - bcum[:, None, :, :] + li[:, None, :, :]
            - m_i[:, :, None, :]
        )  # (B, i, j, H)
        logw = jnp.where(causal[None, :, :, None], logw, -1e30)
        wgt = jnp.exp(logw)
        s_ij = jnp.einsum("bihd,bjhd->bijh", qc, kc) * wgt  # decayed scores
        num_intra = jnp.einsum("bijh,bjhd->bihd", s_ij, vc)
        # denominator n_i . q_i == sum_j wgt_j (q_i . k_j) == sum_j s_ij
        den_intra = jnp.sum(s_ij, axis=2)  # (B, i, H)

        # inter-chunk (carried state)
        w_inter = jnp.exp(m_inter - m_i)  # (B, chunk, H)
        num_inter = jnp.einsum("bihd,bhde->bihe", qc, c_prev) * w_inter[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qc, n_prev) * w_inter

        num = num_intra + num_inter
        den = den_intra + den_inter
        denom = jnp.maximum(jnp.abs(den), jnp.exp(-m_i))
        h_c = num / denom[..., None]

        # carry update to end of chunk
        m_next = jnp.maximum(b_end + m_prev, b_end + src_max[:, -1])
        wk = jnp.exp(b_end[:, None, :] + src - m_next[:, None, :])  # (B, j, H)
        c_next = (
            jnp.exp(b_end + m_prev - m_next)[:, :, None, None] * c_prev
            + jnp.einsum("bjh,bjhd,bjhe->bhde", wk, kc, vc)
        )
        n_next = (
            jnp.exp(b_end + m_prev - m_next)[:, :, None] * n_prev
            + jnp.einsum("bjh,bjhd->bhd", wk, kc)
        )
        return (c_next, n_next, m_next), h_c

    (c_f, n_f, m_f), hs = jax.lax.scan(body, (c0, n0, m0), (qs, ks, vs, lis, lfs))
    h_out = jnp.moveaxis(hs, 0, 1).reshape(b, t, h, d)
    return h_out.astype(q.dtype), (c_f, n_f, m_f)


def mlstm_decode(
    q: Array,
    k: Array,
    v: Array,
    log_i: Array,
    log_f: Array,
    state: Tuple[Array, Array, Array],
) -> Tuple[Array, Tuple[Array, Array, Array]]:
    """Single-step mLSTM. q,k,v: (B, 1, H, D); gates (B, 1, H)."""
    b, _, h, d = q.shape
    c0, n0, m0 = (s.astype(jnp.float32) for s in state)
    scale = 1.0 / (d**0.5)
    qt = q[:, 0].astype(jnp.float32) * scale
    kt = k[:, 0].astype(jnp.float32)
    vt = v[:, 0].astype(jnp.float32)
    li = log_i[:, 0].astype(jnp.float32)
    lf = log_f[:, 0].astype(jnp.float32)

    m_t = jnp.maximum(lf + m0, li)
    fw = jnp.exp(lf + m0 - m_t)
    iw = jnp.exp(li - m_t)
    c_t = fw[..., None, None] * c0 + iw[..., None, None] * (
        kt[..., :, None] * vt[..., None, :]
    )
    n_t = fw[..., None] * n0 + iw[..., None] * kt
    num = jnp.einsum("bhd,bhde->bhe", qt, c_t)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n_t)), jnp.exp(-m_t))
    h_t = (num / den[..., None]).reshape(b, 1, h, d)
    return h_t.astype(q.dtype), (c_t, n_t, m_t)


def mlstm_block(
    x: Array,
    p: Dict[str, Array],
    hook: MatmulHook,
    *,
    n_heads: int,
    chunk: int = 256,
    state=None,
    decode: bool = False,
    pad_mask: Optional[Array] = None,
):
    """Full mLSTM block: up-proj (x2), conv-free simplified variant with
    q/k/v projections, exponential gates, headwise RMS-ish norm, gated
    output, down projection.

    ``pad_mask`` (B, T): right-padded batches. Pad steps are forced to the
    recurrence identity at the gate level (log_i = -inf, log_f = 0), so they
    contribute nothing to the matrix memory (C, n, m) and the carried state
    crosses the pad suffix bit-exactly. Pad-position outputs are garbage the
    caller must never read."""
    b, t, d = x.shape
    hd = d // n_heads
    z = hook("mlstm_z", x, p["w_z"])  # (B,T,d) output gate branch
    q = hook("mlstm_q", x, p["w_q"]).reshape(b, t, n_heads, hd)
    k = hook("mlstm_k", x, p["w_k"]).reshape(b, t, n_heads, hd)
    v = hook("mlstm_v", x, p["w_v"]).reshape(b, t, n_heads, hd)
    gates = x.astype(jnp.float32) @ p["w_gates"].astype(jnp.float32) + p["b_gates"]
    li, lf_pre = jnp.split(gates, 2, axis=-1)  # (B,T,H) each
    lf = jax.nn.log_sigmoid(lf_pre)
    if pad_mask is not None:
        li = jnp.where(pad_mask[..., None], -1e30, li)  # exp(li - m) -> 0
        lf = jnp.where(pad_mask[..., None], 0.0, lf)  # carry weight exp(0) = 1

    if decode:
        h, new_state = mlstm_decode(q, k, v, li, lf, state)
    else:
        h, new_state = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk, state=state)

    # headwise normalization + output gating
    h32 = h.astype(jnp.float32)
    var = jnp.mean(h32 * h32, axis=-1, keepdims=True)
    h_n = h32 * jax.lax.rsqrt(var + 1e-6) * (1.0 + p["norm"].reshape(n_heads, hd))
    h_n = h_n.reshape(b, t, d).astype(x.dtype)
    y = h_n * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = hook("mlstm_o", y, p["w_o"])
    return y, new_state


def slstm_block(
    x: Array,
    p: Dict[str, Array],
    hook: MatmulHook,
    *,
    n_heads: int,
    state=None,
    decode: bool = False,
    pad_mask: Optional[Array] = None,
):
    """sLSTM block: sequential scan with block-diagonal recurrent weights.

    state = (c, n, h, m) each (B, d). Gates z/i/f/o from W x + R h_{t-1}.

    ``pad_mask`` (B, T): right-padded batches. Pad steps pin the gate
    pre-activations (i -> -inf, f -> +inf) so (c, n, m) carry through the pad
    suffix exactly; the recurrent input h drifts at pad steps (its o-gated
    readout is recomputed), so the returned h state is re-gathered at each
    row's last real step. Pad-position outputs are garbage to the caller.
    """
    b, t, d = x.shape
    hd = d // n_heads
    # feedforward part of all four gates at once: (B, T, 4d)
    wx = hook("slstm_wx", x, p["w_x"]).astype(jnp.float32) + p["b"].astype(jnp.float32)
    if pad_mask is not None:
        # gate column blocks of wx: [z | i | f | o]; the recurrent term added
        # per step is O(1)-sized and absorbed by the +-1e30 pins in f32
        col = jnp.arange(4 * d) // d
        pad3 = pad_mask[..., None]
        wx = jnp.where(pad3 & (col == 1), -1e30, wx)  # iw = exp(i - m) -> 0
        wx = jnp.where(pad3 & (col == 2), 1e30, wx)  # f = logsig(inf) = 0 -> fw = 1
    # broadcast the recurrent weights over batch BEFORE the time scan: the
    # per-step weight-grad contributions then accumulate locally in the scan
    # carry and the batch reduction happens once at the broadcast transpose
    # (otherwise SPMD all-reduces a (4,H,hd,hd) grad every timestep).
    r = jnp.broadcast_to(
        p["r"].astype(jnp.float32), (b,) + p["r"].shape
    )  # (B, 4, H, hd, hd)

    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        state = (zeros, zeros, zeros, jnp.full((b, d), -1e30, jnp.float32))

    @jax.checkpoint  # per-timestep remat: save the (c, n, h, m) carries only
    def step(carry, wx_t):
        c, n, h_prev, m = carry
        hb = h_prev.reshape(b, n_heads, hd)
        rec = jnp.einsum("bhk,bghkl->bghl", hb, r).reshape(b, 4, d)
        pre = wx_t.reshape(b, 4, d) + rec
        z_t = jnp.tanh(pre[:, 0])
        i_t = pre[:, 1]  # log-space input gate
        f_t = jax.nn.log_sigmoid(pre[:, 2])  # log-space forget gate
        o_t = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(f_t + m, i_t)
        iw = jnp.exp(i_t - m_new)
        fw = jnp.exp(f_t + m - m_new)
        c_new = fw * c + iw * z_t
        n_new = fw * n + iw
        h_new = o_t * (c_new / jnp.maximum(n_new, 1e-12))
        return (c_new, n_new, h_new, m_new), h_new

    wx_seq = jnp.moveaxis(wx, 1, 0)  # (T, B, 4d)
    new_state, hs = jax.lax.scan(step, state, wx_seq)
    h_seq = jnp.moveaxis(hs, 0, 1).astype(x.dtype)  # (B, T, d)
    if pad_mask is not None:
        # h after the last REAL step (pad steps carry c/n/m but recompute the
        # h readout from garbage o-gates); all-pad rows keep their initial h
        lengths = jnp.sum(jnp.logical_not(pad_mask), axis=1)  # (B,)
        idx = jnp.clip(lengths - 1, 0, t - 1)[:, None, None]
        h_real = jnp.take_along_axis(
            jnp.moveaxis(hs, 0, 1), jnp.broadcast_to(idx, (b, 1, d)), axis=1
        )[:, 0]
        h_real = jnp.where(lengths[:, None] > 0, h_real, state[2])
        c_f, n_f, _, m_f = new_state
        new_state = (c_f, n_f, h_real, m_f)
    y = hook("slstm_o", h_seq, p["w_o"])
    return y, new_state
