from repro.optim.adam import AdamConfig, AdamState, adam_init, adam_update
from repro.optim.clip import clip_by_global_norm, global_norm

__all__ = [
    "AdamConfig",
    "AdamState",
    "adam_init",
    "adam_update",
    "clip_by_global_norm",
    "global_norm",
]
