"""AdamW as pure-functional (init, update) pairs.

Used both for the paper's energy-allocation learning (Adam, lr=0.01,
Appendix A) and for full model training. Moments may be stored in bf16 to
fit large models (state dtype is configurable); update math is f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    state_dtype: Optional[Any] = None  # e.g. jnp.bfloat16 for large models


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamState:
    step: Array
    mu: PyTree
    nu: PyTree


def adam_init(params: PyTree, cfg: AdamConfig) -> AdamState:
    dt = cfg.state_dtype

    def zeros(p):
        return jnp.zeros(p.shape, dt if dt is not None else p.dtype)

    return AdamState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adam_update(
    grads: PyTree, state: AdamState, params: PyTree, cfg: AdamConfig
) -> tuple[PyTree, AdamState]:
    """Returns (new_params, new_state). Decoupled weight decay (AdamW)."""
    step = state.step + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g32
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g32 * g32
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - cfg.lr * delta
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamState(step=step, mu=new_m, nu=new_v)
