"""Gradient clipping utilities."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        jnp.sum(jnp.stack([jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves]))
    )


def clip_by_global_norm(tree: PyTree, max_norm: float) -> tuple[PyTree, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm
