"""Gradient compression: int8 quantization with error feedback.

For bandwidth-constrained data-parallel all-reduce (multi-pod DCN), gradients
are quantized to int8 with a per-tensor scale before the reduce; the
quantization residual is fed back into the next step's gradient (error
feedback, Seide et al. / Karimireddy et al.) so the compression bias
vanishes over time.

Two integration levels:
  * ``ef_int8_roundtrip`` — stateless quantize->dequantize; inserted in the
    jitted train step to reproduce the *numerics* of compressed all-reduce
    under XLA SPMD (where per-device partial gradients are not visible).
  * ``compressed_psum`` — the real collective, for shard_map-style manual-DP
    deployments; validated in tests on a multi-device CPU mesh.
  * ``EFState``/``ef_compress`` — stateful error feedback for driver loops.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_int8_roundtrip(grads: PyTree) -> PyTree:
    """Stateless per-tensor int8 roundtrip (compression numerics in-jit)."""

    def one(g):
        q, s = int8_quantize(g)
        return int8_dequantize(q, s).astype(g.dtype)

    return jax.tree.map(one, grads)


def ef_compress(grads: PyTree, err: Optional[PyTree]) -> Tuple[PyTree, PyTree]:
    """Error-feedback compression: returns (decompressed grads, new error).

    new_err = (g + err) - Q(g + err); the returned gradient is Q(g + err).
    """
    if err is None:
        err = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = int8_quantize(corrected)
        deq = int8_dequantize(q, s)
        return deq.astype(g.dtype), corrected - deq

    out = jax.tree.map(one, grads, err)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    g_new = treedef.unflatten([t[0] for t in flat])
    e_new = treedef.unflatten([t[1] for t in flat])
    return g_new, e_new


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-compressed all-reduce for shard_map deployments.

    Two-phase: (1) a scalar pmax establishes a SHARED scale, (2) the int8
    payload is summed in int32 and rescaled — exact up to one rounding step
    per participant (no mean-scale bias). Payload bytes: 1/4 of f32.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_name)
    scale = jnp.maximum(gmax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale
