"""Affine quantization library (paper §II-B, Eq. 2).

Supports per-tensor and per-channel granularity, straight-through estimators,
fractional bit counts (paper footnote 1: quantize over ceil(2^B - 1) bins),
and percentile-clipped calibration (paper Appendix A).
"""
from repro.quant.affine import (
    QuantParams,
    calibrate_minmax,
    calibrate_percentile,
    dequantize,
    fake_quant,
    quantize,
    ste_round,
)

__all__ = [
    "QuantParams",
    "calibrate_minmax",
    "calibrate_percentile",
    "dequantize",
    "fake_quant",
    "quantize",
    "ste_round",
]
