"""Affine (uniform) quantization with straight-through gradients.

Implements the paper's Eq. 2: values in [x_min, x_max] are mapped onto
``n_bins = 2^B - 1`` uniform bins of width ``delta = range / n_bins``.

Fractional bit-widths are supported per the paper's footnote 1: a fractional
``B`` quantizes over ``ceil(2^B - 1)`` bins (e.g. 4.644 bits -> 25 bins).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@jax.custom_jvp
def ste_round(x: Array) -> Array:
    """round() with a straight-through gradient (paper §V, [57])."""
    return jnp.round(x)


@ste_round.defjvp
def _ste_round_jvp(primals, tangents):
    (x,), (dx,) = primals, tangents
    return jnp.round(x), dx


def ste_snap_levels(e: Array, quantum: float) -> Array:
    """Snap to positive integer multiples of ``quantum`` with a full
    straight-through gradient (gradient 1 even below one quantum, so learned
    energies can recover from the floor)."""
    snapped = jnp.maximum(jnp.round(e / quantum), 1.0) * quantum
    return e + jax.lax.stop_gradient(snapped - e)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Quantizer state for one tensor (or one channel axis of it).

    ``x_min``/``x_max`` may be scalars (per-tensor) or vectors broadcastable
    against the tensor (per-channel).  ``bits`` may be fractional.
    """

    x_min: Array
    x_max: Array
    bits: float = dataclasses.field(metadata=dict(static=True), default=8.0)

    @property
    def n_bins(self) -> Array:
        # ceil(2^B - 1) bins supports fractional bit counts (paper fn. 1).
        return jnp.ceil(2.0 ** jnp.asarray(self.bits, jnp.float32) - 1.0)

    @property
    def delta(self) -> Array:
        rng = jnp.asarray(self.x_max, jnp.float32) - jnp.asarray(self.x_min, jnp.float32)
        return rng / jnp.maximum(self.n_bins, 1.0)

    @property
    def zero_point(self) -> Array:
        return ste_round(-jnp.asarray(self.x_min, jnp.float32) / jnp.maximum(self.delta, 1e-30))


def quantize(x: Array, qp: QuantParams) -> Array:
    """Map float x -> integer codes in [0, n_bins] (stored as f32 for STE)."""
    delta = jnp.maximum(qp.delta, 1e-30)
    code = ste_round(x / delta) + qp.zero_point
    return jnp.clip(code, 0.0, qp.n_bins)


def dequantize(code: Array, qp: QuantParams) -> Array:
    return (code - qp.zero_point) * qp.delta


def fake_quant(x: Array, qp: QuantParams) -> Array:
    """Quantize-dequantize with straight-through gradient.

    The returned tensor equals ``x`` up to quantization error bounded by
    ``delta/2`` inside the clip range.
    """
    return dequantize(quantize(x, qp), qp)


def calibrate_minmax(
    x: Array, *, bits: float = 8.0, channel_axis: Optional[int] = None
) -> QuantParams:
    """Min/max calibration; per-channel if ``channel_axis`` is given.

    Per-channel keeps the stats along ``channel_axis`` and reduces the rest,
    matching the paper's per-channel weight quantization (Appendix A).
    """
    if channel_axis is None:
        lo = jnp.min(x)
        hi = jnp.max(x)
    else:
        axes = tuple(i for i in range(x.ndim) if i != channel_axis % x.ndim)
        lo = jnp.min(x, axis=axes, keepdims=True)
        hi = jnp.max(x, axis=axes, keepdims=True)
    # Guarantee 0 is representable and the range is non-degenerate.
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, lo + 1e-8)
    return QuantParams(x_min=lo, x_max=hi, bits=bits)


def calibrate_percentile(
    x: Array, *, bits: float = 8.0, percentile: float = 99.99
) -> QuantParams:
    """Percentile-clipped activation calibration (paper Appendix A, [66,67]).

    Clips the range at the given two-sided percentile; used for the thermal
    noise experiments where noise magnitude scales with activation range.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    hi = jnp.percentile(flat, percentile)
    lo = jnp.percentile(flat, 100.0 - percentile)
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, lo + 1e-8)
    return QuantParams(x_min=lo, x_max=hi, bits=bits)


def merge_running(qp: QuantParams, new: QuantParams, momentum: float = 0.99) -> QuantParams:
    """Moving-average range tracking (paper Appendix A, weight-noise setup)."""
    return QuantParams(
        x_min=momentum * qp.x_min + (1.0 - momentum) * new.x_min,
        x_max=momentum * qp.x_max + (1.0 - momentum) * new.x_max,
        bits=qp.bits,
    )
