"""Int8 weight storage for serving (the decode-memory lever).

Decode is weight-streaming bound; storing matmul weights as int8 with
per-output-channel scales halves the parameter HBM traffic vs bf16. This is
paper-aligned: the thermal/weight-noise architectures already run 8-bit
digital I/O (Appendix A), so int8 weights change serving numerics no more
than the analog quantization the paper models.

``quantize_params`` converts an LM param tree (matmul leaves -> Int8Weight
with per-column scales; norms/biases/embeddings stay bf16);
``Int8DequantHook`` dequantizes at the matmul site.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Int8Weight:
    q: Array  # int8, same shape as the original weight
    scale: Array  # f32, per-output-channel (1, ..., M) broadcastable


def quantize_weight(w: Array) -> Int8Weight:
    """Symmetric per-output-channel int8: reduce over the contracting axis
    (-2) only, so stacked-layer leading dims survive (scan-sliceable) and
    every (layer, channel) pair gets its own scale."""
    w32 = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(w32), axis=-2, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return Int8Weight(q=q, scale=scale)


def dequantize_weight(iw: Int8Weight, dtype=jnp.bfloat16) -> Array:
    return (iw.q.astype(jnp.float32) * iw.scale).astype(dtype)


def _is_matmul_leaf(path: tuple, leaf: Array) -> bool:
    """Heuristic: >=2-D float leaves whose last-dim is an output channel.

    Embedding tables stay high precision (gather, not matmul); norms/biases
    are 1-D; conv/rope tables excluded by name.
    """
    name = "/".join(str(getattr(p, "key", p)) for p in path)
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    if "embed" in name or "norm" in name or name.endswith("ln"):
        return False
    # layer-stacked matmul weights are >=3-D (L, ..., K, M); 2-D stacked
    # leaves are biases/gains. The only quantizable top-level 2-D leaf is
    # the LM head.
    return leaf.ndim >= 3 or name.endswith("lm_head")


def quantize_params(params: PyTree) -> PyTree:
    """bf16 param tree -> tree with Int8Weight matmul leaves."""
    flat = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat[0]:
        out.append(quantize_weight(leaf) if _is_matmul_leaf(path, leaf) else leaf)
    return jax.tree_util.tree_unflatten(flat[1], out)


def dequantize_params(qparams: PyTree, dtype=jnp.bfloat16) -> PyTree:
    """Inverse map (whole-tree); serving paths instead dequantize per-site
    inside the jitted step so int8 is what streams from HBM."""
    return jax.tree.map(
        lambda l: dequantize_weight(l, dtype) if isinstance(l, Int8Weight) else l,
        qparams,
        is_leaf=lambda l: isinstance(l, Int8Weight),
    )


def param_bytes(params: PyTree) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        total += leaf.size * leaf.dtype.itemsize
    return total
