from repro.runtime.driver import SimulatedFailure, StragglerMonitor, TrainDriver

__all__ = ["SimulatedFailure", "StragglerMonitor", "TrainDriver"]
