"""Fault-tolerant training driver.

The control loop a real cluster job runs:

  restore latest valid checkpoint -> build jitted step -> loop:
      fetch batch(step)   (deterministic in step -> replay-exact restarts)
      run step
      watch step time     (straggler monitor: EWMA + outlier flags)
      periodic async checkpoint
  on failure: tear down, restore, continue  (bounded restarts)
  on elastic resize request: checkpoint, rebuild mesh/shardings, reshard

Failures are injected in tests via ``failure_hook`` (raise SimulatedFailure
at chosen steps — including *mid-save* to exercise atomicity); the driver's
contract, asserted by tests, is that a run with failures produces bit-exact
final state vs. an uninterrupted run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax

from repro.checkpoint.store import CheckpointManager, reshard
from repro.data.pipeline import TokenTaskConfig, markov_batch
from repro.launch.steps import TrainConfig, make_train_step
from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.sharding import use_mesh
from repro.optim.adam import adam_init


class SimulatedFailure(RuntimeError):
    """Raised by failure_hook to simulate a node crash."""


class StragglerMonitor:
    """EWMA step-time tracker; flags steps slower than ``threshold`` x EWMA.

    ``persistent`` trips after ``patience`` consecutive flags — the driver's
    cue to trigger mitigation (re-mesh without the slow host, or rebalance).
    """

    def __init__(self, alpha: float = 0.1, threshold: float = 2.0, patience: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.patience = patience
        self.ewma: Optional[float] = None
        self.consecutive = 0
        self.flags: list = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        if slow:
            self.flags.append((step, dt, self.ewma))
            self.consecutive += 1
        else:
            self.consecutive = 0
            # only fold non-outlier samples into the baseline
            self.ewma = dt if self.ewma is None else (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow

    @property
    def persistent(self) -> bool:
        return self.consecutive >= self.patience


@dataclasses.dataclass
class DriverConfig:
    max_steps: int = 100
    ckpt_every: int = 20
    ckpt_async: bool = True
    max_restarts: int = 5
    log_every: int = 10


class TrainDriver:
    def __init__(
        self,
        model_cfg: ModelConfig,
        data_cfg: TokenTaskConfig,
        mesh,
        *,
        ckpt_dir: str,
        train_cfg: TrainConfig = TrainConfig(),
        driver_cfg: DriverConfig = DriverConfig(),
        failure_hook: Optional[Callable[[int], None]] = None,
        seed: int = 0,
    ):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.mesh = mesh
        self.train_cfg = train_cfg
        self.cfg = driver_cfg
        self.failure_hook = failure_hook
        self.seed = seed
        self.ckpt = CheckpointManager(ckpt_dir)
        self.monitor = StragglerMonitor()
        self.metrics_log: list = []
        self.restarts = 0
        self._build()

    # -- construction / recovery ------------------------------------------

    def _build(self):
        with use_mesh(self.mesh):
            _, jit_for, shardings = make_train_step(self.model_cfg, self.mesh, self.train_cfg)
            self._shardings = shardings
            sample = markov_batch(self.data_cfg, 0)
            specs = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in sample.items()
            }
            self._jit_step = jit_for(specs)

    def _init_state(self):
        with use_mesh(self.mesh):
            params = jax.jit(
                lambda k: lm.init_params(k, self.model_cfg),
                out_shardings=self._shardings["params"],
            )(jax.random.PRNGKey(self.seed))
            opt = jax.jit(
                lambda p: adam_init(p, self.train_cfg.adam()),
                out_shardings=self._shardings["opt"],
            )(params)
        return {"params": params, "opt": opt}

    def _restore_or_init(self):
        template = jax.eval_shape(lambda: self._init_state())
        restored = None
        try:
            restored = self.ckpt.restore_latest(template)
        except FileNotFoundError:
            restored = None
        if restored is None:
            return 0, self._init_state()
        step, host_state = restored
        state = {
            "params": reshard(host_state["params"], self._shardings["params"]),
            "opt": reshard(host_state["opt"], self._shardings["opt"]),
        }
        return step, state

    # -- main loop ----------------------------------------------------------

    def run(self) -> Dict[str, Any]:
        while True:
            try:
                return self._run_once()
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                self.ckpt.wait()
                continue

    def _run_once(self) -> Dict[str, Any]:
        step, state = self._restore_or_init()
        with use_mesh(self.mesh):
            while step < self.cfg.max_steps:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                batch = markov_batch(self.data_cfg, step)
                t0 = time.monotonic()
                state["params"], state["opt"], metrics = self._jit_step(
                    state["params"], state["opt"], batch
                )
                jax.block_until_ready(metrics["loss"])
                dt = time.monotonic() - t0
                self.monitor.observe(step, dt)
                step += 1
                if step % self.cfg.log_every == 0 or step == self.cfg.max_steps:
                    self.metrics_log.append(
                        {"step": step, "loss": float(metrics["loss"]), "dt": dt}
                    )
                if step % self.cfg.ckpt_every == 0 or step == self.cfg.max_steps:
                    self.ckpt.save(step, state, blocking=not self.cfg.ckpt_async)
        self.ckpt.wait()
        return {"step": step, "state": state, "metrics": self.metrics_log}

    # -- elastic ------------------------------------------------------------

    def resize(self, new_mesh) -> None:
        """Elastic re-mesh: restore live state, rebuild the step for the new
        mesh, reshard the live arrays onto it, checkpoint the resharded
        state.

        Resharding the live arrays before the save commits them onto the
        new mesh while the old devices are still reachable, so the blocking
        save reads from the new mesh — on a real cluster the old mesh is
        exactly what is being drained. (Checkpoint bytes are host numpy
        either way; the reshard is about which devices the save path and any
        continued training touch.) After this returns, ``run()`` restores
        and resumes bit-exactly on the new mesh.
        """
        from repro.serving.cache import mesh_fingerprint

        step, state = self._restore_or_init()
        old_fp = mesh_fingerprint(self.mesh)
        self.mesh = new_mesh
        self._build()
        with use_mesh(self.mesh):
            state = {
                "params": reshard(state["params"], self._shardings["params"]),
                "opt": reshard(state["opt"], self._shardings["opt"]),
            }
            jax.block_until_ready(state)
        self.ckpt.save(step, state, blocking=True)
        # same mesh identity the serving AOT cache keys on: a resize is
        # attributable in the metrics log exactly like a retrace would be
        self.metrics_log.append(
            {
                "step": step,
                "event": "resize",
                "mesh_from": old_fp,
                "mesh_to": mesh_fingerprint(self.mesh),
            }
        )
