"""Bucket-batched analog serving: shape buckets, AOT executable cache,
precision-tiered scheduling (uniform-K tiers and per-layer PrecisionProfile
tiers), persistent per-tier decode slot pools (continuous batching), and
the engine tying them to models/lm.py."""
from repro.core.profile import PrecisionProfile
from repro.serving.bucketing import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    bucket_shape,
    next_bucket,
    pad_to_bucket,
    pool_shape,
)
from repro.serving.cache import ExecutableCache, aot_compile
from repro.serving.engine import ServingEngine
from repro.serving.pool import DecodePool, SlotAllocator, SlotRecord
from repro.serving.scheduler import Request, TierScheduler

__all__ = [
    "DEFAULT_BATCH_BUCKETS",
    "DEFAULT_SEQ_BUCKETS",
    "DecodePool",
    "ExecutableCache",
    "PrecisionProfile",
    "Request",
    "ServingEngine",
    "SlotAllocator",
    "SlotRecord",
    "TierScheduler",
    "aot_compile",
    "bucket_shape",
    "next_bucket",
    "pad_to_bucket",
    "pool_shape",
]
