"""Bucket-batched analog serving: shape buckets, AOT executable cache,
precision-tiered scheduling (uniform-K tiers and per-layer PrecisionProfile
tiers), and the engine tying them to models/lm.py."""
from repro.core.profile import PrecisionProfile
from repro.serving.bucketing import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    bucket_shape,
    next_bucket,
    pad_to_bucket,
)
from repro.serving.cache import ExecutableCache, aot_compile
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, TierScheduler

__all__ = [
    "DEFAULT_BATCH_BUCKETS",
    "DEFAULT_SEQ_BUCKETS",
    "ExecutableCache",
    "PrecisionProfile",
    "Request",
    "ServingEngine",
    "TierScheduler",
    "aot_compile",
    "bucket_shape",
    "next_bucket",
    "pad_to_bucket",
]
