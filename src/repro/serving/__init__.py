"""Bucket-batched analog serving: shape buckets, AOT executable cache,
pluggable execution tiers (tiers.py: uniform-K, per-layer PrecisionProfile,
and digital/int8 tiers behind one ExecutionTier interface + TierRegistry),
precision-tiered scheduling, persistent per-tier decode slot pools
(continuous batching), fault injection + noise-drift watchdog + streaming
MetricsFeed + graceful degradation (faults.py, monitor.py), a replicated
cluster router with health-checked failover and hedged dispatch
(cluster.py), and the engine tying them to models/lm.py."""
from repro.core.profile import PrecisionProfile
from repro.serving.bucketing import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    bucket_shape,
    next_bucket,
    pad_to_bucket,
    pool_shape,
)
from repro.serving.cache import ExecutableCache, aot_compile
from repro.serving.cluster import (
    ClusterGovernor,
    ClusterRouter,
    RequestJournalEntry,
)
from repro.serving.engine import (
    Failed,
    RequestFailure,
    ServingEngine,
    TimedOut,
)
from repro.serving.faults import (
    BoundedLog,
    DriftRamp,
    FaultPlan,
    QueueFull,
    ReplicaCrash,
    ReplicaDegraded,
    ReplicaFault,
    ReplicaHang,
    TransientExecutableFault,
)
from repro.serving.monitor import (
    DriftEvent,
    LoadSignals,
    MetricsFeed,
    NoiseDriftWatchdog,
    WatchdogConfig,
    load_signals,
)
from repro.serving.policy import (
    PolicyConfig,
    PolicyEvent,
    PrecisionGovernor,
    TierSpec,
)
from repro.serving.pool import DecodePool, SlotAllocator, SlotRecord
from repro.serving.scheduler import Request, TierScheduler
from repro.serving.tiers import (
    AnalogProfileTier,
    DigitalTier,
    ExecutionTier,
    Int8DigitalTier,
    TierRegistry,
    UniformKTier,
)

__all__ = [
    "AnalogProfileTier",
    "BoundedLog",
    "ClusterGovernor",
    "ClusterRouter",
    "DEFAULT_BATCH_BUCKETS",
    "DEFAULT_SEQ_BUCKETS",
    "DecodePool",
    "DigitalTier",
    "DriftEvent",
    "DriftRamp",
    "ExecutableCache",
    "ExecutionTier",
    "Failed",
    "FaultPlan",
    "Int8DigitalTier",
    "LoadSignals",
    "MetricsFeed",
    "NoiseDriftWatchdog",
    "PolicyConfig",
    "PolicyEvent",
    "PrecisionGovernor",
    "PrecisionProfile",
    "QueueFull",
    "ReplicaCrash",
    "ReplicaDegraded",
    "ReplicaFault",
    "ReplicaHang",
    "Request",
    "RequestFailure",
    "RequestJournalEntry",
    "ServingEngine",
    "SlotAllocator",
    "SlotRecord",
    "TierRegistry",
    "TierScheduler",
    "TierSpec",
    "UniformKTier",
    "TimedOut",
    "TransientExecutableFault",
    "WatchdogConfig",
    "aot_compile",
    "bucket_shape",
    "load_signals",
    "next_bucket",
    "pad_to_bucket",
    "pool_shape",
]
