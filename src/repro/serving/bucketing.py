"""Shape bucketing: pad heterogeneous requests into a bounded shape set.

Every distinct (batch, seq) shape a jitted forward sees costs one trace and
one compile. Serving traffic has essentially unbounded shape diversity, so
the engine rounds every batch up to a small set of power-of-two buckets:
the executable cache then tops out at |batch_buckets| x |seq_buckets| x
|tiers| entries and steady-state serving never re-traces.

Bucket selection is a pure function of the request shapes (deterministic,
jit-free): the same queue always lands in the same buckets.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

#: default power-of-two ladders; callers pass their own for other regimes.
DEFAULT_SEQ_BUCKETS = (32, 64, 128, 256, 512, 1024)
DEFAULT_BATCH_BUCKETS = (1, 2, 4, 8, 16)


def next_bucket(value: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= value. Raises when the ladder can't hold it."""
    if value <= 0:
        raise ValueError(f"bucket input must be positive, got {value}")
    for b in sorted(buckets):
        if value <= b:
            return b
    raise ValueError(f"{value} exceeds largest bucket {max(buckets)}")


def pool_shape(
    slots: int,
    seq_buckets: Sequence[int],
    max_gen: int,
) -> Tuple[int, int]:
    """(slots, cache_len) of a persistent continuous-batching decode pool.

    The pool's KV/recurrent cache is one static shape for the tier's whole
    lifetime (admissions scatter rows in; no retrace), so its sequence
    capacity must hold the *largest* admissible prompt — the top of the seq
    bucket ladder — plus the full decode budget. A request prefilled at any
    smaller seq bucket lands in the same pool: the prefill runs with
    ``cache_len = pool cache_len``, which is exactly the layout adapter (KV
    caches are right-padded to the pool length; ring/recurrent state carries
    no sequence dim beyond the window and is unchanged).
    """
    if slots < 1:
        raise ValueError(f"pool needs at least 1 slot, got {slots}")
    if max_gen < 1:
        raise ValueError(f"max_gen must be >= 1, got {max_gen}")
    return int(slots), int(max(seq_buckets)) + int(max_gen)


def bucket_shape(
    n_rows: int,
    max_len: int,
    *,
    batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
    seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
) -> Tuple[int, int]:
    """(batch_bucket, seq_bucket) for a group of requests."""
    return next_bucket(n_rows, batch_buckets), next_bucket(max_len, seq_buckets)


def pad_to_bucket(
    prompts: Sequence[np.ndarray],
    bucket: Tuple[int, int],
    *,
    pad_id: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Right-pad prompts into a (Bb, Sb) token block.

    Returns (tokens (Bb, Sb) int32, lengths (Bb,) int32). Rows beyond
    ``len(prompts)`` are batch padding: all-pad tokens with length 0 — the
    models treat length-0 rows as fully inert (no recurrent-state update, no
    MoE capacity, last-token gathers clip to position 0). Their outputs are
    discarded by the engine, and per-request noise keys keep them from
    perturbing real rows. Real prompts must be non-empty (the engine
    validates at submit), so a real row is never aliased to a pad row.
    """
    bb, sb = bucket
    if len(prompts) > bb:
        raise ValueError(f"{len(prompts)} prompts > batch bucket {bb}")
    tokens = np.full((bb, sb), pad_id, np.int32)
    lengths = np.zeros((bb,), np.int32)
    for i, p in enumerate(prompts):
        p = np.asarray(p, np.int32).reshape(-1)
        if p.size == 0:
            raise ValueError(f"prompt {i} is empty; length 0 marks pad rows")
        if p.size > sb:
            raise ValueError(f"prompt length {p.size} > seq bucket {sb}")
        tokens[i, : p.size] = p
        lengths[i] = p.size
    return tokens, lengths
