"""AOT executable cache: compile once per (bucket, tier, backend), then hit.

Keys are built by the engine from everything that changes the lowered
program: phase (prefill/decode), bucket shape, cache length, n_repeats tier,
backend, and noise kind. Values are ``jax.jit(...).lower(...).compile()``
executables — calling one can *never* re-trace, so a 100% steady-state hit
rate is equivalent to zero steady-state retraces.

Hit/miss/compile-time counters are first-class: the serving bench asserts
on them and they belong in any production dashboard.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Hashable, List


class ExecutableCache:
    """Maps hashable keys -> compiled executables, counting hits/misses."""

    def __init__(self):
        self._exes: Dict[Hashable, Any] = {}
        self.hits = 0
        self.misses = 0
        self.compile_s = 0.0
        #: per-miss records [(key, seconds)] — the bench's retrace audit trail
        self.miss_log: List[tuple] = []

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the executable for ``key``, compiling via ``build`` on miss."""
        exe = self._exes.get(key)
        if exe is not None:
            self.hits += 1
            return exe
        self.misses += 1
        t0 = time.perf_counter()
        exe = build()
        dt = time.perf_counter() - t0
        self.compile_s += dt
        self.miss_log.append((key, dt))
        self._exes[key] = exe
        return exe

    def __len__(self) -> int:
        return len(self._exes)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._exes

    def reset_stats(self) -> None:
        """Zero the counters, keeping compiled executables (warmup -> steady)."""
        self.hits = 0
        self.misses = 0
        self.compile_s = 0.0
        self.miss_log = []

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "entries": len(self._exes),
            "compile_s": self.compile_s,
        }


def aot_compile(fn, *arg_specs, donate_argnums=()) -> Any:
    """``jax.jit(fn).lower(specs).compile()`` — the cache's build helper."""
    import jax

    return jax.jit(fn, donate_argnums=donate_argnums).lower(*arg_specs).compile()
