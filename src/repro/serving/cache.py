"""AOT executable cache: compile once per (bucket, tier, backend), then hit.

Keys are built by the engine from everything that changes the lowered
program: phase (prefill/decode/insert), bucket or pool shape, cache length,
n_repeats tier, backend, and noise kind. Values are
``jax.jit(...).lower(...).compile()`` executables — calling one can *never*
re-trace, so a 100% steady-state hit rate is equivalent to zero steady-state
retraces.

Hit/miss/compile-time counters are first-class: the serving bench asserts
on them and they belong in any production dashboard. ``max_entries`` bounds
the cache with LRU eviction — continuous batching multiplies the key space
(pool shapes x prefill buckets x tiers x families), so a long-lived engine
serving many tiers can cap resident executables; the default is unbounded,
preserving the classic behavior (an evicted key simply recompiles on its
next use, surfacing as a miss + eviction in ``stats()``).
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Any, Callable, Deque, Hashable, Optional


def mesh_fingerprint(mesh) -> tuple:
    """Hashable identity of a device mesh for AOT cache keys.

    Everything that changes a lowered program's device assignment — axis
    names, axis sizes, and the concrete device ordering — and nothing else.
    ``()`` for no mesh, so unmeshed engines keep their exact legacy keys
    (appending an empty tuple is the identity). Two meshes with equal
    fingerprints produce interchangeable executables, which is what lets a
    reshard *back* to a previous mesh hit its still-warm entries.
    """
    if mesh is None:
        return ()
    return (
        tuple(mesh.axis_names),
        tuple(mesh.devices.shape),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


class ExecutableCache:
    """Maps hashable keys -> compiled executables, counting hits/misses.

    ``max_entries=None`` (default) never evicts. With a bound, the cache is
    LRU: a hit refreshes the key, an insert beyond the bound evicts the
    least-recently-used executable (counted in ``evictions``).

    ``fault_hook`` is the fault-injection seam (serving/faults.py): called
    with the cache key before *every* invocation of a cached executable,
    raising to simulate a transient executable failure. The guard fires
    strictly pre-dispatch, so donated buffers (decode caches) are never
    consumed by a faulted call — the engine can retry against intact state.
    ``None`` (the default) wraps nothing: the cache returns the raw
    executable exactly as before.
    """

    def __init__(self, max_entries: Optional[int] = None, fault_hook=None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self.fault_hook = fault_hook
        self._exes: "OrderedDict[Hashable, Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_s = 0.0
        #: per-miss records [(key, seconds)] — the bench's retrace audit
        #: trail. A bounded cache churns executables (eviction -> recompile
        #: -> fresh miss), so the log is capped there too: an unbounded log
        #: would leak host memory linearly in misses while the executable
        #: dict itself stays at max_entries.
        self.miss_log: Deque[tuple] = deque(maxlen=self._miss_log_cap())

    def _miss_log_cap(self) -> Optional[int]:
        if self.max_entries is None:
            return None  # unbounded cache: every miss is a one-time compile
        return max(64, 4 * self.max_entries)

    def _guard(self, key: Hashable, exe: Any) -> Any:
        """Wrap an executable so ``fault_hook(key)`` runs before dispatch."""
        if self.fault_hook is None:
            return exe
        hook = self.fault_hook

        def guarded(*args, **kwargs):
            hook(key)  # may raise TransientExecutableFault — pre-dispatch
            return exe(*args, **kwargs)

        return guarded

    def get(self, key: Hashable, build: Callable[[], Any]) -> Any:
        """Return the executable for ``key``, compiling via ``build`` on miss."""
        exe = self._exes.get(key)
        if exe is not None:
            self.hits += 1
            self._exes.move_to_end(key)  # LRU refresh (no-op when unbounded)
            return self._guard(key, exe)
        self.misses += 1
        t0 = time.perf_counter()
        exe = build()
        dt = time.perf_counter() - t0
        self.compile_s += dt
        self.miss_log.append((key, dt))
        self._exes[key] = exe
        if self.max_entries is not None:
            while len(self._exes) > self.max_entries:
                self._exes.popitem(last=False)
                self.evictions += 1
        return self._guard(key, exe)

    def __len__(self) -> int:
        return len(self._exes)

    def __contains__(self, key: Hashable) -> bool:
        return key in self._exes

    def reset_stats(self) -> None:
        """Zero the counters, keeping compiled executables (warmup -> steady)."""
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.compile_s = 0.0
        self.miss_log = deque(maxlen=self._miss_log_cap())

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "entries": len(self._exes),
            "evictions": self.evictions,
            "max_entries": self.max_entries,
            "compile_s": self.compile_s,
        }


def aot_compile(fn, *arg_specs, donate_argnums=(), out_shardings=None) -> Any:
    """``jax.jit(fn).lower(specs).compile()`` — the cache's build helper.

    ``out_shardings`` (a single sharding applied to every output leaf, or
    None) pins the executable's outputs; mesh-attached engines pass their
    replicated sharding so a donated decode cache comes back exactly as the
    next call's input spec expects it. ``None`` lowers precisely as before.
    """
    import jax

    kw = {} if out_shardings is None else {"out_shardings": out_shardings}
    return (
        jax.jit(fn, donate_argnums=donate_argnums, **kw)
        .lower(*arg_specs)
        .compile()
    )
