"""Replicated serving cluster: health-checked failover with bit-identical
request re-dispatch.

PRs 6-9 made a *single* :class:`~repro.serving.engine.ServingEngine`
survive drift, stalls, transient executable faults and overload. A
production deployment runs many engine replicas — and a dead or wedged
replica takes its queued and in-flight requests with it. This module adds
the cluster layer: a :class:`ClusterRouter` fronting N data-parallel
replicas (each optionally mesh-attached, PR 9) with health checking,
exactly-once-equivalent failover, hedged dispatch, and a cluster-level
power-budget governor.

The whole design leans on one property the engine has maintained since
PR 1: **every request carries its own stacked PRNG key**, so its token
stream depends only on (prompt, tier, key, noise scale) — never on which
replica, slot, batch-mates or padding served it. Failover is therefore
cheap and *verifiable*: re-dispatching a failed request to any nominal
replica reproduces bit-identical tokens, the already-streamed prefix can
be asserted equal and deduped (never re-emitted), and a hedged duplicate
is provably identical to its primary, which is what makes cancelling the
loser safe.

The pieces:

**Health checking.** Each replica's :class:`~repro.serving.monitor.
MetricsFeed` carries a ``replica_id`` and a monotone ``heartbeat_step``
that advances once per pump round. The router's detector drives a
``healthy -> suspect -> dead`` machine off that heartbeat with hysteresis:
``suspect_after`` stalled rounds raise suspicion (new dispatches route
around the replica), ``dead_after`` stalled rounds declare death
(terminal; failover fires), and a suspect replica must heartbeat for
``recover_after`` consecutive rounds before it is healthy again — a
transient stall never flaps the detector. The feed's drift-estimate
series drives a parallel ``healthy -> degraded`` edge: a drift excursion
outside ``drift_band`` sustained for ``drift_patience`` rounds
quarantines the replica (its *queued* work re-dispatches to nominal
replicas, whose noise scale still matches the request's solo run; its
pooled rows finish where they are, honestly drift-tinted).

**Failover.** The router journals every request at submission: cluster
uid, prompt, tier ask, PRNG key, SLO fields, and — refreshed every round
from the serving replica's pool records — the tokens emitted so far (the
streamed prefix). When the detector declares a replica dead, its queued
and pooled requests re-dispatch to healthy replicas after a bounded,
seedable backoff (one jittered delay per failover event, so journal
replay re-enters the target queues in arrival order and never reorders a
tier's FIFO). The re-served stream is checked bit-identical against the
journaled prefix; only the suffix is newly delivered (``dedup_tokens``
counts what re-serving regenerated but never re-emitted). Re-dispatches
are bounded by ``max_redispatch``; exhaustion surfaces as a structured
:class:`~repro.serving.engine.Failed`, never a lost request.

**Hedged dispatch.** A deadline-urgent request (slack below
``hedge_slack``, or ``submit(..., hedge=True)``) is additionally
submitted to a second healthy replica with the *same* key. First
finisher wins; the loser is cancelled (queued or mid-decode — per-request
keys make retiring a pool row safe) or, if it outruns cancellation, its
result is discarded after an identity check. A hedge whose primary dies
is promoted to primary on the spot: failover without re-dispatch.

**Cluster governor.** With ``power_budget_aj`` set, a thin coordinator
splits the global energy/token ceiling across the live replicas' own
:class:`~repro.serving.policy.PrecisionGovernor`s (via their runtime
``set_power_budget`` override) and rebalances when membership changes or
a replica's governor demotes — lending headroom to the replica under
energy pressure while the load-weighted mean ceiling stays at the global
budget (ROADMAP item #3's cluster-level governor). Demote-before-shed
ordering is preserved per replica by the engine governor itself.

Everything here is host-side and deterministic: the same engines, traffic,
fault schedule and clock readings replay the same episode event-for-event.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from .engine import Failed, RequestFailure, ServingEngine
from .faults import (
    BoundedLog,
    QueueFull,
    ReplicaCrash,
    ReplicaDegraded,
    ReplicaFault,
    ReplicaHang,
)
from .monitor import MetricsFeed

__all__ = [
    "ClusterGovernor",
    "ClusterRouter",
    "RequestJournalEntry",
    "DEAD",
    "DEGRADED",
    "HEALTHY",
    "SUSPECT",
]

#: replica health states. DEAD is terminal (a restarted process would
#: join as a *new* replica); DEGRADED and SUSPECT recover with hysteresis.
HEALTHY = "healthy"
SUSPECT = "suspect"
DEGRADED = "degraded"
DEAD = "dead"


@dataclasses.dataclass
class RequestJournalEntry:
    """Everything needed to re-dispatch one request bit-identically.

    The key fields are the determinism lever: ``key`` is the request's
    PRNG key, minted by the *router* (``fold_in(base_key, cuid)``) so it
    is independent of any replica's uid counter — the same (prompt, tier,
    key) served anywhere at nominal noise reproduces the same tokens.
    ``delivered`` is the streamed prefix, refreshed every round from the
    serving replica's pool records; on failover it is the dedup baseline
    the re-served stream is verified against. ``deadline`` is resolved to
    an absolute timestamp at first submission so a re-dispatch never
    extends the request's SLO.
    """

    cuid: int
    tokens: np.ndarray
    tier: object  # the submit-time ask, engine-agnostic (id / profile / tier)
    key: object  # jax PRNG key — replica-independent request identity
    max_new_tokens: Optional[int]
    stop_tokens: Tuple[int, ...]
    arrival: float
    deadline: Optional[float] = None
    target_latency: Optional[float] = None
    accuracy_floor: Optional[float] = None
    #: current primary assignment (replica id, engine-local uid)
    replica: Optional[int] = None
    engine_uid: Optional[int] = None
    #: live hedge assignment, if any
    hedge_replica: Optional[int] = None
    hedge_uid: Optional[int] = None
    #: tokens already streamed to the client (never re-emitted)
    delivered: List[int] = dataclasses.field(default_factory=list)
    attempts: int = 0  # dispatches so far (1 = primary only)
    retry_at: Optional[int] = None  # cluster round of the pending re-dispatch
    failed_over: bool = False
    hedged: bool = False
    done: bool = False


class _Replica:
    """Router-side handle on one engine replica: feed, detector state,
    and the engine-uid -> cluster-uid mapping for its live requests."""

    def __init__(self, rid: int, engine: ServingEngine):
        self.rid = rid
        self.engine = engine
        feed = engine.metrics
        if feed is None:
            feed = MetricsFeed(capacity=4096, replica_id=rid)
            engine.metrics = feed
        elif getattr(feed, "replica_id", None) is None:
            feed.replica_id = rid
        self.feed = feed
        self.state = HEALTHY
        self.last_heartbeat = int(feed.heartbeat_step)
        self.stalled_rounds = 0  # consecutive rounds without a heartbeat
        self.ok_rounds = 0  # consecutive rounds WITH one (recovery evidence)
        self.drift_rounds = 0  # consecutive out-of-band drift estimates
        self.inband_rounds = 0  # consecutive nominal estimates (recovery)
        self.crashed = False  # injection ground truth; detection is separate
        self.hang_until = -1  # injection: pump wedged while round < this
        self.injected_drift: Optional[float] = None
        self.uids: Dict[int, int] = {}  # engine uid -> cluster uid
        self.dispatched = 0  # router dispatches to this replica (tiebreak)

    @property
    def servable(self) -> bool:
        """Accepts new dispatches: only fully-healthy replicas do. A
        crashed replica's submit RPC fails fast (nobody listening), so
        the router skips it even before the detector declares death."""
        return self.state == HEALTHY and not self.crashed

    @property
    def alive(self) -> bool:
        """Still pumped by the router (its process exists)."""
        return not self.crashed and self.state != DEAD


class ClusterGovernor:
    """Splits a global power budget across replica precision governors.

    ``power_budget_aj`` is the cluster's energy/token ceiling — an
    *intensive* quantity, so the split preserves the mean: with every
    live replica nominal each gets the global ceiling; when one demotes
    (its governor left nominal — it is starving for energy headroom) the
    rebalance lends it headroom from the others while the weighted mean
    stays at the budget. Re-splits fire only when the live set or the
    demoted set changes, each one logged as a ``rebalance`` event.
    """

    def __init__(self, router: "ClusterRouter", power_budget_aj: float):
        if power_budget_aj <= 0.0:
            raise ValueError(
                f"power_budget_aj must be > 0, got {power_budget_aj}"
            )
        self.router = router
        self.power_budget_aj = float(power_budget_aj)
        self._last_key = None
        #: the current per-replica ceilings (rid -> aJ/token)
        self.split: Dict[int, float] = {}

    def _governed(self) -> List[_Replica]:
        return [
            h for h in self.router.replicas
            if h.alive and h.state in (HEALTHY, SUSPECT)
            and h.engine.governor is not None
        ]

    def step(self, rnd: int) -> None:
        live = self._governed()
        demoted = tuple(
            sorted(h.rid for h in live if h.engine.governor.mode != "nominal")
        )
        key = (tuple(h.rid for h in live), demoted)
        if key == self._last_key or not live:
            self._last_key = key if live else self._last_key
            return
        self._last_key = key
        # weight 2 for a demoted replica, 1 otherwise; ceilings scaled so
        # the unweighted mean across live replicas stays at the budget
        weights = {
            h.rid: 2.0 if h.engine.governor.mode != "nominal" else 1.0
            for h in live
        }
        total = sum(weights.values())
        self.split = {
            rid: self.power_budget_aj * w * len(live) / total
            for rid, w in weights.items()
        }
        for h in live:
            h.engine.governor.set_power_budget(self.split[h.rid])
        self.router.stats["rebalances"] += 1
        self.router._event(
            "rebalance", round=rnd,
            reason="demotion" if demoted else "membership",
            demoted=list(demoted),
            split={rid: round(v, 3) for rid, v in self.split.items()},
        )


class ClusterRouter:
    """N data-parallel ``ServingEngine`` replicas behind one submit/pump
    surface, with health-checked failover (see module docstring).

    Every engine must be continuous (``pump_step`` is the cluster's unit
    of progress) and the replicas are assumed interchangeable: same
    params, model config, analog config and energy tree — the premise
    under which a re-dispatched request is bit-identical. Each replica
    gets (or brings) a :class:`MetricsFeed`; the router stamps its
    ``replica_id``.

    ``faults`` is the deterministic replica-fault schedule
    (:class:`ReplicaCrash` / :class:`ReplicaHang` /
    :class:`ReplicaDegraded`), applied on the router's shared fault clock
    — one tick per :meth:`pump_step`.
    """

    def __init__(
        self,
        engines: Sequence[ServingEngine],
        *,
        seed: int = 0,
        suspect_after: int = 2,
        dead_after: int = 5,
        recover_after: int = 2,
        drift_band: Tuple[float, float] = (0.7, 1.4),
        drift_patience: int = 3,
        hedge_slack: Optional[float] = None,
        max_redispatch: int = 2,
        backoff_rounds: int = 1,
        backoff_jitter: int = 2,
        power_budget_aj: Optional[float] = None,
        faults: Sequence[ReplicaFault] = (),
        event_log_maxlen: Optional[int] = 4096,
    ):
        engines = list(engines)
        if not engines:
            raise ValueError("a cluster needs at least one engine replica")
        for i, eng in enumerate(engines):
            if not eng.continuous:
                raise ValueError(
                    f"replica {i} is not continuous: the cluster pumps "
                    "replicas round-by-round (construct engines with "
                    "continuous=True)"
                )
        if suspect_after < 1:
            raise ValueError(f"suspect_after must be >= 1, got {suspect_after}")
        if dead_after <= suspect_after:
            raise ValueError(
                "dead_after must exceed suspect_after (the hysteresis "
                f"window), got {dead_after} <= {suspect_after}"
            )
        if recover_after < 1:
            raise ValueError(f"recover_after must be >= 1, got {recover_after}")
        if not (0.0 < drift_band[0] < 1.0 < drift_band[1]):
            raise ValueError(
                f"drift_band must straddle the nominal scale 1.0, got {drift_band}"
            )
        if drift_patience < 1:
            raise ValueError(f"drift_patience must be >= 1, got {drift_patience}")
        if hedge_slack is not None and hedge_slack <= 0.0:
            raise ValueError(f"hedge_slack must be > 0, got {hedge_slack}")
        if max_redispatch < 0:
            raise ValueError(f"max_redispatch must be >= 0, got {max_redispatch}")
        if backoff_rounds < 0 or backoff_jitter < 0:
            raise ValueError("backoff_rounds/backoff_jitter must be >= 0")
        for f in faults:
            if not isinstance(f, ReplicaFault):
                raise TypeError(f"expected a ReplicaFault, got {type(f)!r}")
            if not 0 <= f.replica < len(engines):
                raise ValueError(
                    f"fault {f!r} names replica {f.replica} but the cluster "
                    f"has {len(engines)}"
                )
        self.replicas = [_Replica(i, eng) for i, eng in enumerate(engines)]
        self.suspect_after = int(suspect_after)
        self.dead_after = int(dead_after)
        self.recover_after = int(recover_after)
        self.drift_band = (float(drift_band[0]), float(drift_band[1]))
        self.drift_patience = int(drift_patience)
        self.hedge_slack = None if hedge_slack is None else float(hedge_slack)
        self.max_redispatch = int(max_redispatch)
        self.backoff_rounds = int(backoff_rounds)
        self.backoff_jitter = int(backoff_jitter)
        self._base_key = jax.random.PRNGKey(seed)
        self._rng = np.random.default_rng(seed)  # backoff jitter (seedable)
        self._faults = sorted(faults, key=lambda f: (f.at, f.replica))
        self._faults_applied = 0
        self._round = 0  # the cluster's shared fault clock
        self._cuid = 0
        self.journal: Dict[int, RequestJournalEntry] = {}
        self.results: Dict[int, object] = {}
        self.events: List[dict] = BoundedLog(maxlen=event_log_maxlen)
        self.governor: Optional[ClusterGovernor] = None
        if power_budget_aj is not None:
            self.governor = ClusterGovernor(self, power_budget_aj)
        self.stats = {
            "submitted": 0,
            "delivered": 0,  # requests finished with tokens
            "failed": 0,  # structured cluster-level failures
            "dispatches": 0,  # engine submissions (incl. re-dispatches)
            "redispatched": 0,  # journal replays onto another replica
            "failed_over": 0,  # requests orphaned by a death
            "quarantined": 0,  # queued requests pulled off a degraded replica
            "hedges": 0,  # backup submissions placed
            "hedge_wins_primary": 0,
            "hedge_wins_backup": 0,
            "hedge_cancelled": 0,  # losers withdrawn before finishing
            "hedge_promoted": 0,  # hedges promoted to primary by a death
            "duplicates_discarded": 0,  # loser results dropped after the fact
            "dedup_tokens": 0,  # re-served tokens verified + never re-emitted
            "prefix_mismatches": 0,  # determinism violations (must stay 0)
            "replicas_dead": 0,
            "replicas_degraded": 0,
            "rebalances": 0,
        }

    # -- introspection -------------------------------------------------------

    @property
    def round(self) -> int:
        """The shared fault clock: pump rounds completed."""
        return self._round

    @property
    def n_in_flight(self) -> int:
        """Journaled requests not yet resolved (on any replica or awaiting
        re-dispatch)."""
        return sum(1 for e in self.journal.values() if not e.done)

    @property
    def health(self) -> Dict[int, str]:
        """Replica id -> current detector state."""
        return {h.rid: h.state for h in self.replicas}

    def replica(self, rid: int) -> _Replica:
        return self.replicas[rid]

    def replica_stats(self) -> List[dict]:
        """Per-replica serving summary (bench/artifact surface)."""
        out = []
        for h in self.replicas:
            out.append({
                "replica_id": h.rid,
                "state": h.state,
                "heartbeat_step": int(h.feed.heartbeat_step),
                "dispatched": h.dispatched,
                "traces": int(h.engine.trace_count),
                "requests": h.engine.stats["requests"],
                "tokens_generated": h.engine.stats["tokens_generated"],
                "demoted": h.engine.stats["demoted"],
                "shed": h.engine.stats["shed"],
                "cancelled": h.engine.stats["cancelled"],
            })
        return out

    def _event(self, kind: str, **fields) -> dict:
        ev = {"kind": kind, **fields}
        self.events.append(ev)
        return ev

    # -- intake --------------------------------------------------------------

    def submit(
        self,
        tokens,
        *,
        n_repeats: int = 1,
        profile=None,
        tier=None,
        max_new_tokens: Optional[int] = None,
        stop_tokens: Sequence[int] = (),
        now: Optional[float] = None,
        deadline: Optional[float] = None,
        target_latency: Optional[float] = None,
        accuracy_floor: Optional[float] = None,
        hedge: bool = False,
    ) -> int:
        """Journal one request and dispatch it to the least-loaded healthy
        replica; returns the cluster uid (the results key).

        The tier ask mirrors ``ServingEngine.submit`` (``n_repeats`` /
        ``profile`` / ``tier``) and is stored verbatim for re-dispatch —
        a failed-over request is always re-asked at its *original* tier.
        The router mints the request's PRNG key from its own base key and
        cluster uid, so the key (and with it the token stream) is
        independent of any replica's uid counter. ``hedge=True`` places
        an immediate backup submission on a second healthy replica.

        With no servable replica the request stays journaled and is
        dispatched by the next pump round that finds one (or failed once
        every replica is dead).
        """
        if tier is not None:
            if profile is not None or n_repeats != 1:
                raise ValueError(
                    "pass either tier, or the legacy n_repeats/profile "
                    "knobs, not both"
                )
            ask = tier
        elif profile is not None:
            if n_repeats != 1:
                raise ValueError("pass either n_repeats or profile, not both")
            ask = profile
        else:
            ask = int(n_repeats)
        cuid = self._cuid
        self._cuid += 1
        arrival = 0.0 if now is None else float(now)
        if deadline is None and target_latency is not None:
            # resolve the SLO to an absolute deadline NOW: a re-dispatch
            # must never restart the latency budget
            deadline = arrival + float(target_latency)
        entry = RequestJournalEntry(
            cuid=cuid,
            tokens=np.asarray(tokens, np.int32).reshape(-1),
            tier=ask,
            key=jax.random.fold_in(self._base_key, cuid),
            max_new_tokens=max_new_tokens,
            stop_tokens=tuple(int(t) for t in stop_tokens),
            arrival=arrival,
            deadline=deadline,
            target_latency=target_latency,
            accuracy_floor=accuracy_floor,
        )
        self.journal[cuid] = entry
        self.stats["submitted"] += 1
        if not self._dispatch(entry, now=now):
            entry.retry_at = self._round  # first pump round retries
        if hedge:
            self._hedge(entry, now=now)
        return cuid

    # -- dispatch ------------------------------------------------------------

    def _servable(self, exclude: Sequence[int] = ()) -> List[_Replica]:
        return [
            h for h in self.replicas if h.servable and h.rid not in exclude
        ]

    def _pick(self, exclude: Sequence[int] = ()) -> Optional[_Replica]:
        cands = self._servable(exclude)
        if not cands:
            return None
        return min(
            cands, key=lambda h: (h.engine.n_in_flight, h.dispatched, h.rid)
        )

    def _submit_to(self, h: _Replica, entry: RequestJournalEntry,
                   now: Optional[float]) -> Optional[int]:
        try:
            return h.engine.submit(
                entry.tokens,
                tier=entry.tier,
                max_new_tokens=entry.max_new_tokens,
                stop_tokens=entry.stop_tokens,
                key=entry.key,
                now=now,
                deadline=entry.deadline,
                target_latency=entry.target_latency,
                accuracy_floor=entry.accuracy_floor,
            )
        except QueueFull:
            return None  # backpressure/shedding: try another replica

    def _dispatch(self, entry: RequestJournalEntry, *,
                  now: Optional[float], exclude: Sequence[int] = ()) -> bool:
        tried = list(exclude)
        while True:
            h = self._pick(exclude=tried)
            if h is None:
                return False
            uid = self._submit_to(h, entry, now)
            if uid is None:
                tried.append(h.rid)
                continue
            h.uids[uid] = entry.cuid
            h.dispatched += 1
            entry.replica, entry.engine_uid = h.rid, uid
            entry.attempts += 1
            entry.retry_at = None
            self.stats["dispatches"] += 1
            return True

    def _hedge(self, entry: RequestJournalEntry, *,
               now: Optional[float]) -> bool:
        """Place a backup submission on a second healthy replica. The
        duplicate shares the request's key, so determinism makes it
        provably identical to the primary — whichever finishes first
        wins, and cancelling the other is safe by construction."""
        if entry.done or entry.hedge_uid is not None or entry.replica is None:
            return False
        h = self._pick(exclude=(entry.replica,))
        if h is None:
            return False
        uid = self._submit_to(h, entry, now)
        if uid is None:
            return False
        h.uids[uid] = entry.cuid
        h.dispatched += 1
        entry.hedge_replica, entry.hedge_uid = h.rid, uid
        entry.hedged = True
        self.stats["hedges"] += 1
        self.stats["dispatches"] += 1
        self._event(
            "hedge", round=self._round, cuid=entry.cuid,
            primary=entry.replica, backup=h.rid,
        )
        return True

    # -- the cluster pump round ----------------------------------------------

    def pump_step(self, now: Optional[float] = None) -> Dict[int, object]:
        """One cluster round: apply scheduled replica faults, pump every
        live replica, refresh journal prefixes, run the health detector
        (failover on death, quarantine on degradation), re-dispatch due
        retries, place automatic hedges, and rebalance the power budget.
        Returns the requests resolved this round, keyed by cluster uid
        (token rows, or structured ``TimedOut``/``Failed``)."""
        rnd = self._round
        self._round += 1
        self._apply_faults(rnd)
        finished: Dict[int, object] = {}
        for h in self.replicas:
            if not h.alive:
                continue
            if rnd < h.hang_until:
                continue  # wedged pump: no progress, no heartbeat
            if h.injected_drift is not None:
                # what a production NoiseDriftWatchdog would report; the
                # injection short-circuits the probe (tests/test_faults.py
                # covers the probe -> estimate pipeline itself)
                h.feed.note_drift(h.injected_drift)
            for uid, val in h.engine.pump_step(now=now).items():
                self._on_result(h, uid, val, finished)
        self._snapshot_partials()
        self._update_health(rnd, now, finished)
        self._retry_due(rnd, now, finished)
        if self.hedge_slack is not None and now is not None:
            self._auto_hedge(now)
        if self.governor is not None:
            self.governor.step(rnd)
        return finished

    def run_until_drained(
        self, now: float, dt: float = 0.01, max_rounds: int = 2000
    ) -> Tuple[Dict[int, object], float]:
        """Pump the virtual clock until every journaled request resolves;
        returns (results, final time). Bounded: a hang is a failure."""
        results: Dict[int, object] = {}
        t = float(now)
        for _ in range(max_rounds):
            if not self.n_in_flight:
                return results, t
            t += dt
            results.update(self.pump_step(now=t))
        raise RuntimeError(
            f"cluster failed to drain within {max_rounds} rounds "
            f"({self.n_in_flight} still in flight)"
        )

    # -- fault injection -----------------------------------------------------

    def _apply_faults(self, rnd: int) -> None:
        while self._faults_applied < len(self._faults):
            f = self._faults[self._faults_applied]
            if f.at > rnd:
                break
            self._faults_applied += 1
            h = self.replicas[f.replica]
            if isinstance(f, ReplicaCrash):
                h.crashed = True
                self._event("crash_injected", round=rnd, replica=h.rid)
            elif isinstance(f, ReplicaHang):
                h.hang_until = max(h.hang_until, rnd + f.steps)
                self._event(
                    "hang_injected", round=rnd, replica=h.rid, steps=f.steps
                )
            elif isinstance(f, ReplicaDegraded):
                h.engine.set_noise_scale(f.scale)
                h.injected_drift = f.scale
                h.feed.note_drift(f.scale)
                self._event(
                    "degraded_injected", round=rnd, replica=h.rid,
                    scale=f.scale,
                )

    def clear_degradation(self, rid: int, *, now: Optional[float] = None) -> None:
        """Recalibrate one replica: nominal noise scale, drift estimate
        cleared (the detector walks it back to healthy with hysteresis)."""
        h = self.replicas[rid]
        h.injected_drift = None
        h.engine.recalibrate()
        h.feed.note_drift(None)
        self._event("recalibrated", round=self._round, replica=rid)

    # -- journal bookkeeping -------------------------------------------------

    def _snapshot_partials(self) -> None:
        """Refresh every live primary assignment's streamed prefix from
        its pool record — the journal's 'tokens emitted so far'. Only the
        primary streams to the client; hedge partials stay private until
        the hedge wins."""
        for h in self.replicas:
            if not h.alive:
                continue
            for pool in h.engine.pools.values():
                for s in pool.active_slots():
                    rec = pool.record(s)
                    cuid = h.uids.get(rec.request.uid)
                    if cuid is None:
                        continue
                    e = self.journal[cuid]
                    if (
                        not e.done
                        and e.replica == h.rid
                        and e.engine_uid == rec.request.uid
                        and len(rec.emitted) > len(e.delivered)
                    ):
                        e.delivered = [int(t) for t in rec.emitted]

    def _on_result(self, h: _Replica, uid: int, val, finished: dict) -> None:
        cuid = h.uids.pop(uid, None)
        if cuid is None:
            return
        entry = self.journal[cuid]
        is_hedge = entry.hedge_replica == h.rid and entry.hedge_uid == uid
        if entry.done:
            # a hedge loser (or stale duplicate) that outran cancellation:
            # discard — but verify determinism did what it promises
            self.stats["duplicates_discarded"] += 1
            prev = self.results.get(cuid)
            if (
                isinstance(val, np.ndarray)
                and isinstance(prev, np.ndarray)
                and not np.array_equal(prev, val)
            ):
                self.stats["prefix_mismatches"] += 1
                self._event(
                    "identity_violation", round=self._round, cuid=cuid,
                    replica=h.rid,
                )
            return
        if isinstance(val, RequestFailure):
            self._on_failure(h, entry, val, is_hedge, finished)
            return
        # success: verify the streamed prefix, dedup, deliver the suffix
        toks = np.asarray(val, np.int32)
        pre = np.asarray(entry.delivered, np.int32)
        if pre.size and not np.array_equal(toks[: pre.size], pre):
            self.stats["prefix_mismatches"] += 1
            self._event(
                "prefix_mismatch", round=self._round, cuid=cuid,
                replica=h.rid, delivered=int(pre.size),
            )
        elif entry.failed_over:
            # the re-served stream regenerated the already-streamed
            # prefix bit-identically; only the suffix is newly emitted
            self.stats["dedup_tokens"] += int(pre.size)
        entry.delivered = [int(t) for t in toks]
        entry.done = True
        entry.retry_at = None
        self.results[cuid] = toks
        finished[cuid] = toks
        self.stats["delivered"] += 1
        # hedge resolution: first finisher won, cancel the other copy
        if entry.hedged and (entry.hedge_uid is not None or is_hedge):
            if is_hedge:
                self.stats["hedge_wins_backup"] += 1
                loser_rid, loser_uid = entry.replica, entry.engine_uid
            else:
                self.stats["hedge_wins_primary"] += 1
                loser_rid, loser_uid = entry.hedge_replica, entry.hedge_uid
            entry.replica = h.rid
            entry.engine_uid = uid
            entry.hedge_replica = entry.hedge_uid = None
            if loser_rid is not None and loser_uid is not None:
                lh = self.replicas[loser_rid]
                if lh.alive and lh.engine.cancel(loser_uid):
                    self.stats["hedge_cancelled"] += 1
                lh.uids.pop(loser_uid, None)

    def _on_failure(self, h: _Replica, entry: RequestJournalEntry, val,
                    is_hedge: bool, finished: dict) -> None:
        if is_hedge:
            # the backup copy failed; the primary is still racing
            entry.hedge_replica = entry.hedge_uid = None
            return
        if entry.hedge_uid is not None:
            # primary failed but a live hedge is still racing: promote it
            entry.replica, entry.engine_uid = entry.hedge_replica, entry.hedge_uid
            entry.hedge_replica = entry.hedge_uid = None
            self.stats["hedge_promoted"] += 1
            return
        if isinstance(val, Failed) and entry.attempts <= self.max_redispatch:
            # a replica-local Failed (bounded retries exhausted THERE) is
            # a cluster-level retry opportunity elsewhere
            entry.replica = entry.engine_uid = None
            entry.retry_at = self._round
            return
        self._deliver_failure(entry, val, finished)

    def _deliver_failure(self, entry: RequestJournalEntry, val,
                         finished: dict) -> None:
        out = dataclasses.replace(val, uid=entry.cuid)
        entry.done = True
        entry.retry_at = None
        self.results[entry.cuid] = out
        finished[entry.cuid] = out
        self.stats["failed"] += 1

    def _fail(self, entry: RequestJournalEntry, detail: str,
              finished: dict) -> None:
        self._deliver_failure(
            entry,
            Failed(
                uid=entry.cuid,
                tokens=np.asarray(entry.delivered, np.int32),
                detail=detail,
                retries=entry.attempts,
            ),
            finished,
        )

    # -- health detection ----------------------------------------------------

    def _transition(self, h: _Replica, state: str, rnd: int,
                    detail: str) -> None:
        self._event(
            "health", round=rnd, replica=h.rid, frm=h.state, to=state,
            detail=detail,
        )
        h.state = state

    def _update_health(self, rnd: int, now, finished: dict) -> None:
        lo, hi = self.drift_band
        for h in self.replicas:
            if h.state == DEAD:
                continue
            hb = int(h.feed.heartbeat_step)
            advanced = hb > h.last_heartbeat
            h.last_heartbeat = hb
            if advanced:
                h.stalled_rounds = 0
                h.ok_rounds += 1
            else:
                h.stalled_rounds += 1
                h.ok_rounds = 0
            drift = h.feed.drift_estimate
            out_of_band = drift is not None and not (lo <= drift <= hi)
            if out_of_band:
                h.drift_rounds += 1
                h.inband_rounds = 0
            else:
                h.drift_rounds = 0
                h.inband_rounds += 1
            if h.stalled_rounds >= self.dead_after:
                self._transition(
                    h, DEAD, rnd,
                    f"no heartbeat for {h.stalled_rounds} rounds",
                )
                self.stats["replicas_dead"] += 1
                self._failover(h, rnd)
                continue
            if h.state == HEALTHY:
                if h.stalled_rounds >= self.suspect_after:
                    self._transition(
                        h, SUSPECT, rnd,
                        f"heartbeat stalled {h.stalled_rounds} rounds",
                    )
                elif h.drift_rounds >= self.drift_patience:
                    self._transition(
                        h, DEGRADED, rnd,
                        f"drift {drift:.3g} outside {self.drift_band} for "
                        f"{h.drift_rounds} rounds",
                    )
                    self.stats["replicas_degraded"] += 1
                    self._quarantine(h, rnd, now)
            elif h.state == SUSPECT:
                # hysteresis: recovery needs sustained heartbeats, so a
                # flickering pump can't flap the detector
                if h.ok_rounds >= self.recover_after:
                    self._transition(h, HEALTHY, rnd, "heartbeat recovered")
            elif h.state == DEGRADED:
                if h.inband_rounds >= self.recover_after:
                    self._transition(
                        h, HEALTHY, rnd, "drift back in band"
                    )

    # -- failover ------------------------------------------------------------

    def _failover(self, h: _Replica, rnd: int) -> None:
        """Re-dispatch everything the dead replica took with it. One
        jittered, seedable backoff per failover event — every orphaned
        request shares it, so journal replay (sorted by arrival, cuid)
        re-enters the target tier queues in their original FIFO order."""
        orphans: List[RequestJournalEntry] = []
        for cuid in sorted(self.journal):
            e = self.journal[cuid]
            if e.done:
                continue
            if e.hedge_replica == h.rid:
                # the hedge died with the replica; the primary races on
                e.hedge_replica = e.hedge_uid = None
            if e.replica == h.rid:
                if e.hedge_uid is not None:
                    # a live hedge IS a warm re-dispatch: promote it
                    e.replica, e.engine_uid = e.hedge_replica, e.hedge_uid
                    e.hedge_replica = e.hedge_uid = None
                    self.stats["hedge_promoted"] += 1
                else:
                    e.replica = e.engine_uid = None
                    orphans.append(e)
        h.uids.clear()
        if self.governor is not None:
            self.governor.step(rnd)  # membership changed: rebalance now
        if not orphans:
            return
        delay = self.backoff_rounds + int(
            self._rng.integers(0, self.backoff_jitter + 1)
        )
        for e in orphans:
            e.failed_over = True
            e.retry_at = rnd + delay
        self.stats["failed_over"] += len(orphans)
        self._event(
            "failover", round=rnd, replica=h.rid,
            uids=[e.cuid for e in orphans], retry_round=rnd + delay,
        )

    def _quarantine(self, h: _Replica, rnd: int, now) -> None:
        """Pull a degraded replica's *queued* work (no tokens emitted yet
        — nominal replicas will serve it bit-identical to its solo run)
        and route new traffic around it. Pooled rows finish where they
        are: their noise keys bound them at admission, and retiring them
        would trade a drift-tinted answer for no answer."""
        moved = []
        for r in list(h.engine.scheduler.queued_requests()):
            cuid = h.uids.get(r.uid)
            if cuid is None:
                continue
            e = self.journal[cuid]
            if e.done:
                continue
            if e.replica == h.rid and e.engine_uid == r.uid:
                if h.engine.cancel(r.uid):
                    h.uids.pop(r.uid, None)
                    e.replica = e.engine_uid = None
                    e.retry_at = rnd  # proactive: re-dispatch this round
                    moved.append(e.cuid)
            elif e.hedge_replica == h.rid and e.hedge_uid == r.uid:
                if h.engine.cancel(r.uid):
                    h.uids.pop(r.uid, None)
                    e.hedge_replica = e.hedge_uid = None
        self.stats["quarantined"] += len(moved)
        self._event("quarantine", round=rnd, replica=h.rid, uids=moved)

    def _retry_due(self, rnd: int, now, finished: dict) -> None:
        due = [
            e for e in self.journal.values()
            if not e.done and e.retry_at is not None and e.retry_at <= rnd
        ]
        # journal replay order: (arrival, cuid) — cross-engine re-dispatch
        # must not reorder any tier's FIFO
        due.sort(key=lambda e: (e.arrival, e.cuid))
        for e in due:
            if e.attempts > self.max_redispatch:
                self._fail(
                    e,
                    f"re-dispatch budget exhausted after {e.attempts} "
                    "dispatches",
                    finished,
                )
                continue
            redispatch = e.attempts > 0
            if self._dispatch(e, now=now):
                if redispatch:
                    self.stats["redispatched"] += 1
            elif not any(x.alive for x in self.replicas):
                self._fail(e, "no live replicas", finished)
            else:
                e.retry_at = rnd + 1  # backpressure: try again next round

    def _auto_hedge(self, now: float) -> None:
        for e in self.journal.values():
            if (
                e.done
                or e.hedged
                or e.replica is None
                or e.deadline is None
                or e.retry_at is not None
            ):
                continue
            if e.deadline - now <= self.hedge_slack:
                self._hedge(e, now=now)
