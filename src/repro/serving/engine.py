"""Bucket-batched analog serving engine.

Takes a queue of heterogeneous generation requests — varying prompt length,
batch arrival pattern, and precision tier (``n_repeats`` = the paper's
dynamic-precision K) — and serves them through the fused analog path:

  submit -> TierScheduler groups same-K requests         (scheduler.py)
         -> pad into a power-of-two (batch, seq) bucket  (bucketing.py)
         -> AOT executable per (bucket, K, backend)      (cache.py)
         -> prefill once, then bucketed decode steps     (models/lm.py)

Correctness contract: every request is served with its *own* PRNG key
stacked into the batch (per-request noise streams, see AnalogHook), its own
true prompt length (per-row decode positions), and greedy sampling — so its
tokens are bit-identical to running it alone at the same seq bucket,
regardless of batch-mates or batch padding. The engine's batching is a pure
throughput optimization, not a numerics change.

Every model family rides this contract via length-aware prefill/decode
(``lengths`` threaded through ``models/lm.py``): global causal attention
masks right-padding by construction; sliding-window ring caches are built
from each row's *true* last `window` tokens; griffin/xlstm recurrences
treat pad steps as identity so state crosses the pad suffix exactly; MoE
routing drops pad tokens so they never consume expert capacity. Two honest
caveats remain for MoE: real tokens from co-batched requests still compete
for expert capacity (run a no-drop ``capacity_factor >= n_experts / top_k``
when per-request bit-identity matters), and analog-mode expert matmuls draw
one batch-level noise stream (capacity buffers mix requests, so per-request
streams are physically meaningless there — see ``AnalogHook.batched``).

Precision tiers can never share a batch: K is static in the fused kernel
(baked into the trace), which is exactly why the tier scheduler exists. A
tier is a repeat *schedule*: the uniform ``n_repeats=K``, or a registered
per-layer ``PrecisionProfile`` (the paper's learned per-layer precision,
§V-VI) — profile batches run the segmented layer scan, their executables
are cache-keyed on the profile's repeat tuple, and their energy/token is
the true ``sum_l K_l * E_l * MACs_l``.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog import AnalogConfig, raw_key
from repro.core.profile import PrecisionProfile
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving.bucketing import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    bucket_shape,
    pad_to_bucket,
)
from repro.serving.cache import ExecutableCache, aot_compile
from repro.serving.scheduler import Request, TierScheduler

Array = jax.Array


class ServingEngine:
    """Serves mixed-precision generation traffic over a frozen analog model.

    ``analog_cfg=None`` serves the digital model (same batching machinery,
    no noise). ``energies`` is an ``init_energy_tree``-shaped allocation —
    per-site energy at K=1; a tier's total spend is ``K * energy`` (uniform)
    or ``sum_l K_l * E_l * MACs_l`` for a per-layer profile tier
    (``profiles`` / ``register_profile`` / ``submit(profile=...)``).

    ``analog_cfg`` and ``energies`` are FROZEN for the engine's lifetime:
    they are baked into every compiled executable as trace-time constants
    (the cache key doesn't cover them), so mutation would silently serve
    stale energies from warm buckets. ``energies`` is a read-only property;
    a recalibrated allocation means a new engine. ``params`` are runtime
    arguments and may be swapped freely.
    """

    def __init__(
        self,
        params,
        model_cfg: ModelConfig,
        *,
        analog_cfg: Optional[AnalogConfig] = None,
        energies=None,
        max_gen: int = 32,
        max_batch: int = 8,
        max_wait: float = 0.05,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
        seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
        pad_id: int = 0,
        seed: int = 0,
        profiles: Optional[Sequence[PrecisionProfile]] = None,
    ):
        if analog_cfg is not None and energies is None:
            raise ValueError("analog serving requires an energy tree")
        self.params = params
        self.model_cfg = model_cfg
        self.analog_cfg = analog_cfg
        self._energies = energies
        #: registered per-layer repeat schedules: tier id -> frozen profile.
        #: add-only (profiles are hashed into executable cache keys).
        self._profiles: Dict[str, PrecisionProfile] = {}
        for p in profiles or ():
            self.register_profile(p)
        self.max_gen = max_gen
        self.batch_buckets = tuple(batch_buckets)
        self.seq_buckets = tuple(seq_buckets)
        self.pad_id = pad_id
        self.scheduler = TierScheduler(
            max_batch=min(max_batch, max(batch_buckets)),
            max_wait=max_wait,
            seq_buckets=seq_buckets,
        )
        self.exe_cache = ExecutableCache()
        self._base_key = raw_key(jax.random.PRNGKey(seed))
        self._param_specs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
        )
        self._uid = 0
        self._clock: Optional[str] = None  # "real" | "virtual", set on first use
        self._traces = 0  # incremented at trace time inside the step fns
        self.stats = {
            "requests": 0,
            "batches": 0,
            "tokens_generated": 0,
            "padded_rows": 0,
            "decode_steps": 0,
        }

    # -- request intake ------------------------------------------------------

    def _now(self, now: Optional[float], phase: str) -> float:
        """Resolve a timestamp, pinning the engine to one clock domain.

        Deadlines compare submit arrivals against poll times, so mixing the
        real clock (``now=None``) with caller-supplied virtual times would
        silently dispatch everything immediately (or never) — rejected
        instead. A fully drained engine (no pending requests) holds no
        timestamps to compare against, so it may re-pin to the other clock:
        a finished virtual-time replay can be reused live, and vice versa.
        """
        mode = "real" if now is None else "virtual"
        if self._clock is None or (
            self._clock != mode and self.scheduler.n_pending == 0
        ):
            self._clock = mode
        elif self._clock != mode:
            raise ValueError(
                f"{phase}() used the {mode} clock but this engine is on the "
                f"{self._clock} clock with requests pending; pass `now` "
                f"consistently (or never), or drain before switching"
            )
        return time.monotonic() if now is None else now

    def register_profile(self, profile: PrecisionProfile) -> str:
        """Register a per-layer repeat schedule as a servable tier.

        Validates the schedule against the model's layer layout. The registry
        is add-only: re-registering a name with a *different* schedule is
        rejected (profiles are baked into executable cache keys, so renaming
        a schedule in place would silently serve the old trace). Returns the
        tier id (the profile's name) for ``submit(profile=...)``.
        """
        lm.profile_rows(self.model_cfg, profile)  # validates length vs model
        prev = self._profiles.get(profile.name)
        if prev is not None and prev.cache_key() != profile.cache_key():
            raise ValueError(
                f"profile {profile.name!r} is already registered with a "
                f"different schedule {prev.repeats}; profiles are frozen — "
                "register the new schedule under a new name"
            )
        self._profiles[profile.name] = profile
        return profile.name

    def submit(
        self,
        tokens,
        *,
        n_repeats: int = 1,
        profile=None,
        max_new_tokens: int = 16,
        key: Optional[Array] = None,
        now: Optional[float] = None,
    ) -> int:
        """Enqueue one request; returns its uid (results key in poll()).

        ``profile`` selects a per-layer precision tier: a registered tier id
        or a ``PrecisionProfile`` (auto-registered). Mutually exclusive with
        ``n_repeats``; a *uniform* profile degenerates to the equivalent
        ``n_repeats=K`` tier (identical trace, shared executables, shared
        batches). Digital engines ignore both — K is a no-op without noise.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError(
                "empty prompt: a request must carry at least one token "
                "(there is no position to continue generation from)"
            )
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if n_repeats < 1:
            raise ValueError(f"n_repeats must be >= 1, got {n_repeats}")
        profile_id = None
        if profile is not None:
            if n_repeats != 1:
                raise ValueError(
                    "pass either n_repeats or profile, not both: a profile "
                    "is the per-layer form of the same knob"
                )
            if isinstance(profile, PrecisionProfile):
                profile_id = self.register_profile(profile)
            else:
                profile_id = str(profile)
                if profile_id not in self._profiles:
                    raise ValueError(
                        f"unknown profile {profile_id!r}; register_profile() "
                        "it first (or pass the PrecisionProfile itself)"
                    )
            p = self._profiles[profile_id]
            # degenerate case: a uniform coalesced profile IS the uniform-K
            # tier (coalesce=False is the unrolled test oracle — its trace is
            # deliberately distinct, so it must stay a profile tier)
            if p.is_uniform and p.coalesce:
                n_repeats, profile_id = int(p.repeats[0]), None
        uid = self._uid
        self._uid += 1
        if key is None:
            key = jax.random.fold_in(self._base_key, uid)
        if self.analog_cfg is None:
            # digital serving: K is a no-op, don't split batches on it
            n_repeats, profile_id = 1, None
        req = Request(
            uid=uid,
            tokens=tokens,
            n_repeats=int(n_repeats),
            max_new_tokens=min(int(max_new_tokens), self.max_gen),
            key=raw_key(key),
            arrival=self._now(now, "submit"),
            profile_id=profile_id,
        )
        self.scheduler.submit(req)
        self.stats["requests"] += 1
        return uid

    def poll(self, now: Optional[float] = None) -> Dict[int, np.ndarray]:
        """Run every batch that is ready at ``now``; returns finished uids."""
        now = self._now(now, "poll")
        results: Dict[int, np.ndarray] = {}
        for reqs in self.scheduler.pop_ready(now):
            results.update(self._run_batch(reqs))
        return results

    def flush(self) -> Dict[int, np.ndarray]:
        """Drain the queue regardless of deadlines (end of replay/shutdown)."""
        results: Dict[int, np.ndarray] = {}
        for reqs in self.scheduler.flush():
            results.update(self._run_batch(reqs))
        return results

    # -- execution -----------------------------------------------------------

    def _cfg_sig(self) -> tuple:
        if self.analog_cfg is None:
            return ("digital",)
        return (self.analog_cfg.backend, self.analog_cfg.noise.kind)

    def _analog_spec(
        self,
        keys: Array,
        n_repeats: int,
        profile: Optional[PrecisionProfile] = None,
        pos: Optional[Array] = None,
    ):
        """AnalogSpec for one batch: stacked per-request keys, folded with
        the decode position so every generated token draws fresh noise.
        ``profile`` (a trace-time constant) switches the layer scan to the
        segmented per-layer-K form."""
        if self.analog_cfg is None:
            return None
        k = keys if pos is None else jax.vmap(jax.random.fold_in)(keys, pos)
        return lm.AnalogSpec(
            cfg=self.analog_cfg, energies=self._energies, key=k,
            n_repeats=n_repeats, profile=profile,
        )

    def _keys_spec(self, bb: int) -> jax.ShapeDtypeStruct:
        """Spec for a stacked raw-key batch, sized from the actual key impl
        (threefry keys are 2 uint32 words; other impls differ)."""
        return jax.ShapeDtypeStruct(
            (bb,) + self._base_key.shape, self._base_key.dtype
        )

    def _build_prefill(
        self, bb: int, sb: int, n_repeats: int,
        profile: Optional[PrecisionProfile] = None,
    ):
        cfg = self.model_cfg
        cache_len = sb + self.max_gen

        def fn(params, tokens, lengths, keys):
            self._traces += 1  # runs at trace time only: the retrace audit
            analog = self._analog_spec(keys, n_repeats, profile)
            cache, h_last = lm.prefill(
                params, {"tokens": tokens}, cfg,
                analog=analog, cache_len=cache_len, lengths=lengths,
            )
            logits = lm.logits_last(params, h_last, cfg)
            tok = jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32)
            return cache, tok

        i32 = jnp.int32
        return aot_compile(
            fn,
            self._param_specs,
            jax.ShapeDtypeStruct((bb, sb), i32),
            jax.ShapeDtypeStruct((bb,), i32),
            self._keys_spec(bb),
        )

    def _build_decode(
        self, bb: int, sb: int, n_repeats: int,
        profile: Optional[PrecisionProfile] = None,
    ):
        cfg = self.model_cfg
        cache_len = sb + self.max_gen

        def fn(params, cache, tok, pos, lengths, keys):
            self._traces += 1
            analog = self._analog_spec(keys, n_repeats, profile, pos=pos)
            logits, new_cache = lm.decode_step(
                params, cache, {"tokens": tok}, pos, cfg, analog=analog,
                lengths=lengths,
            )
            nxt = jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32)
            return nxt, new_cache

        i32 = jnp.int32
        cache_specs = jax.eval_shape(lambda: lm.init_cache(cfg, bb, cache_len))
        return aot_compile(
            fn,
            self._param_specs,
            cache_specs,
            jax.ShapeDtypeStruct((bb, 1), i32),
            jax.ShapeDtypeStruct((bb,), i32),
            jax.ShapeDtypeStruct((bb,), i32),
            self._keys_spec(bb),
            donate_argnums=(1,),
        )

    def _batch_keys(self, reqs: List[Request], bb: int) -> Array:
        rows = [r.key for r in reqs]
        # batch-padding rows get a fixed key; their outputs are discarded,
        # per-request streams keep them from touching real rows, and the
        # batch-level MoE expert fold excludes length-0 rows entirely
        # (collapse_keys valid mask), so the pad count never changes noise
        rows += [raw_key(jax.random.PRNGKey(0))] * (bb - len(reqs))
        return jnp.stack([jnp.asarray(k, self._base_key.dtype) for k in rows])

    def _run_batch(self, reqs: List[Request]) -> Dict[int, np.ndarray]:
        tier = reqs[0].tier
        assert all(r.tier == tier for r in reqs), "mixed-tier batch"
        n_repeats = reqs[0].n_repeats
        profile = self._profiles[tier] if isinstance(tier, str) else None
        tier_key = profile.cache_key() if profile is not None else n_repeats
        bb, sb = bucket_shape(
            len(reqs), max(r.prompt_len for r in reqs),
            batch_buckets=self.batch_buckets, seq_buckets=self.seq_buckets,
        )
        tokens_np, lengths_np = pad_to_bucket(
            [r.tokens for r in reqs], (bb, sb), pad_id=self.pad_id
        )
        tokens = jnp.asarray(tokens_np)
        lengths = jnp.asarray(lengths_np)
        keys = self._batch_keys(reqs, bb)
        sig = self._cfg_sig()

        prefill_exe = self.exe_cache.get(
            ("prefill", bb, sb, tier_key) + sig,
            lambda: self._build_prefill(bb, sb, n_repeats, profile),
        )
        cache, tok = prefill_exe(self.params, tokens, lengths, keys)
        toks = [tok]
        n_steps = max(r.max_new_tokens for r in reqs) - 1
        if n_steps > 0:  # single-token batches never need the decode exe
            decode_exe = self.exe_cache.get(
                ("decode", bb, sb, tier_key) + sig,
                lambda: self._build_decode(bb, sb, n_repeats, profile),
            )
        for t in range(n_steps):
            pos = lengths + t
            tok, cache = decode_exe(
                self.params, cache, tok[:, None], pos, lengths, keys
            )
            toks.append(tok)

        seq = np.stack([np.asarray(t) for t in toks], axis=1)  # (bb, n_steps+1)
        out: Dict[int, np.ndarray] = {}
        for i, r in enumerate(reqs):
            out[r.uid] = seq[i, : r.max_new_tokens].copy()
            self.stats["tokens_generated"] += r.max_new_tokens
        self.stats["batches"] += 1
        self.stats["padded_rows"] += bb - len(reqs)
        self.stats["decode_steps"] += n_steps
        return out

    # -- introspection -------------------------------------------------------

    @property
    def energies(self):
        """The frozen energy allocation (baked into compiled executables)."""
        return self._energies

    @property
    def profiles(self) -> Dict[str, PrecisionProfile]:
        """The registered per-layer precision tiers (read-only copy)."""
        return dict(self._profiles)

    def tier_energy_per_token(self, tier) -> float:
        """True analog energy per generated token of a tier (aJ):
        ``sum_l K_l * E_l * MACs_l`` over the frozen per-site energies.

        ``tier``: a uniform K int, a registered profile id, or a
        ``PrecisionProfile``. Uniform K is priced as the degenerate
        uniform profile — same formula, every K_l = K.
        """
        if self._energies is None:
            raise ValueError("digital engine: no energy tree to account")
        if isinstance(tier, PrecisionProfile):
            profile = tier
        elif isinstance(tier, str):
            if tier not in self._profiles:
                raise ValueError(f"unknown profile {tier!r}")
            profile = self._profiles[tier]
        else:
            profile = PrecisionProfile.uniform(int(tier), self.model_cfg.n_layers)
        return lm.profile_token_energy(self.model_cfg, self._energies, profile)

    @property
    def trace_count(self) -> int:
        """Number of jax traces performed (== executable-cache misses)."""
        return self._traces

    def cache_stats(self) -> dict:
        return self.exe_cache.stats()
