"""Bucket-batched analog serving engine.

Takes a queue of heterogeneous generation requests — varying prompt length,
batch arrival pattern, and precision tier (``n_repeats`` = the paper's
dynamic-precision K) — and serves them through the fused analog path:

  submit -> TierScheduler groups same-K requests         (scheduler.py)
         -> pad into a power-of-two (batch, seq) bucket  (bucketing.py)
         -> AOT executable per (bucket, K, backend)      (cache.py)
         -> prefill once, then bucketed decode steps     (models/lm.py)

Two decode disciplines share that pipeline:

  batch-synchronous (default) — a dispatched batch decodes to completion:
      ``max(max_new_tokens)`` steps for every row. Simple, but a 4-token
      request co-batched with a 64-token one pays 16x its own decode work,
      finished rows keep burning analog energy, and nothing new is admitted
      until the batch drains.

  continuous (``continuous=True``) — each tier owns a persistent
      **decode slot pool** (pool.py): a fixed ``(slots, cache_len)`` cache
      that decodes every step under an active-slot mask, *retires* a row
      the step it hits its token budget or emits a stop id, and *admits*
      freshly prefilled requests into the freed slots mid-flight — the
      prefill runs at the pool's cache length and its cache rows are
      scattered in under jit (``lm.scatter_cache_rows``), no retrace, no
      host round-trip of the cache. Decode slots stay saturated with real
      work, which is the throughput headline of every production serving
      stack.

Correctness contract: every request is served with its *own* PRNG key
stacked into the batch (per-request noise streams, see AnalogHook), its own
true prompt length (per-row decode positions), and greedy sampling — so its
tokens are bit-identical to running it alone at the same seq bucket,
regardless of batch-mates, batch padding, decode discipline, slot index, or
admission step. The engine's batching is a pure throughput optimization,
not a numerics change. (Inactive pool slots are exactly length-0
batch-padding rows; a noise stream depends only on the request key, layer,
site, and token position — never on where the row sits.)

Every model family rides this contract via length-aware prefill/decode
(``lengths`` threaded through ``models/lm.py``) — with one exception:
**MoE stays batch-synchronous.** Its expert capacity buffers mix requests
inside one matmul, so analog expert sites draw a *batch-level* noise stream
(``AnalogHook.batched``); under in-flight admission that stream would
change mid-request every time a neighbor retired or arrived. Rather than
silently weakening MoE's (already batch-level) reproducibility story,
``continuous=True`` is rejected for the moe family — serve it with the
batch-synchronous engine, whose noise is reproducible per batch
composition. (Re-folding ``collapse_keys(valid=active)`` per step is the
documented alternative if mid-request noise drift is ever acceptable.)

Execution tiers can never share a batch (or a pool): what a tier computes
is static in the fused kernel (baked into the trace), which is exactly why
the tier scheduler exists. A tier is an *execution configuration*
(serving/tiers.py): the uniform analog ``n_repeats=K``, a registered
per-layer ``PrecisionProfile`` (the paper's learned per-layer precision,
§V-VI — profile batches run the segmented layer scan, their executables
are cache-keyed on the profile's repeat tuple, and their energy/token is
the true ``sum_l K_l * E_l * MACs_l``), or a registered custom tier such
as the weight-only ``Int8DigitalTier`` — all three are implementations of
one ``ExecutionTier`` interface resolved through the engine-owned
``TierRegistry``, so analog and digital traffic serve side by side in one
engine with per-tier executables, params, energy models, and degradation
ladders.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog import AnalogConfig, raw_key
from repro.core.profile import PrecisionProfile
from repro.models import lm
from repro.models.config import ModelConfig
from repro.serving.bucketing import (
    DEFAULT_BATCH_BUCKETS,
    DEFAULT_SEQ_BUCKETS,
    bucket_shape,
    next_bucket,
    pad_to_bucket,
    pool_shape,
)
from repro.serving.cache import ExecutableCache, mesh_fingerprint
from repro.serving.faults import (
    BoundedLog,
    FaultPlan,
    QueueFull,
    TransientExecutableFault,
)
from repro.serving.policy import PolicyConfig, PrecisionGovernor
from repro.serving.pool import DecodePool
from repro.serving.scheduler import Request, TierScheduler
from repro.serving.tiers import ExecutionTier, TierRegistry

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class RequestFailure:
    """Structured non-success result: a request the engine gave up on.

    Successes stay plain ``np.ndarray`` token rows; a failed or timed-out
    request resolves (exactly once, in the same results dict) to one of
    these instead — no hang, no exception swallowing a batch, no leaked
    slot. ``tokens`` carries whatever was generated before the failure
    (a timeout mid-decode keeps its partial output, a queue timeout is
    empty); partial tokens are a *prefix* of the fault-free output — the
    bit-identity contract holds for every token actually emitted.
    """

    uid: int
    tokens: np.ndarray  # tokens emitted before the failure (maybe empty)
    detail: str
    retries: int = 0

    @property
    def ok(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class TimedOut(RequestFailure):
    """The request's deadline passed while it was queued or decoding."""


@dataclasses.dataclass(frozen=True)
class Failed(RequestFailure):
    """The request hit an injected/transient fault and ran out of retries."""


#: what poll()/flush() map a uid to: a token row, or a structured failure
RequestResult = Union[np.ndarray, RequestFailure]


class ServingEngine:
    """Serves mixed-precision generation traffic over a frozen analog model.

    ``analog_cfg=None`` serves the digital model (same batching machinery,
    no noise). ``energies`` is an ``init_energy_tree``-shaped allocation —
    per-site energy at K=1; a tier's total spend is ``K * energy`` (uniform)
    or ``sum_l K_l * E_l * MACs_l`` for a per-layer profile tier
    (``profiles`` / ``register_profile`` / ``submit(profile=...)``).

    ``analog_cfg`` and ``energies`` are FROZEN for the engine's lifetime:
    they are baked into every compiled executable as trace-time constants
    (the cache key doesn't cover them), so mutation would silently serve
    stale energies from warm buckets. ``energies`` is a read-only property;
    a recalibrated allocation means a new engine. ``params`` are runtime
    arguments and may be swapped freely.

    ``continuous=True`` switches decode to persistent per-tier slot pools
    (see the module docstring): ``pool_slots`` sizes each pool (default:
    the largest batch bucket), and the pool cache length defaults to
    ``max(seq_buckets) + max_gen`` so any admissible request fits any slot.
    Every pool step attends over the full pool cache, so SIZE THE SEQ
    LADDER (or pass ``pool_cache_len``) TO YOUR TRAFFIC: with the default
    1024-top ladder, short-prompt traffic would decode against a ~1056-slot
    cache each step and hand the throughput win back. A smaller
    ``pool_cache_len`` is enforced at submit — a request whose seq bucket
    plus decode budget can't fit a slot is rejected with the resize advice
    (pool-shape *ladders* are future work, see ROADMAP). ``max_entries``
    optionally LRU-bounds the executable cache — pool shapes multiply the
    key space, so long-lived multi-tier engines may want a cap (default
    unbounded).
    """

    def __init__(
        self,
        params,
        model_cfg: ModelConfig,
        *,
        analog_cfg: Optional[AnalogConfig] = None,
        energies=None,
        max_gen: int = 32,
        max_batch: int = 8,
        max_wait: float = 0.05,
        batch_buckets: Sequence[int] = DEFAULT_BATCH_BUCKETS,
        seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
        pad_id: int = 0,
        seed: int = 0,
        profiles: Optional[Sequence[PrecisionProfile]] = None,
        continuous: bool = False,
        pool_slots: Optional[int] = None,
        pool_cache_len: Optional[int] = None,
        max_entries: Optional[int] = None,
        max_queue: Optional[int] = None,
        fault_plan: Optional[FaultPlan] = None,
        max_retries: int = 1,
        k_ladder: Sequence[int] = (1, 2, 4, 8),
        fault_log_maxlen: Optional[int] = 4096,
        policy: Optional[PolicyConfig] = None,
        metrics=None,
        mesh=None,
    ):
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if not k_ladder or any(int(k) < 1 for k in k_ladder):
            raise ValueError(f"k_ladder must be positive Ks, got {k_ladder}")
        if analog_cfg is not None and energies is None:
            raise ValueError("analog serving requires an energy tree")
        if continuous and model_cfg.family == "moe":
            raise ValueError(
                "continuous batching is unavailable for the moe family: "
                "analog expert sites draw a batch-level noise stream "
                "(capacity buffers mix requests), so in-flight admission/"
                "retirement would change a request's noise mid-stream; "
                "serve MoE batch-synchronously (continuous=False)"
            )
        self.params = params
        self.model_cfg = model_cfg
        self.analog_cfg = analog_cfg
        self._energies = energies
        #: the tier registry (serving/tiers.py): the ONE component that
        #: maps tier ids — uniform K ints, profile names, custom digital
        #: tier ids — to ExecutionTier objects (executable factory, cache
        #: identity, params, energy model, degradation ladder). Add-only,
        #: like the profile store it subsumes.
        self.tiers = TierRegistry(self)
        for p in profiles or ():
            self.register_profile(p)
        self.max_gen = max_gen
        self.batch_buckets = tuple(batch_buckets)
        self.seq_buckets = tuple(seq_buckets)
        self.pad_id = pad_id
        self.scheduler = TierScheduler(
            max_batch=min(max_batch, max(batch_buckets)),
            max_wait=max_wait,
            seq_buckets=seq_buckets,
            max_queue=max_queue,
        )
        #: injection schedule (serving/faults.py); clearing it to None
        #: mid-run models repaired hardware — every site (including the
        #: cache's executable guard, which reads it dynamically) goes quiet
        self.fault_plan = fault_plan
        self.max_retries = int(max_retries)
        self.k_ladder = tuple(sorted({int(k) for k in k_ladder}))

        def _exe_guard(key):
            if self.fault_plan is not None:
                self.fault_plan.check_executable(key)

        self.exe_cache = ExecutableCache(
            max_entries=max_entries,
            fault_hook=_exe_guard if fault_plan is not None else None,
        )
        self.continuous = bool(continuous)
        self.pool_slots, self.pool_cache_len = pool_shape(
            pool_slots if pool_slots is not None else max(batch_buckets),
            seq_buckets,
            max_gen,
        )
        if pool_cache_len is not None:
            # explicit pool sizing for traffic shorter than the seq ladder's
            # top: requests that can't fit a slot are rejected at submit
            if pool_cache_len <= min(seq_buckets):
                raise ValueError(
                    f"pool_cache_len={pool_cache_len} can't hold even a "
                    f"minimum-bucket prompt ({min(seq_buckets)}) plus one "
                    "generated token"
                )
            self.pool_cache_len = int(pool_cache_len)
        #: tier -> persistent DecodePool, created lazily at first admission
        self._pools: Dict[object, DecodePool] = {}
        #: attached device mesh (tensor-parallel serving) + its AOT-key
        #: fingerprint; () unmeshed so legacy cache keys are unchanged
        self._mesh = None
        self._mesh_key: tuple = ()
        self._base_key = raw_key(jax.random.PRNGKey(seed))
        self._param_specs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params
        )
        self._uid = 0
        self._clock: Optional[str] = None  # "real" | "virtual", set on first use
        self._traces = 0  # incremented at trace time inside the step fns
        #: realized noise-std drift factor: 1.0 is nominal (bit-identical to
        #: an engine without the knob — the executables divide energies by
        #: scale**2 as a runtime operand, and x/1.0 is IEEE-exact)
        self._noise_scale = 1.0
        #: drift response: when set, newly submitted uniform-K requests are
        #: promoted one rung up the k_ladder until recalibrate() clears it
        self._promoted = False
        #: monotone per-decode-step-attempt counter — the fault plan's clock
        #: (advances on stalled steps too, so schedules can't wedge a drain)
        self._fault_clock = 0
        self.stats = {
            "requests": 0,
            "batches": 0,
            "tokens_generated": 0,
            "padded_rows": 0,
            "decode_steps": 0,
            # decode work actually dispatched, in row-slots (steps x batch
            # rows, or steps x pool slots): the structural quantity
            # continuous batching shrinks on heterogeneous traffic
            "decode_slot_steps": 0,
            # of those, row-slots that carried a live request (pool only)
            "active_slot_steps": 0,
            "admitted": 0,  # requests admitted into a pool slot
            "retired": 0,  # pool retirements (budget hit or stop id)
            # fault tolerance: structured-failure and degradation counters
            "timed_out": 0,  # requests retired past their deadline
            "failed": 0,  # requests that exhausted fault retries
            "retried": 0,  # fault-triggered resubmissions
            "stalled_steps": 0,  # pool decode steps lost to injected stalls
            "exe_faults": 0,  # transient executable failures absorbed
            "exe_errors": 0,  # unexpected executable exceptions contained
            "poisoned_rows": 0,  # corrupted decode rows detected + retired
            "cancelled": 0,  # requests withdrawn via cancel()
            "promotions": 0,  # drift-response tier promotions activated
            # SLA policy (serving/policy.py) + bounded-log accounting
            "shed": 0,  # submissions rejected by the governor's last rung
            "demoted": 0,  # queued requests retiered down under pressure
            "promoted_back": 0,  # queued requests restored after the drain
            "policy_transitions": 0,  # governor mode flips (dwell-gated)
            "dropped_events": 0,  # fault_log entries evicted by the bound
            # per-tier realized work: tier -> generated tokens / decode
            # steps dispatched (the energy-attribution surface: multiply by
            # tier_energy_per_token for realized spend)
            "tier_tokens": {},
            "tier_decode_steps": {},
        }
        #: engine-side record of every fault consequence and policy action:
        #: which uids were retried/failed/timed out/retiered, and every
        #: drift response — the bench and tests derive the affected-request
        #: set from this. Ring-bounded (``fault_log_maxlen``): evictions
        #: are counted in stats["dropped_events"], never silently lost.
        self.fault_log: List[dict] = BoundedLog(
            maxlen=fault_log_maxlen, on_drop=self._note_dropped_events
        )
        #: uid -> tier the request was actually dispatched at (set when it
        #: enters a prefill batch; governor demotions land *before*
        #: dispatch, so this is the ground truth for accuracy-floor audits
        #: and the bench's realized accuracy proxy). A fault retry that
        #: re-dispatches at a promoted tier overwrites its entry.
        self.served_tiers: Dict[int, object] = {}
        #: streaming observability feed (monitor.MetricsFeed or anything
        #: with a ``record(engine, now=...)`` method): sampled once per
        #: pump/poll round — the per-tier time-series surface
        self.metrics = metrics
        #: SLA-aware precision governor (None without a policy config)
        self.governor: Optional[PrecisionGovernor] = None
        if policy is not None:
            self.governor = PrecisionGovernor(self, policy)
        if mesh is not None:
            self.attach_mesh(mesh)

    def _note_dropped_events(self, n: int) -> None:
        """BoundedLog eviction hook: surface ring-buffer drops as a stat."""
        self.stats["dropped_events"] += n

    def _bump_tier(self, stat: str, tier, n: int) -> None:
        """Accumulate per-tier realized work (tokens / decode steps)."""
        d = self.stats[stat]
        d[tier] = d.get(tier, 0) + n

    # -- request intake ------------------------------------------------------

    def _now(self, now: Optional[float], phase: str) -> float:
        """Resolve a timestamp, pinning the engine to one clock domain.

        Deadlines compare submit arrivals against poll times, so mixing the
        real clock (``now=None``) with caller-supplied virtual times would
        silently dispatch everything immediately (or never) — rejected
        instead. A fully drained engine (no pending requests) holds no
        timestamps to compare against, so it may re-pin to the other clock:
        a finished virtual-time replay can be reused live, and vice versa.
        """
        mode = "real" if now is None else "virtual"
        if self._clock is None or (
            self._clock != mode and self.scheduler.n_pending == 0
        ):
            self._clock = mode
        elif self._clock != mode:
            raise ValueError(
                f"{phase}() used the {mode} clock but this engine is on the "
                f"{self._clock} clock with requests pending; pass `now` "
                f"consistently (or never), or drain before switching"
            )
        return time.monotonic() if now is None else now

    def register_profile(self, profile: PrecisionProfile) -> str:
        """Register a per-layer repeat schedule as a servable tier.

        Validates the schedule against the model's layer layout. The registry
        is add-only: re-registering a name with a *different* schedule is
        rejected (profiles are baked into executable cache keys, so renaming
        a schedule in place would silently serve the old trace). Returns the
        tier id (the profile's name) for ``submit(profile=...)``.
        """
        return self.tiers.register_profile(profile)

    def register_tier(self, tier: ExecutionTier):
        """Register a custom execution tier (e.g. ``Int8DigitalTier``) as
        a servable tier id for ``submit(tier=...)`` — the plug point for
        execution domains beyond analog K-repeats. Add-only, same AOT
        contract as profiles. Returns the tier id."""
        return self.tiers.register(tier)

    def submit(
        self,
        tokens,
        *,
        n_repeats: int = 1,
        profile=None,
        tier=None,
        max_new_tokens: Optional[int] = None,
        stop_tokens: Sequence[int] = (),
        key: Optional[Array] = None,
        now: Optional[float] = None,
        deadline: Optional[float] = None,
        target_latency: Optional[float] = None,
        accuracy_floor: Optional[float] = None,
        max_degradation: Optional[float] = None,
    ) -> int:
        """Enqueue one request; returns its uid (results key in poll()).

        ``profile`` selects a per-layer precision tier: a registered tier id
        or a ``PrecisionProfile`` (auto-registered). Mutually exclusive with
        ``n_repeats``; a *uniform* profile degenerates to the equivalent
        ``n_repeats=K`` tier (identical trace, shared executables, shared
        batches). Digital engines ignore both — K is a no-op without noise.

        ``tier`` is the general form: any registered tier id (a uniform K
        int, a profile name, or a custom tier id such as the int8 digital
        tier's — see ``register_tier``), a ``PrecisionProfile``, or an
        ``ExecutionTier`` instance (auto-registered). Mutually exclusive
        with the two legacy knobs above; unlike them it is honored on
        digital engines too (an explicitly requested digital tier is not
        an analog precision knob to coalesce away).

        ``stop_tokens``: EOS-style ids. Greedy decode finishes the request
        the step it emits one (the stop id is included as the last output
        token); without any, the request runs its full ``max_new_tokens``.

        ``deadline``: absolute timestamp (same clock domain as ``now``)
        past which the request is retired with a structured ``TimedOut``
        result — empty if still queued, the partial output if mid-decode.
        Deadlines are enforced on clocked ``poll``/``pump_step`` calls;
        ``flush()`` drains everything and checks none (like ``max_wait``).

        SLO fields (the precision governor's inputs, serving/policy.py):
        ``target_latency`` is a *relative* latency target in seconds from
        arrival — it defaults ``deadline`` to ``arrival + target_latency``
        when no explicit deadline is given, and feeds the governor's
        deadline-headroom urgency signal. ``accuracy_floor`` bounds how far
        the governor may demote this request under overload (the minimum
        acceptable tier accuracy); ``max_degradation`` expresses the same
        floor relative to the *requested* tier's measured accuracy
        (``floor = acc(requested tier) - max_degradation``, the paper's
        degradation form — requires a governor whose table prices the
        requested tier). Without a governor the floors are inert metadata
        and ``target_latency`` still arms the deadline.

        Raises :class:`~repro.serving.faults.QueueFull` when the scheduler
        queue is at its ``max_queue`` high-water mark (backpressure), when
        the governor is **shedding** (the policy's last rung: every queued
        request is already at its accuracy floor and pressure is still
        above the shed threshold), and
        ``ValueError`` for requests the engine could never serve: an empty
        prompt, a prompt longer than the largest seq bucket, or a
        ``max_new_tokens`` outside ``[1, max_gen]`` (the decode budget is
        part of every compiled cache length — silently clamping it would
        return fewer tokens than asked for). ``max_new_tokens=None`` (the
        default) requests the full ``max_gen`` budget.
        """
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        if tokens.size == 0:
            raise ValueError(
                "empty prompt: a request must carry at least one token "
                "(there is no position to continue generation from)"
            )
        if tokens.size > max(self.seq_buckets):
            raise ValueError(
                f"prompt of {tokens.size} tokens exceeds the largest seq "
                f"bucket ({max(self.seq_buckets)}); extend seq_buckets or "
                "truncate the prompt"
            )
        if max_new_tokens is None:
            max_new_tokens = self.max_gen  # default: the full decode budget
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if max_new_tokens > self.max_gen:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} exceeds this engine's "
                f"decode budget max_gen={self.max_gen} (cache lengths are "
                "compiled around it); raise max_gen or lower the request"
            )
        if n_repeats < 1:
            raise ValueError(f"n_repeats must be >= 1, got {n_repeats}")
        if target_latency is not None and target_latency <= 0.0:
            raise ValueError(
                f"target_latency must be > 0 seconds, got {target_latency}"
            )
        if accuracy_floor is not None and max_degradation is not None:
            raise ValueError(
                "pass either accuracy_floor or max_degradation, not both: "
                "max_degradation is the floor expressed relative to the "
                "requested tier's accuracy"
            )
        if max_degradation is not None:
            if max_degradation < 0.0:
                raise ValueError(
                    f"max_degradation must be >= 0, got {max_degradation}"
                )
            if self.governor is None:
                raise ValueError(
                    "max_degradation needs a policy governor: the floor is "
                    "relative to the requested tier's measured accuracy, "
                    "which lives in the governor's tier table (pass "
                    "accuracy_floor for an absolute bound instead)"
                )
        if self.continuous:
            # a pool slot must hold the prompt's seq bucket + decode budget
            sb = next_bucket(tokens.size, self.seq_buckets)
            budget = int(max_new_tokens)
            if sb + budget > self.pool_cache_len:
                raise ValueError(
                    f"request needs {sb} (seq bucket) + {budget} (decode "
                    f"budget) cache slots but the decode pools hold "
                    f"{self.pool_cache_len}; raise pool_cache_len or size "
                    "seq_buckets/max_gen to the traffic"
                )
        stop_tokens = tuple(int(t) for t in stop_tokens)
        if tier is not None:
            if profile is not None or n_repeats != 1:
                raise ValueError(
                    "pass either tier, or the legacy n_repeats/profile "
                    "knobs, not both: tier is the general form of the "
                    "same dial"
                )
            tier_id = self.tiers.resolve(tier)
        elif profile is not None:
            if n_repeats != 1:
                raise ValueError(
                    "pass either n_repeats or profile, not both: a profile "
                    "is the per-layer form of the same knob"
                )
            # a uniform coalesced profile degenerates to its bare-K tier id
            # (coalesce=False is the unrolled test oracle — its trace is
            # deliberately distinct, so it stays a profile tier)
            tier_id = self.tiers.resolve_profile(profile)
        else:
            tier_id = int(n_repeats)
        if max_degradation is not None:
            # the paper's degradation form: floor relative to the requested
            # tier's measured accuracy (raises if the tier is unpriced)
            accuracy_floor = (
                self.governor.tier_accuracy(tier_id) - float(max_degradation)
            )
        if self.governor is not None and self.governor.shedding:
            # the policy's last rung: demotion headroom is exhausted, so new
            # traffic is rejected instead of queued past every deadline
            self.stats["shed"] += 1
            self.fault_log.append({
                "kind": "shed", "clock": self._fault_clock,
                "queue_depth": self.scheduler.n_pending,
            })
            raise QueueFull(
                f"precision governor is shedding load: every queued request "
                f"is already at its accuracy floor and pressure is still "
                f"above the shed threshold ({self.scheduler.n_pending} "
                "pending); retry after the queue drains"
            )
        uid = self._uid
        self._uid += 1
        if key is None:
            key = jax.random.fold_in(self._base_key, uid)
        if tier is None and self.analog_cfg is None:
            # digital serving: K/profile are analog precision no-ops, don't
            # split batches on them (explicit tier= requests keep their tier)
            tier_id = self.tiers.base_id
        elif self._promoted:
            # drift response: serve new traffic one rung up its tier's own
            # ladder until recalibration clears the event (queued/in-flight
            # requests keep their tier — their noise keys already bind them;
            # profile and drift-exempt digital tiers pass through unchanged)
            tier_id = self.tiers.drift_promote(tier_id)
        arrival = self._now(now, "submit")
        if deadline is None and target_latency is not None:
            # the SLO arms the deadline: a missed latency target surfaces as
            # a structured TimedOut (which the governor's job is to prevent)
            deadline = arrival + float(target_latency)
        req = Request(
            uid=uid,
            tokens=tokens,
            max_new_tokens=int(max_new_tokens),
            key=raw_key(key),
            arrival=arrival,
            stop_tokens=stop_tokens,
            deadline=deadline,
            target_latency=(
                None if target_latency is None else float(target_latency)
            ),
            accuracy_floor=(
                None if accuracy_floor is None else float(accuracy_floor)
            ),
        )
        req.retier(tier_id)
        self.scheduler.submit(req)
        self.stats["requests"] += 1
        return uid

    def poll(self, now: Optional[float] = None) -> Dict[int, RequestResult]:
        """Serve every request that is ready at ``now``; returns finished
        uids (token rows, or structured ``TimedOut``/``Failed`` values).
        Batch-synchronous: runs each ready batch to completion. Continuous:
        admits ready requests into pool slots and pumps masked decode steps
        — re-admitting as retirements free slots — until the pools drain
        and nothing else is deadline-ready. Requests requeued by a
        transient fault are reserved within the same call when ready."""
        now = self._now(now, "poll")
        if self.continuous:
            return self._pump(now, force=False)
        results: Dict[int, RequestResult] = self._expire_queued(now)
        if self.governor is not None:
            self.governor.step(now)
        # loop: a faulted batch requeues its requests (aged arrivals stay
        # deadline-ready), so one poll drains everything ready at `now`
        while True:
            batches = self.scheduler.pop_ready(now)
            if not batches:
                break
            for reqs in batches:
                results.update(self._run_batch(reqs))
        if self.metrics is not None:
            self.metrics.record(self, now=now)
        return results

    def cancel(self, uid: int) -> bool:
        """Withdraw a submitted request before it finishes.

        A queued request leaves the scheduler; a pooled request retires
        immediately (its slot frees for admission on the very next pump
        round) and its partial tokens are discarded. Per-request noise
        keys make this safe mid-batch: batch-mates' token streams never
        depended on the cancelled row. Returns ``False`` when the uid is
        unknown or already finished — the caller (e.g. a cluster router
        cancelling a hedged-dispatch loser) treats that as "the result
        already shipped" and dedupes it instead.
        """
        if self.scheduler.cancel(uid) is not None:
            self.stats["cancelled"] += 1
            self.fault_log.append(
                {"kind": "cancel", "where": "queue", "uids": [uid]}
            )
            return True
        for pool in self._pools.values():
            for s in pool.active_slots():
                if pool.record(s).request.uid == uid:
                    pool.retire(s)
                    self.stats["retired"] += 1
                    self.stats["cancelled"] += 1
                    self.fault_log.append(
                        {"kind": "cancel", "where": "pool", "uids": [uid]}
                    )
                    return True
        return False

    def flush(self) -> Dict[int, RequestResult]:
        """Drain the queue regardless of deadlines (end of replay/shutdown)."""
        if self.continuous:
            return self._pump(None, force=True)
        results: Dict[int, RequestResult] = {}
        while self.scheduler.n_pending:  # fault retries re-enter the queue
            for reqs in self.scheduler.flush():
                results.update(self._run_batch(reqs))
        return results

    # -- graceful degradation ------------------------------------------------

    def _expire_queued(self, now: Optional[float]) -> Dict[int, RequestResult]:
        """Retire queued requests whose deadline passed (clocked calls only)."""
        out: Dict[int, RequestResult] = {}
        if now is None:
            return out
        for r in self.scheduler.pop_expired(now):
            out[r.uid] = TimedOut(
                uid=r.uid, tokens=np.zeros((0,), np.int32), retries=r.retries,
                detail=f"deadline {r.deadline:g} passed at {now:g} in queue",
            )
            self.stats["timed_out"] += 1
            self.fault_log.append(
                {"kind": "timeout", "where": "queue", "uids": [r.uid]}
            )
        return out

    def _expire_pooled(self, now: Optional[float]) -> Dict[int, RequestResult]:
        """Retire pooled requests past deadline; partial tokens are kept
        (a prefix of the fault-free output) and slots free immediately."""
        out: Dict[int, RequestResult] = {}
        if now is None:
            return out
        for pool in self._pools.values():
            for s in pool.expired(now):
                rec = pool.retire(s)
                r = rec.request
                out[r.uid] = TimedOut(
                    uid=r.uid,
                    tokens=np.asarray(rec.emitted, np.int32),
                    retries=r.retries,
                    detail=(
                        f"deadline {r.deadline:g} passed at {now:g} after "
                        f"{len(rec.emitted)} tokens"
                    ),
                )
                self.stats["timed_out"] += 1
                self.stats["retired"] += 1
                self.fault_log.append(
                    {"kind": "timeout", "where": "pool", "uids": [r.uid]}
                )
        return out

    def _fault_requeue(
        self, reqs: List[Request], kind: str, detail: str
    ) -> Dict[int, RequestResult]:
        """Handle requests whose batch hit a transient fault: one bounded
        retry from scratch at the tier's own *promoted* rung — uniform K
        goes one rung up the ladder (noise/sqrt(K) buys margin against
        whatever corrupted the batch), a profile tier promotes to a
        registered higher-accuracy tier or a per-layer re-trim, digital
        tiers retry in place (repeats buy nothing without noise) — else
        a structured ``Failed``. Partial output is discarded: a faulted
        batch's tokens are not trustworthy."""
        out: Dict[int, RequestResult] = {}
        entry = {
            "kind": kind, "clock": self._fault_clock, "detail": detail,
            "uids": [r.uid for r in reqs], "retried": [], "failed": [],
            "promoted": {},
        }
        for r in reqs:
            if r.retries < self.max_retries:
                r2 = dataclasses.replace(r, retries=r.retries + 1)
                r2.retier(self.tiers.get(r.tier).promote())
                # force: an internal requeue must never bounce off QueueFull
                self.scheduler.submit(r2, force=True)
                self.stats["retried"] += 1
                entry["retried"].append(r.uid)
                entry["promoted"][r.uid] = r2.tier
            else:
                out[r.uid] = Failed(
                    uid=r.uid, tokens=np.zeros((0,), np.int32),
                    detail=detail, retries=r.retries,
                )
                self.stats["failed"] += 1
                entry["failed"].append(r.uid)
        self.fault_log.append(entry)
        return out

    def set_noise_scale(self, scale: float) -> None:
        """Set the realized noise-std drift factor (1.0 = nominal). The
        scale is a *runtime operand* of every compiled executable — no
        retrace, and 1.0 is bit-identical to an engine without the knob."""
        if scale <= 0.0:
            raise ValueError(f"noise scale must be > 0, got {scale}")
        self._noise_scale = float(scale)

    @property
    def noise_scale(self) -> float:
        return self._noise_scale

    @property
    def promoted(self) -> bool:
        """True while the drift response is promoting new uniform-K traffic."""
        return self._promoted

    def promote_tiers(self, event=None) -> None:
        """Drift response: until :meth:`recalibrate`, newly submitted
        uniform-K requests serve one rung up the ``k_ladder`` (extra
        repeats buy back the drifted noise floor at higher energy; the
        ladder top is the calibrated bound). Typically driven by a
        ``NoiseDriftWatchdog`` event; idempotent."""
        if not self._promoted:
            self.stats["promotions"] += 1
        self._promoted = True
        self.fault_log.append(
            {"kind": "drift_promotion", "clock": self._fault_clock,
             "event": event if event is None else dataclasses.asdict(event),
             # attribution: registered tiers the response does NOT touch
             # (digital executions don't share the analog array's physics)
             "exempt_tiers": self.tiers.drift_exempt_ids()}
        )

    def recalibrate(self, *, noise_scale: float = 1.0) -> None:
        """The recalibration hook: clear the drift response and pin the
        realized noise scale (1.0 after physical recalibration; the
        measured residual factor if the hardware can only partially
        correct). New submissions return to their requested tiers."""
        self._promoted = False
        self.set_noise_scale(noise_scale)
        self.fault_log.append(
            {"kind": "recalibrated", "clock": self._fault_clock,
             "noise_scale": float(noise_scale)}
        )

    def _sync_noise_scale(self) -> None:
        """Pull the fault plan's drift factor at the current fault clock."""
        if self.fault_plan is not None and self.fault_plan.drift is not None:
            self._noise_scale = self.fault_plan.noise_scale_at(self._fault_clock)

    def _scale_arr(self) -> Array:
        return jnp.asarray(self._noise_scale, jnp.float32)

    # -- mesh attach / resize ------------------------------------------------

    @property
    def mesh(self):
        """The attached device mesh (None = single-device serving)."""
        return self._mesh

    @property
    def mesh_key(self) -> tuple:
        """The mesh fingerprint appended to every AOT cache key (() unmeshed)."""
        return self._mesh_key

    def attach_mesh(self, mesh) -> None:
        """Attach (or resize to) a device mesh for tensor-parallel serving.

        All jit-boundary arrays — params, decode-pool caches, batch inputs —
        stay *replicated* across the mesh (``SERVING_RULES``); tensor
        parallelism lives entirely inside ``analog_dot``'s shard_map, whose
        column shards salt their counter-based noise on global tile
        coordinates, so a mesh engine's tokens are bit-identical to the
        single-device oracle. Because replication is mesh-shape-agnostic,
        executables survive *as lowered programs* across resize — but their
        device assignment does not, so cache keys carry the mesh fingerprint:
        a resize compiles fresh entries once, then serves at a 100% hit rate
        again (and a resize back to a previous mesh re-hits its warm entries).

        Resizing requires a drained engine (no queued or pooled requests):
        live decode state is pinned to the old mesh's devices. Pools are
        dropped and lazily rebuilt replicated on the new mesh — empty pools
        hold no request state, so nothing is lost. ``attach_mesh(None)``
        detaches (back to single-device serving).
        """
        if self.n_in_flight:
            raise ValueError(
                f"cannot attach/resize a mesh with {self.n_in_flight} "
                "requests in flight (their decode state is pinned to the "
                "current devices); drain with flush() first"
            )
        self._mesh = mesh
        self._mesh_key = mesh_fingerprint(mesh)
        self._pools.clear()  # rebuilt lazily, replicated on the new mesh
        self.params = self._replicate(self.params)
        self._param_specs = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), self.params
        )

    def _replicated_sharding(self):
        """NamedSharding(mesh, P()) when a mesh is attached, else None."""
        if self._mesh is None:
            return None
        from jax.sharding import NamedSharding, PartitionSpec

        return NamedSharding(self._mesh, PartitionSpec())

    def _replicate(self, tree):
        """device_put a tree replicated onto the attached mesh (identity
        unmeshed) — run once at attach/pool-build time, never per dispatch."""
        sh = self._replicated_sharding()
        if sh is None:
            return tree
        return jax.device_put(tree, sh)

    def _mesh_ctx(self):
        """Ambient-mesh context the tier builders lower under: the attached
        mesh with every logical axis replicated (``SERVING_RULES``), which
        is what routes analog matmuls through the tensor-parallel shard_map
        at trace time. A no-op context unmeshed."""
        if self._mesh is None:
            return contextlib.nullcontext()
        from repro.models import sharding as shardlib

        return shardlib.use_mesh(self._mesh, shardlib.SERVING_RULES)

    # -- execution -----------------------------------------------------------
    # the executable builders and cache-key identity live on the tiers
    # themselves (serving/tiers.py): the engine only composes
    # ``tiers.exe_key(phase, tier, *shape)`` with ``tier.build_*`` and
    # dispatches ``tier.params`` — it never inspects what kind of tier it
    # is holding (the lint test in tests/test_tiers.py keeps it that way)

    def _keys_spec(self, bb: int) -> jax.ShapeDtypeStruct:
        """Spec for a stacked raw-key batch, sized from the actual key impl
        (threefry keys are 2 uint32 words; other impls differ)."""
        sh = self._replicated_sharding()
        if sh is None:
            return jax.ShapeDtypeStruct(
                (bb,) + self._base_key.shape, self._base_key.dtype
            )
        return jax.ShapeDtypeStruct(
            (bb,) + self._base_key.shape, self._base_key.dtype, sharding=sh
        )

    def _batch_keys(self, reqs: List[Request], bb: int) -> Array:
        rows = [r.key for r in reqs]
        # batch-padding rows get a fixed key; their outputs are discarded,
        # per-request streams keep them from touching real rows, and the
        # batch-level MoE expert fold excludes length-0 rows entirely
        # (collapse_keys valid mask), so the pad count never changes noise
        rows += [raw_key(jax.random.PRNGKey(0))] * (bb - len(reqs))
        return jnp.stack([jnp.asarray(k, self._base_key.dtype) for k in rows])

    def _prefill_batch(self, reqs: List[Request], cache_len: Optional[int] = None):
        """Shared prefill dispatch: pad into a bucket, run the AOT prefill
        at ``cache_len`` (default: the batch-synchronous ``sb + max_gen``;
        continuous admission passes the pool's cache length), returning
        ((bucket, cache_len), cache, first tokens). The tokens stay a
        device array — only callers that need host values (admission
        bookkeeping, stop-id checks) should materialize them, so the
        batch-synchronous path keeps enqueueing work without a sync."""
        tier = reqs[0].tier
        assert all(r.tier == tier for r in reqs), "mixed-tier batch"
        for r in reqs:  # dispatch point: the tier is now bound (see ctor)
            self.served_tiers[r.uid] = tier
        t = self.tiers.get(tier)
        bb, sb = bucket_shape(
            len(reqs), max(r.prompt_len for r in reqs),
            batch_buckets=self.batch_buckets, seq_buckets=self.seq_buckets,
        )
        if cache_len is None:
            cache_len = sb + self.max_gen
        tokens_np, lengths_np = pad_to_bucket(
            [r.tokens for r in reqs], (bb, sb), pad_id=self.pad_id
        )
        keys = self._batch_keys(reqs, bb)
        prefill_exe = self.exe_cache.get(
            self.tiers.exe_key("prefill", tier, bb, sb, cache_len),
            lambda: t.build_prefill(bb, sb, cache_len),
        )
        self._sync_noise_scale()
        cache, tok = prefill_exe(
            t.params, jnp.asarray(tokens_np), jnp.asarray(lengths_np), keys,
            self._scale_arr(),
        )
        self.stats["batches"] += 1
        self.stats["padded_rows"] += bb - len(reqs)
        return (bb, sb, cache_len), keys, cache, tok

    # -- batch-synchronous execution ----------------------------------------

    def _run_batch(self, reqs: List[Request]) -> Dict[int, RequestResult]:
        tier = reqs[0].tier
        exec_tier = self.tiers.get(tier)
        try:
            (bb, _sb, cache_len), keys, cache, tok = self._prefill_batch(reqs)
        except TransientExecutableFault as f:
            self.stats["exe_faults"] += 1
            return self._fault_requeue(reqs, "exe_fault", str(f))
        except Exception as e:  # noqa: BLE001 - serving must not crash
            # an executable raising anything else mid-batch is contained
            # the same way: the batch retires into the bounded-retry path
            # (structured Failed once retries exhaust), never a crashed
            # serving loop with requests stranded in limbo
            self.stats["exe_errors"] += 1
            return self._fault_requeue(reqs, "exe_error", repr(e))
        lengths = jnp.asarray([r.prompt_len for r in reqs] + [0] * (bb - len(reqs)),
                              jnp.int32)
        toks = [tok]
        stop_sets = [r.stop_set for r in reqs]
        has_stops = any(stop_sets)
        n_steps = max(r.max_new_tokens for r in reqs) - 1
        if has_stops:  # host read only when EOS is in play
            tok0 = np.asarray(tok)
            emitted = [1] * len(reqs)
            done = [
                emitted[i] >= r.max_new_tokens or int(tok0[i]) in stop_sets[i]
                for i, r in enumerate(reqs)
            ]
        steps_run = 0
        if n_steps > 0:  # single-token batches never need the decode exe
            decode_exe = self.exe_cache.get(
                self.tiers.exe_key("decode", tier, bb, cache_len),
                lambda: exec_tier.build_decode(bb, cache_len),
            )
        for t in range(n_steps):
            if has_stops and all(done):
                break  # EOS early exit: every real row hit budget or stop id
            pos = lengths + t
            self._fault_clock += 1
            self._sync_noise_scale()
            try:
                tok, cache = decode_exe(
                    exec_tier.params, cache, tok[:, None], pos, lengths, keys,
                    self._scale_arr(),
                )
            except TransientExecutableFault as f:
                # pre-dispatch guard: the donated cache was not consumed,
                # but a faulted batch's partial tokens are discarded — the
                # whole batch retries from scratch (or fails, bounded)
                self.stats["exe_faults"] += 1
                self.stats["decode_steps"] += steps_run
                self.stats["decode_slot_steps"] += steps_run * bb
                return self._fault_requeue(reqs, "exe_fault", str(f))
            except Exception as e:  # noqa: BLE001 - serving must not crash
                self.stats["exe_errors"] += 1
                self.stats["decode_steps"] += steps_run
                self.stats["decode_slot_steps"] += steps_run * bb
                return self._fault_requeue(reqs, "exe_error", repr(e))
            toks.append(tok)
            steps_run += 1
            if has_stops:  # per-step host read only when EOS is in play
                tok_np = np.asarray(tok)
                for i, r in enumerate(reqs):
                    if not done[i]:
                        emitted[i] += 1
                        done[i] = (
                            emitted[i] >= r.max_new_tokens
                            or int(tok_np[i]) in stop_sets[i]
                        )

        seq = np.stack([np.asarray(t) for t in toks], axis=1)  # (bb, steps+1)
        out: Dict[int, np.ndarray] = {}
        for i, r in enumerate(reqs):
            row = seq[i, : min(r.max_new_tokens, seq.shape[1])]
            if stop_sets[i]:
                hits = np.flatnonzero(np.isin(row, list(stop_sets[i])))
                if hits.size:  # the stop id is the last emitted token
                    row = row[: hits[0] + 1]
            out[r.uid] = row.copy()
            self.stats["tokens_generated"] += int(row.size)
            self._bump_tier("tier_tokens", tier, int(row.size))
        self.stats["decode_steps"] += steps_run
        self.stats["decode_slot_steps"] += steps_run * bb
        self._bump_tier("tier_decode_steps", tier, steps_run)
        return out

    # -- continuous execution: persistent per-tier decode slot pools ---------

    def _pool(self, tier) -> DecodePool:
        pool = self._pools.get(tier)
        if pool is None:
            pool = DecodePool(
                tier=tier,
                slots=self.pool_slots,
                cache_len=self.pool_cache_len,
                key_shape=self._base_key.shape,
                key_dtype=self._base_key.dtype,
                cache=lm.init_cache(
                    self.model_cfg, self.pool_slots, self.pool_cache_len
                ),
                exec_tier=self.tiers.get(tier),
            )
            # mesh serving: the pool cache lives replicated on every shard
            # from birth, so the first donated decode/insert call already
            # matches its executable's pinned input sharding
            pool.place_cache(self._replicate)
            self._pools[tier] = pool
        return pool

    @property
    def n_in_flight(self) -> int:
        """Requests submitted but not yet finished: queued + pooled."""
        return self.scheduler.n_pending + sum(
            p.n_active for p in self._pools.values()
        )

    def pump_step(
        self, now: Optional[float] = None, *, force: bool = False
    ) -> Dict[int, RequestResult]:
        """One continuous-scheduling iteration (the unit real serving loops
        and latency measurements want): admit deadline-ready requests into
        free slots (all pending requests when ``force``), then run ONE
        masked decode step across every pool with active slots. Returns the
        requests finished this iteration."""
        if not self.continuous:
            raise ValueError("pump_step() requires continuous=True")
        now = self._now(now, "poll")
        results, _ = self._pump_once(now, force)
        return results

    def _pump(self, now: Optional[float], force: bool) -> Dict[int, RequestResult]:
        results: Dict[int, RequestResult] = {}
        while True:
            step_results, progressed = self._pump_once(now, force)
            results.update(step_results)
            if not progressed:
                return results

    def _pump_once(self, now, force):
        """(finished requests, progressed) for one admit-then-decode round.

        Admission runs before decode (prefill-first: freed slots refill as
        eagerly as the scheduler's readiness rule allows — ``max_wait`` is
        the prefill/decode interleave knob), then every pool with active
        slots takes exactly one masked decode step. ``progressed`` is False
        only when nothing was admitted and no slot decoded: the caller's
        drain loop is done. Deadline expiry runs first on clocked calls
        (``now=None`` flush drains everything and times out nothing).
        """
        results: Dict[int, RequestResult] = {}
        progressed = False
        results.update(self._expire_queued(now))
        results.update(self._expire_pooled(now))
        if results:
            progressed = True
        if self.governor is not None and not force:
            # one policy step per pump round: demotions land *before*
            # admission, so retiered requests prefill into their new tier's
            # pool this very round (flush keeps requests as-submitted)
            self.governor.step(now)
        free = {}
        for tier in self.scheduler.pending_tiers():
            pool = self._pools.get(tier)
            free[tier] = pool.n_free if pool is not None else self.pool_slots
        for reqs in self.scheduler.pop_admissible(now, free, force=force):
            results.update(self._admit(reqs))
            progressed = True
        for pool in self._pools.values():
            if pool.n_active:
                results.update(self._pool_step(pool))
                progressed = True
        if self.metrics is not None:
            # one observability sample per pump round: the feed's time base
            self.metrics.record(self, now=now)
        return results, progressed

    def _admit(self, reqs: List[Request]) -> Dict[int, RequestResult]:
        """Prefill a ready group at the pool's cache length and scatter it
        into free slots. Requests that finish at their first token (1-token
        budget, or the first token is a stop id) complete here and never
        occupy a decode slot. A transient executable fault at either
        dispatch requeues the whole admission wave (taken slots released;
        the pre-dispatch guard left the pool cache intact)."""
        pool = self._pool(reqs[0].tier)
        assert len(reqs) <= pool.n_free, "scheduler admitted beyond free slots"
        try:
            (bb, _sb, _cl), _keys, src_cache, tok0 = self._prefill_batch(
                reqs, pool.cache_len
            )
        except TransientExecutableFault as f:
            self.stats["exe_faults"] += 1
            return self._fault_requeue(reqs, "exe_fault", str(f))
        except Exception as e:  # noqa: BLE001 - serving must not crash
            # exception safety at admission: no slot was taken yet, so an
            # executable raising anything mid-pump leaks nothing — the
            # wave retires into the bounded-retry path exactly once
            self.stats["exe_errors"] += 1
            return self._fault_requeue(reqs, "exe_error", repr(e))
        tok0 = np.asarray(tok0)  # admission bookkeeping needs host values
        slots = pool.take(len(reqs))
        # prefill batch-padding rows aim past the pool: dropped by the scatter
        slot_ids = np.full((bb,), pool.slots, np.int32)
        slot_ids[: len(reqs)] = slots
        # tier-free key: the cache layout is parameter- and noise-free, so
        # one insert executable is shared across every tier's pool shape
        insert_exe = self.exe_cache.get(
            self.tiers.exe_key("insert", None, pool.slots, pool.cache_len, bb),
            lambda: pool.exec_tier.build_insert(pool.slots, pool.cache_len, bb),
        )
        try:
            pool.cache = insert_exe(pool.cache, src_cache, jnp.asarray(slot_ids))
        except TransientExecutableFault as f:
            for s in slots:
                pool.release(s)
            self.stats["exe_faults"] += 1
            return self._fault_requeue(reqs, "exe_fault", str(f))
        except Exception as e:  # noqa: BLE001 - serving must not crash
            # taken slots are released before the requeue: a raising
            # insert neither leaks nor aliases pool slots
            for s in slots:
                pool.release(s)
            self.stats["exe_errors"] += 1
            return self._fault_requeue(reqs, "exe_error", repr(e))
        self.stats["admitted"] += len(reqs)
        out: Dict[int, np.ndarray] = {}
        for i, (r, s) in enumerate(zip(reqs, slots)):
            t0 = int(tok0[i])
            if r.max_new_tokens == 1 or t0 in r.stop_set:
                pool.release(s)
                out[r.uid] = np.asarray([t0], np.int32)
                self.stats["tokens_generated"] += 1
                self._bump_tier("tier_tokens", r.tier, 1)
                self.stats["retired"] += 1
            else:
                pool.activate(s, r, t0, r.key)
        return out

    def _pool_step(self, pool: DecodePool) -> Dict[int, RequestResult]:
        """One masked decode step over a whole pool: inactive slots are
        length-0 rows (inert), active rows decode at their own position
        under their own key, and rows that hit their budget or emit a stop
        id retire immediately — the freed slots are admission targets on the
        very next pump iteration.

        Fault sites live here too (injected by the engine's FaultPlan): a
        *stalled* step skips the dispatch (the latency cost of a wedged
        batch, charged to the fault clock so schedules can't stall a drain
        forever), a *transient executable fault* retires every active row
        into the bounded-retry path (pre-dispatch: the donated cache
        survives), and a *poisoned row* — any emitted token outside the
        vocab — retires just that row the step it appears (per-request
        noise keys keep batch-mates bit-identical through all of it).
        """
        plan = self.fault_plan
        clock = self._fault_clock
        self._fault_clock += 1
        if plan is not None and plan.stalled(clock):
            self.stats["stalled_steps"] += 1
            self.fault_log.append(
                {"kind": "stall", "clock": clock, "tier": pool.tier,
                 "uids": [pool.record(s).request.uid
                          for s in pool.active_slots()]}
            )
            return {}
        # the pool carries its ExecutionTier object (the registry is
        # add-only, so the reference can't drift from it)
        t = pool.exec_tier
        decode_exe = self.exe_cache.get(
            self.tiers.exe_key("decode", pool.tier, pool.slots, pool.cache_len),
            lambda: t.build_decode(pool.slots, pool.cache_len),
        )
        self._sync_noise_scale()
        try:
            tok, pool.cache = decode_exe(
                t.params,
                pool.cache,
                jnp.asarray(pool.tok[:, None]),
                jnp.asarray(pool.pos),
                jnp.asarray(pool.lengths),
                jnp.asarray(pool.keys),
                self._scale_arr(),
            )
        except TransientExecutableFault as f:
            self.stats["exe_faults"] += 1
            out: Dict[int, RequestResult] = {}
            reqs = []
            for s in pool.active_slots():
                rec = pool.retire(s)
                self.stats["retired"] += 1
                reqs.append(rec.request)
            out.update(self._fault_requeue(reqs, "exe_fault", str(f)))
            return out
        except Exception as e:  # noqa: BLE001 - serving must not crash
            # same containment for an executable raising anything else:
            # every active row retires (slots freed, never aliased) and
            # re-enters through the bounded-retry path exactly once
            self.stats["exe_errors"] += 1
            out: Dict[int, RequestResult] = {}
            reqs = []
            for s in pool.active_slots():
                rec = pool.retire(s)
                self.stats["retired"] += 1
                reqs.append(rec.request)
            out.update(self._fault_requeue(reqs, "exe_error", repr(e)))
            return out
        tok_np = np.asarray(tok)
        if plan is not None and plan.poison_map:
            tok_np = tok_np.copy()  # device views are read-only
            plan.poison_rows(clock, tok_np)  # detected below by value
        self.stats["decode_steps"] += 1
        self.stats["decode_slot_steps"] += pool.slots
        self.stats["active_slot_steps"] += pool.n_active
        self._bump_tier("tier_decode_steps", pool.tier, 1)
        out: Dict[int, RequestResult] = {}
        poisoned_reqs: List[Request] = []
        vocab = self.model_cfg.vocab_size
        for s in pool.active_slots():
            t = int(tok_np[s])
            if not 0 <= t < vocab:
                # corrupted readout: retire the row alone; its batch-mates'
                # noise streams never depended on it
                rec = pool.retire(s)
                self.stats["poisoned_rows"] += 1
                self.stats["retired"] += 1
                poisoned_reqs.append(rec.request)
                continue
            rec = pool.record(s)
            rec.emitted.append(t)
            pool.tok[s] = t
            pool.pos[s] += 1
            if rec.done:
                pool.retire(s)
                out[rec.request.uid] = np.asarray(rec.emitted, np.int32)
                self.stats["tokens_generated"] += len(rec.emitted)
                self._bump_tier("tier_tokens", pool.tier, len(rec.emitted))
                self.stats["retired"] += 1
        for r in poisoned_reqs:
            out.update(self._fault_requeue([r], "poison", "out-of-vocab token"))
        return out

    # -- introspection -------------------------------------------------------

    @property
    def energies(self):
        """The frozen energy allocation (baked into compiled executables)."""
        return self._energies

    def effective_energies(self):
        """The energy tree the hardware is *actually* delivering right now:
        registered energies divided by the realized drift factor squared
        (std ~ 1/sqrt(E)). At the nominal scale 1.0 this is the registered
        tree bit-for-bit."""
        if self._energies is None:
            raise ValueError("digital engine: no energy tree")
        s = self._noise_scale
        if s == 1.0:
            return self._energies
        return jax.tree.map(lambda e: e / (s * s), self._energies)

    def probe_apply(self):
        """``(energies, tokens, key) -> final hidden states`` over the live
        model — the calibrate-machinery apply fn the drift watchdog probes
        through. Cached on the engine (one object) so the probe's jitted
        executable compiles once; energies are runtime arguments, so
        probing at drifted energies never retraces."""
        if self.analog_cfg is None:
            raise ValueError("digital engine: nothing to probe for drift")
        fn = getattr(self, "_probe_apply_fn", None)
        if fn is None:
            params, cfg, a_cfg = self.params, self.model_cfg, self.analog_cfg

            def fn(energies, tokens, key):
                spec = lm.AnalogSpec(cfg=a_cfg, energies=energies, key=key)
                h, _ = lm.forward_hidden(
                    params, {"tokens": tokens}, cfg, mode="train", analog=spec
                )
                return h

            self._probe_apply_fn = fn
        return fn

    def probe_reference(self, tokens) -> Array:
        """Clean (digital) hidden states for a probe batch — the zero-noise
        reference the watchdog measures residual RMS against."""
        h, _ = lm.forward_hidden(
            self.params, {"tokens": jnp.asarray(tokens, jnp.int32)},
            self.model_cfg, mode="train", analog=None,
        )
        return h

    @property
    def profiles(self) -> Dict[str, PrecisionProfile]:
        """The registered per-layer precision tiers (read-only copy)."""
        return self.tiers.profiles

    @property
    def pools(self) -> Dict[object, DecodePool]:
        """The live per-tier decode pools (continuous mode; read-only copy)."""
        return dict(self._pools)

    def tier_energy_per_token(self, tier) -> float:
        """Honest energy per generated token of a tier (aJ), from the
        tier's OWN cost model: analog tiers report the true ``sum_l K_l *
        E_l * MACs_l`` over the frozen per-site energies (uniform K is the
        degenerate profile — same formula, every K_l = K), digital tiers
        report ``aj_per_mac * MACs/token`` from their per-MAC digital cost
        constant — never the analog energy tree.

        ``tier``: any registered tier id (uniform K int, profile name,
        custom tier id) or an ad-hoc ``PrecisionProfile``.
        """
        if isinstance(tier, PrecisionProfile):
            if self._energies is None:
                raise ValueError("digital engine: no energy tree to account")
            return lm.profile_token_energy(self.model_cfg, self._energies, tier)
        return float(self.tiers.get(tier).energy_per_token())

    @property
    def trace_count(self) -> int:
        """Number of jax traces performed (== executable-cache misses)."""
        return self._traces

    def cache_stats(self) -> dict:
        return self.exe_cache.stats()
