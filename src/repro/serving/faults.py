"""Deterministic fault injection for the serving engine.

Real analog accelerators fail in ways digital stacks never see: the noise
floor *drifts* as the device ages or heats (arxiv 2309.10759 calls drift
the dominant deployed failure mode), batches stall on a wedged dispatch,
and transient component faults corrupt a row or kill a kernel launch. The
engine owns every one of those sites — the noise spec it builds, the pool
step it dispatches, the executable cache it calls through — so faults are
injected *at the engine's seams*, never inside model code.

A :class:`FaultPlan` is the injection schedule. It is deterministic and
seedable: explicit schedules name exact injection points (the engine's
fault clock for drift/stalls/poison, a per-phase call counter for
executable faults), and the optional probabilistic knobs draw from a
seeded ``numpy`` generator so the same plan replayed against the same
traffic injects the same faults. Plans are *stateful* (call counters, the
injection log) — use a fresh plan per engine run when comparing a faulted
run against a baseline.

Sites:

``drift``
    A :class:`DriftRamp` mapping the engine's fault clock to a noise-scale
    factor ``d``: every analog site's noise std is multiplied by ``d``.
    Because all three noise models have std proportional to ``1/sqrt(E)``
    (core/noise.py Eqs. 9-11), the engine realizes the drift exactly by
    serving at effective energies ``E / d**2`` — threaded into compiled
    executables as a runtime scalar operand, so drift never retraces.

``exe_faults``
    ``(phase, n)`` pairs: the ``n``-th call (0-based, counted per phase
    over the engine's lifetime) of a cached executable for ``phase``
    (``"prefill"`` / ``"decode"`` / ``"insert"``) raises
    :class:`TransientExecutableFault` *before* dispatch — donated buffers
    are never consumed, so the engine can retry cleanly.

``stall_steps``
    Fault-clock steps at which a pool decode step is stuck: the engine
    skips the dispatch (the latency is a lost step — virtual-clock
    friendly), optionally also sleeping ``stall_sleep_s`` on a real clock.

``poison``
    ``(clock, slot) -> token`` overrides applied to the decode step's
    emitted tokens — an out-of-vocab id models a corrupted readout row.
    Poison is per-row: batch-mates are untouched.

Every injection is appended to ``plan.log`` so tests and the bench can
assert exactly what fired and derive the affected-request set from the
engine's own ``fault_log``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np


class TransientExecutableFault(RuntimeError):
    """A compiled executable transiently failed (pre-dispatch).

    Carries the cache-key phase and the per-phase call index so handlers
    and logs can name the exact injection point.
    """

    def __init__(self, phase: str, call_index: int, key=None):
        super().__init__(
            f"injected transient fault: {phase} call #{call_index}"
            + (f" (key={key!r})" if key is not None else "")
        )
        self.phase = phase
        self.call_index = call_index
        self.key = key


class QueueFull(RuntimeError):
    """Backpressure: the scheduler queue is at its high-water mark.

    Raised by ``submit`` instead of growing the queue without bound —
    callers shed load or retry later; nothing is silently dropped.
    (The precision governor raises it too, as its last rung: load is shed
    only once every queued request is already at its accuracy floor.)
    """


class BoundedLog(list):
    """An event log with list semantics and a ring-buffer bound.

    ``append`` keeps at most ``maxlen`` entries, evicting the oldest and
    counting evictions in ``dropped`` (optionally reporting each eviction
    batch through ``on_drop``) — long fault storms and policy episodes
    can't grow host memory without bound. It IS a ``list`` (equality,
    slicing, iteration all behave), so test assertions like
    ``engine.fault_log == []`` keep working; ``maxlen=None`` is an
    ordinary unbounded list with a drop counter pinned at zero.
    """

    def __init__(self, maxlen: Optional[int] = None, *, on_drop=None):
        super().__init__()
        if maxlen is not None and maxlen < 1:
            raise ValueError(f"maxlen must be >= 1, got {maxlen}")
        self.maxlen = maxlen
        self.on_drop = on_drop
        self.dropped = 0

    def append(self, item) -> None:
        if self.maxlen is not None and len(self) >= self.maxlen:
            n = len(self) - self.maxlen + 1
            del self[:n]
            self.dropped += n
            if self.on_drop is not None:
                self.on_drop(n)
        super().append(item)


@dataclasses.dataclass(frozen=True)
class DriftRamp:
    """Noise-scale drift schedule over the engine's fault clock.

    Scale is 1.0 before ``start``, then grows multiplicatively by
    ``rate`` per step, capped at ``max_scale``. ``rate=None`` is a step
    function: the scale jumps straight to ``max_scale`` at ``start``
    (the sharpest drift a watchdog can be asked to catch).
    """

    start: int
    rate: Optional[float] = 0.25
    max_scale: float = 2.0

    def scale_at(self, clock: int) -> float:
        if clock < self.start:
            return 1.0
        if self.rate is None:
            return float(self.max_scale)
        return float(min(self.max_scale, (1.0 + self.rate) ** (clock - self.start)))


class FaultPlan:
    """A deterministic, seedable injection schedule (see module docstring).

    Parameters
    ----------
    seed:
        Seeds the generator behind ``exe_fault_rate`` (the only stochastic
        knob); explicit schedules ignore it.
    drift:
        Optional :class:`DriftRamp`. ``noise_scale_at(clock)`` is 1.0
        without one.
    exe_faults:
        Iterable of ``(phase, nth_call)`` pairs — fail that phase's n-th
        executable invocation (0-based, counted across the engine's life).
    exe_fault_rate:
        Probability of failing any executable call, drawn from the seeded
        generator (deterministic given seed and call order). Composes with
        the explicit schedule.
    stall_steps:
        Fault-clock steps whose pool decode dispatch is stuck.
    stall_sleep_s:
        Optional real-time sleep per stalled step (wall-clock runs only;
        virtual-clock tests leave it 0).
    poison:
        Mapping ``(clock, slot) -> token`` (or an iterable of
        ``(clock, slot)`` pairs, poisoned with ``poison_token``) applied
        to the decode step's emitted tokens.
    poison_token:
        Token injected for iterable-form ``poison`` entries; out-of-vocab
        by default so the engine's row validation trips.
    """

    def __init__(
        self,
        *,
        seed: int = 0,
        drift: Optional[DriftRamp] = None,
        exe_faults: Iterable[Tuple[str, int]] = (),
        exe_fault_rate: float = 0.0,
        stall_steps: Iterable[int] = (),
        stall_sleep_s: float = 0.0,
        poison=(),
        poison_token: int = -1,
    ):
        if not 0.0 <= exe_fault_rate <= 1.0:
            raise ValueError(f"exe_fault_rate must be in [0, 1], got {exe_fault_rate}")
        self.seed = int(seed)
        self.drift = drift
        self.exe_faults = frozenset((str(p), int(n)) for p, n in exe_faults)
        self.exe_fault_rate = float(exe_fault_rate)
        self.stall_steps = frozenset(int(s) for s in stall_steps)
        self.stall_sleep_s = float(stall_sleep_s)
        if isinstance(poison, dict):
            self.poison_map: Dict[Tuple[int, int], int] = {
                (int(c), int(s)): int(t) for (c, s), t in poison.items()
            }
        else:
            self.poison_map = {
                (int(c), int(s)): int(poison_token) for c, s in poison
            }
        self._rng = np.random.default_rng(self.seed)
        self._calls: Dict[str, int] = {}
        #: every injection that actually fired, in order: dicts with a
        #: ``site`` field (drift is continuous, not logged per step)
        self.log: List[dict] = []

    # -- drift ---------------------------------------------------------------

    def noise_scale_at(self, clock: int) -> float:
        """Noise-std drift factor at a fault-clock step (1.0 = nominal)."""
        return 1.0 if self.drift is None else self.drift.scale_at(clock)

    # -- transient executable failures ---------------------------------------

    def check_executable(self, key) -> None:
        """Called by the ExecutableCache guard before every invocation;
        raises :class:`TransientExecutableFault` at scheduled calls."""
        phase = key[0] if isinstance(key, tuple) and key else str(key)
        n = self._calls.get(phase, 0)
        self._calls[phase] = n + 1
        hit = (phase, n) in self.exe_faults
        if not hit and self.exe_fault_rate > 0.0:
            hit = bool(self._rng.random() < self.exe_fault_rate)
        if hit:
            self.log.append({"site": "executable", "phase": phase, "call": n})
            raise TransientExecutableFault(phase, n, key)

    # -- stuck batches -------------------------------------------------------

    def stalled(self, clock: int) -> bool:
        """True when the pool decode step at ``clock`` is stuck; the engine
        skips the dispatch (and this method sleeps ``stall_sleep_s``)."""
        if clock not in self.stall_steps:
            return False
        self.log.append({"site": "stall", "clock": clock})
        if self.stall_sleep_s > 0.0:
            import time

            time.sleep(self.stall_sleep_s)
        return True

    # -- poisoned rows -------------------------------------------------------

    def poison_rows(self, clock: int, tok: np.ndarray) -> List[int]:
        """Apply scheduled token overrides for ``clock`` in place; returns
        the poisoned slot indices (empty for an unscheduled step)."""
        slots = []
        for (c, s), t in self.poison_map.items():
            if c == clock and 0 <= s < tok.shape[0]:
                tok[s] = t
                slots.append(s)
                self.log.append({"site": "poison", "clock": c, "slot": s, "token": t})
        return slots


# ===========================================================================
# replica-level faults (cluster injection schedule, serving/cluster.py)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class ReplicaFault:
    """One scheduled fault against a whole engine replica.

    ``replica`` is the ClusterRouter-assigned replica id; ``at`` is the
    round of the cluster's shared fault clock (one tick per
    ``ClusterRouter.pump_step``) at which the fault engages. Replica
    faults are declarative and deterministic like :class:`FaultPlan`
    schedules: the same fault list replayed against the same traffic
    produces the same failover episode event-for-event.
    """

    replica: int
    at: int

    def __post_init__(self):
        if self.replica < 0:
            raise ValueError(f"replica id must be >= 0, got {self.replica}")
        if self.at < 0:
            raise ValueError(f"fault round must be >= 0, got {self.at}")


@dataclasses.dataclass(frozen=True)
class ReplicaCrash(ReplicaFault):
    """Process death: from round ``at`` the replica never pumps again.

    Its queued and pooled requests are lost with it; new dispatches to it
    fail fast (the submit RPC has nobody listening). The router's health
    detector still has to *discover* the death through the stalled
    heartbeat — failover fires only when the detector declares the
    replica dead, never off this injection record."""


@dataclasses.dataclass(frozen=True)
class ReplicaHang(ReplicaFault):
    """A wedged pump loop: for ``steps`` rounds starting at ``at`` the
    replica's ``pump_step`` makes no progress, so its ``MetricsFeed``
    heartbeat stops advancing. A hang shorter than the detector's dead
    threshold must ride out as ``suspect`` and recover — the hysteresis
    the flap tests pin down."""

    steps: int = 4

    def __post_init__(self):
        super().__post_init__()
        if self.steps < 1:
            raise ValueError(f"hang steps must be >= 1, got {self.steps}")


@dataclasses.dataclass(frozen=True)
class ReplicaDegraded(ReplicaFault):
    """Sustained noise drift on one replica's analog array.

    From round ``at`` the replica serves at noise-scale ``scale`` (std
    multiplier; a runtime operand, never a retrace) and its feed carries
    the drift estimate a production watchdog would report. The router's
    detector quarantines the replica once the excursion outlasts its
    drift patience: queued work re-dispatches to nominal replicas, new
    traffic routes around it, and the cluster governor rebalances the
    power budget."""

    scale: float = 1.8

    def __post_init__(self):
        super().__post_init__()
        if self.scale <= 0.0 or self.scale == 1.0:
            raise ValueError(
                f"degraded scale must be > 0 and != 1.0 (nominal), "
                f"got {self.scale}"
            )
