"""Noise-drift watchdog: detect when the hardware leaves calibration.

The engine's energy allocation was calibrated against a *nominal* noise
floor; deployed analog hardware drifts off it (temperature, aging — arxiv
2309.10759). Drift is invisible to a digital health check: the kernels
still run, the tokens are still tokens, only the noise statistics moved.
The watchdog makes drift observable with the same machinery that
calibrated the model in the first place (core/calibrate.py): periodically
run a small *fixed* probe batch through the live analog config and compare
the residual RMS against a clean digital reference.

Because every noise model's std is proportional to ``1/sqrt(E)``
(core/noise.py Eqs. 9-11), the probe's residual RMS moves linearly (to
first order) with a global noise-scale drift factor — so

    estimate = rms(live energies) / rms(registered energies at attach)

is a direct estimate of the realized drift factor. The RMS averages over
``n_samples`` draws x every probe-batch element x the hidden dimension, so
the estimator is tight enough for a narrow band (a few percent) without
burning real probe energy.

A probe outside ``band`` raises a :class:`DriftEvent` (returned, not
thrown). The intended response loop is the engine's graceful-degradation
pair: ``engine.promote_tiers(event)`` serves new uniform-K traffic one
rung up the K ladder (repeats buy the drifted noise floor back at higher
energy), and ``engine.recalibrate()`` + ``watchdog.clear()`` return to
nominal once the hardware is re-trimmed.

Probing costs one forward per interval and hits a single cached jitted
executable (energies are runtime arguments) — it never retraces the
serving path and never touches the request stream.

The third surface here is the streaming observability feed
(:class:`MetricsFeed`): a bounded ring of per-pump-step samples — per-tier
token/decode counters, pool occupancy, queue depth, energy/token, drift
state, policy mode — with an optional JSONL sink. The engine samples it
once per pump/poll round (``ServingEngine(metrics=...)``); the serving
bench and ``examples/analog_serving.py --dashboard`` consume it. Tier
attribution rides the ``TierRegistry`` (serving/tiers.py): every tier in
the feed reports its own honest energy model and its ``drift_exempt``
flag, so a drift episode is attributable per tier — digital tiers ride
through it unpromoted and unconcerned.
"""
from __future__ import annotations

import dataclasses
import json
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.calibrate import noise_rms

__all__ = [
    "DriftEvent",
    "WatchdogConfig",
    "NoiseDriftWatchdog",
    "LoadSignals",
    "load_signals",
    "MetricsFeed",
]


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Probe cadence and detection band.

    ``interval``: probe every N watchdog steps (the caller decides what a
    step is — one ``pump_step``/``poll`` is the natural unit).
    ``n_samples``: noise draws averaged per probe (more = tighter
    estimate, linearly more probe compute).
    ``band``: (lo, hi) on the realized-scale estimate; outside -> event.
    The estimate is first-order in the true drift factor (noise propagates
    nonlinearly, compressing large factors toward 1), and small probe
    batches scatter a few percent — size the band to the probe, not to the
    drift you hope to see: the default comfortably detects a 1.5-2x drift
    while staying quiet at nominal even for tiny probe batches.
    """

    interval: int = 8
    n_samples: int = 4
    band: Tuple[float, float] = (0.7, 1.4)

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if not (0.0 < self.band[0] < 1.0 < self.band[1]):
            raise ValueError(
                f"band must straddle the nominal scale 1.0, got {self.band}"
            )


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One out-of-band probe: the realized noise scale left calibration.

    ``clock`` is the engine's fault-clock step at the probe and
    ``residual_rms`` the triggering measurement (the probe's raw residual
    RMS, before dividing by the baseline) — the event lines up against
    stalls/timeouts/policy actions in the same ``fault_log``.
    """

    step: int  # watchdog step at which the probe fired
    probe_idx: int  # how many probes had run (0-based)
    estimate: float  # realized noise-scale estimate
    band: Tuple[float, float]
    clock: int = 0  # engine fault clock at the probe (attribution)
    residual_rms: float = 0.0  # the triggering measurement (raw probe RMS)


class NoiseDriftWatchdog:
    """Periodic realized-noise-scale estimation over a live engine.

    Attach once (computes the clean reference and the nominal-RMS
    baseline, compiling the single probe executable), then call
    :meth:`maybe_probe` from the serving loop. An active event is held
    until :meth:`clear` (the recalibration hook) — repeated out-of-band
    probes do not raise duplicate events, and ``estimates`` keeps the full
    probe trajectory for dashboards and the bench artifact.
    """

    def __init__(
        self,
        engine,
        tokens,
        *,
        config: WatchdogConfig = WatchdogConfig(),
        key: Optional[jax.Array] = None,
    ):
        if engine.analog_cfg is None:
            raise ValueError("digital engine: no analog noise to watch")
        self.engine = engine
        self.config = config
        self.tokens = np.asarray(tokens, np.int32)
        if self.tokens.ndim != 2:
            raise ValueError(
                f"probe tokens must be (batch, seq), got {self.tokens.shape}"
            )
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self._apply = engine.probe_apply()
        self._ref = engine.probe_reference(self.tokens)
        # nominal baseline at the *registered* energies: what a healthy
        # device's probe RMS looks like. Different key fold than the live
        # probes so baseline noise never cancels against a probe's.
        self._baseline = noise_rms(
            self._apply, engine.energies, self.tokens, self._ref,
            key=jax.random.fold_in(self.key, 0xB43E),
            n_noise_samples=config.n_samples,
        )
        self._last_probe_step: Optional[int] = None
        self._n_probes = 0
        #: (step, realized-scale estimate) per probe, in order
        self.estimates: List[Tuple[int, float]] = []
        #: every event ever raised (active is the last un-cleared one)
        self.events: List[DriftEvent] = []
        self.active: Optional[DriftEvent] = None

    @property
    def baseline_rms(self) -> float:
        return self._baseline

    def probe(self, step: int = 0) -> Optional[DriftEvent]:
        """Run one probe now: estimate the realized noise scale through the
        engine's *effective* energies, record it, and return a new
        :class:`DriftEvent` when the estimate leaves the band (and no
        event is already active)."""
        rms = noise_rms(
            self._apply, self.engine.effective_energies(), self.tokens,
            self._ref, key=jax.random.fold_in(self.key, self._n_probes),
            n_noise_samples=self.config.n_samples,
        )
        estimate = rms / self._baseline
        self.estimates.append((step, float(estimate)))
        self._n_probes += 1
        self._last_probe_step = step
        lo, hi = self.config.band
        if (estimate < lo or estimate > hi) and self.active is None:
            event = DriftEvent(
                step=step, probe_idx=self._n_probes - 1,
                estimate=float(estimate), band=(lo, hi),
                clock=int(getattr(self.engine, "_fault_clock", 0)),
                residual_rms=float(rms),
            )
            self.events.append(event)
            self.active = event
            return event
        return None

    def maybe_probe(self, step: int) -> Optional[DriftEvent]:
        """Probe when ``step`` has advanced ``config.interval`` past the
        last probe (first call always probes)."""
        if (
            self._last_probe_step is not None
            and step - self._last_probe_step < self.config.interval
        ):
            return None
        return self.probe(step)

    def clear(self) -> None:
        """Recalibration hook: drop the active event (probing continues)."""
        self.active = None


# ===========================================================================
# load / headroom signals (the precision governor's observation surface)
# ===========================================================================


@dataclasses.dataclass(frozen=True)
class LoadSignals:
    """One observation of the engine's load and deadline headroom.

    The drift watchdog above watches the *noise* leave calibration; these
    signals watch the *load* leave capacity — together they are the
    monitoring surface the serving policy reacts to. All host-side reads,
    no dispatch: observing load never costs analog energy.

    ``queue_pressure`` is queue depth in units of one pool's slot capacity
    (batch-synchronous engines: the max batch) — 1.0 means a full pool's
    worth of work is waiting. ``urgent_frac`` is the fraction of queued
    SLO-carrying requests that have already burned over half their
    ``target_latency`` waiting — the p99-vs-deadline headroom signal: it
    climbs before deadlines start striking. ``min_slack`` is the tightest
    ``deadline - now`` over queued + pooled requests (``None`` without a
    clock or deadlines).

    ``drift`` is the latest realized-noise-scale estimate flowing through
    the engine's :class:`MetricsFeed` (``note_drift``), ``None`` when no
    feed is attached or no probe has run — it puts the *noise* axis on the
    same observation record as the load axes, so the precision governor
    can treat a hardware-health excursion as demote pressure with the
    identical registry-resolved retier path it uses for queue pressure.
    """

    clock: int  # engine fault clock at the observation
    queue_depth: int
    active: int  # occupied decode slots across live pools
    slots: int  # total decode slots across live pools (or max_batch)
    occupancy: float  # active / slots
    queue_pressure: float  # queue_depth / per-tier slot capacity
    min_slack: Optional[float]  # tightest deadline - now, None if unknowable
    urgent_frac: float  # queued SLO requests past half their latency budget
    drift: Optional[float] = None  # latest watchdog noise-scale estimate


def load_signals(engine, now: Optional[float] = None) -> LoadSignals:
    """Read the engine's current load/headroom signals (host-only)."""
    sched = engine.scheduler
    queued = sched.queued_requests()
    pooled = []
    for pool in engine.pools.values():
        for s in pool.active_slots():
            pooled.append(pool.record(s).request)
    unit = engine.pool_slots if engine.continuous else sched.max_batch
    slots = unit * max(1, len(engine.pools)) if engine.continuous else unit
    min_slack = None
    urgent = with_slo = 0
    if now is not None:
        slacks = [
            r.deadline - now for r in queued + pooled if r.deadline is not None
        ]
        if slacks:
            min_slack = float(min(slacks))
        for r in queued:
            if r.target_latency is not None:
                with_slo += 1
                if now - r.arrival >= 0.5 * r.target_latency:
                    urgent += 1
    feed = getattr(engine, "metrics", None)
    return LoadSignals(
        clock=int(getattr(engine, "_fault_clock", 0)),
        queue_depth=len(queued),
        active=len(pooled),
        slots=int(slots),
        occupancy=len(pooled) / max(1, slots),
        queue_pressure=len(queued) / max(1, unit),
        min_slack=min_slack,
        urgent_frac=urgent / with_slo if with_slo else 0.0,
        drift=None if feed is None else feed.drift_estimate,
    )


# ===========================================================================
# streaming observability: the per-tier metrics feed
# ===========================================================================


class MetricsFeed:
    """Bounded ring of per-pump-step serving samples with a JSONL sink.

    The engine calls :meth:`record` once per pump/poll round
    (``ServingEngine(metrics=MetricsFeed(...))``). Each sample is a plain
    JSON-ready dict: engine-level load (queue depth, in-flight, pool
    occupancy), drift state (noise scale, watchdog estimate, active
    promotion), policy mode, the retrace audit counter, and a ``tiers``
    block — one entry per tier that has served or pooled work, carrying
    cumulative tokens/decode-steps, the delta since the previous sample
    (divide by ``dt`` for tokens/s), pool occupancy, the tier's own honest
    energy/token, and its ``drift_exempt`` flag. Tier keys are
    stringified so samples round-trip through JSON unchanged.

    ``capacity`` bounds the in-memory ring (oldest samples drop);
    ``jsonl_path`` streams every sample as one JSON line (append mode,
    flushed per sample) for dashboards and the bench artifact. The feed
    never dispatches device work: sampling is host-side reads only.

    ``replica_id`` names the engine replica this feed observes (set by
    the :class:`~repro.serving.cluster.ClusterRouter` when left unset;
    ``None`` for a standalone engine). Every sample also carries a
    monotone ``heartbeat_step`` — it advances exactly once per recorded
    sample, i.e. once per pump/poll round, so a reader that sees it stop
    is watching a crashed or wedged replica. Both are *additions*: every
    pre-existing sample field is unchanged, so old JSONL consumers keep
    working (pinned by a schema regression test).
    """

    def __init__(self, capacity: int = 1024, jsonl_path=None, *,
                 replica_id: Optional[int] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.jsonl_path = None if jsonl_path is None else str(jsonl_path)
        self.replica_id = replica_id
        self._ring = deque(maxlen=self.capacity)
        self._fh = None
        self._step = 0
        self._heartbeat = 0
        self._drift_estimate: Optional[float] = None
        self._last_now: Optional[float] = None
        self._last_tokens: Dict[str, int] = {}

    @property
    def heartbeat_step(self) -> int:
        """Monotone liveness counter: the number of samples recorded so
        far. A replica whose heartbeat stops advancing between cluster
        rounds is stalled (crashed, hung, or partitioned) — the health
        detector's primary signal."""
        return self._heartbeat

    # -- drift attribution ---------------------------------------------------

    def note_drift(self, estimate: Optional[float]) -> None:
        """Feed the watchdog's latest realized-noise-scale estimate into
        subsequent samples (None clears it after recalibration)."""
        self._drift_estimate = None if estimate is None else float(estimate)

    @property
    def drift_estimate(self) -> Optional[float]:
        """The latest noted estimate (``load_signals``'s drift source)."""
        return self._drift_estimate

    # -- sampling ------------------------------------------------------------

    def record(self, engine, now: Optional[float] = None) -> dict:
        """Take one sample of the engine (host-side only) and append it to
        the ring (and the JSONL sink, when configured)."""
        sig = load_signals(engine, now)
        pools = engine.pools
        tier_ids = (
            set(engine.stats["tier_tokens"])
            | set(engine.stats["tier_decode_steps"])
            | set(pools)
        )
        tiers = {}
        for tid in tier_ids:
            key = str(tid)
            tokens = int(engine.stats["tier_tokens"].get(tid, 0))
            pool = pools.get(tid)
            try:
                tier_obj = engine.tiers.get(tid)
                energy = float(tier_obj.energy_per_token())
                exempt = bool(tier_obj.drift_exempt)
            except ValueError:
                energy, exempt = None, False  # unpriceable (pure digital)
            tiers[key] = {
                "tokens": tokens,
                "tokens_delta": tokens - self._last_tokens.get(key, 0),
                "decode_steps": int(
                    engine.stats["tier_decode_steps"].get(tid, 0)
                ),
                "pool_active": None if pool is None else pool.n_active,
                "pool_free": None if pool is None else pool.n_free,
                "energy_per_token_aj": energy,
                "drift_exempt": exempt,
            }
            self._last_tokens[key] = tokens
        governor = engine.governor
        self._heartbeat += 1
        sample = {
            "step": self._step,
            "clock": sig.clock,
            "now": None if now is None else float(now),
            "dt": (
                None if now is None or self._last_now is None
                else float(now - self._last_now)
            ),
            "queue_depth": sig.queue_depth,
            "in_flight": sig.queue_depth + sig.active,
            "pool_active": sig.active,
            "pool_slots": sig.slots,
            "occupancy": sig.occupancy,
            "queue_pressure": sig.queue_pressure,
            "urgent_frac": sig.urgent_frac,
            "policy_mode": None if governor is None else governor.mode,
            "noise_scale": float(engine.noise_scale),
            "drift_promoted": bool(engine.promoted),
            "drift_estimate": self._drift_estimate,
            "traces": int(engine.trace_count),
            "tokens_total": int(engine.stats["tokens_generated"]),
            "tiers": tiers,
            # replication fields (appended last: old JSONL consumers that
            # read the fields above see an unchanged schema)
            "replica_id": self.replica_id,
            "heartbeat_step": self._heartbeat,
        }
        self._step += 1
        if now is not None:
            self._last_now = float(now)
        self._ring.append(sample)
        if self.jsonl_path is not None:
            if self._fh is None:
                self._fh = open(self.jsonl_path, "a")
            self._fh.write(json.dumps(sample) + "\n")
            self._fh.flush()
        return sample

    # -- consumption ---------------------------------------------------------

    def samples(self) -> List[dict]:
        """The retained samples, oldest first (a copy)."""
        return list(self._ring)

    def tier_series(self, field: str) -> Dict[str, List]:
        """Per-tier time series of one tier field over the retained ring
        (e.g. ``tier_series("tokens")``) — the bench's artifact shape."""
        out: Dict[str, List] = {}
        for s in self._ring:
            for tid, rec in s["tiers"].items():
                out.setdefault(tid, []).append(rec.get(field))
        return out

    def __len__(self) -> int:
        return len(self._ring)

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
