"""Noise-drift watchdog: detect when the hardware leaves calibration.

The engine's energy allocation was calibrated against a *nominal* noise
floor; deployed analog hardware drifts off it (temperature, aging — arxiv
2309.10759). Drift is invisible to a digital health check: the kernels
still run, the tokens are still tokens, only the noise statistics moved.
The watchdog makes drift observable with the same machinery that
calibrated the model in the first place (core/calibrate.py): periodically
run a small *fixed* probe batch through the live analog config and compare
the residual RMS against a clean digital reference.

Because every noise model's std is proportional to ``1/sqrt(E)``
(core/noise.py Eqs. 9-11), the probe's residual RMS moves linearly (to
first order) with a global noise-scale drift factor — so

    estimate = rms(live energies) / rms(registered energies at attach)

is a direct estimate of the realized drift factor. The RMS averages over
``n_samples`` draws x every probe-batch element x the hidden dimension, so
the estimator is tight enough for a narrow band (a few percent) without
burning real probe energy.

A probe outside ``band`` raises a :class:`DriftEvent` (returned, not
thrown). The intended response loop is the engine's graceful-degradation
pair: ``engine.promote_tiers(event)`` serves new uniform-K traffic one
rung up the K ladder (repeats buy the drifted noise floor back at higher
energy), and ``engine.recalibrate()`` + ``watchdog.clear()`` return to
nominal once the hardware is re-trimmed.

Probing costs one forward per interval and hits a single cached jitted
executable (energies are runtime arguments) — it never retraces the
serving path and never touches the request stream.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import numpy as np

from repro.core.calibrate import noise_rms

__all__ = ["DriftEvent", "WatchdogConfig", "NoiseDriftWatchdog"]


@dataclasses.dataclass(frozen=True)
class WatchdogConfig:
    """Probe cadence and detection band.

    ``interval``: probe every N watchdog steps (the caller decides what a
    step is — one ``pump_step``/``poll`` is the natural unit).
    ``n_samples``: noise draws averaged per probe (more = tighter
    estimate, linearly more probe compute).
    ``band``: (lo, hi) on the realized-scale estimate; outside -> event.
    The estimate is first-order in the true drift factor (noise propagates
    nonlinearly, compressing large factors toward 1), and small probe
    batches scatter a few percent — size the band to the probe, not to the
    drift you hope to see: the default comfortably detects a 1.5-2x drift
    while staying quiet at nominal even for tiny probe batches.
    """

    interval: int = 8
    n_samples: int = 4
    band: Tuple[float, float] = (0.7, 1.4)

    def __post_init__(self):
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if not (0.0 < self.band[0] < 1.0 < self.band[1]):
            raise ValueError(
                f"band must straddle the nominal scale 1.0, got {self.band}"
            )


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One out-of-band probe: the realized noise scale left calibration."""

    step: int  # watchdog step at which the probe fired
    probe_idx: int  # how many probes had run (0-based)
    estimate: float  # realized noise-scale estimate
    band: Tuple[float, float]


class NoiseDriftWatchdog:
    """Periodic realized-noise-scale estimation over a live engine.

    Attach once (computes the clean reference and the nominal-RMS
    baseline, compiling the single probe executable), then call
    :meth:`maybe_probe` from the serving loop. An active event is held
    until :meth:`clear` (the recalibration hook) — repeated out-of-band
    probes do not raise duplicate events, and ``estimates`` keeps the full
    probe trajectory for dashboards and the bench artifact.
    """

    def __init__(
        self,
        engine,
        tokens,
        *,
        config: WatchdogConfig = WatchdogConfig(),
        key: Optional[jax.Array] = None,
    ):
        if engine.analog_cfg is None:
            raise ValueError("digital engine: no analog noise to watch")
        self.engine = engine
        self.config = config
        self.tokens = np.asarray(tokens, np.int32)
        if self.tokens.ndim != 2:
            raise ValueError(
                f"probe tokens must be (batch, seq), got {self.tokens.shape}"
            )
        self.key = key if key is not None else jax.random.PRNGKey(0)
        self._apply = engine.probe_apply()
        self._ref = engine.probe_reference(self.tokens)
        # nominal baseline at the *registered* energies: what a healthy
        # device's probe RMS looks like. Different key fold than the live
        # probes so baseline noise never cancels against a probe's.
        self._baseline = noise_rms(
            self._apply, engine.energies, self.tokens, self._ref,
            key=jax.random.fold_in(self.key, 0xB43E),
            n_noise_samples=config.n_samples,
        )
        self._last_probe_step: Optional[int] = None
        self._n_probes = 0
        #: (step, realized-scale estimate) per probe, in order
        self.estimates: List[Tuple[int, float]] = []
        #: every event ever raised (active is the last un-cleared one)
        self.events: List[DriftEvent] = []
        self.active: Optional[DriftEvent] = None

    @property
    def baseline_rms(self) -> float:
        return self._baseline

    def probe(self, step: int = 0) -> Optional[DriftEvent]:
        """Run one probe now: estimate the realized noise scale through the
        engine's *effective* energies, record it, and return a new
        :class:`DriftEvent` when the estimate leaves the band (and no
        event is already active)."""
        rms = noise_rms(
            self._apply, self.engine.effective_energies(), self.tokens,
            self._ref, key=jax.random.fold_in(self.key, self._n_probes),
            n_noise_samples=self.config.n_samples,
        )
        estimate = rms / self._baseline
        self.estimates.append((step, float(estimate)))
        self._n_probes += 1
        self._last_probe_step = step
        lo, hi = self.config.band
        if (estimate < lo or estimate > hi) and self.active is None:
            event = DriftEvent(
                step=step, probe_idx=self._n_probes - 1,
                estimate=float(estimate), band=(lo, hi),
            )
            self.events.append(event)
            self.active = event
            return event
        return None

    def maybe_probe(self, step: int) -> Optional[DriftEvent]:
        """Probe when ``step`` has advanced ``config.interval`` past the
        last probe (first call always probes)."""
        if (
            self._last_probe_step is not None
            and step - self._last_probe_step < self.config.interval
        ):
            return None
        return self.probe(step)

    def clear(self) -> None:
        """Recalibration hook: drop the active event (probing continues)."""
        self.active = None
