"""SLA-aware precision governor: proactive overload policy over the K dial.

The paper's central claim is that analog precision is a *runtime* dial —
repeat-and-average K trades accuracy against energy and throughput on the
fly. PR-6 built the reactive half of graceful degradation (deadlines ->
``TimedOut``, ``max_queue`` backpressure, drift-driven K promotion); this
module is the proactive half: a policy layer that *uses* the dial to keep
SLOs under load, the analog analogue of fault-tolerant degradation in
arXiv 2309.10759.

The :class:`PrecisionGovernor` closes the loop from observed load
(``serving/monitor.load_signals``: queue depth, pool occupancy,
deadline-headroom urgency) to the tier of every *queued* request:

``nominal -> demoted``
    Under pressure, each admissible queued request is **demoted** to the
    cheapest registered tier that still satisfies its ``accuracy_floor``
    (tier accuracy metadata comes from ``core/search.py`` evals, carried
    on :class:`~repro.core.profile.PrecisionProfile` or passed as
    :class:`TierSpec`). Cheaper tiers decode at lower energy/token — on
    time-redundant analog hardware that is directly more throughput, so
    demotion drains the queue instead of letting deadlines burn.

``demoted -> shedding``
    Load shedding is the LAST rung: only once every queued request is
    already at its floor (demotion headroom exhausted) and pressure keeps
    climbing does ``submit`` start rejecting new traffic with
    :class:`~repro.serving.faults.QueueFull`.

``-> back``
    When the queue drains the governor **promotes** still-queued demoted
    requests back to their original tiers and returns to nominal.

Two properties make the policy servable:

* **Hysteresis + min-dwell.** The demote threshold sits above the promote
  threshold (a band, not a line) and every mode transition must dwell
  ``min_dwell`` policy steps — the governor never oscillates
  demote->promote within a dwell window (asserted by a property test).
* **Registered tiers only.** Demotion picks among tiers named in the
  :class:`PolicyConfig` table, all registered/warmed up front — tier
  reassignment of a queued request swaps which *existing* executable
  serves it, so the AOT cache's zero-steady-state-retrace contract holds
  through an entire overload episode.

An optional engine-level **power budget** (``power_budget_aj``, an
energy/token ceiling priced by ``engine.tier_energy_per_token``) adds
demote pressure independent of queue depth, and blocks promotion while
restoring original tiers would overrun the ceiling.

Requests already decoding in a pool keep their tier: their noise keys and
compiled executables are bound at admission, so the dial only turns on
queued work (which is exactly where overload lives).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.serving.monitor import load_signals
from repro.serving.scheduler import Request

__all__ = ["TierSpec", "PolicyConfig", "PolicyEvent", "PrecisionGovernor"]

NOMINAL = "nominal"
DEMOTED = "demoted"
SHEDDING = "shedding"

#: PolicyEvent kinds that are mode transitions (dwell-gated); "retier" is
#: the in-mode sweep that folds newly queued traffic into a running episode
TRANSITIONS = ("demote", "promote", "shed_on", "shed_off")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One rung of the governor's precision ladder.

    ``tier`` is a uniform K int or a registered profile id. ``accuracy``
    is the tier's measured accuracy proxy (a ``core/search.py`` /
    ``core/calibrate.py`` eval); ``None`` reads it off the registered
    profile's ``accuracy`` metadata.
    """

    tier: object
    accuracy: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class PolicyConfig:
    """Governor knobs: the tier ladder, hysteresis band, dwell, budget.

    ``pressure`` is the governor's scalar load signal:
    ``queue_depth / pool_slots + urgency_weight * urgent_frac`` where
    ``urgent_frac`` is the fraction of queued SLO requests that have burned
    over half their latency budget waiting (see ``monitor.load_signals``).

    ``promote_at < demote_at <= shed_at`` is the hysteresis band: demote
    when pressure rises past ``demote_at``, promote back only once it has
    fallen below ``promote_at``, shed (reject new traffic) only past
    ``shed_at`` *and* with demotion headroom exhausted. ``min_dwell`` is
    the minimum number of policy steps between mode transitions — the
    anti-flapping floor.

    ``power_budget_aj``: optional energy/token ceiling (aJ, same unit as
    ``engine.tier_energy_per_token``) over the blended spend of queued +
    in-flight requests; exceeding it is demote pressure on its own, and
    promotion is blocked while restoring original tiers would overrun it.

    ``drift_band``: optional (lo, hi) band on the noise-drift estimate the
    engine's :class:`~repro.serving.monitor.MetricsFeed` carries
    (``load_signals(...).drift``). A drifted device delivers less
    effective precision per unit energy, so *sustained* out-of-band drift
    — at least ``drift_patience`` consecutive policy steps — is demote
    pressure exactly like queue load, firing the same registry-resolved
    retier path; promotion back to nominal is blocked while the excursion
    persists. ``None`` estimates (no feed / no probe yet) never count
    toward the streak. Set the band at least as wide as the watchdog's
    probe band: the estimate scatters a few percent at nominal.
    """

    tiers: Tuple[TierSpec, ...]
    demote_at: float = 1.5
    promote_at: float = 0.25
    shed_at: float = 3.0
    min_dwell: int = 4
    urgency_weight: float = 1.0
    power_budget_aj: Optional[float] = None
    drift_band: Optional[Tuple[float, float]] = None
    drift_patience: int = 2

    def __post_init__(self):
        # convenience: bare tier ids (ints / profile names) become TierSpecs
        specs = tuple(
            t if isinstance(t, TierSpec) else TierSpec(t) for t in self.tiers
        )
        object.__setattr__(self, "tiers", specs)
        if not specs:
            raise ValueError("policy needs at least one tier to govern")
        if not 0.0 <= self.promote_at < self.demote_at <= self.shed_at:
            raise ValueError(
                "hysteresis band must satisfy 0 <= promote_at < demote_at "
                f"<= shed_at, got ({self.promote_at}, {self.demote_at}, "
                f"{self.shed_at})"
            )
        if self.min_dwell < 1:
            raise ValueError(f"min_dwell must be >= 1, got {self.min_dwell}")
        if self.urgency_weight < 0.0:
            raise ValueError(
                f"urgency_weight must be >= 0, got {self.urgency_weight}"
            )
        if self.power_budget_aj is not None and self.power_budget_aj <= 0.0:
            raise ValueError(
                f"power_budget_aj must be > 0, got {self.power_budget_aj}"
            )
        if self.drift_band is not None and not (
            0.0 < self.drift_band[0] < 1.0 < self.drift_band[1]
        ):
            raise ValueError(
                "drift_band must straddle the nominal scale 1.0, got "
                f"{self.drift_band}"
            )
        if self.drift_patience < 1:
            raise ValueError(
                f"drift_patience must be >= 1, got {self.drift_patience}"
            )


@dataclasses.dataclass(frozen=True)
class PolicyEvent:
    """One governor action, attributable across logs and dashboards.

    Carries the engine's fault-clock step (``clock``) and the triggering
    measurement (``pressure`` with its ``queue_depth``/``occupancy``
    inputs) so a policy episode lines up against drift events, stalls and
    timeouts in the same ``fault_log``. ``uids`` are the requests retiered
    by this action (empty for pure mode flips).
    """

    kind: str  # "demote" | "retier" | "promote" | "shed_on" | "shed_off"
    step: int  # governor policy step (one per engine pump/poll round)
    clock: int  # engine fault clock at the observation
    pressure: float  # the triggering measurement
    queue_depth: int
    occupancy: float
    moved: int = 0
    uids: Tuple[int, ...] = ()
    detail: str = ""


class PrecisionGovernor:
    """SLA-aware precision policy over a live engine (see module docstring).

    Built by the engine from ``ServingEngine(policy=PolicyConfig(...))``;
    the engine calls :meth:`step` once per pump/poll round and consults
    :attr:`shedding` in ``submit``. All state is host-side and
    deterministic: the same traffic and clock readings replay the same
    episode event-for-event.
    """

    def __init__(self, engine, config: PolicyConfig):
        if engine.analog_cfg is None:
            raise ValueError(
                "policy governor needs an analog engine: precision is the "
                "dial it turns (digital serving has no energy/accuracy "
                "tradeoff to govern)"
            )
        self.engine = engine
        self.config = config
        table = []
        for spec in config.tiers:
            tier = spec.tier
            acc = spec.accuracy
            # every target resolves through the engine's TierRegistry: the
            # ladder may span execution domains (analog K / profile tiers
            # next to registered digital tiers), and demotion must pick
            # among already-materializable tiers so the AOT contract holds
            try:
                tier_obj = engine.tiers.get(tier)
            except ValueError as e:
                raise ValueError(
                    f"policy tier {tier!r} is not a registered profile or "
                    "tier; demotion must pick among already-registered "
                    "tiers so the AOT cache contract holds"
                ) from e
            tier = tier_obj.tier_id
            if acc is None:
                acc = tier_obj.accuracy
            if acc is None:
                raise ValueError(
                    f"policy tier {tier!r} has no accuracy metadata: pass "
                    "TierSpec(tier, accuracy=...) or register the tier "
                    "with accuracy= from a core/search.py eval — floors "
                    "can't be enforced against an unmeasured tier"
                )
            table.append(
                (float(engine.tier_energy_per_token(tier)), float(acc), tier)
            )
        # the demotion ladder: (energy/token, accuracy, tier) cheapest
        # first — the registry's floor-ordered ladder, priced per tier
        table.sort(key=lambda row: (row[0], str(row[2])))
        self._table: Tuple[Tuple[float, float, object], ...] = tuple(table)
        self.mode = NOMINAL
        self._step = 0
        # allow an immediate first transition: dwell gates *re*-transitions
        self._last_change = -int(config.min_dwell)
        #: runtime override of the config's power budget (aJ/token), set
        #: by a cluster-level governor rebalancing budget across replicas
        self._budget_override: Optional[float] = None
        #: uid -> original tier of every currently-demoted queued request
        self._demoted: Dict[int, object] = {}
        #: consecutive policy steps with an out-of-band drift estimate
        self._drift_streak = 0
        #: every PolicyEvent ever emitted, in order (bench/test surface)
        self.events: List[PolicyEvent] = []

    # -- tier metadata -------------------------------------------------------

    @property
    def shedding(self) -> bool:
        """True while ``submit`` must reject new traffic (the last rung)."""
        return self.mode == SHEDDING

    @property
    def tiers(self) -> Tuple[Tuple[float, float, object], ...]:
        """The resolved ladder: (energy/token aJ, accuracy, tier), cheapest
        first (read-only)."""
        return self._table

    def tier_accuracy(self, tier) -> float:
        for _e, acc, t in self._table:
            if t == tier:
                return acc
        raise ValueError(
            f"tier {tier!r} is not in the policy table "
            f"{[t for _e, _a, t in self._table]}"
        )

    def tier_energy(self, tier) -> float:
        return float(self.engine.tier_energy_per_token(tier))

    @property
    def power_budget_aj(self) -> Optional[float]:
        """The energy/token ceiling currently in force: the runtime
        override (a cluster governor's rebalanced share) when set, else
        the config's static budget."""
        if self._budget_override is not None:
            return self._budget_override
        return self.config.power_budget_aj

    def set_power_budget(self, aj: Optional[float]) -> None:
        """Override the power budget at runtime (``None`` restores the
        config's static value). The cluster-level governor calls this
        when it rebalances the global budget across replicas — e.g. after
        a replica death shifts load, or to lend headroom to a replica
        that demoted. Takes effect at the next policy step; no retrace
        (the budget is pure host-side policy state)."""
        if aj is not None and aj <= 0.0:
            raise ValueError(f"power budget must be > 0 aJ/token, got {aj}")
        self._budget_override = None if aj is None else float(aj)

    def cheapest_admissible(self, req: Request):
        """The cheapest policy tier strictly cheaper than the request's
        current tier that still satisfies its accuracy floor, or ``None``
        when the request has no demotion headroom left. A floorless
        request may ride all the way down the ladder."""
        floor = -float("inf") if req.accuracy_floor is None else req.accuracy_floor
        cur_e = self.tier_energy(req.tier)
        for e, acc, tier in self._table:
            if e < cur_e and acc >= floor:
                return tier
        return None

    # -- load / budget signals -----------------------------------------------

    def _live_requests(self) -> List[Request]:
        reqs = list(self.engine.scheduler.queued_requests())
        for pool in self.engine.pools.values():
            for s in pool.active_slots():
                reqs.append(pool.record(s).request)
        return reqs

    def blended_energy(self, *, restore: bool = False) -> float:
        """Mean energy/token over queued + in-flight requests — the
        engine's current spend rate. ``restore=True`` prices demoted
        requests at their *original* tiers (the promotion-feasibility
        check against the power budget)."""
        reqs = self._live_requests()
        if not reqs:
            return 0.0
        total = 0.0
        for r in reqs:
            tier = self._demoted.get(r.uid, r.tier) if restore else r.tier
            total += self.tier_energy(tier)
        return total / len(reqs)

    def _over_budget(self, *, restore: bool = False) -> bool:
        budget = self.power_budget_aj
        return budget is not None and self.blended_energy(restore=restore) > budget

    def _drift_sustained(self, sig) -> bool:
        """Update the out-of-band streak from this step's observation and
        report whether the excursion has outlasted ``drift_patience``.
        Missing estimates (no feed attached, no probe yet, or cleared by
        recalibration) reset the streak: absence of evidence is nominal."""
        band = self.config.drift_band
        if band is None:
            return False
        d = sig.drift
        if d is not None and not (band[0] <= d <= band[1]):
            self._drift_streak += 1
        else:
            self._drift_streak = 0
        return self._drift_streak >= self.config.drift_patience

    def _headroom_exhausted(self) -> bool:
        """True when no queued request can be demoted any further — the
        precondition for shedding (reject only as the last rung)."""
        return all(
            self.cheapest_admissible(r) is None
            for r in self.engine.scheduler.queued_requests()
        )

    # -- the policy step ------------------------------------------------------

    def _demote_assign(self, req: Request):
        return self.cheapest_admissible(req)

    def _promote_assign(self, req: Request):
        orig = self._demoted.get(req.uid)
        if orig is None or orig == req.tier:
            return None
        return orig

    def _demote_sweep(self):
        moved = self.engine.scheduler.reassign(self._demote_assign)
        for r, old, _new in moved:
            # keep the *first* original across repeated demotions so
            # promotion retraces the request's own ask, not a midpoint
            self._demoted.setdefault(r.uid, old)
        return moved

    def step(self, now: Optional[float] = None) -> List[PolicyEvent]:
        """One policy evaluation: observe load, maybe turn the dial.

        Called by the engine once per ``pump_step``/``poll`` round.
        Returns the events fired this step (also appended to
        :attr:`events` and the engine's ``fault_log``).
        """
        cfg = self.config
        sig = load_signals(self.engine, now)
        pressure = sig.queue_pressure + cfg.urgency_weight * sig.urgent_frac
        step = self._step
        self._step += 1
        fired: List[PolicyEvent] = []

        def emit(kind: str, moved=(), detail: str = "") -> PolicyEvent:
            ev = PolicyEvent(
                kind=kind, step=step, clock=sig.clock,
                pressure=float(pressure), queue_depth=sig.queue_depth,
                occupancy=sig.occupancy, moved=len(moved),
                uids=tuple(r.uid for r, _old, _new in moved), detail=detail,
            )
            self.events.append(ev)
            fired.append(ev)
            entry = dataclasses.asdict(ev)
            entry["policy_kind"] = entry.pop("kind")
            entry["kind"] = "policy"
            self.engine.fault_log.append(entry)
            return ev

        can_flip = (step - self._last_change) >= cfg.min_dwell
        over = self._over_budget()
        drifted = self._drift_sustained(sig)
        stats = self.engine.stats
        if self.mode == NOMINAL:
            if can_flip and (pressure >= cfg.demote_at or over or drifted):
                moved = self._demote_sweep()
                self.mode = DEMOTED
                self._last_change = step
                stats["demoted"] += len(moved)
                stats["policy_transitions"] += 1
                if pressure >= cfg.demote_at:
                    detail = "load"
                elif over:
                    detail = "power budget"
                else:
                    detail = "drift"
                emit("demote", moved, detail=detail)
        elif self.mode == DEMOTED:
            if can_flip and pressure >= cfg.shed_at and self._headroom_exhausted():
                self.mode = SHEDDING
                self._last_change = step
                stats["policy_transitions"] += 1
                emit("shed_on", detail="demotion headroom exhausted")
            elif (
                can_flip
                and pressure <= cfg.promote_at
                and not drifted
                and not self._over_budget(restore=True)
            ):
                moved = self.engine.scheduler.reassign(self._promote_assign)
                self._demoted.clear()
                self.mode = NOMINAL
                self._last_change = step
                stats["promoted_back"] += len(moved)
                stats["policy_transitions"] += 1
                emit("promote", moved)
            else:
                # the episode is live: newly queued traffic joins it
                moved = self._demote_sweep()
                if moved:
                    stats["demoted"] += len(moved)
                    emit("retier", moved)
        else:  # SHEDDING
            if can_flip and pressure <= cfg.demote_at:
                self.mode = DEMOTED
                self._last_change = step
                stats["policy_transitions"] += 1
                emit("shed_off")
            else:
                moved = self._demote_sweep()  # bounded fault requeues, etc.
                if moved:
                    stats["demoted"] += len(moved)
                    emit("retier", moved)
        return fired
