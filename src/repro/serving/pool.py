"""Persistent decode slot pools: the state behind continuous batching.

A ``DecodePool`` is one tier's always-resident decode batch: a fixed number
of ``slots``, each either free or carrying one in-flight request, over a
single static-shape device cache (``init_cache(cfg, slots, cache_len)``).
The engine decodes the whole pool every step — inactive slots ride along as
length-0 rows (exactly the bucket batch-padding contract: no recurrent
update that matters, no MoE capacity, outputs discarded) — retires a slot
the step its request hits its token budget or emits a stop id, and admits
freshly prefilled requests into free slots mid-flight by scattering their
cache rows in under jit (``models.lm.scatter_cache_rows``).

Host-side per-slot state (current token, position, true length, stacked
PRNG key words) is tiny — O(slots) scalars shipped with each step's inputs;
only the cache itself stays device-resident and is never round-tripped.

``SlotAllocator`` is the pool's free-list, split out so its invariants are
independently testable: a slot is never handed out twice while held, never
released twice, and retire->admit reuse can never alias another request's
rows (a slot re-enters the free list only after its record is cleared, and
activation overwrites token/position/length/key before the slot decodes).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Any, List, Optional

import numpy as np


class SlotAllocator:
    """Lowest-index-first free-list allocator with invariant checks.

    Deterministic: the same take/release sequence always yields the same
    slot assignments (continuous batching must replay bit-identically, and
    the bit-identity contract itself must not depend on which slot a request
    lands in — determinism makes both testable).
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"allocator needs at least 1 slot, got {n_slots}")
        self.n_slots = n_slots
        self._free: List[int] = list(range(n_slots))
        self._held: set = set()

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_held(self) -> int:
        return len(self._held)

    def held(self) -> frozenset:
        return frozenset(self._held)

    def take(self, k: int) -> List[int]:
        """Claim the ``k`` lowest free slots; raises if fewer are free."""
        if k < 0:
            raise ValueError(f"cannot take {k} slots")
        if k > len(self._free):
            raise ValueError(f"take({k}) with only {len(self._free)} free slots")
        out, self._free = self._free[:k], self._free[k:]
        self._held.update(out)
        return out

    def release(self, slot: int) -> None:
        """Return a held slot to the free list; raises on double-release or
        a slot that was never taken (the aliasing bugs this class exists to
        make impossible)."""
        if slot not in self._held:
            raise ValueError(f"slot {slot} is not held (double release?)")
        self._held.remove(slot)
        bisect.insort(self._free, slot)


@dataclasses.dataclass
class SlotRecord:
    """One in-flight request pinned to a decode slot."""

    request: Any  # repro.serving.scheduler.Request
    emitted: List[int]  # greedy tokens so far (first one from prefill)
    stop_set: frozenset  # EOS ids: emitting one retires the slot

    @property
    def done(self) -> bool:
        return len(self.emitted) >= self.request.max_new_tokens or (
            bool(self.emitted) and self.emitted[-1] in self.stop_set
        )


class DecodePool:
    """One execution tier's persistent decode batch.

    Device state: ``cache`` (static ``(slots, cache_len)`` layout, swapped
    wholesale each donated decode/insert call). Host state: per-slot token /
    position / true-length / PRNG-key rows, passed as the decode step's
    small operands. A free slot has length 0 — the decode step treats it as
    a batch-padding row, so pool occupancy never changes any active row's
    numerics (per-row noise keys and per-row positions do the rest).

    ``tier`` is the scheduler-facing tier id; ``exec_tier`` is the bound
    ``ExecutionTier`` object the engine dispatches through (executable
    factory, cache identity, parameter tree). The pool itself never
    interprets either — it is pure slot bookkeeping.
    """

    def __init__(
        self,
        *,
        tier,
        slots: int,
        cache_len: int,
        key_shape,
        key_dtype,
        cache,
        exec_tier=None,
    ):
        self.tier = tier
        self.slots = int(slots)
        self.cache_len = int(cache_len)
        self.exec_tier = exec_tier
        self.cache = cache
        self.allocator = SlotAllocator(self.slots)
        self.tok = np.zeros((self.slots,), np.int32)
        self.pos = np.zeros((self.slots,), np.int32)
        self.lengths = np.zeros((self.slots,), np.int32)  # 0 == inactive row
        self.keys = np.zeros((self.slots,) + tuple(key_shape), key_dtype)
        self._rec: List[Optional[SlotRecord]] = [None] * self.slots

    def place_cache(self, put) -> None:
        """Re-home the device cache through ``put`` (e.g. the engine's
        replicated device_put onto an attached mesh). Host slot state is
        device-agnostic; only the cache has a residency to manage. Called
        at pool build — once resident, donated decode/insert calls keep the
        cache on its devices without ever round-tripping it."""
        self.cache = put(self.cache)

    # -- occupancy -----------------------------------------------------------

    @property
    def n_free(self) -> int:
        return self.allocator.n_free

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self._rec)

    def active_slots(self) -> List[int]:
        """Snapshot of occupied slots (stable under retire-while-iterating)."""
        return [s for s, r in enumerate(self._rec) if r is not None]

    def record(self, slot: int) -> SlotRecord:
        rec = self._rec[slot]
        assert rec is not None, f"slot {slot} is not active"
        return rec

    def expired(self, now: float) -> List[int]:
        """Active slots whose request's deadline has passed at ``now`` —
        the engine retires them with a partial ``TimedOut`` result through
        the normal :meth:`retire` path (no special slot state)."""
        out = []
        for s in self.active_slots():
            d = self.record(s).request.deadline
            if d is not None and d <= now:
                out.append(s)
        return out

    # -- lifecycle -----------------------------------------------------------

    def take(self, k: int) -> List[int]:
        """Claim ``k`` free slots for an admission wave (cache rows are
        scattered before activation, so taken-but-inactive slots exist
        briefly; they don't decode until :meth:`activate`)."""
        return self.allocator.take(k)

    def activate(self, slot: int, request, first_token: int, key_row) -> None:
        """Arm a taken slot with a prefilled request: its first generated
        token, decode position (= prompt length), true length, and stacked
        PRNG key row — everything the masked decode step reads per row."""
        assert self._rec[slot] is None, f"slot {slot} already active"
        self._rec[slot] = SlotRecord(
            request=request,
            emitted=[int(first_token)],
            stop_set=request.stop_set,
        )
        self.tok[slot] = int(first_token)
        self.pos[slot] = request.prompt_len
        self.lengths[slot] = request.prompt_len
        self.keys[slot] = np.asarray(key_row, self.keys.dtype)

    def release(self, slot: int) -> None:
        """Return a taken-but-never-activated slot (the request finished at
        prefill: 1-token budget, or its first token was a stop id)."""
        assert self._rec[slot] is None, f"slot {slot} is active; retire() it"
        self.allocator.release(slot)

    def retire(self, slot: int) -> SlotRecord:
        """Free an active slot the step its request finishes. The row is
        zeroed to the inert length-0 state; its cache rows are left in place
        and fully overwritten by the next admission's scatter."""
        rec = self.record(slot)
        self._rec[slot] = None
        self.tok[slot] = 0
        self.pos[slot] = 0
        self.lengths[slot] = 0
        self.allocator.release(slot)
        return rec
