"""Precision-tiered request scheduling.

What a tier computes is *static* (baked into the trace), so a single batch
cannot mix execution tiers — tier grouping is what makes dynamic precision
servable at all. A tier id is an opaque grouping key here: the classic
uniform ``n_repeats=K`` int, a registered per-layer ``PrecisionProfile``
name, or any custom tier id from the engine's ``TierRegistry``
(serving/tiers.py) — the scheduler only compares ids for equality and
never interprets them. It keeps one FIFO queue per (tier, seq_bucket)
group and dispatches a group when it fills its batch bucket or its oldest
request has waited ``max_wait`` seconds (the anti-starvation deadline for
low-traffic tiers).

Everything here is pure Python and deterministic: the same submissions with
the same clock readings always produce the same batches in the same order.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.bucketing import DEFAULT_SEQ_BUCKETS, next_bucket
from repro.serving.faults import QueueFull


@dataclasses.dataclass
class Request:
    """One generation request at a precision tier.

    ``n_repeats`` is the paper's dynamic-precision knob: K analog repeats
    per matmul (noise / sqrt(K) at K x energy). ``profile_id`` names a
    registered per-layer K schedule instead — a tier IS a profile, with the
    classic uniform K as the degenerate case (``profile_id=None``). ``key``
    seeds this request's private noise streams — outputs are reproducible
    and independent of batch-mates. ``stop_tokens`` are EOS-style ids:
    greedy decode retires the request the step it emits one (the stop id is
    the last token of the output), instead of running out its full
    ``max_new_tokens`` budget.

    ``deadline`` is an absolute timestamp on the engine's clock domain: a
    request still queued or decoding past it is retired with a structured
    ``TimedOut`` result instead of burning more analog energy on an answer
    nobody is waiting for. ``retries`` counts fault-triggered
    resubmissions (the engine bounds them and promotes the precision tier
    on each retry).

    ``target_latency`` and ``accuracy_floor`` are the request's SLO for
    the precision governor (serving/policy.py): the latency target is
    *relative* to arrival (it defaults the absolute ``deadline``), and the
    floor is the minimum tier accuracy the governor may demote the request
    to under overload (``None``: any registered tier is acceptable).
    """

    uid: int
    tokens: np.ndarray  # (L,) prompt token ids
    n_repeats: int = 1
    max_new_tokens: int = 16
    key: Optional[object] = None  # jax PRNG key; engine fills a default
    arrival: float = 0.0
    profile_id: Optional[str] = None  # registered PrecisionProfile tier
    stop_tokens: Tuple[int, ...] = ()  # EOS ids: emit one -> retire the row
    deadline: Optional[float] = None  # absolute timeout (engine clock)
    retries: int = 0  # fault-triggered resubmissions so far
    target_latency: Optional[float] = None  # SLO: seconds from arrival
    accuracy_floor: Optional[float] = None  # SLO: min acceptable tier accuracy
    tier_id: Optional[object] = None  # canonical tier id (engine registry)

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.tokens).reshape(-1).shape[0])

    @property
    def stop_set(self) -> frozenset:
        return frozenset(int(t) for t in self.stop_tokens)

    @property
    def tier(self):
        """The batch-compatibility key: requests only share a batch when
        their compiled execution configuration is identical. ``tier_id``
        (set by :meth:`retier`) is canonical; the legacy ``profile_id`` /
        ``n_repeats`` pair backs it for directly-constructed requests."""
        if self.tier_id is not None:
            return self.tier_id
        return self.profile_id if self.profile_id is not None else self.n_repeats

    def retier(self, tier) -> None:
        """Bind this request to a tier id, keeping the legacy mirror
        fields consistent: named tiers land in ``profile_id`` (with the
        neutral ``n_repeats=1``), numeric uniform-K tiers in
        ``n_repeats``. The scheduler never interprets the id beyond
        equality — what it *means* is the engine registry's business."""
        self.tier_id = tier
        named = isinstance(tier, str)
        self.profile_id = tier if named else None
        self.n_repeats = 1 if named else int(tier)


class TierScheduler:
    """Groups same-tier requests into shared bucket batches with a deadline."""

    def __init__(
        self,
        *,
        max_batch: int = 8,
        max_wait: float = 0.05,
        seq_buckets: Sequence[int] = DEFAULT_SEQ_BUCKETS,
        max_queue: Optional[int] = None,
    ):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.max_queue = max_queue
        self.seq_buckets = tuple(seq_buckets)
        # group (tier, seq_bucket) -> FIFO of requests, where tier is the
        # uniform K int or a profile id string. OrderedDict so dispatch order
        # over groups is submission-ordered, not hash-ordered.
        self._queues: "OrderedDict[Tuple[object, int], List[Request]]" = OrderedDict()

    def group_of(self, req: Request) -> Tuple[object, int]:
        return (req.tier, next_bucket(req.prompt_len, self.seq_buckets))

    def submit(self, req: Request, *, force: bool = False) -> Tuple[int, int]:
        """Enqueue one request. With ``max_queue`` set, submission past the
        high-water mark raises :class:`QueueFull` — explicit backpressure
        instead of unbounded queue growth. ``force`` bypasses the bound:
        the engine's internal fault-retry requeues must never be shed (the
        request was already admitted once)."""
        if (
            not force
            and self.max_queue is not None
            and self.n_pending >= self.max_queue
        ):
            raise QueueFull(
                f"scheduler queue is at its high-water mark "
                f"({self.n_pending}/{self.max_queue} pending); poll/pump to "
                "drain or shed load upstream"
            )
        g = self.group_of(req)
        self._queues.setdefault(g, []).append(req)
        return g

    @property
    def n_pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def pop_ready(self, now: float) -> List[List[Request]]:
        """Batches ready at time ``now``: full groups, plus any group whose
        oldest request has aged past the max-wait deadline."""
        batches: List[List[Request]] = []
        for g in list(self._queues):
            q = self._queues[g]
            while len(q) >= self.max_batch:
                batches.append(q[: self.max_batch])
                del q[: self.max_batch]
            if q and now - q[0].arrival >= self.max_wait:
                batches.append(q[:])
                q.clear()
            if not q:
                del self._queues[g]
        return batches

    def pop_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request whose deadline has passed
        at ``now`` (the engine turns them into structured ``TimedOut``
        results). FIFO order is preserved for the survivors."""
        expired: List[Request] = []
        for g in list(self._queues):
            q = self._queues[g]
            keep = []
            for r in q:
                (expired if r.deadline is not None and r.deadline <= now
                 else keep).append(r)
            if keep:
                self._queues[g] = keep
            else:
                del self._queues[g]
        return expired

    def cancel(self, uid: int) -> Optional[Request]:
        """Withdraw one queued request by uid; returns it, or ``None``
        when the uid is not queued (already dispatched, finished, or
        unknown). Survivors keep their FIFO order — cancellation is how
        a cluster router retracts a hedged-dispatch loser or pulls work
        off a quarantined replica without disturbing its queue-mates."""
        for g in list(self._queues):
            q = self._queues[g]
            for i, r in enumerate(q):
                if r.uid == uid:
                    del q[i]
                    if not q:
                        del self._queues[g]
                    return r
        return None

    def pending_tiers(self):
        """Tiers with queued requests (continuous pools are created lazily,
        so the engine sizes free-slot accounting off this set)."""
        return {tier for tier, _sb in self._queues}

    def queued_requests(self) -> List[Request]:
        """Every queued request, in deterministic group-then-FIFO order
        (the precision governor's observation/sweep surface)."""
        out: List[Request] = []
        for q in self._queues.values():
            out.extend(q)
        return out

    def reassign(self, assign) -> List[Tuple[Request, object, object]]:
        """Move queued requests between precision tiers (the governor's
        demote/promote sweep).

        ``assign(req)`` returns the request's new tier — a uniform K int
        or a registered profile id — or ``None`` to leave it in place.
        Retiered requests are re-grouped under their new
        ``(tier, seq_bucket)`` queue, and every destination queue is
        re-sorted by ``(arrival, uid)`` so dispatch order stays global
        FIFO: a demoted request never loses its place to younger traffic.
        ``assign`` must be idempotent (return ``None`` once a request is
        already at its target) — requests can land in a group the sweep
        has not visited yet and be offered again.

        Returns ``[(request, old_tier, new_tier)]`` in sweep order.
        Requests already dispatched to a batch or pool slot are out of
        reach by design: their noise keys and compiled executables bound
        them to their tier at admission.
        """
        moves: List[Tuple[Request, object, object]] = []
        touched = set()
        for g in list(self._queues):
            q = self._queues.get(g)
            if not q:
                continue
            keep: List[Request] = []
            for r in q:
                new = assign(r)
                if new is None or new == r.tier:
                    keep.append(r)
                    continue
                old = r.tier
                r.retier(new)
                ng = self.group_of(r)
                self._queues.setdefault(ng, []).append(r)
                touched.add(ng)
                moves.append((r, old, new))
            if keep:
                self._queues[g] = keep
            else:
                del self._queues[g]
        for ng in touched:
            self._queues[ng].sort(key=lambda r: (r.arrival, r.uid))
        return moves

    def pop_admissible(
        self,
        now: Optional[float],
        free_slots: Dict[object, int],
        *,
        force: bool = False,
    ) -> List[List[Request]]:
        """Slot-aware admission for continuous (in-flight) batching.

        ``free_slots`` maps tier -> currently free decode slots in that
        tier's persistent pool; it is decremented in place as requests are
        admitted (groups of one tier at different seq buckets share the
        tier's pool, so the accounting spans groups). A group dispatches
        under the same readiness rule as ``pop_ready`` — a full batch, or an
        oldest request aged past ``max_wait`` (``force`` ignores both, for
        flush/drain) — but never more rows than the tier has free slots:
        the remainder stays queued, FIFO order preserved, and is admitted
        mid-flight as retirements free slots. Deadline semantics over a
        partial pool follow directly: an aged group admits as many rows as
        fit *now* rather than waiting for a full batch's worth of slots.

        The interleave policy this implements is prefill-first: the engine
        calls this before every decode round, so free slots are refilled as
        eagerly as readiness allows. ``max_wait`` is the policy knob —
        larger values batch prefills (fewer, fuller prefill dispatches at
        higher time-to-first-token), ``max_wait=0`` admits instantly.
        """
        batches: List[List[Request]] = []
        for g in list(self._queues):
            tier, _sb = g
            q = self._queues[g]
            free = free_slots.get(tier, 0)
            while q and free > 0 and (
                force
                or len(q) >= self.max_batch
                or now - q[0].arrival >= self.max_wait
            ):
                n = min(len(q), self.max_batch, free)
                batches.append(q[:n])
                del q[:n]
                free -= n
            free_slots[tier] = free
            if not q:
                del self._queues[g]
        return batches

    def flush(self) -> List[List[Request]]:
        """Drain everything (shutdown / end of replay), deadline ignored."""
        batches = []
        for g in list(self._queues):
            q = self._queues.pop(g)
            for i in range(0, len(q), self.max_batch):
                batches.append(q[i : i + self.max_batch])
        return batches
