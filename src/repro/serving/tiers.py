"""Pluggable execution tiers: one interface, three execution domains.

The serving stack used to know exactly two kinds of "tier": a uniform
analog repeat count K (an ``int``) and a named per-layer repeat profile
(a ``str``).  Every consumer — AOT cache keys, slot pools, the SLA
governor, fault-retry promotion, energy accounting — branched on which
kind it was holding, and the digital path hid behind a ``("digital",)``
sentinel baked into the executable keys.  This module replaces all of
that with a single abstraction:

``ExecutionTier``
    *identity*   — ``tier_id`` (the scheduler-facing id) and
    ``cache_key()`` (the executable-identity suffix: everything that
    changes the trace must be in it, nothing else may be).
    *execution*  — an AOT executable factory (``build_prefill`` /
    ``build_decode`` / ``build_insert``) plus the parameter tree those
    executables consume (``params`` / ``param_specs``; the int8 tier
    substitutes a quantized tree here).
    *economics*  — ``energy_per_token()``, an honest per-token cost:
    analog tiers price through the calibrated per-site energy tree,
    digital tiers through a per-MAC digital cost constant — never each
    other's.
    *health*     — ``accuracy`` floor metadata (the governor's ladder
    coordinate), ``drift_exempt`` (digital executions don't ride the
    analog noise-drift watchdog), and the ``promote()`` /
    ``drift_promote()`` degradation ladder used by fault retries and the
    drift response.

``TierRegistry``
    owned by the engine; the only component that maps tier ids to tier
    objects.  Uniform-K tiers materialize lazily (any ``int`` is
    servable on an analog engine), profiles register by name (add-only,
    frozen), and custom tiers — e.g. :class:`Int8DigitalTier` — plug in
    via :meth:`register`.  Everything else in ``serving/`` asks the
    registry; a lint test (``tests/test_tiers.py``) keeps the old
    branches from creeping back.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.energy import (
    DIGITAL_BF16_AJ_PER_MAC,
    DIGITAL_INT8_AJ_PER_MAC,
    total_macs,
)
from ..core.profile import PrecisionProfile
from ..models import lm
from ..quant.weights import quantize_params
from .cache import aot_compile

__all__ = [
    "AnalogProfileTier",
    "DigitalTier",
    "ExecutionTier",
    "Int8DigitalTier",
    "TierRegistry",
    "UniformKTier",
]


def _spec_tree(tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree
    )


def _next_rung(k: int, ladder: Tuple[int, ...]) -> int:
    """Smallest ladder rung strictly above ``k`` (saturates at the top:
    the calibrated bound — promotion never invents an uncalibrated K)."""
    for rung in ladder:
        if rung > k:
            return rung
    return k


class ExecutionTier:
    """One servable execution configuration. Subclass and register.

    A tier is bound to exactly one engine (the registry binds it at
    registration); binding gives it access to the model config, the
    live parameter tree, and the engine's retrace audit counter. The
    base class owns the three AOT executable builders — subclasses
    customize them entirely through :meth:`analog_spec` (the noise
    model traced into the executables) and :attr:`params` /
    :attr:`param_specs` (the weight tree they consume).
    """

    #: digital executions don't share the analog array's physics: the
    #: noise-drift watchdog and the drift promotion response skip them
    drift_exempt = False

    def __init__(self, tier_id, *, accuracy: Optional[float] = None):
        self.tier_id = tier_id
        self.accuracy = None if accuracy is None else float(accuracy)
        self._engine = None

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}({self.tier_id!r})"

    # -- binding -------------------------------------------------------------

    def _bind(self, engine) -> None:
        if self._engine is not None and self._engine is not engine:
            raise ValueError(
                f"tier {self.tier_id!r} is already bound to another engine"
            )
        self._engine = engine

    @property
    def engine(self):
        if self._engine is None:
            raise ValueError(
                f"tier {self.tier_id!r} is not registered with an engine"
            )
        return self._engine

    # -- mesh-aware lowering helpers -----------------------------------------

    def _sds(self, shape, dtype):
        """ShapeDtypeStruct pinned replicated on the engine's mesh (plain
        spec when unmeshed — the legacy lowering, byte-identical)."""
        sh = self.engine._replicated_sharding()
        if sh is None:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)

    def _pin(self, spec_tree):
        """Pin a tree of specs (e.g. an eval_shape'd cache) replicated."""
        sh = self.engine._replicated_sharding()
        if sh is None:
            return spec_tree
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            spec_tree,
        )

    # -- identity ------------------------------------------------------------

    def cache_key(self) -> tuple:
        """Executable-identity suffix appended to every AOT cache key.

        Must capture everything that changes the traced computation
        (repeat schedule, backend, noise kind, numeric format) and
        nothing that doesn't — two tiers with equal ``cache_key()``
        share warm executables by construction."""
        raise NotImplementedError

    # -- execution -----------------------------------------------------------

    @property
    def params(self):
        """The parameter tree this tier's executables consume."""
        return self.engine.params

    @property
    def param_specs(self):
        return self.engine._param_specs

    def analog_spec(self, keys, pos=None, noise_scale=None):
        """AnalogSpec traced into this tier's executables (None =
        noiseless digital execution). ``keys`` are the stacked
        per-request raw keys, folded with the decode position so every
        generated token draws fresh noise; ``noise_scale`` is the
        *traced* drift operand (runtime value, never a compile
        constant)."""
        return None

    def build_prefill(self, bb: int, sb: int, cache_len: int):
        eng = self.engine
        cfg = eng.model_cfg

        def fn(params, tokens, lengths, keys, noise_scale):
            eng._traces += 1  # runs at trace time only: the retrace audit
            analog = self.analog_spec(keys, noise_scale=noise_scale)
            cache, h_last = lm.prefill(
                params, {"tokens": tokens}, cfg,
                analog=analog, cache_len=cache_len, lengths=lengths,
            )
            logits = lm.logits_last(params, h_last, cfg)
            tok = jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32)
            return cache, tok

        i32 = jnp.int32
        with eng._mesh_ctx():
            return aot_compile(
                fn,
                self._pin(self.param_specs),
                self._sds((bb, sb), i32),
                self._sds((bb,), i32),
                eng._keys_spec(bb),
                self._sds((), jnp.float32),
                out_shardings=eng._replicated_sharding(),
            )

    def build_decode(self, bb: int, cache_len: int):
        eng = self.engine
        cfg = eng.model_cfg

        def fn(params, cache, tok, pos, lengths, keys, noise_scale):
            eng._traces += 1
            analog = self.analog_spec(keys, pos=pos, noise_scale=noise_scale)
            logits, new_cache = lm.decode_step(
                params, cache, {"tokens": tok}, pos, cfg, analog=analog,
                lengths=lengths,
            )
            nxt = jnp.argmax(logits[:, 0, 0], axis=-1).astype(jnp.int32)
            return nxt, new_cache

        i32 = jnp.int32
        cache_specs = jax.eval_shape(lambda: lm.init_cache(cfg, bb, cache_len))
        with eng._mesh_ctx():
            return aot_compile(
                fn,
                self._pin(self.param_specs),
                self._pin(cache_specs),
                self._sds((bb, 1), i32),
                self._sds((bb,), i32),
                self._sds((bb,), i32),
                eng._keys_spec(bb),
                self._sds((), jnp.float32),
                donate_argnums=(1,),
                out_shardings=eng._replicated_sharding(),
            )

    def build_insert(self, slots: int, cache_len: int, bb: int):
        """Admission scatter: prefilled cache rows (batch ``bb``) into
        the pool cache (batch ``slots``) at per-row slot ids, under jit.
        Rows pointed at slot id ``slots`` (prefill batch padding) are
        dropped. The cache layout is parameter- and noise-free, so the
        insert executable is shared across every tier (the registry
        keys it without a tier suffix)."""
        eng = self.engine
        cfg = eng.model_cfg

        def fn(pool_cache, src_cache, slot_ids):
            eng._traces += 1
            return lm.scatter_cache_rows(cfg, pool_cache, src_cache, slot_ids)

        pool_specs = jax.eval_shape(lambda: lm.init_cache(cfg, slots, cache_len))
        src_specs = jax.eval_shape(lambda: lm.init_cache(cfg, bb, cache_len))
        with eng._mesh_ctx():
            return aot_compile(
                fn,
                self._pin(pool_specs),
                self._pin(src_specs),
                self._sds((bb,), jnp.int32),
                donate_argnums=(0,),
                out_shardings=eng._replicated_sharding(),
            )

    # -- economics -----------------------------------------------------------

    def energy_per_token(self) -> float:
        """Honest energy per generated token in aJ, from this tier's own
        cost model (analog energy tree or digital per-MAC constant)."""
        raise NotImplementedError

    # -- degradation ladder --------------------------------------------------

    def promote(self):
        """Tier id a bounded-retry fault promotes this tier's requests
        to (more repeats buy margin against whatever made the first
        attempt fail). Returning ``self.tier_id`` means "retry at the
        same tier" — the digital default, where repeats buy nothing."""
        return self.tier_id

    def drift_promote(self):
        """Tier id new submissions serve at while the engine's drift
        response is active (see ``ServingEngine.promote_tiers``)."""
        return self.tier_id


class UniformKTier(ExecutionTier):
    """The paper's uniform dynamic-precision dial: every analog matmul
    runs K repeated evaluations (noise/sqrt(K) at K x energy). The id
    is the bare ``int`` K, which is also the legacy wire format —
    ``submit(n_repeats=K)`` resolves here."""

    def __init__(self, k: int, *, accuracy: Optional[float] = None):
        if k < 1:
            raise ValueError(f"n_repeats must be >= 1, got {k}")
        super().__init__(int(k), accuracy=accuracy)
        self.k = int(k)

    def cache_key(self) -> tuple:
        cfg = self.engine.analog_cfg
        return (self.k, cfg.backend, cfg.noise.kind)

    def analog_spec(self, keys, pos=None, noise_scale=None):
        eng = self.engine
        k = keys if pos is None else jax.vmap(jax.random.fold_in)(keys, pos)
        return lm.AnalogSpec(
            cfg=eng.analog_cfg, energies=eng._energies, key=k,
            n_repeats=self.k, profile=None, noise_scale=noise_scale,
        )

    def energy_per_token(self) -> float:
        eng = self.engine
        profile = PrecisionProfile.uniform(self.k, eng.model_cfg.n_layers)
        return lm.profile_token_energy(eng.model_cfg, eng._energies, profile)

    def promote(self):
        return _next_rung(self.k, self.engine.k_ladder)

    # drift response: one rung up the calibrated ladder, same as retries
    drift_promote = promote


class AnalogProfileTier(ExecutionTier):
    """A named per-layer repeat schedule (the paper's learned profile).
    The id is the profile name; the repeat tuple is frozen at
    registration (add-only), so the executable identity can't drift."""

    def __init__(self, profile: PrecisionProfile):
        super().__init__(profile.name, accuracy=profile.accuracy)
        self.profile = profile

    def cache_key(self) -> tuple:
        cfg = self.engine.analog_cfg
        if cfg is None:
            # profiles are registrable on digital engines for API parity
            # but never served there (submit coalesces to the base tier)
            return ("digital", "bf16")
        # uniform+coalesce profiles share the bare-K element with
        # UniformKTier on purpose: equal schedule => shared executables
        return (self.profile.cache_key(), cfg.backend, cfg.noise.kind)

    def analog_spec(self, keys, pos=None, noise_scale=None):
        eng = self.engine
        if eng.analog_cfg is None:
            return None
        k = keys if pos is None else jax.vmap(jax.random.fold_in)(keys, pos)
        return lm.AnalogSpec(
            cfg=eng.analog_cfg, energies=eng._energies, key=k,
            n_repeats=1, profile=self.profile, noise_scale=noise_scale,
        )

    def energy_per_token(self) -> float:
        eng = self.engine
        if eng._energies is None:
            raise ValueError("digital engine: no energy tree to account")
        return lm.profile_token_energy(eng.model_cfg, eng._energies, self.profile)

    def promote(self):
        """Fault promotion for a non-uniform schedule: prefer the
        smallest *registered* strictly-higher-accuracy tier (its
        executables are already warm), else re-trim the whole profile
        one ladder rung up per layer — never a silent collapse to
        uniform K."""
        eng = self.engine
        if self.accuracy is not None:
            best = None
            for cand in eng.tiers.registered():
                if cand is self or cand.accuracy is None:
                    continue
                if cand.accuracy > self.accuracy and (
                    best is None or cand.accuracy < best.accuracy
                ):
                    best = cand
            if best is not None:
                return best.tier_id
        ladder = eng.k_ladder
        reps = tuple(_next_rung(k, ladder) for k in self.profile.repeats)
        if reps == self.profile.repeats:
            return self.tier_id  # already at the calibrated top everywhere
        retrim = PrecisionProfile(reps, name=f"{self.profile.name}+retrim")
        return eng.tiers.register_profile(retrim)


class DigitalTier(ExecutionTier):
    """Noiseless digital execution of the engine's parameter tree.

    This is both the implicit tier of a digital engine (no analog
    config; the registry creates one as the base tier) and a
    registrable escape hatch on analog engines: an always-exact tier
    the governor can demote to across domains. Accuracy defaults to
    1.0 — digital *is* the reference the analog agreement proxy is
    measured against. Energy prices through a per-MAC digital cost
    constant when one is supplied; without one there is nothing honest
    to report and :meth:`energy_per_token` refuses."""

    drift_exempt = True

    def __init__(
        self,
        tier_id="bf16",
        *,
        aj_per_mac: Optional[float] = DIGITAL_BF16_AJ_PER_MAC,
        accuracy: Optional[float] = 1.0,
    ):
        super().__init__(tier_id, accuracy=accuracy)
        self.aj_per_mac = None if aj_per_mac is None else float(aj_per_mac)
        self._macs_per_token = None

    def cache_key(self) -> tuple:
        return ("digital", "bf16")

    def energy_per_token(self) -> float:
        if self.aj_per_mac is None:
            raise ValueError("digital engine: no energy tree to account")
        if self._macs_per_token is None:
            self._macs_per_token = float(
                total_macs(lm.energy_macs(self.engine.model_cfg, 1))
            )
        return self.aj_per_mac * self._macs_per_token


class Int8DigitalTier(DigitalTier):
    """Weight-only int8 digital execution (``quant/weights.py``).

    The executables consume a quantized parameter tree (int8 q +
    per-output-channel f32 scale, dequantized per layer-slice inside
    the model's scan — see ``lm._maybe_dequant``), re-quantized lazily
    whenever the engine's live tree is swapped. Energy prices through
    the int8 per-MAC digital constant, NOT the analog energy tree;
    accuracy defaults to 1.0 (greedy-decode agreement with the bf16
    reference is near-exact at 8 bits — pass a measured value to be
    stricter)."""

    def __init__(
        self,
        tier_id="int8",
        *,
        aj_per_mac: Optional[float] = DIGITAL_INT8_AJ_PER_MAC,
        accuracy: Optional[float] = 1.0,
    ):
        super().__init__(tier_id, aj_per_mac=aj_per_mac, accuracy=accuracy)
        self._src = None
        self._qparams = None
        self._qspecs = None

    def cache_key(self) -> tuple:
        return ("digital", "int8")

    @property
    def params(self):
        src = self.engine.params
        if self._qparams is None or self._src is not src:
            self._qparams = quantize_params(src)
            self._qspecs = _spec_tree(self._qparams)
            self._src = src
        return self._qparams

    @property
    def param_specs(self):
        self.params  # materialize (and track engine param swaps)
        return self._qspecs


class TierRegistry:
    """Engine-owned map from tier ids to :class:`ExecutionTier`s.

    Add-only, like the profile store it subsumes: executables compiled
    against a tier id must stay valid for the engine's lifetime.
    Uniform-K tiers materialize lazily (any positive ``int`` is a valid
    analog tier); named tiers — profiles and custom/digital tiers —
    must be registered first. On a digital engine every numeric tier
    resolves to the single base :class:`DigitalTier` (K is a no-op
    without noise), which is how heterogeneous-K traffic coalesces
    into shared batches there."""

    def __init__(self, engine):
        self._engine = engine
        self._tiers: Dict[object, ExecutionTier] = {}
        self._profiles: Dict[str, PrecisionProfile] = {}
        self.base_id = 1
        if engine.analog_cfg is None:
            base = DigitalTier(tier_id=self.base_id, aj_per_mac=None)
            base._bind(engine)
            self._tiers[self.base_id] = base

    # -- registration --------------------------------------------------------

    def register(self, tier: ExecutionTier):
        """Register a custom tier under its ``tier_id``. Idempotent for
        the same object; re-registering a taken id is an error (the
        AOT contract: ids are frozen to their executables)."""
        if not isinstance(tier, ExecutionTier):
            raise TypeError(f"expected an ExecutionTier, got {type(tier)!r}")
        prev = self._tiers.get(tier.tier_id)
        if prev is tier:
            return tier.tier_id
        if prev is not None:
            raise ValueError(
                f"tier id {tier.tier_id!r} is frozen to an already-registered "
                "tier; pick a new id (executables compiled against it must "
                "stay valid)"
            )
        tier._bind(self._engine)
        self._tiers[tier.tier_id] = tier
        return tier.tier_id

    def register_profile(self, profile: PrecisionProfile) -> str:
        """Register (or re-confirm) a per-layer repeat profile under its
        name. Validates the schedule against the model; idempotent for
        an identical schedule, an error for a conflicting one."""
        eng = self._engine
        lm.profile_rows(eng.model_cfg, profile)  # layer-count validation
        prev = self._profiles.get(profile.name)
        if prev is not None:
            if prev.cache_key() != profile.cache_key():
                raise ValueError(
                    f"profile name {profile.name!r} is frozen to a different "
                    "repeat schedule; profiles are add-only (executables "
                    "compiled against the name must stay valid)"
                )
            return profile.name
        if profile.name in self._tiers:
            raise ValueError(
                f"tier id {profile.name!r} is frozen to an already-registered "
                "non-profile tier; pick a new profile name"
            )
        self._profiles[profile.name] = profile
        tier = AnalogProfileTier(profile)
        tier._bind(eng)
        self._tiers[profile.name] = tier
        return profile.name

    # -- resolution ----------------------------------------------------------

    def get(self, tier_id) -> ExecutionTier:
        """The tier serving ``tier_id``; lazily materializes uniform-K
        tiers on analog engines, raises for unknown named tiers."""
        tier = self._tiers.get(tier_id)
        if tier is not None:
            return tier
        if isinstance(tier_id, (int,)) and not isinstance(tier_id, bool):
            eng = self._engine
            if eng.analog_cfg is None:
                return self._tiers[self.base_id]  # K is a no-op without noise
            tier = UniformKTier(tier_id)
            tier._bind(eng)
            self._tiers[tier_id] = tier
            return tier
        raise ValueError(
            f"unknown profile {tier_id!r}; register_profile() it first"
        )

    def resolve(self, tier):
        """Normalize a submit-time ``tier=`` argument to a tier id:
        accepts a registered id, a bare uniform K, a PrecisionProfile,
        or an ExecutionTier instance (auto-registered)."""
        if isinstance(tier, ExecutionTier):
            if self._tiers.get(tier.tier_id) is not tier:
                self.register(tier)
            return tier.tier_id
        if isinstance(tier, PrecisionProfile):
            return self.resolve_profile(tier)
        self.get(tier)  # existence check (materializes uniform Ks)
        return tier

    def resolve_profile(self, profile):
        """Normalize a submit-time ``profile=`` argument to a tier id.
        A degenerate uniform+coalesce profile resolves to its bare K so
        it shares batches and executables with ``n_repeats=K`` traffic."""
        if isinstance(profile, PrecisionProfile):
            pid = self.register_profile(profile)
        else:
            pid = str(profile)
            if pid not in self._profiles:
                raise ValueError(
                    f"unknown profile {pid!r}; register_profile() it first "
                    "(or pass the PrecisionProfile itself)"
                )
        p = self._profiles[pid]
        if p.is_uniform and p.coalesce:
            return int(p.repeats[0])
        return pid

    # -- executable identity -------------------------------------------------

    def exe_key(self, phase: str, tier_id, *shape) -> tuple:
        """The full AOT cache key for one executable: phase + static
        shape + the engine's mesh fingerprint + the tier's identity
        suffix. ``tier_id=None`` builds a tier-free key (the admission
        insert, shared across tiers). The mesh fingerprint is ``()``
        unmeshed (legacy keys unchanged); on a mesh-attached engine it
        makes resharding compile fresh executables while a reshard back
        to a previous mesh hits that mesh's still-warm entries."""
        base = (phase,) + tuple(shape) + self._engine.mesh_key
        if tier_id is None:
            return base
        return base + self.get(tier_id).cache_key()

    # -- introspection -------------------------------------------------------

    @property
    def profiles(self) -> Dict[str, PrecisionProfile]:
        """Registered profiles by name (a copy; the registry is add-only)."""
        return dict(self._profiles)

    def registered(self) -> List[ExecutionTier]:
        """Every explicitly-known tier (registration order)."""
        return list(self._tiers.values())

    def ladder(self) -> List[ExecutionTier]:
        """Registered tiers with accuracy metadata, floor-ordered
        (ascending accuracy): the governor's demotion ladder spans
        analog and digital domains in one ordering."""
        tiers = [t for t in self._tiers.values() if t.accuracy is not None]
        return sorted(tiers, key=lambda t: (t.accuracy, str(t.tier_id)))

    def drift_exempt_ids(self) -> List[object]:
        return [t.tier_id for t in self._tiers.values() if t.drift_exempt]

    def drift_promote(self, tier_id):
        """Tier id a new submission serves at under the active drift
        response (digital tiers and profiles pass through unchanged)."""
        return self.get(tier_id).drift_promote()

    def __contains__(self, tier_id) -> bool:
        return tier_id in self._tiers

    def __len__(self) -> int:
        return len(self._tiers)
