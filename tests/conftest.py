"""Shared test setup.

Provides a minimal, deterministic stand-in for ``hypothesis`` when the real
package is not installed (the CI/container image bakes in the jax toolchain
but not hypothesis). The stub covers exactly the API surface this suite
uses — ``given`` with keyword strategies, ``settings(max_examples, deadline)``
and ``strategies.floats/integers`` — drawing a fixed number of samples from
a per-test seeded PRNG, always including both range endpoints, so the
property tests stay meaningful and reproducible without the dependency.
"""
from __future__ import annotations

import math
import random
import sys
import types
import zlib

try:  # pragma: no cover - prefer the real package when present
    import hypothesis  # noqa: F401
except ImportError:
    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng, i):
            return self._draw_fn(rng, i)

    def _floats(min_value=None, max_value=None, **_kw):
        lo, hi = float(min_value), float(max_value)

        def draw(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            if lo > 0 and hi / lo >= 1e3:  # wide positive range: log-uniform
                return math.exp(math.log(lo) + (math.log(hi) - math.log(lo)) * rng.random())
            return lo + (hi - lo) * rng.random()

        return _Strategy(draw)

    def _integers(min_value=None, max_value=None, **_kw):
        lo, hi = int(min_value), int(max_value)

        def draw(rng, i):
            if i == 0:
                return lo
            if i == 1:
                return hi
            return rng.randint(lo, hi)

        return _Strategy(draw)

    def _given(*args, **strategies):
        if args:
            raise TypeError("hypothesis stub supports keyword strategies only")

        def deco(fn):
            # NOT functools.wraps: the wrapper must expose a zero-arg
            # signature or pytest mistakes the strategy params for fixtures.
            def wrapper():
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for i in range(n):
                    drawn = {name: s.draw(rng, i) for name, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper

        return deco

    def _settings(max_examples=10, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn

        return deco

    _mod = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.floats = _floats
    _st.integers = _integers
    _mod.given = _given
    _mod.settings = _settings
    _mod.strategies = _st
    sys.modules["hypothesis"] = _mod
    sys.modules["hypothesis.strategies"] = _st
