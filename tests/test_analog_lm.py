"""The paper's technique integrated into the LM stack: analog forward,
energy gradients, calibrate step on the local mesh, analog decode."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import AnalogConfig, avg_energy_per_mac, to_energy
from repro.core.energy import uniform_log_energies
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_calibrate_step, make_decode_step
from repro.models import (
    AnalogSpec,
    decode_step,
    energy_macs,
    init_cache,
    init_energy_tree,
    init_params,
    train_loss,
)
from repro.models.sharding import use_mesh
from repro.optim.adam import AdamConfig, adam_init

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def _batch(cfg):
    return {
        "tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ["granite-3-8b", "grok-1-314b", "recurrentgemma-2b", "xlstm-1.3b"])
def test_analog_forward_and_energy_grads(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    energies = init_energy_tree(cfg, 50.0)

    def loss_of(e_tree):
        a = AnalogSpec(cfg=AnalogConfig.shot(), energies=e_tree, key=KEY)
        return train_loss(params, batch, cfg, analog=a)

    loss = loss_of(energies)
    assert jnp.isfinite(loss)
    g = jax.grad(loss_of)(energies)
    gnorm = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert gnorm > 0, arch
    # lower energy => noisier => (statistically) higher loss
    low = init_energy_tree(cfg, 0.05)
    losses_hi = [float(loss_of(energies)) for _ in range(1)]
    losses_lo = [float(loss_of(low)) for _ in range(1)]
    assert losses_lo[0] >= losses_hi[0] - 0.05


def test_calibrate_step_runs_and_reduces_energy():
    cfg = dataclasses.replace(get_smoke_config("granite-3-8b"), dtype="float32", remat=False)
    mesh = make_local_mesh()
    with use_mesh(mesh):
        params = init_params(KEY, cfg)
        target = 1.0
        _, jit_for, aux = make_calibrate_step(
            cfg, mesh, analog_cfg=AnalogConfig.shot(), seq_len=T,
            target_e_per_mac=target, lam=20.0, lr=0.1,
        )
        macs = aux["macs"]
        log_e = uniform_log_energies(macs, 8.0)  # start 8x over budget
        opt = adam_init(log_e, AdamConfig(lr=0.1))
        batch = _batch(cfg)
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
        step = jit_for(specs)
        start = float(avg_energy_per_mac(to_energy(log_e), macs))
        for i in range(30):
            log_e, opt, m = step(log_e, opt, params, batch, jax.random.fold_in(KEY, i))
        end = float(avg_energy_per_mac(to_energy(log_e), macs))
        assert end < start * 0.6, (start, end)
        assert jnp.isfinite(m["nll"])


def test_analog_decode_step():
    cfg = dataclasses.replace(get_smoke_config("granite-3-8b"), dtype="float32")
    mesh = make_local_mesh()
    with use_mesh(mesh):
        params = init_params(KEY, cfg)
        cache = init_cache(cfg, B, T)
        energies = init_energy_tree(cfg, 1000.0)
        a = AnalogSpec(cfg=AnalogConfig.shot(), energies=energies, key=KEY)
        tok = jnp.ones((B, 1), jnp.int32)
        logits_a, _ = decode_step(params, cache, {"tokens": tok}, 5, cfg, analog=a)
        logits_d, _ = decode_step(params, cache, {"tokens": tok}, 5, cfg)
        assert jnp.all(jnp.isfinite(logits_a))
        # at very high energy the analog decode approaches the digital one
        err = float(jnp.abs(logits_a - logits_d).max())
        scale = float(jnp.abs(logits_d).max()) + 1e-6
        assert err < 0.1 * scale, (err, scale)
