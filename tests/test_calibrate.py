"""End-to-end Eq.-14 validation on a small frozen model: learned dynamic
precision must beat uniform precision at matched energy (the paper's central
claim, Table II mechanism)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AnalogConfig,
    CalibConfig,
    SiteQuant,
    analog_dot,
    avg_energy_per_mac,
    dense_site_macs,
    eval_accuracy,
    eval_profile_accuracy,
    learn_energies,
    log_energy_penalty,
    min_energy_search,
    repeat_profile_search,
    site_key,
    to_energy,
    total_macs,
    uniform_log_energies,
)
from repro.data import make_tabular_dataset

KEY = jax.random.PRNGKey(0)
DIMS = [32, 64, 64, 8]  # 3-layer MLP


def _train_mlp(x, y, steps=1200):
    sizes = list(zip(DIMS[:-1], DIMS[1:]))
    keys = jax.random.split(KEY, len(sizes))
    params = [
        jax.random.normal(k, s, jnp.float32) / np.sqrt(s[0]) for k, s in zip(keys, sizes)
    ]

    def fwd(params, xb):
        h = xb
        for i, w in enumerate(params):
            h = h @ w
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        return h

    def loss(params, xb, yb):
        logits = fwd(params, xb)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], axis=1))

    opt = jax.jit(
        lambda p, xb, yb: jax.tree.map(
            lambda w, g: w - 0.5 * g, p, jax.grad(loss)(p, xb, yb)
        )
    )
    for i in range(steps):
        params = opt(params, x, y)
    return params


@pytest.fixture(scope="module")
def problem():
    x, y = make_tabular_dataset(4096, dim=DIMS[0], n_classes=DIMS[-1], depth=2, seed=3)
    x, y = jnp.asarray(x), jnp.asarray(y)
    params = _train_mlp(x[:3072], y[:3072])
    macs = {f"l{i}": dense_site_macs(1, a, b, per_channel=False)
            for i, (a, b) in enumerate(zip(DIMS[:-1], DIMS[1:]))}
    cfg = AnalogConfig.shot()

    def apply_fn(energies, xb, key):
        h = xb
        for i, w in enumerate(params):
            h = analog_dot(h, w, cfg=cfg, energy=energies[f"l{i}"],
                           key=site_key(jax.random.fold_in(key, i), f"l{i}"))
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        return h

    # clean accuracy
    def clean_fn(energies, xb, key):
        h = xb
        for w in params:
            h = jax.nn.relu(h @ w) if w is not params[-1] else h @ w
        return h

    clean_acc = eval_accuracy(
        lambda e, xb, k: clean_fn(e, xb, k), {}, [(x[3072:], y[3072:])], key=KEY
    )
    return dict(apply_fn=apply_fn, macs=macs, x=x, y=y, clean_acc=clean_acc)


def test_energy_learning_beats_uniform(problem):
    """At a fixed average energy/MAC budget, learned per-layer energies give
    higher noisy accuracy than the uniform allocation."""
    apply_fn, macs = problem["apply_fn"], problem["macs"]
    x, y = problem["x"], problem["y"]
    batches = [(x[i : i + 256], y[i : i + 256]) for i in range(0, 3072, 256)]
    test_batch = [(x[3072:], y[3072:])]

    # pick a budget where uniform noticeably degrades
    target = 0.1  # aJ/MAC
    uni = to_energy(uniform_log_energies(macs, target))
    acc_uni = eval_accuracy(apply_fn, uni, test_batch, key=KEY, n_noise_samples=16)

    energies, diag = learn_energies(
        apply_fn, macs, batches, key=KEY, target_e_per_mac=target,
        cfg=CalibConfig(lam=20.0, lr=0.05, steps=200, init_mult=4.0),
    )
    # budget respected within the soft-penalty slack
    assert diag["avg_e_per_mac"] <= target * 1.15
    acc_dyn = eval_accuracy(apply_fn, energies, test_batch, key=KEY, n_noise_samples=16)
    assert acc_dyn > acc_uni + 0.015, (acc_dyn, acc_uni)
    # learned allocation is non-uniform: first/last layers get more energy
    # than the middle layer (paper Fig. 6 structure)
    assert float(energies["l1"]) < float(energies["l0"])
    assert float(energies["l1"]) < float(energies["l2"])


def test_min_energy_search_dynamic_below_uniform(problem):
    """The paper's headline: minimum energy/MAC at <2% degradation is lower
    with dynamic precision than with uniform precision."""
    apply_fn, macs = problem["apply_fn"], problem["macs"]
    x, y = problem["x"], problem["y"]
    batches = [(x[i : i + 256], y[i : i + 256]) for i in range(0, 3072, 256)]
    test_batch = [(x[3072:], y[3072:])]
    clean_acc = problem["clean_acc"]

    def make_uniform(target):
        e = to_energy(uniform_log_energies(macs, target))
        return e, float(avg_energy_per_mac(e, macs))

    def make_dynamic(target):
        e, d = learn_energies(
            apply_fn, macs, batches, key=KEY, target_e_per_mac=target,
            cfg=CalibConfig(lam=20.0, lr=0.05, steps=120, init_mult=4.0),
        )
        return e, d["avg_e_per_mac"]

    def acc_fn(energies):
        return eval_accuracy(apply_fn, energies, test_batch, key=KEY, n_noise_samples=8)

    res_uni = min_energy_search(
        make_uniform, acc_fn, float_acc=clean_acc, lo=1e-4, hi=10.0, max_iters=7
    )
    res_dyn = min_energy_search(
        make_dynamic, acc_fn, float_acc=clean_acc, lo=1e-4, hi=10.0, max_iters=7
    )
    assert res_dyn.accuracy >= clean_acc - 0.02
    assert res_dyn.achieved_e_per_mac < res_uni.achieved_e_per_mac, (
        res_dyn.achieved_e_per_mac,
        res_uni.achieved_e_per_mac,
    )


def test_lo_feasible_result_comes_from_one_probe():
    """Regression: when the lo probe is feasible, the result must be one
    coherent probe — previously it reported target=lo with acc/achieved/
    artifact unpacked from the best-by-achieved probe, which can be the hi
    probe when a calibration-style make_fn undershoots its target there."""
    seen = {}

    def make(target):
        # achieved energy DECREASES in the target: hi undershoots lo
        art = {"target": target}
        seen[target] = art
        return art, 10.0 / target

    res = min_energy_search(
        make, lambda art: 0.9, float_acc=0.9, max_degradation=0.02,
        lo=1.0, hi=10.0,
    )
    # best feasible probe is hi (achieved 1.0 < lo's achieved 10.0): every
    # field must come from it, never a lo/hi mix
    assert res.min_e_per_mac == 10.0
    assert res.achieved_e_per_mac == 1.0
    assert res.accuracy == 0.9
    assert res.artifact is seen[10.0]
    assert res.trace == [(10.0, 0.9, 1.0), (1.0, 0.9, 10.0)]

    # sanity: when lo genuinely achieves less, lo is reported whole
    res2 = min_energy_search(
        lambda t: ({"target": t}, t), lambda art: 0.9, float_acc=0.9,
        max_degradation=0.02, lo=1.0, hi=10.0,
    )
    assert res2.min_e_per_mac == 1.0
    assert res2.achieved_e_per_mac == 1.0
    assert res2.artifact["target"] == 1.0


def test_repeat_profile_search_on_trained_mlp(problem):
    """Learn a per-layer K schedule over fixed per-site energies: at a noisy
    budget the greedy search must keep the accuracy floor while pricing in
    below the uniform max-K schedule — the serving-side analogue of the
    dynamic-beats-uniform result, with eval_profile_accuracy (scaled
    energies == K repeats on the jnp path) as the oracle."""
    apply_fn, macs = problem["apply_fn"], problem["macs"]
    x, y = problem["x"], problem["y"]
    test_batch = [(x[3072:], y[3072:])]
    clean_acc = problem["clean_acc"]
    sites = sorted(macs)
    # base energy where K=1 degrades past the floor but uniform K=8 recovers
    # it: the search has real room to trade per-layer precision for energy
    energies = to_energy(uniform_log_energies(macs, 1.0))

    def acc_fn(reps):
        rep_tree = {s: k for s, k in zip(sites, reps)}
        return eval_profile_accuracy(
            apply_fn, energies, rep_tree, test_batch, key=KEY, n_noise_samples=8
        )

    weights = tuple(float(energies[s] * macs[s]) for s in sites)
    res = repeat_profile_search(
        acc_fn, n_layers=len(sites), float_acc=clean_acc,
        k_levels=(1, 2, 4, 8), weights=weights,
    )
    assert res.feasible
    assert res.accuracy >= clean_acc - 0.02
    assert res.cost <= res.uniform_cost
    # the uniform max-K start must itself have been feasible and the search
    # monotone: re-evaluating the learned schedule reproduces its accuracy
    assert acc_fn(res.repeats) == res.accuracy


def test_warm_start_plumbing_leaves_search_unchanged(problem):
    """A make_fn that accepts ``init`` gets the best feasible probe's
    artifact threaded in — and for a make_fn whose output doesn't depend on
    it (uniform allocation), the search trajectory and result are identical
    to the cold path."""
    apply_fn, macs = problem["apply_fn"], problem["macs"]
    x, y = problem["x"], problem["y"]
    test_batch = [(x[3072:], y[3072:])]
    clean_acc = problem["clean_acc"]
    seen_inits = []

    def make_cold(target):
        e = to_energy(uniform_log_energies(macs, target))
        return e, float(avg_energy_per_mac(e, macs))

    def make_warm(target, init=None):
        seen_inits.append(init)
        return make_cold(target)

    def acc_fn(energies):
        return eval_accuracy(apply_fn, energies, test_batch, key=KEY, n_noise_samples=4)

    kw = dict(float_acc=clean_acc, lo=1e-4, hi=10.0, max_iters=5)
    res_cold = min_energy_search(make_cold, acc_fn, **kw)
    res_warm = min_energy_search(make_warm, acc_fn, **kw)
    assert res_warm.trace == res_cold.trace
    assert res_warm.min_e_per_mac == res_cold.min_e_per_mac
    assert res_warm.achieved_e_per_mac == res_cold.achieved_e_per_mac
    # first probe is cold; once a feasible allocation exists it is threaded
    assert seen_inits[0] is None
    assert any(i is not None for i in seen_inits[1:])


def test_eval_accuracy_vectorized_matches_loop(problem):
    """The vmapped-noise eval must reproduce the per-sample loop exactly."""
    apply_fn, macs = problem["apply_fn"], problem["macs"]
    x, y = problem["x"], problem["y"]
    batches = [(x[3072:3456], y[3072:3456]), (x[3456:3840], y[3456:3840])]
    energies = to_energy(uniform_log_energies(macs, 0.5))

    def loop_eval(n):
        fwd = jax.jit(apply_fn)
        correct = total = 0
        for bi, (xb, yb) in enumerate(batches):
            for s in range(n):
                k = jax.random.fold_in(jax.random.fold_in(KEY, bi), s)
                pred = jnp.argmax(fwd(energies, xb, k), axis=-1)
                correct += int(jnp.sum(pred == yb))
                total += int(yb.size)
        return correct / total

    # n=1/5 take the vmap branch, n=9 the memory-bounded lax.map branch
    for n in (1, 5, 9):
        got = eval_accuracy(apply_fn, energies, batches, key=KEY, n_noise_samples=n)
        assert got == loop_eval(n), n


def test_penalty_pulls_energy_down(problem):
    apply_fn, macs = problem["apply_fn"], problem["macs"]
    x, y = problem["x"], problem["y"]
    batches = [(x[:256], y[:256])]
    energies, diag = learn_energies(
        apply_fn, macs, batches, key=KEY, target_e_per_mac=0.01,
        cfg=CalibConfig(lam=20.0, lr=0.05, steps=200, init_mult=16.0),
    )
    # started at 16x the budget (0.16 avg); the log-penalty must pull the
    # total meaningfully toward the budget against the NLL gradient
    assert diag["avg_e_per_mac"] < 0.1
