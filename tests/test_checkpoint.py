"""Checkpoint store: roundtrip, atomicity, corruption handling, retention."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


@pytest.fixture
def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16) * 1.5, "d": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip_bitexact(tmp_path, tree):
    save_checkpoint(str(tmp_path), 5, tree)
    step, restored = restore_checkpoint(str(tmp_path), template=tree)
    assert step == 5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == np.asarray(b).dtype or str(a.dtype) == str(b.dtype)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bfloat16_preserved(tmp_path):
    t = {"w": (jnp.arange(7, dtype=jnp.float32) * 0.3).astype(jnp.bfloat16)}
    save_checkpoint(str(tmp_path), 0, t)
    _, r = restore_checkpoint(str(tmp_path), template=t)
    assert np.asarray(r["w"]).dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(t["w"]).view(np.uint16),
                                  np.asarray(r["w"]).view(np.uint16))


def test_latest_skips_corrupt(tmp_path, tree):
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    # corrupt step 2's shard: latest must fall back to step 1
    shard = os.path.join(str(tmp_path), "step_000000002", "shard_00000.ckpt")
    with open(shard, "r+b") as f:
        f.seek(10)
        f.write(b"\x00\x00\x00\x00")
    assert latest_step(str(tmp_path)) == 1


def test_missing_manifest_invalid(tmp_path, tree):
    save_checkpoint(str(tmp_path), 3, tree)
    os.remove(os.path.join(str(tmp_path), "step_000000003", "MANIFEST.json"))
    assert latest_step(str(tmp_path)) is None


def test_manager_async_and_retention(tmp_path, tree):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, blocking=False)
    mgr.wait()
    steps = sorted(int(n[5:]) for n in os.listdir(str(tmp_path)) if n.startswith("step_"))
    assert steps == [3, 4]
    got = mgr.restore_latest(tree)
    assert got is not None and got[0] == 4


def test_restore_template_structure(tmp_path, tree):
    save_checkpoint(str(tmp_path), 0, tree)
    _, r = restore_checkpoint(str(tmp_path), template=tree)
    assert jax.tree.structure(r) == jax.tree.structure(tree)
