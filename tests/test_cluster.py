"""Replicated serving cluster: router validation, healthy routing,
crash failover with bit-identical re-dispatch, hang suspect/recover
hysteresis (no flapping), degraded-replica quarantine, hedged dispatch
cancellation, engine cancel(), the MetricsFeed heartbeat/replica_id
schema regression, and the cluster power-budget governor's rebalance."""
import json

import jax
import numpy as np
import pytest

from repro.core import AnalogConfig
from repro.models import init_energy_tree, init_params
from repro.serving import (
    ClusterRouter,
    Failed,
    MetricsFeed,
    ReplicaCrash,
    ReplicaDegraded,
    ReplicaHang,
    RequestFailure,
    ServingEngine,
)
from repro.serving.cluster import DEAD, DEGRADED, HEALTHY, SUSPECT
from test_policy import MODEL, _policy, _prompts
from test_serving import ENERGY_AJ, SB

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def env():
    params = init_params(KEY, MODEL)
    energies = init_energy_tree(MODEL, ENERGY_AJ)
    return dict(params=params, energies=energies)


def _engine(env, *, policy=None, pool_slots=2, **kw):
    kw.setdefault("max_gen", 6)
    kw.setdefault("max_wait", 0.0)
    return ServingEngine(
        env["params"], MODEL, analog_cfg=AnalogConfig.shot(),
        energies=env["energies"], max_batch=4, batch_buckets=(1, 2, 4),
        seq_buckets=(SB,), continuous=True, pool_slots=pool_slots,
        k_ladder=(1, 2, 4), policy=policy, **kw,
    )


def _cluster(env, n=2, *, pool_slots=2, policy=None, **kw):
    kw.setdefault("backoff_jitter", 0)  # deterministic retry rounds
    engines = [
        _engine(env, pool_slots=pool_slots, policy=policy) for _ in range(n)
    ]
    return ClusterRouter(engines, **kw)


def _entries(n, seed=3):
    """(prompt, tier) pairs mixing the k ladder."""
    tiers = (1, 2, 4)
    return [
        (p, tiers[i % len(tiers)])
        for i, p in enumerate(_prompts(n, seed=seed))
    ]


def _solo_reference(env, entries, *, seed=0):
    """Serve the same (prompt, tier) list on a standalone engine with the
    router's key derivation — the bit-identity oracle: per-request stacked
    keys make tokens a function of (prompt, tier, key) only, so ANY
    replica assignment must reproduce these rows exactly."""
    eng = _engine(env)
    base = jax.random.PRNGKey(seed)
    uid_to_cuid = {}
    for cuid, (prompt, tier) in enumerate(entries):
        uid = eng.submit(
            prompt, tier=tier, now=0.0, key=jax.random.fold_in(base, cuid),
        )
        uid_to_cuid[uid] = cuid
    results, t = {}, 0.0
    for _ in range(400):
        if not eng.n_in_flight:
            break
        t += 0.01
        results.update(eng.pump_step(now=t))
    assert not eng.n_in_flight
    return {uid_to_cuid[u]: np.asarray(v) for u, v in results.items()}


# --------------------------------------------------------------------------
# validation
# --------------------------------------------------------------------------


def test_cluster_validation(env):
    with pytest.raises(ValueError, match="at least one"):
        ClusterRouter([])
    batch_eng = ServingEngine(
        env["params"], MODEL, max_batch=2, batch_buckets=(1, 2),
        seq_buckets=(SB,), max_gen=4,
    )
    with pytest.raises(ValueError, match="continuous"):
        ClusterRouter([batch_eng])
    with pytest.raises(ValueError, match="dead_after"):
        _cluster(env, 1, suspect_after=3, dead_after=3)
    with pytest.raises(ValueError, match="drift_band"):
        _cluster(env, 1, drift_band=(1.1, 1.4))
    with pytest.raises(ValueError, match="hedge_slack"):
        _cluster(env, 1, hedge_slack=0.0)
    with pytest.raises(ValueError, match="replica 4"):
        _cluster(env, 2, faults=(ReplicaCrash(replica=4, at=0),))
    with pytest.raises(ValueError, match="power_budget"):
        _cluster(env, 1, power_budget_aj=0.0)


def test_replica_fault_validation():
    with pytest.raises(ValueError, match="replica"):
        ReplicaCrash(replica=-1, at=0)
    with pytest.raises(ValueError, match="round"):
        ReplicaCrash(replica=0, at=-2)
    with pytest.raises(ValueError, match="steps"):
        ReplicaHang(replica=0, at=0, steps=0)
    with pytest.raises(ValueError, match="scale"):
        ReplicaDegraded(replica=0, at=0, scale=1.0)
    with pytest.raises(ValueError, match="scale"):
        ReplicaDegraded(replica=0, at=0, scale=-0.5)


# --------------------------------------------------------------------------
# healthy routing: load balance + bit-identity with a solo engine
# --------------------------------------------------------------------------


def test_healthy_cluster_matches_solo_engine(env):
    entries = _entries(6)
    cluster = _cluster(env, 2, seed=0)
    for prompt, tier in entries:
        cluster.submit(prompt, tier=tier, now=0.0)
    results, _ = cluster.run_until_drained(0.0)
    assert set(results) == set(range(len(entries)))
    assert cluster.stats["delivered"] == len(entries)
    assert cluster.stats["failed"] == 0
    assert cluster.stats["prefix_mismatches"] == 0
    assert cluster.health == {0: HEALTHY, 1: HEALTHY}
    # both replicas actually served traffic (least-loaded routing)
    assert all(h.dispatched > 0 for h in cluster.replicas)
    # replica assignment is invisible in the tokens: per-request keys
    ref = _solo_reference(env, entries, seed=0)
    for cuid, toks in results.items():
        np.testing.assert_array_equal(np.asarray(toks), ref[cuid])


def test_results_land_in_router_results_map(env):
    cluster = _cluster(env, 2)
    cuid = cluster.submit(_prompts(1)[0], tier=2, now=0.0)
    results, _ = cluster.run_until_drained(0.0)
    assert cuid in results and cuid in cluster.results
    np.testing.assert_array_equal(results[cuid], cluster.results[cuid])


# --------------------------------------------------------------------------
# crash failover: zero lost requests, bit-identical re-dispatch
# --------------------------------------------------------------------------


def test_crash_failover_bit_identical(env):
    entries = _entries(9)
    cluster = _cluster(
        env, 3, seed=0, suspect_after=2, dead_after=4,
        faults=(ReplicaCrash(replica=0, at=2),),
    )
    for prompt, tier in entries:
        cluster.submit(prompt, tier=tier, now=0.0)
    assert cluster.replicas[0].dispatched > 0  # the crash orphans real work
    results, _ = cluster.run_until_drained(0.0)
    # zero lost: every cluster uid resolves, with tokens (no deadlines set)
    assert set(results) == set(range(len(entries)))
    assert all(isinstance(v, np.ndarray) for v in results.values())
    assert cluster.stats["failed"] == 0
    assert cluster.health[0] == DEAD
    assert cluster.stats["replicas_dead"] == 1
    assert cluster.stats["failed_over"] > 0
    assert cluster.stats["redispatched"] > 0
    # the determinism contract: re-served streams reproduced any already-
    # streamed prefix bit-identically, and every row matches the solo run
    assert cluster.stats["prefix_mismatches"] == 0
    ref = _solo_reference(env, entries, seed=0)
    for cuid, toks in results.items():
        np.testing.assert_array_equal(np.asarray(toks), ref[cuid])
    ev_kinds = [e["kind"] for e in cluster.events]
    assert "crash_injected" in ev_kinds and "failover" in ev_kinds


def test_all_replicas_dead_fails_structurally(env):
    cluster = _cluster(
        env, 1, dead_after=3,
        faults=(ReplicaCrash(replica=0, at=1),),
    )
    cuid = cluster.submit(_prompts(1)[0], tier=1, now=0.0)
    t = 0.0
    results = {}
    for _ in range(30):
        t += 0.01
        results.update(cluster.pump_step(now=t))
        if cuid in results:
            break
    # never silently lost: a structured Failed names the cause
    assert isinstance(results[cuid], Failed)
    assert "no live replicas" in results[cuid].detail
    assert cluster.stats["failed"] == 1 and cluster.n_in_flight == 0


def test_redispatch_budget_bounded(env):
    # every replica crashed except one that refuses via a full queue is
    # hard to stage; instead exhaust the budget directly: max_redispatch=0
    # means an orphaned request fails rather than retrying forever
    cluster = _cluster(
        env, 2, dead_after=3, max_redispatch=0, backoff_rounds=0,
        faults=(ReplicaCrash(replica=0, at=0), ReplicaCrash(replica=1, at=0)),
    )
    cuid = cluster.submit(_prompts(1)[0], tier=1, now=0.0)
    results, _ = cluster.run_until_drained(0.0, max_rounds=50)
    assert isinstance(results[cuid], RequestFailure)


# --------------------------------------------------------------------------
# hang: suspect -> recover with hysteresis, no failover, no flapping
# --------------------------------------------------------------------------


def test_hang_suspects_then_recovers_without_failover(env):
    entries = _entries(6)
    cluster = _cluster(
        env, 2, suspect_after=2, dead_after=8, recover_after=2,
        faults=(ReplicaHang(replica=1, at=1, steps=3),),
    )
    for prompt, tier in entries:
        cluster.submit(prompt, tier=tier, now=0.0)
    states = []
    t, results = 0.0, {}
    for _ in range(400):
        if not cluster.n_in_flight and cluster.health[1] == HEALTHY:
            break
        t += 0.01
        results.update(cluster.pump_step(now=t))
        states.append(cluster.health[1])
    # the stall was transient: suspected, then recovered — never dead
    assert SUSPECT in states and DEAD not in states
    assert cluster.health[1] == HEALTHY
    assert cluster.stats["failed_over"] == 0
    assert cluster.stats["replicas_dead"] == 0
    # hysteresis: exactly one suspect episode, no flapping
    transitions = [
        (e["frm"], e["to"]) for e in cluster.events if e["kind"] == "health"
    ]
    assert transitions == [(HEALTHY, SUSPECT), (SUSPECT, HEALTHY)]
    # nothing was lost to the stall
    assert set(results) == set(range(len(entries)))
    assert cluster.stats["prefix_mismatches"] == 0


# --------------------------------------------------------------------------
# degradation: drift quarantine re-routes queued work to nominal replicas
# --------------------------------------------------------------------------


def test_degraded_replica_quarantines_queued_work(env):
    # pool_slots=1 keeps most of replica 0's initial share *queued* when
    # the drift trips, so the quarantine has real work to pull back
    cluster = _cluster(
        env, 2, pool_slots=1, drift_patience=2, recover_after=2,
        faults=(ReplicaDegraded(replica=0, at=0, scale=2.5),),
    )
    entries = _entries(8)
    for prompt, tier in entries:
        cluster.submit(prompt, tier=tier, now=0.0)
    results, t = cluster.run_until_drained(0.0)
    assert set(results) == set(range(len(entries)))  # zero lost
    assert cluster.stats["replicas_degraded"] == 1
    assert cluster.stats["quarantined"] > 0
    assert cluster.health[0] == DEGRADED  # drift persists until recalibrated
    # traffic submitted after detection routes around the degraded replica
    # entirely, so it must match the solo nominal run bit-for-bit
    before = cluster.replicas[0].dispatched
    late = [(p, 1) for p in _prompts(3, seed=11)]
    late_uids = [cluster.submit(p, tier=tr, now=t) for p, tr in late]
    late_results, t = cluster.run_until_drained(t)
    assert cluster.replicas[0].dispatched == before
    ref = _solo_reference(env, late, seed=0)
    # the late requests' keys fold their *cluster* uid, not the list index
    for i, cuid in enumerate(late_uids):
        eng = _engine(env)
        uid = eng.submit(
            late[i][0], tier=late[i][1], now=0.0,
            key=jax.random.fold_in(jax.random.PRNGKey(0), cuid),
        )
        tt, solo = 0.0, {}
        while eng.n_in_flight:
            tt += 0.01
            solo.update(eng.pump_step(now=tt))
        np.testing.assert_array_equal(
            np.asarray(late_results[cuid]), np.asarray(solo[uid])
        )
    # recalibration walks the replica back to healthy with hysteresis
    cluster.clear_degradation(0)
    for _ in range(6):
        t += 0.01
        cluster.pump_step(now=t)
    assert cluster.health[0] == HEALTHY


# --------------------------------------------------------------------------
# hedged dispatch (satellite: cancellation tests)
# --------------------------------------------------------------------------


def test_hedged_dispatch_winner_once_loser_cancelled(env):
    cluster = _cluster(env, 2)
    prompt = _prompts(1)[0]
    cuid = cluster.submit(prompt, tier=2, now=0.0, hedge=True)
    assert cluster.stats["hedges"] == 1
    assert cluster.stats["dispatches"] == 2  # primary + backup placed
    results, t = cluster.run_until_drained(0.0)
    # winner delivered exactly once
    assert list(results) == [cuid]
    assert cluster.stats["delivered"] == 1
    assert (
        cluster.stats["hedge_wins_primary"] + cluster.stats["hedge_wins_backup"]
    ) == 1
    # loser withdrawn (cancelled mid-flight) or discarded after the fact —
    # never delivered as a second result
    assert (
        cluster.stats["hedge_cancelled"] + cluster.stats["duplicates_discarded"]
    ) >= 1
    # the duplicate was provably identical: same key, same tokens
    ref = _solo_reference(env, [(prompt, 2)], seed=0)
    np.testing.assert_array_equal(np.asarray(results[cuid]), ref[0])
    # keep pumping: the loser's ghost never re-delivers
    for _ in range(5):
        t += 0.01
        assert cluster.pump_step(now=t) == {}
    assert cluster.stats["delivered"] == 1
    assert cluster.stats["prefix_mismatches"] == 0


def test_hedge_counts_one_serve_in_journal(env):
    cluster = _cluster(env, 2)
    cuid = cluster.submit(_prompts(1)[0], tier=1, now=0.0, hedge=True)
    cluster.run_until_drained(0.0)
    entry = cluster.journal[cuid]
    assert entry.done and entry.hedge_uid is None
    # the journal converged on one primary assignment (the winner)
    assert entry.replica is not None
    # engine-side: both replicas saw a submission, the cluster served once
    total_requests = sum(
        h.engine.stats["requests"] for h in cluster.replicas
    )
    assert total_requests == 2 and cluster.stats["delivered"] == 1


def test_auto_hedge_fires_on_deadline_pressure(env):
    cluster = _cluster(env, 2, hedge_slack=10.0)
    cluster.submit(
        _prompts(1)[0], tier=1, now=0.0, target_latency=5.0,
    )
    cluster.pump_step(now=0.01)  # slack 4.99 < 10: urgent from the start
    assert cluster.stats["hedges"] == 1
    results, _ = cluster.run_until_drained(0.02)
    assert cluster.stats["delivered"] == 1


def test_hedge_promoted_when_primary_replica_dies(env):
    # the hedge IS the failover path: primary replica crashes, the backup
    # copy is promoted in place — no re-dispatch, no lost request
    cluster = _cluster(
        env, 2, dead_after=3,
        faults=(ReplicaCrash(replica=0, at=1),),
    )
    prompt = _prompts(1)[0]
    cuid = cluster.submit(prompt, tier=2, now=0.0, hedge=True)
    entry = cluster.journal[cuid]
    if entry.replica != 0:
        pytest.skip("primary landed on the surviving replica")
    results, _ = cluster.run_until_drained(0.0)
    assert isinstance(results[cuid], np.ndarray)
    assert cluster.stats["hedge_promoted"] == 1
    assert cluster.stats["redispatched"] == 0
    ref = _solo_reference(env, [(prompt, 2)], seed=0)
    np.testing.assert_array_equal(np.asarray(results[cuid]), ref[0])


# --------------------------------------------------------------------------
# engine cancel() — the hedging/quarantine primitive
# --------------------------------------------------------------------------


def test_engine_cancel_queued_and_pooled(env):
    eng = _engine(env, pool_slots=1)
    prompts = _prompts(3, seed=7)
    uids = [eng.submit(p, tier=1, now=0.0) for p in prompts]
    eng.pump_step(now=0.01)  # admits one row; the rest stay queued
    pooled = next(
        rec.request.uid
        for pool in eng.pools.values()
        for s in pool.active_slots()
        for rec in [pool.record(s)]
    )
    queued = [u for u in uids if u != pooled]
    assert eng.cancel(queued[0]) is True  # withdrawn from the scheduler
    assert eng.cancel(pooled) is True  # retired mid-decode, slot freed
    assert eng.cancel(10_000) is False  # unknown uid
    assert eng.stats["cancelled"] == 2
    results = {}
    t = 0.01
    while eng.n_in_flight:
        t += 0.01
        results.update(eng.pump_step(now=t))
    # only the survivor resolves; cancelled uids never produce results
    assert set(results) == {queued[1]}
    assert eng.cancel(queued[1]) is False  # already finished
    for pool in eng.pools.values():
        assert pool.n_active == 0 and pool.allocator.n_free == pool.slots


# --------------------------------------------------------------------------
# MetricsFeed schema regression (satellite: replica_id + heartbeat_step)
# --------------------------------------------------------------------------

#: the pre-cluster sample schema, in order — old JSONL consumers index
#: these fields; the cluster fields may only APPEND after them
LEGACY_FIELDS = [
    "step", "clock", "now", "dt", "queue_depth", "in_flight", "pool_active",
    "pool_slots", "occupancy", "queue_pressure", "urgent_frac", "policy_mode",
    "noise_scale", "drift_promoted", "drift_estimate", "traces",
    "tokens_total", "tiers",
]


def test_metrics_schema_appends_cluster_fields_last(env, tmp_path):
    path = tmp_path / "metrics.jsonl"
    feed = MetricsFeed(capacity=8, jsonl_path=path, replica_id=3)
    eng = _engine(env, metrics=feed)
    eng.submit(_prompts(1)[0], tier=1, now=0.0)
    t = 0.0
    while eng.n_in_flight:
        t += 0.01
        eng.pump_step(now=t)
    sample = feed.samples()[-1]
    # backward compatibility: legacy fields first, unchanged, in order
    assert list(sample)[: len(LEGACY_FIELDS)] == LEGACY_FIELDS
    assert list(sample)[len(LEGACY_FIELDS):] == ["replica_id", "heartbeat_step"]
    assert sample["replica_id"] == 3
    # heartbeat is monotone, one tick per recorded sample
    steps = [s["heartbeat_step"] for s in feed.samples()]
    assert steps == list(range(1, len(steps) + 1))
    assert feed.heartbeat_step == steps[-1]
    # the JSONL sink carries the same schema
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert lines and all(
        list(d)[: len(LEGACY_FIELDS)] == LEGACY_FIELDS for d in lines
    )
    assert lines[-1]["heartbeat_step"] == feed.heartbeat_step


def test_metrics_replica_id_defaults_none(env):
    feed = MetricsFeed(capacity=4)
    eng = _engine(env, metrics=feed)
    eng.submit(_prompts(1)[0], tier=1, now=0.0)
    eng.pump_step(now=0.01)
    assert feed.samples()[-1]["replica_id"] is None
    assert feed.heartbeat_step >= 1


def test_router_stamps_replica_ids(env):
    cluster = _cluster(env, 3)
    assert [h.feed.replica_id for h in cluster.replicas] == [0, 1, 2]
    cluster.pump_step(now=0.01)
    assert all(h.feed.heartbeat_step == 1 for h in cluster.replicas)


# --------------------------------------------------------------------------
# cluster power-budget governor
# --------------------------------------------------------------------------


def test_cluster_governor_splits_and_rebalances_on_death(env):
    budget = 400.0
    policy = _policy(power_budget_aj=budget)
    cluster = _cluster(
        env, 2, policy=policy, power_budget_aj=budget, dead_after=3,
        faults=(ReplicaCrash(replica=0, at=2),),
    )
    for prompt, tier in _entries(6):
        cluster.submit(prompt, tier=tier, now=0.0)
    cluster.pump_step(now=0.01)
    # first round: membership rebalance, equal split at the global budget
    assert cluster.stats["rebalances"] == 1
    assert cluster.governor.split == {0: budget, 1: budget}
    for h in cluster.replicas:
        assert h.engine.governor.power_budget_aj == budget
    results, _ = cluster.run_until_drained(0.02)
    # the death re-split over the survivor — still the global budget
    assert cluster.stats["rebalances"] >= 2
    assert cluster.governor.split == {1: budget}
    assert cluster.stats["failed"] == 0


def test_cluster_governor_lends_headroom_to_demoted_replica(env):
    budget = 400.0
    policy = _policy(power_budget_aj=budget)
    cluster = _cluster(env, 2, policy=policy, power_budget_aj=budget)
    cluster.pump_step(now=0.01)
    # force one governor out of nominal and step the cluster governor
    # directly (an idle engine's own governor would promote right back
    # mid-pump): it must lend the demoted replica headroom (2:1 weights)
    # while the mean stays at the global budget
    cluster.replicas[0].engine.governor.mode = "demoted"
    cluster.governor.step(cluster.round)
    split = cluster.governor.split
    assert split[0] == pytest.approx(budget * 4 / 3)
    assert split[1] == pytest.approx(budget * 2 / 3)
    assert (split[0] + split[1]) / 2 == pytest.approx(budget)
    ev = [e for e in cluster.events if e["kind"] == "rebalance"][-1]
    assert ev["reason"] == "demotion" and ev["demoted"] == [0]
    # engines see their ceilings through the runtime override
    assert cluster.replicas[0].engine.governor.power_budget_aj == \
        pytest.approx(budget * 4 / 3)
    # recovery: back to nominal -> equal split again
    cluster.replicas[0].engine.governor.mode = "nominal"
    cluster.governor.step(cluster.round)
    assert cluster.governor.split == {0: budget, 1: budget}


def test_governor_budget_override_roundtrip(env):
    policy = _policy(power_budget_aj=100.0)
    eng = _engine(env, policy=policy)
    gov = eng.governor
    assert gov.power_budget_aj == 100.0
    gov.set_power_budget(250.0)
    assert gov.power_budget_aj == 250.0
    assert gov.config.power_budget_aj == 100.0  # config untouched
    with pytest.raises(ValueError, match="power budget"):
        gov.set_power_budget(0.0)
    gov.set_power_budget(None)  # restore the configured budget
    assert gov.power_budget_aj == 100.0
