"""Gradient compression: int8 bounds, error feedback, compressed psum."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.optim.compress import (
    ef_compress,
    ef_int8_roundtrip,
    int8_dequantize,
    int8_quantize,
)


@settings(max_examples=30, deadline=None)
@given(scale=st.floats(min_value=1e-4, max_value=1e4), seed=st.integers(0, 2**31 - 1))
def test_int8_roundtrip_error_bound(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=128) * scale, jnp.float32)
    q, s = int8_quantize(x)
    err = jnp.abs(int8_dequantize(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-7 * scale


def test_ef_bias_vanishes():
    """With error feedback, the TIME-AVERAGED compressed gradient converges
    to the true gradient (compression bias is eliminated)."""
    key = jax.random.PRNGKey(0)
    g_true = {"w": jax.random.normal(key, (64,)) * 1e-3}
    # a tiny constant gradient that int8 alone would mangle badly
    err = None
    acc = jnp.zeros((64,))
    n = 200
    for i in range(n):
        g_c, err = ef_compress(g_true, err)
        acc = acc + g_c["w"]
    mean = acc / n
    rel = float(jnp.linalg.norm(mean - g_true["w"]) / jnp.linalg.norm(g_true["w"]))
    assert rel < 0.05

    # without EF the bias persists for adversarial values
    x = {"w": jnp.full((64,), 1.0).at[0].set(300.0)}  # scale -> 300/127
    plain = ef_int8_roundtrip(x)["w"]
    assert float(jnp.abs(plain[1:] - 1.0).max()) > 0.1


def test_roundtrip_preserves_dtype_and_shape():
    g = {"a": jnp.ones((3, 5), jnp.bfloat16), "b": jnp.ones((7,), jnp.float32)}
    out = ef_int8_roundtrip(g)
    assert out["a"].shape == (3, 5) and out["a"].dtype == jnp.bfloat16
    assert out["b"].dtype == jnp.float32


def test_compressed_psum_multidevice_subprocess():
    """compressed_psum on an 8-device CPU mesh approximates the exact psum."""
    import subprocess, sys, textwrap

    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.optim.compress import compressed_psum
        mesh = jax.make_mesh((8,), ("d",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
        f = shard_map(lambda v: compressed_psum(v[0], "d")[None],
                      mesh=mesh, in_specs=P("d", None), out_specs=P("d", None))
        got = np.asarray(f(x))
        want = np.asarray(jnp.sum(x, axis=0))
        # mean-scale reconstruction: ~1 int8 step of error per participant
        rel = np.abs(got - want[None]).max() / np.abs(want).max()
        assert rel < 0.15, rel
        corr = np.corrcoef(got[0], want)[0, 1]
        assert corr > 0.999, corr
        print("OK", rel)
        """
    )
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "OK" in r.stdout
