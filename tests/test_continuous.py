"""Continuous batching invariants: the slot allocator can never alias two
requests, slot-aware admission respects per-tier free-slot accounting and
deadlines over partial pools, pooled decode retires rows the step they
finish (budget or stop id), and — the acceptance contract — a request's
tokens through a persistent decode pool are bit-identical to its solo run,
for every served family, regardless of slot index, admission step, or
pool neighbors."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AnalogConfig
from repro.models import init_energy_tree, init_params, lm
from repro.serving import (
    DecodePool,
    ExecutableCache,
    FaultPlan,
    PrecisionProfile,
    Request,
    RequestFailure,
    ServingEngine,
    SlotAllocator,
    TierScheduler,
    TransientExecutableFault,
)
from test_serving import ENERGY_AJ, FAMILY_CONFIGS, SB, _solo_tokens

KEY = jax.random.PRNGKey(0)


def _requests(n=3, lens=(7, 19, 28), gens=(2, 5, 8), vocab=128, seed=3):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab, L) for L in lens[:n]]
    keys = [jax.random.fold_in(jax.random.PRNGKey(5), i) for i in range(n)]
    return prompts, list(gens[:n]), keys


def _continuous_engine(params, cfg, *, pool_slots=2, analog=False, **kw):
    extra = {}
    if analog:
        extra = dict(
            analog_cfg=AnalogConfig.shot(),
            energies=init_energy_tree(cfg, ENERGY_AJ),
        )
    return ServingEngine(
        params, cfg, max_gen=8, max_batch=4, max_wait=1.0,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
        continuous=True, pool_slots=pool_slots, **extra, **kw,
    )


# --------------------------------------------------------------------------
# slot allocator: no double assignment, no aliasing across retire->admit
# --------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(n_slots=st.integers(1, 8), seed=st.integers(0, 2**16))
def test_slot_allocator_property(n_slots, seed):
    """Random take/release traffic: a slot is never handed out while held
    (no double assignment), releases only succeed on held slots, and the
    free+held partition always covers exactly the pool."""
    rng = np.random.default_rng(seed)
    alloc = SlotAllocator(n_slots)
    held = {}  # slot -> owning uid
    uid = 0
    for _ in range(200):
        if rng.random() < 0.55 and alloc.n_free:
            k = int(rng.integers(1, alloc.n_free + 1))
            got = alloc.take(k)
            assert len(got) == len(set(got)) == k
            assert not set(got) & set(held)  # never double-assigned
            for s in got:
                assert 0 <= s < n_slots
                held[s] = uid
                uid += 1
        elif held:
            s = int(rng.choice(sorted(held)))
            alloc.release(s)
            del held[s]
        assert alloc.n_free + len(held) == n_slots
        assert alloc.held() == set(held)
    with pytest.raises(ValueError):
        alloc.take(alloc.n_free + 1)
    if held:
        s = next(iter(held))
        alloc.release(s)
        with pytest.raises(ValueError, match="not held"):
            alloc.release(s)  # double release


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_pool_reuse_never_aliases_rows_or_keys(seed):
    """Retire->admit slot reuse through the DecodePool host state: an
    activated slot always carries its OWN request's token/position/length/
    key row, never a previous or concurrent occupant's."""
    rng = np.random.default_rng(seed)
    slots = 4
    pool = DecodePool(
        tier=1, slots=slots, cache_len=40, key_shape=(2,),
        key_dtype=np.uint32, cache=None,
    )
    uid = 0
    live = {}  # slot -> uid
    for _ in range(60):
        if rng.random() < 0.5 and pool.n_free:
            (s,) = pool.take(1)
            req = Request(
                uid=uid, tokens=np.arange(1 + uid % 7, dtype=np.int32),
                max_new_tokens=4,
            )
            pool.activate(s, req, first_token=100 + uid, key_row=[uid, uid ^ 0xFF])
            live[s] = uid
            uid += 1
        elif live:
            s = int(rng.choice(sorted(live)))
            rec = pool.retire(s)
            assert rec.request.uid == live.pop(s)
        # every live slot still holds exactly its own request's row state
        assert set(pool.active_slots()) == set(live)
        for s, u in live.items():
            assert pool.record(s).request.uid == u
            assert pool.tok[s] == 100 + u
            assert pool.lengths[s] == pool.record(s).request.prompt_len
            np.testing.assert_array_equal(pool.keys[s], [u, u ^ 0xFF])
        for s in range(slots):  # freed rows are inert length-0 pad rows
            if s not in live:
                assert pool.lengths[s] == 0
        assert len(set(live.values())) == len(live)  # no uid in two slots


# --------------------------------------------------------------------------
# scheduler: slot-aware admission
# --------------------------------------------------------------------------


def _req(uid, length, k, arrival):
    return Request(uid=uid, tokens=np.zeros(length, np.int32), n_repeats=k,
                   arrival=arrival)


def test_pop_admissible_caps_at_free_slots():
    sch = TierScheduler(max_batch=4, max_wait=10.0, seq_buckets=(32,))
    for uid in range(6):
        sch.submit(_req(uid, 8, 1, arrival=0.0))
    free = {1: 3}
    batches = sch.pop_admissible(0.0, free, force=True)
    assert [[r.uid for r in b] for b in batches] == [[0, 1, 2]]
    assert free[1] == 0 and sch.n_pending == 3
    assert sch.pop_admissible(0.0, {1: 0}, force=True) == []  # pool full
    # freed slots admit the FIFO remainder, max_batch still caps one wave
    batches = sch.pop_admissible(0.0, {1: 6}, force=True)
    assert [[r.uid for r in b] for b in batches] == [[3, 4, 5]]
    assert sch.n_pending == 0


def test_pop_admissible_deadline_over_partial_pool():
    sch = TierScheduler(max_batch=4, max_wait=5.0, seq_buckets=(32,))
    for uid in range(2):
        sch.submit(_req(uid, 8, 1, arrival=0.0))
    # not full, not aged: stays queued even though slots are free
    assert sch.pop_admissible(4.9, {1: 4}) == []
    # aged past max_wait with ONE free slot: admit what fits now, keep FIFO
    batches = sch.pop_admissible(5.0, {1: 1})
    assert [[r.uid for r in b] for b in batches] == [[0]]
    assert sch.n_pending == 1
    assert sch.pending_tiers() == {1}


def test_pop_admissible_shares_tier_slots_across_seq_buckets():
    """Two seq-bucket groups of one tier draw from the same pool: the free
    accounting spans groups, submission order first."""
    sch = TierScheduler(max_batch=4, max_wait=10.0, seq_buckets=(16, 32))
    sch.submit(_req(0, 8, 1, arrival=0.0))
    sch.submit(_req(1, 8, 1, arrival=0.0))
    sch.submit(_req(2, 30, 1, arrival=0.0))
    sch.submit(_req(3, 30, 1, arrival=0.0))
    free = {1: 3}
    batches = sch.pop_admissible(0.0, free, force=True)
    assert [[r.uid for r in b] for b in batches] == [[0, 1], [2]]
    assert free[1] == 0 and sch.n_pending == 1


# --------------------------------------------------------------------------
# pooled decode == solo run, per family (the acceptance contract)
# --------------------------------------------------------------------------

POOLED_FAMILIES = ["dense", "windowed", "griffin", "xlstm"]


@pytest.mark.parametrize("family", POOLED_FAMILIES)
def test_family_pooled_vs_solo(family):
    """3 requests with heterogeneous budgets through a 2-slot pool: the
    third is admitted mid-flight into a retired slot, yet every request's
    tokens equal its solo unpadded run (slot index, admission step, and
    neighbors are invisible)."""
    cfg = FAMILY_CONFIGS[family]
    params = init_params(KEY, cfg)
    prompts, gens, _ = _requests(vocab=cfg.vocab_size)
    eng = _continuous_engine(params, cfg, pool_slots=2)
    uids = [
        eng.submit(p, max_new_tokens=g, now=0.0) for p, g in zip(prompts, gens)
    ]
    pooled = eng.flush()
    assert eng.stats["admitted"] == 3 and eng.stats["retired"] == 3
    for uid, p, g in zip(uids, prompts, gens):
        np.testing.assert_array_equal(pooled[uid], _solo_tokens(params, cfg, p, g))


@pytest.mark.parametrize("family", ["dense", "griffin"])
def test_family_analog_pooled_matches_sync_and_solo(family):
    """Analog serving: pooled tokens == the batch-synchronous engine ==
    a solo run through the pool itself (per-request noise keys make pool
    occupancy and decode discipline invisible to the numerics)."""
    cfg = FAMILY_CONFIGS[family]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    prompts, gens, keys = _requests(vocab=cfg.vocab_size)
    pooled_eng = _continuous_engine(params, cfg, pool_slots=2, analog=True)
    uids = [
        pooled_eng.submit(p, n_repeats=2, max_new_tokens=g, key=k, now=0.0)
        for p, g, k in zip(prompts, gens, keys)
    ]
    pooled = pooled_eng.flush()

    sync_eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=8, max_batch=4, max_wait=1.0, batch_buckets=(1, 2, 4),
        seq_buckets=(SB,),
    )
    sync_uids = [
        sync_eng.submit(p, n_repeats=2, max_new_tokens=g, key=k, now=0.0)
        for p, g, k in zip(prompts, gens, keys)
    ]
    sync = sync_eng.flush()
    for pu, su in zip(uids, sync_uids):
        np.testing.assert_array_equal(pooled[pu], sync[su])

    # solo through the SAME pool (lands in slot 0, no neighbors)
    for pu, p, g, k in zip(uids, prompts, gens, keys):
        solo_uid = pooled_eng.submit(
            p, n_repeats=2, max_new_tokens=g, key=k, now=0.0
        )
        np.testing.assert_array_equal(pooled_eng.flush()[solo_uid], pooled[pu])


def test_profile_tier_pools_and_uniform_coexist():
    """A per-layer profile tier gets its own pool next to the uniform-K
    pool; both serve retrace-free on replay and match the batch-synchronous
    engine bit-for-bit."""
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    profile = PrecisionProfile((2, 1), name="lop")
    prompts, gens, keys = _requests(vocab=cfg.vocab_size)
    tiers = [{"profile": profile}, {"n_repeats": 2}, {"profile": "lop"}]

    def run(continuous):
        eng = _continuous_engine(
            params, cfg, pool_slots=2, analog=True, profiles=[profile],
        ) if continuous else ServingEngine(
            params, cfg, analog_cfg=AnalogConfig.shot(),
            energies=init_energy_tree(cfg, ENERGY_AJ), max_gen=8, max_batch=4,
            max_wait=1.0, batch_buckets=(1, 2, 4), seq_buckets=(SB,),
            profiles=[profile],
        )
        out = []
        for replay in range(2):
            uids = [
                eng.submit(p, max_new_tokens=g, key=k, now=0.0, **tier)
                for p, g, k, tier in zip(prompts, gens, keys, tiers)
            ]
            if replay == 1:
                eng.exe_cache.reset_stats()
                traces = eng.trace_count
            done = eng.flush()
            out = [done[u] for u in uids]
        assert eng.exe_cache.stats()["misses"] == 0  # steady replay: all hits
        assert eng.trace_count == traces
        return out, eng

    pooled, eng = run(continuous=True)
    assert set(eng.pools) == {"lop", 2}  # one persistent pool per tier
    sync, _ = run(continuous=False)
    for a, b in zip(pooled, sync):
        np.testing.assert_array_equal(a, b)


def test_pool_cache_len_override_and_fit_check():
    """An explicit pool_cache_len sizes the pools below the seq ladder's
    worst case; requests that can't fit a slot are rejected at submit, and
    fitting traffic still matches its solo run."""
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    prompts, _, _ = _requests(vocab=cfg.vocab_size)
    with pytest.raises(ValueError, match="pool_cache_len"):
        _continuous_engine(params, cfg, pool_cache_len=SB)  # <= min bucket
    eng = _continuous_engine(params, cfg, pool_cache_len=SB + 4)
    assert eng.pool_cache_len == SB + 4
    with pytest.raises(ValueError, match="decode"):
        eng.submit(prompts[0], max_new_tokens=8, now=0.0)  # 32+8 > 36
    uid = eng.submit(prompts[0], max_new_tokens=4, now=0.0)  # 32+4 fits
    np.testing.assert_array_equal(
        eng.flush()[uid], _solo_tokens(params, cfg, prompts[0], 4)
    )


def test_moe_continuous_rejected():
    """MoE keeps the batch-synchronous path: expert noise is batch-level,
    so in-flight admission would change a request's noise mid-stream."""
    cfg = FAMILY_CONFIGS["moe"]
    params = init_params(KEY, cfg)
    with pytest.raises(ValueError, match="moe"):
        ServingEngine(params, cfg, continuous=True)


# --------------------------------------------------------------------------
# early retirement: stop tokens and budgets, both decode disciplines
# --------------------------------------------------------------------------


@pytest.mark.parametrize("continuous", [False, True])
def test_stop_tokens_retire_early(continuous):
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    prompts, _, _ = _requests(vocab=cfg.vocab_size)
    full = _solo_tokens(params, cfg, prompts[2], 8)
    stop = int(full[3])
    kw = dict(continuous=True, pool_slots=4) if continuous else {}
    eng = ServingEngine(
        params, cfg, max_gen=8, max_batch=4, max_wait=1.0,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,), **kw,
    )
    tokens_before = eng.stats["tokens_generated"]
    u_stop = eng.submit(prompts[2], max_new_tokens=8, stop_tokens=(stop,), now=0.0)
    u_free = eng.submit(prompts[2], max_new_tokens=8, now=0.0)
    out = eng.flush()
    # the stop id is the LAST emitted token; the no-stop twin runs out its
    # budget untouched (batch-mates don't inherit each other's stops)
    np.testing.assert_array_equal(out[u_stop], full[:4])
    np.testing.assert_array_equal(out[u_free], full)
    assert eng.stats["tokens_generated"] - tokens_before == 4 + 8


def test_stop_token_at_first_token_and_budget_one():
    """A stop id emitted at prefill (or a 1-token budget) finishes the
    request without ever occupying a decode slot."""
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    prompts, _, _ = _requests(vocab=cfg.vocab_size)
    first = int(_solo_tokens(params, cfg, prompts[0], 1)[0])
    eng = _continuous_engine(params, cfg, pool_slots=2)
    u0 = eng.submit(prompts[0], max_new_tokens=8, stop_tokens=(first,), now=0.0)
    u1 = eng.submit(prompts[1], max_new_tokens=1, now=0.0)
    out = eng.flush()
    np.testing.assert_array_equal(out[u0], [first])
    assert out[u1].shape == (1,)
    assert eng.stats["decode_steps"] == 0  # nothing ever decoded
    assert all(p.n_active == 0 for p in eng.pools.values())


def test_legacy_batch_early_exit_on_all_stopped():
    """Batch-synchronous EOS: once every row has hit its budget or stop id,
    the batch stops decoding (no more wasted steps) and tokens_generated
    counts actual emissions."""
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    prompts, _, _ = _requests(vocab=cfg.vocab_size)
    refs = [_solo_tokens(params, cfg, p, 8) for p in prompts[:2]]
    stops = [int(refs[0][1]), int(refs[1][2])]
    eng = ServingEngine(
        params, cfg, max_gen=8, max_batch=4, max_wait=1.0,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
    )
    uids = [
        eng.submit(p, max_new_tokens=8, stop_tokens=(s,), now=0.0)
        for p, s in zip(prompts[:2], stops)
    ]
    out = eng.flush()
    np.testing.assert_array_equal(out[uids[0]], refs[0][:2])
    np.testing.assert_array_equal(out[uids[1]], refs[1][:3])
    assert eng.stats["decode_steps"] == 2  # stopped at the slowest row, not 7
    assert eng.stats["tokens_generated"] == 2 + 3


# --------------------------------------------------------------------------
# throughput structure, pump_step API, cache insert, LRU
# --------------------------------------------------------------------------


def test_continuous_uses_fewer_decode_row_slots_and_stays_compiled():
    """Heterogeneous budgets: the pool dispatches strictly less decode work
    (row-slots) than run-to-completion batching of the same traffic, with
    identical outputs and zero steady-state retraces on replay."""
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    rng = np.random.default_rng(9)
    lens = rng.integers(4, SB + 1, 8)
    gens = [2, 2, 8, 2, 4, 2, 8, 2]  # one batch would decode 8 steps for all
    prompts = [rng.integers(0, cfg.vocab_size, L) for L in lens]
    keys = [jax.random.fold_in(jax.random.PRNGKey(11), i) for i in range(8)]

    outputs, slot_steps = {}, {}
    for mode, continuous in (("sync", False), ("continuous", True)):
        eng = ServingEngine(
            params, cfg, max_gen=8, max_batch=8, max_wait=1.0,
            batch_buckets=(1, 2, 4, 8), seq_buckets=(SB,),
            continuous=continuous, pool_slots=4,
        )
        for replay in range(2):
            if replay == 1:
                eng.exe_cache.reset_stats()
                traces = eng.trace_count
                before = eng.stats["decode_slot_steps"]
            uids = [
                eng.submit(p, max_new_tokens=g, key=k, now=0.0)
                for p, g, k in zip(prompts, gens, keys)
            ]
            done = eng.flush()
            outputs.setdefault(mode, [done[u] for u in uids])
        slot_steps[mode] = eng.stats["decode_slot_steps"] - before
        assert eng.exe_cache.stats()["misses"] == 0, mode
        assert eng.trace_count == traces, mode
    for a, b in zip(outputs["sync"], outputs["continuous"]):
        np.testing.assert_array_equal(a, b)
    assert slot_steps["continuous"] < slot_steps["sync"], slot_steps


def test_pump_step_drains_incrementally():
    cfg = FAMILY_CONFIGS["dense"]
    params = init_params(KEY, cfg)
    prompts, gens, _ = _requests(vocab=cfg.vocab_size)
    eng = _continuous_engine(params, cfg, pool_slots=2)
    with pytest.raises(ValueError, match="continuous"):
        ServingEngine(
            params, cfg, max_gen=8, batch_buckets=(1, 2), seq_buckets=(SB,)
        ).pump_step()
    uids = [
        eng.submit(p, max_new_tokens=g, now=0.0) for p, g in zip(prompts, gens)
    ]
    assert eng.n_in_flight == 3
    results, steps = {}, 0
    while eng.n_in_flight:
        results.update(eng.pump_step(now=1.0, force=True))
        steps += 1
        assert steps < 50
    assert set(results) == set(uids)
    assert steps > 1  # finished across iterations, not one run-to-completion
    for uid, p, g in zip(uids, prompts, gens):
        np.testing.assert_array_equal(results[uid], _solo_tokens(params, cfg, p, g))


def test_scatter_cache_rows_places_and_drops():
    cfg = FAMILY_CONFIGS["dense"]
    slots, bb, cache_len = 4, 2, 12
    dst = lm.init_cache(cfg, slots, cache_len)
    src = jax.tree.map(
        lambda a: jax.numpy.ones_like(a), lm.init_cache(cfg, bb, cache_len)
    )
    out = lm.scatter_cache_rows(cfg, dst, src, np.asarray([2, slots], np.int32))
    for leaf in jax.tree.leaves(out):
        leaf = np.asarray(leaf)  # (g, per, batch, s, kh, hd): batch axis 2
        assert (leaf[:, :, 2] == 1).all()  # row 0 of src landed in slot 2
        mask = np.ones(slots, bool)
        mask[2] = False
        assert (leaf[:, :, mask] == 0).all()  # oob row dropped, rest untouched


def test_executable_cache_lru_eviction():
    cache = ExecutableCache(max_entries=2)
    built = []

    def make(name):
        def build():
            built.append(name)
            return name

        return build

    assert cache.get("a", make("a")) == "a"
    assert cache.get("b", make("b")) == "b"
    assert cache.get("a", make("a")) == "a"  # hit refreshes "a"
    assert cache.get("c", make("c")) == "c"  # evicts LRU "b"
    assert "b" not in cache and "a" in cache and "c" in cache
    stats = cache.stats()
    assert stats["evictions"] == 1 and stats["entries"] == 2
    assert stats["max_entries"] == 2
    assert cache.get("b", make("b")) == "b"  # re-compiles: a fresh miss
    assert built == ["a", "b", "c", "b"]
    assert cache.stats()["evictions"] == 2  # "a" fell out when "b" returned
    with pytest.raises(ValueError):
        ExecutableCache(max_entries=0)
    # default stays unbounded
    unbounded = ExecutableCache()
    for i in range(10):
        unbounded.get(i, make(i))
    assert len(unbounded) == 10 and unbounded.stats()["evictions"] == 0


# --------------------------------------------------------------------------
# fault hygiene: random faults + deadlines never leak or alias slots
# --------------------------------------------------------------------------

_FAULT_ENG = []  # lazy singleton: examples share executables, not state


def _fault_engine():
    if not _FAULT_ENG:
        cfg = FAMILY_CONFIGS["dense"]
        params = init_params(KEY, cfg)
        # constructed WITH a (empty) plan so the cache fault guard is armed;
        # each example swaps in its own plan, then clears it
        _FAULT_ENG.append(
            _continuous_engine(params, cfg, pool_slots=2,
                               fault_plan=FaultPlan())
        )
    return _FAULT_ENG[0]


class _GenericExeFaultPlan(FaultPlan):
    """A plan whose scheduled executable faults raise a *generic*
    ``RuntimeError`` instead of :class:`TransientExecutableFault` — the
    unplanned mid-pump crash (driver bug, OOM, cosmic ray in the host
    code) that the engine's containment must treat like any other
    executable failure: the fault fires pre-dispatch, so no donated
    buffer is consumed, no pool slot leaks or aliases, and the affected
    requests retire-or-requeue exactly once."""

    def check_executable(self, key) -> None:
        try:
            super().check_executable(key)
        except TransientExecutableFault as e:
            raise RuntimeError(
                f"unplanned executable crash: {e.phase} call #{e.call_index}"
            ) from None


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_faulted_pool_accounting_property(seed):
    """Random stalls, executable faults (transient AND generic unplanned
    exceptions), poisoned rows, and tight deadlines over continuous
    traffic: every submitted uid resolves exactly once (tokens or a
    structured RequestFailure), nothing hangs, and after the drain every
    pool's slots are fully free with the scheduler empty — faults may
    fail requests but can never leak or alias a slot."""
    rng = np.random.default_rng(seed)
    eng = _fault_engine()
    cfg = eng.model_cfg
    c0 = eng._fault_clock  # plans are scheduled relative to the live clock
    # half the examples raise generic exceptions at the same injection
    # points: containment must not depend on the fault's type
    plan_cls = _GenericExeFaultPlan if rng.random() < 0.5 else FaultPlan
    errs0 = eng.stats["exe_errors"]
    plan = plan_cls(
        seed=seed,
        stall_steps=tuple(c0 + int(o) for o in rng.integers(0, 14, 3)),
        exe_faults=tuple(
            ("decode", int(n)) for n in rng.choice(12, 2, replace=False)
        ) + ((("prefill", int(rng.integers(0, 3))),) if rng.random() < 0.5
             else ()),
        poison={(c0 + int(rng.integers(0, 10)), int(rng.integers(0, 2))): -7}
        if rng.random() < 0.5 else (),
    )
    eng.fault_plan = plan
    try:
        n = int(rng.integers(2, 5))
        uids = []
        for i in range(n):
            prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(1, SB)))
            deadline = float(rng.uniform(0.002, 0.02)) if rng.random() < 0.4 \
                else None
            uids.append(eng.submit(
                prompt, max_new_tokens=int(rng.integers(1, 9)),
                now=0.0, deadline=deadline,
            ))
        results, t, steps = {}, 0.0, 0
        while eng.n_in_flight:
            t += 1e-3
            for uid, res in eng.pump_step(now=t, force=True).items():
                assert uid not in results  # resolved at most once
                results[uid] = res
            steps += 1
            assert steps < 500, "faulted drain hung"
    finally:
        eng.fault_plan = FaultPlan()  # disarm for the next example
    assert set(results) == set(uids)  # every uid resolved exactly once
    # generic exceptions route through the containment path, not retries
    exe_fired = sum(1 for e in plan.log if e["site"] == "executable")
    if plan_cls is _GenericExeFaultPlan and exe_fired:
        assert eng.stats["exe_errors"] >= errs0 + 1
    for res in results.values():
        if isinstance(res, RequestFailure):
            assert res.detail and not res.ok
        else:
            assert isinstance(res, np.ndarray) and res.dtype == np.int32
    # slot hygiene: nothing leaked, nothing half-held, scheduler empty
    assert eng.scheduler.n_pending == 0 and eng.n_in_flight == 0
    for pool in eng.pools.values():
        assert pool.n_active == 0
        assert pool.allocator.n_free == pool.slots
        assert not pool.allocator.held()
        assert (np.asarray(pool.lengths) == 0).all()  # all rows inert
