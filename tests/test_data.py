"""Data pipeline: determinism, rank disjointness, prefetch restart."""
import numpy as np

from repro.data.pipeline import DataPipeline, TokenTaskConfig, markov_batch


CFG = TokenTaskConfig(vocab_size=128, seq_len=16, global_batch=8, seed=9)


def test_batch_is_pure_function_of_step():
    a = markov_batch(CFG, 7)
    b = markov_batch(CFG, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = markov_batch(CFG, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_next_tokens():
    b = markov_batch(CFG, 0)
    # label t equals token t+1 by construction of the stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_ranks_disjoint_and_partition_global_batch():
    world = 4
    parts = [markov_batch(CFG, 3, rank=r, world=world) for r in range(world)]
    assert all(p["tokens"].shape[0] == CFG.global_batch // world for p in parts)
    flat = [p["tokens"].tobytes() for p in parts]
    assert len(set(flat)) == world  # all different


def test_markov_task_is_learnable_structure():
    """The chain restricts successors: consecutive-token pairs must hit far
    fewer distinct bigrams than a uniform random stream would."""
    b = markov_batch(TokenTaskConfig(vocab_size=64, seq_len=256, global_batch=16, seed=1), 0)
    toks = b["tokens"]
    bigrams = set(zip(toks[:, :-1].reshape(-1).tolist(), toks[:, 1:].reshape(-1).tolist()))
    assert len(bigrams) <= 64 * 4  # vocab * branching


def test_pipeline_prefetch_and_restart():
    p1 = DataPipeline(CFG, start_step=0)
    seq1 = [next(p1) for _ in range(5)]
    p1.close()
    # restart from step 3 reproduces the same batches
    p2 = DataPipeline(CFG, start_step=3)
    s, batch = next(p2)
    p2.close()
    assert s == 3
    np.testing.assert_array_equal(batch["tokens"], seq1[3][1]["tokens"])
    assert [s for s, _ in seq1] == [0, 1, 2, 3, 4]
