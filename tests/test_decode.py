"""Serving correctness: prefill+decode must agree with the full forward for
every architecture family (the cache/ring/state machinery is exact)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_smoke_config
from repro.models import decode_step, forward_hidden, init_params, prefill
from repro.models import lm

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def _f32(cfg):
    return dataclasses.replace(cfg, dtype="float32")


@pytest.mark.parametrize(
    "arch",
    ["granite-3-8b", "grok-1-314b", "llama4-maverick-400b-a17b", "recurrentgemma-2b",
     "xlstm-1.3b", "qwen2.5-32b", "musicgen-large", "internvl2-2b"],
)
def test_decode_matches_full_forward(arch):
    cfg = _f32(get_smoke_config(arch))
    if cfg.family == "moe":  # avoid capacity drops for exactness
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(KEY, cfg)

    if cfg.frontend == "frames":
        embeds = jax.random.normal(KEY, (B, T + 1, cfg.d_model), jnp.float32)
        full_batch = {"embeds": embeds}
        pre_batch = {"embeds": embeds[:, :T]}
        dec_batch = {"embeds": embeds[:, T : T + 1]}
    elif cfg.frontend == "patch":
        p = cfg.n_frontend_tokens
        toks = jax.random.randint(KEY, (B, T + 1 - p), 0, cfg.vocab_size)
        patches = jax.random.normal(KEY, (B, p, cfg.d_model), jnp.float32)
        full_batch = {"tokens": toks, "patch_embeds": patches}
        pre_batch = {"tokens": toks[:, :-1], "patch_embeds": patches}
        dec_batch = {"tokens": toks[:, -1:]}
    else:
        toks = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab_size)
        full_batch = {"tokens": toks}
        pre_batch = {"tokens": toks[:, :T]}
        dec_batch = {"tokens": toks[:, T:]}

    h_full, _ = forward_hidden(params, full_batch, cfg, mode="train")
    logits_full = lm.logits_last(params, h_full[:, -1:], cfg)

    cache, _ = prefill(params, pre_batch, cfg, cache_len=T + 1)
    logits_dec, new_cache = decode_step(params, cache, dec_batch, T, cfg)

    err = float(jnp.abs(logits_full - logits_dec).max())
    scale = float(jnp.abs(logits_full).max()) + 1e-6
    assert err < 3e-2 * scale + 1e-3, (arch, err, scale)
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_two_step_decode_chain():
    """Decode twice; compare against full forward at T+2."""
    cfg = _f32(get_smoke_config("granite-3-8b"))
    params = init_params(KEY, cfg)
    toks = jax.random.randint(KEY, (B, T + 2), 0, cfg.vocab_size)
    h_full, _ = forward_hidden(params, {"tokens": toks}, cfg, mode="train")
    want = lm.logits_last(params, h_full[:, -1:], cfg)

    cache, _ = prefill(params, {"tokens": toks[:, :T]}, cfg, cache_len=T + 2)
    _, cache = decode_step(params, cache, {"tokens": toks[:, T : T + 1]}, T, cfg)
    got, _ = decode_step(params, cache, {"tokens": toks[:, T + 1 :]}, T + 1, cfg)
    err = float(jnp.abs(want - got).max())
    assert err < 3e-2 * float(jnp.abs(want).max()) + 1e-3


def test_griffin_ring_buffer_wraps():
    """Decode far past the window: ring cache slots must stay coherent."""
    cfg = _f32(get_smoke_config("recurrentgemma-2b"))
    w = cfg.local_window
    params = init_params(KEY, cfg)
    total = w + 8  # forces wraparound
    toks = jax.random.randint(KEY, (B, total + 1), 0, cfg.vocab_size)
    h_full, _ = forward_hidden(params, {"tokens": toks}, cfg, mode="train")
    want = lm.logits_last(params, h_full[:, -1:], cfg)

    cache, _ = prefill(params, {"tokens": toks[:, :total]}, cfg, cache_len=total + 1)
    got, _ = decode_step(params, cache, {"tokens": toks[:, total:]}, total, cfg)
    err = float(jnp.abs(want - got).max())
    assert err < 3e-2 * float(jnp.abs(want).max()) + 1e-3
