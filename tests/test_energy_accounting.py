"""Energy accounting (Eq. 14 machinery) + LM energy/MAC tree consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core import (
    avg_energy_per_mac,
    log_energy_penalty,
    to_energy,
    total_energy,
    total_macs,
    uniform_log_energies,
)
from repro.models import energy_macs, init_energy_tree
from repro.models.lm import group_sites, group_structure


def test_total_energy_linear_in_energies():
    macs = {"a": jnp.asarray(100.0), "b": jnp.full((4,), 25.0)}
    e1 = {"a": jnp.asarray(2.0), "b": jnp.full((4,), 1.0)}
    t1 = float(total_energy(e1, macs))
    assert t1 == pytest.approx(200.0 + 100.0)
    e2 = jax.tree.map(lambda x: 3.0 * x, e1)
    assert float(total_energy(e2, macs)) == pytest.approx(3 * t1)


def test_uniform_energy_average_is_exact():
    macs = {"a": jnp.asarray(123.0), "b": jnp.full((7,), 5.0)}
    e = to_energy(uniform_log_energies(macs, 0.37))
    assert float(avg_energy_per_mac(e, macs)) == pytest.approx(0.37, rel=1e-5)


@settings(max_examples=25, deadline=None)
@given(target=st.floats(1e-3, 1e3), actual=st.floats(1e-3, 1e3))
def test_penalty_active_iff_over_budget(target, actual):
    macs = {"a": jnp.asarray(10.0)}
    e = {"a": jnp.asarray(actual)}
    pen = float(log_energy_penalty(e, macs, target, lam=2.0))
    if actual <= target:
        assert pen == 0.0
    else:
        assert pen == pytest.approx(2.0 * np.log(actual / target), rel=1e-4)


@pytest.mark.parametrize(
    "arch", ["granite-3-8b", "grok-1-314b", "recurrentgemma-2b", "xlstm-1.3b"]
)
def test_lm_energy_and_macs_trees_align(arch):
    cfg = get_smoke_config(arch)
    e = init_energy_tree(cfg, 2.0)
    m = energy_macs(cfg, seq_len=64)
    assert jax.tree.structure(e) == jax.tree.structure(m)
    for le, lm_ in zip(jax.tree.leaves(e), jax.tree.leaves(m)):
        assert le.shape == lm_.shape
        assert float(jnp.min(lm_)) > 0
    # uniform energies give exactly the uniform average
    assert float(avg_energy_per_mac(e, m)) == pytest.approx(2.0, rel=1e-5)


def test_lm_macs_scale_with_seq_len():
    cfg = get_smoke_config("granite-3-8b")
    m1 = energy_macs(cfg, 64)
    m2 = energy_macs(cfg, 128)
    assert float(total_macs(m2)) == pytest.approx(2 * float(total_macs(m1)), rel=1e-6)


def test_group_sites_cover_hook_sites():
    """Every site the models' hooks reference exists in the energy tree
    (exercised end-to-end by the analog train_loss in test_models via
    lm.AnalogSpec; here we sanity-check counts per family)."""
    for arch, min_sites in (("grok-1-314b", 8), ("recurrentgemma-2b", 10),
                            ("xlstm-1.3b", 5)):
        cfg = get_smoke_config(arch)
        sites = group_sites(cfg)
        assert len(sites) >= min_sites, (arch, sites)
