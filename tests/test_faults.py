"""Fault tolerance: deterministic injection (FaultPlan), structured
failure results (TimedOut/Failed), bounded retry with K-promotion, the
noise-drift watchdog, and the acceptance contract — with faults injected
(drift + transient executable failure + stalled batches + poisoned rows),
every surviving request's tokens are bit-identical to a fault-free run for
unaffected requests, expired requests time out with structured results (no
hangs, no leaked slots), and the watchdog detects injected drift within
its probe budget. The no-fault path stays bit-identical with zero
steady-state retraces."""
import jax
import numpy as np
import pytest

from repro.core import AnalogConfig
from repro.models import init_energy_tree, init_params
from repro.models.config import ModelConfig
from repro.serving import (
    DriftRamp,
    ExecutableCache,
    Failed,
    FaultPlan,
    NoiseDriftWatchdog,
    QueueFull,
    ServingEngine,
    TimedOut,
    TransientExecutableFault,
    WatchdogConfig,
)
from test_serving import ENERGY_AJ, SB

KEY = jax.random.PRNGKey(0)
MODEL = ModelConfig(
    name="fault-test", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)


@pytest.fixture(scope="module")
def env():
    params = init_params(KEY, MODEL)
    energies = init_energy_tree(MODEL, ENERGY_AJ)
    return dict(params=params, energies=energies)


def _engine(env, *, analog=True, plan=None, **kw):
    extra = {}
    if analog:
        extra = dict(analog_cfg=AnalogConfig.shot(), energies=env["energies"])
    kw.setdefault("max_gen", 8)
    kw.setdefault("max_wait", 0.0)  # instant admission on the virtual clock
    return ServingEngine(
        env["params"], MODEL, max_batch=4,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
        continuous=True, pool_slots=2, fault_plan=plan,
        k_ladder=(1, 2, 4), **extra, **kw,
    )


def _traffic(n=3, lens=(7, 19, 28), vocab=128, seed=3):
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab, L).astype(np.int32) for L in lens[:n]]
    keys = [jax.random.fold_in(jax.random.PRNGKey(5), i) for i in range(n)]
    return prompts, keys


def _serve(eng, submits, max_iters=300):
    """Submit (prompt, kwargs) pairs at t=0 and pump on a virtual clock
    until everything resolves; returns {uid: result}. Bounded iterations:
    a hang is a failure, not a timeout of the test suite."""
    uids = [eng.submit(p, now=0.0, **kw) for p, kw in submits]
    results, t = {}, 0.0
    for _ in range(max_iters):
        if not eng.n_in_flight:
            break
        t += 1e-3
        results.update(eng.poll(now=t))
    assert not eng.n_in_flight, "engine failed to drain (hang)"
    return uids, results


def _affected_uids(eng):
    """Every uid named by an injection consequence in the engine's log."""
    out = set()
    for e in eng.fault_log:
        out.update(e.get("uids", ()))
    return out


def _assert_slot_hygiene(eng):
    for pool in eng.pools.values():
        assert pool.allocator.n_free == pool.slots
        assert pool.n_active == 0
        assert (pool.lengths == 0).all()
    assert eng.scheduler.n_pending == 0


# --------------------------------------------------------------------------
# FaultPlan: deterministic, seedable, logged
# --------------------------------------------------------------------------


def test_fault_plan_schedules_are_deterministic():
    def drive(plan):
        fired = []
        for i in range(20):
            try:
                plan.check_executable(("decode", 4, 40, 2))
            except TransientExecutableFault as f:
                fired.append(("exe", f.phase, f.call_index))
            if plan.stalled(i):
                fired.append(("stall", i))
            tok = np.zeros(4, np.int32)
            for s in plan.poison_rows(i, tok):
                fired.append(("poison", i, s, int(tok[s])))
        return fired

    mk = lambda: FaultPlan(
        seed=7, exe_faults=[("decode", 3), ("decode", 11)],
        exe_fault_rate=0.1, stall_steps=(2, 5), poison={(4, 1): -9},
    )
    a, b = mk(), mk()
    assert drive(a) == drive(b)  # same seed + schedule -> same injections
    assert ("exe", "decode", 3) in drive(mk())
    assert ("stall", 2) in drive(mk()) and ("poison", 4, 1, -9) in drive(mk())
    assert a.log == b.log and len(a.log) > 0


def test_drift_ramp_shapes():
    step = DriftRamp(start=5, rate=None, max_scale=2.0)
    assert step.scale_at(4) == 1.0 and step.scale_at(5) == 2.0
    ramp = DriftRamp(start=0, rate=0.5, max_scale=3.0)
    assert ramp.scale_at(0) == 1.0
    assert ramp.scale_at(1) == 1.5
    assert ramp.scale_at(100) == 3.0
    assert FaultPlan().noise_scale_at(123) == 1.0


def test_cache_fault_hook_fires_pre_dispatch():
    calls = []

    def exe(*a):
        calls.append(a)
        return "ran"

    plan = FaultPlan(exe_faults=[("prefill", 1)])
    cache = ExecutableCache(fault_hook=plan.check_executable)
    got = cache.get(("prefill", 1, 32), lambda: exe)
    assert got(1) == "ran"  # call #0 passes through
    with pytest.raises(TransientExecutableFault):
        cache.get(("prefill", 1, 32), lambda: exe)(2)  # call #1 injected
    # the guard raised BEFORE dispatch: the executable never saw call #2
    assert calls == [(1,)]
    assert cache.stats()["hits"] == 1 and cache.stats()["misses"] == 1


# --------------------------------------------------------------------------
# submit validation + backpressure
# --------------------------------------------------------------------------


def test_submit_rejects_unservable_requests(env):
    eng = _engine(env, analog=False)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit([], now=0.0)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=0, now=0.0)
    with pytest.raises(ValueError, match="max_gen"):
        eng.submit([1, 2], max_new_tokens=eng.max_gen + 1, now=0.0)
    with pytest.raises(ValueError, match="largest seq bucket"):
        eng.submit(np.zeros(SB + 1, np.int32), now=0.0)
    assert eng.scheduler.n_pending == 0  # nothing half-enqueued


def test_queue_full_backpressure(env):
    eng = _engine(env, analog=False, max_queue=2, max_wait=0.0)
    p = np.arange(4, dtype=np.int32)
    eng.submit(p, now=0.0)
    eng.submit(p, now=0.0)
    with pytest.raises(QueueFull, match="high-water"):
        eng.submit(p, now=0.0)
    eng.poll(now=1.0)  # drain
    eng.flush()
    eng.submit(p, now=2.0)  # capacity is back


# --------------------------------------------------------------------------
# deadlines -> structured TimedOut, slots released
# --------------------------------------------------------------------------


def test_queued_deadline_times_out_with_empty_result(env):
    # max_wait keeps the lone request queued past its deadline
    eng = _engine(env, analog=False, max_wait=10.0)
    u = eng.submit(np.arange(5, dtype=np.int32), now=0.0, deadline=0.5)
    assert eng.poll(now=0.1) == {}
    res = eng.poll(now=0.6)
    assert isinstance(res[u], TimedOut) and res[u].tokens.size == 0
    assert not res[u].ok
    assert eng.stats["timed_out"] == 1
    _assert_slot_hygiene(eng)


def test_pooled_deadline_keeps_partial_prefix(env):
    prompts, keys = _traffic(1)
    # stall every decode step from clock 1 on: the request can never finish,
    # so its deadline must retire it with the partial tokens it earned
    plan = FaultPlan(stall_steps=range(1, 1000))
    eng = _engine(env, plan=plan, max_wait=0.0)
    base = _engine(env, max_wait=0.0)
    (u_b,), res_b = _serve(
        base, [(prompts[0], dict(n_repeats=2, max_new_tokens=8, key=keys[0]))]
    )
    u = eng.submit(prompts[0], n_repeats=2, max_new_tokens=8, key=keys[0],
                   now=0.0, deadline=0.004)
    res, t = {}, 0.0
    for _ in range(50):
        t += 1e-3
        res.update(eng.pump_step(now=t))
        if u in res:
            break
    r = res[u]
    assert isinstance(r, TimedOut) and 1 <= r.tokens.size < 8
    # partial output is a strict PREFIX of the fault-free tokens: timeout
    # retirement never perturbs the numerics of what was already emitted
    np.testing.assert_array_equal(r.tokens, res_b[u_b][: r.tokens.size])
    assert eng.stats["stalled_steps"] > 0
    _assert_slot_hygiene(eng)


# --------------------------------------------------------------------------
# transient executable faults -> bounded retry at a promoted K
# --------------------------------------------------------------------------


def test_transient_decode_fault_retries_promoted_and_preserves_neighbors(env):
    prompts, keys = _traffic(3)
    submits = [
        (prompts[0], dict(n_repeats=1, max_new_tokens=6, key=keys[0])),
        (prompts[1], dict(n_repeats=2, max_new_tokens=6, key=keys[1])),
        (prompts[2], dict(n_repeats=2, max_new_tokens=6, key=keys[2])),
    ]
    base_uids, base_res = _serve(_engine(env), list(submits))
    plan = FaultPlan(exe_faults=[("decode", 2)])
    eng = _engine(env, plan=plan)
    uids, res = _serve(eng, list(submits))
    assert eng.stats["exe_faults"] == 1 and eng.stats["retried"] >= 1
    affected = _affected_uids(eng)
    assert affected, "the injected fault must have hit someone"
    for u, b in zip(uids, base_uids):
        assert isinstance(res[u], np.ndarray), res[u]  # all survived (1 retry)
        if u not in affected:  # bit-identity for unaffected requests
            np.testing.assert_array_equal(res[u], base_res[b])
    # retried uniform-K requests were promoted one rung up the ladder
    entry = next(e for e in eng.fault_log if e["kind"] == "exe_fault")
    for u in entry["retried"]:
        assert entry["promoted"][u] > 1
    _assert_slot_hygiene(eng)


def test_fault_beyond_retry_budget_fails_structured(env):
    prompts, keys = _traffic(1)
    # fail every decode call: the retry also faults -> structured Failed
    plan = FaultPlan(exe_fault_rate=1.0)
    eng = _engine(env, plan=plan, max_retries=1)
    uids, res = _serve(eng, [(prompts[0], dict(n_repeats=1, max_new_tokens=4,
                                               key=keys[0]))])
    r = res[uids[0]]
    assert isinstance(r, Failed) and r.retries == 1
    assert eng.stats["failed"] == 1 and eng.stats["retried"] == 1
    _assert_slot_hygiene(eng)


def test_poisoned_row_retires_only_that_row(env):
    prompts, keys = _traffic(2, lens=(7, 19))
    submits = [
        (prompts[0], dict(n_repeats=2, max_new_tokens=8, key=keys[0])),
        (prompts[1], dict(n_repeats=2, max_new_tokens=8, key=keys[1])),
    ]
    base_uids, base_res = _serve(_engine(env), list(submits))
    # poison slot 0's readout a few steps in (token -9 is out-of-vocab)
    plan = FaultPlan(poison={(2, 0): -9})
    eng = _engine(env, plan=plan)
    uids, res = _serve(eng, list(submits))
    assert eng.stats["poisoned_rows"] == 1
    affected = _affected_uids(eng)
    assert len(affected) == 1  # per-row fault: exactly one request touched
    for u, b in zip(uids, base_uids):
        assert isinstance(res[u], np.ndarray)
        if u not in affected:
            np.testing.assert_array_equal(res[u], base_res[b])
    _assert_slot_hygiene(eng)


# --------------------------------------------------------------------------
# no-fault path: bit-identical, zero steady-state retraces
# --------------------------------------------------------------------------


def test_empty_fault_plan_is_bit_identical_and_never_retraces(env):
    prompts, keys = _traffic(3)
    submits = [
        (p, dict(n_repeats=2, max_new_tokens=g, key=k))
        for p, g, k in zip(prompts, (2, 5, 8), keys)
    ]
    base_uids, base_res = _serve(_engine(env), list(submits))
    eng = _engine(env, plan=FaultPlan())  # armed but empty: injects nothing
    uids, res = _serve(eng, list(submits))
    for u, b in zip(uids, base_uids):
        np.testing.assert_array_equal(res[u], base_res[b])
    traces = eng.trace_count
    eng.exe_cache.reset_stats()
    uids2, res2 = _serve(eng, list(submits))  # warm replay
    for u, b in zip(uids2, base_uids):
        np.testing.assert_array_equal(res2[u], base_res[b])
    assert eng.trace_count == traces  # zero steady-state retraces
    assert eng.exe_cache.stats()["hit_rate"] == 1.0
    assert eng.fault_log == [] and eng.stats["exe_faults"] == 0


# --------------------------------------------------------------------------
# noise-drift watchdog + graceful precision degradation
# --------------------------------------------------------------------------


def test_watchdog_quiet_at_nominal_and_config_validation(env):
    eng = _engine(env)
    probe = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % 128
    wd = NoiseDriftWatchdog(eng, probe, key=jax.random.PRNGKey(3))
    assert wd.baseline_rms > 0
    for step in range(0, 3 * wd.config.interval, wd.config.interval):
        assert wd.maybe_probe(step) is None  # healthy device: no events
    assert all(0.7 < e < 1.4 for _, e in wd.estimates)
    # interval honored: a mid-interval step does not probe
    n = len(wd.estimates)
    assert wd.maybe_probe(wd.estimates[-1][0] + 1) is None
    assert len(wd.estimates) == n
    with pytest.raises(ValueError, match="band"):
        WatchdogConfig(band=(1.1, 1.4))
    with pytest.raises(ValueError, match="analog"):
        NoiseDriftWatchdog(_engine(env, analog=False), probe)


def test_watchdog_detects_injected_drift_within_budget(env):
    prompts, keys = _traffic(2, lens=(7, 19))
    onset = 6  # fault-clock step the hardware jumps to 2x noise
    plan = FaultPlan(drift=DriftRamp(start=onset, rate=None, max_scale=2.0))
    eng = _engine(env, plan=plan)
    probe = np.arange(2 * 16, dtype=np.int32).reshape(2, 16) % 128
    cfg = WatchdogConfig(interval=2, n_samples=4)
    wd = NoiseDriftWatchdog(eng, probe, config=cfg, key=jax.random.PRNGKey(3))
    for i, (p, k) in enumerate(zip(prompts, keys)):
        eng.submit(p, n_repeats=2, max_new_tokens=8, key=k, now=0.0)
    event, t = None, 0.0
    for step in range(60):
        t += 1e-3
        eng.pump_step(now=t)
        if eng.n_in_flight == 0:  # keep the pools decoding under drift
            eng.submit(prompts[0], n_repeats=2, max_new_tokens=8,
                       key=keys[0], now=t)
        event = event or wd.maybe_probe(step)
        if event is not None:
            break
    assert event is not None, "watchdog missed a 2x drift"
    assert event.estimate > cfg.band[1]
    # detection budget: the drift was visible at the first probe after the
    # engine's clock crossed the onset, caught within 2 probe intervals
    assert event.step <= onset + 2 * cfg.interval
    # drift response: promote new uniform-K traffic one rung up the ladder
    eng.promote_tiers(event)
    assert eng.promoted and eng.stats["promotions"] == 1
    u = eng.submit(prompts[0], n_repeats=2, max_new_tokens=2, key=keys[0],
                   now=t + 1e-3)
    assert 4 in eng.scheduler.pending_tiers()  # K=2 -> K=4
    eng.flush()
    # recalibration: hardware repaired (stop injecting), scale re-pinned,
    # response cleared — new traffic returns to its requested tier
    eng.fault_plan = None
    eng.recalibrate()
    wd.clear()
    assert not eng.promoted and eng.noise_scale == 1.0
    assert wd.probe(step=100) is None
    assert 0.7 < wd.estimates[-1][1] < 1.4
    eng.submit(prompts[0], n_repeats=2, max_new_tokens=2, key=keys[0],
               now=t + 2e-3)
    assert 2 in eng.scheduler.pending_tiers()
    eng.flush()
    _assert_slot_hygiene(eng)


def test_drift_is_zero_retrace(env):
    """The drift factor is a runtime operand: serving through a drifting
    noise floor compiles nothing new."""
    prompts, keys = _traffic(1)
    submits = [(prompts[0], dict(n_repeats=2, max_new_tokens=8, key=keys[0]))]
    eng = _engine(env)
    _serve(eng, list(submits))  # warm the executables at nominal
    traces = eng.trace_count
    eng.exe_cache.reset_stats()
    eng.fault_plan = FaultPlan(drift=DriftRamp(start=0, rate=None, max_scale=2.0))
    uids, res = _serve(eng, list(submits))
    assert isinstance(res[uids[0]], np.ndarray)
    assert eng.trace_count == traces
    assert eng.exe_cache.stats()["hit_rate"] == 1.0
