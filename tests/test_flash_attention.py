"""Flash attention (pure-XLA, custom VJP) vs naive reference: forward,
gradients, GQA, windows, causal-skip, chunk-size invariance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import chunked_attention, decode_attention, local_attention

KEY = jax.random.PRNGKey(0)


def naive(q, k, v, causal=True, window=None):
    b, t, h, d = q.shape
    _, s, kh, _ = k.shape
    g = h // kh
    q5 = q.reshape(b, t, kh, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k) / (d**0.5)
    qp = jnp.arange(t)[:, None]
    kp = jnp.arange(s)[None, :]
    m = jnp.ones((t, s), bool)
    if causal:
        m &= qp >= kp
    if window is not None:
        m &= (qp - kp) < window
    scores = jnp.where(m[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(b, t, h, d)


@pytest.fixture(scope="module")
def qkv():
    b, t, h, kh, d = 2, 64, 4, 2, 16
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, kh, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, kh, d))
    return q, k, v


@pytest.mark.parametrize("qc,kc", [(16, 16), (32, 16), (64, 64), (16, 32)])
def test_forward_matches_naive(qkv, qc, kc):
    q, k, v = qkv
    got = chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc, causal=True)
    want = naive(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("mode", ["plain", "window", "causal_skip"])
def test_gradients_match_naive(qkv, mode):
    q, k, v = qkv
    window = 16 if mode == "window" else None
    cskip = mode == "causal_skip"

    def f(q, k, v):
        o = chunked_attention(
            q, k, v, q_chunk=16, kv_chunk=16, causal=True,
            window=window, causal_skip=cskip,
        )
        return jnp.sum(o * jnp.cos(o))  # non-trivial cotangent

    def g(q, k, v):
        o = naive(q, k, v, window=window)
        return jnp.sum(o * jnp.cos(o))

    got = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    want = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_chunk_size_invariance(qkv):
    q, k, v = qkv
    outs = [
        chunked_attention(q, k, v, q_chunk=qc, kv_chunk=kc, causal=True)
        for qc, kc in ((8, 8), (64, 64), (16, 64))
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o), atol=1e-5)


def test_local_attention_matches_naive_window(qkv):
    q, k, v = qkv
    got = local_attention(q, k, v, window=16)
    want = naive(q, k, v, window=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_decode_attention_matches_last_row(qkv):
    q, k, v = qkv
    t = q.shape[1]
    full = naive(q, k, v)
    got = decode_attention(q[:, -1:], k, v, pos=t - 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, -1:]), atol=1e-5)


def test_bf16_inputs_stable(qkv):
    q, k, v = (x.astype(jnp.bfloat16) for x in qkv)
    got = chunked_attention(q, k, v, q_chunk=16, kv_chunk=16, causal=True)
    want = naive(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), atol=0.05
    )
