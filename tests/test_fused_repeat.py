"""Fused K-repeat dynamic precision (paper §IV) + backend dispatch.

Covers the acceptance criteria of the fused-execution refactor:
  * kernel vs pure-jnp oracle agreement for every noise kind at K in
    {1, 4, 16}, including non-multiple-of-128 shapes (K-tail masking);
  * bit-exact repeat-averaged draws: tiled windows of the averaged noise
    reproduce the full-array draw exactly (the kernel/oracle contract);
  * fused K-repeat variance matches the explicit O(K) time-averaging oracle;
  * AnalogHook reaches the Pallas kernel under backend="pallas";
  * the analytic HBM traffic of the fused form is independent of K.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnalogConfig, SiteQuant
from repro.core.analog import analog_dot
from repro.core.redundant import (
    spatial_averaged_dot_explicit,
    time_averaged_dot,
    time_averaged_dot_explicit,
)
from repro.kernels import analog_matmul, analog_matmul_reference
from repro.kernels.dispatch import resolve_backend
from repro.kernels.prng import repeat_averaged_gaussian_tile, repeat_key
from repro.models.hooks import AnalogHook
from repro.quant import calibrate_minmax

KEY = jax.random.PRNGKey(23)

# deliberately ragged: exercises the K-tail masking and M/N block padding
SHAPES = [(96, 200, 72), (17, 130, 33)]


def _setup(m, k, n):
    x = jax.random.normal(KEY, (m, k))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n)) * 0.2
    sq = SiteQuant(
        wqp=calibrate_minmax(w, channel_axis=1),
        xqp=calibrate_minmax(x),
        oqp=calibrate_minmax(x @ w),
    )
    return x, w, sq


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("k_rep", [1, 4, 16])
@pytest.mark.parametrize(
    "cfg,e",
    [
        (AnalogConfig.shot(), 10.0),
        (AnalogConfig.thermal(0.01), 4.0),
        (AnalogConfig.weight(0.1), 5.0),
        (AnalogConfig(mode="analog"), 1.0),
    ],
    ids=["shot", "thermal", "weight", "none"],
)
def test_fused_kernel_matches_oracle(shape, k_rep, cfg, e):
    m, k, n = shape
    x, w, sq = _setup(m, k, n)
    yk = analog_matmul(
        x, w, energy=jnp.asarray(e), key=KEY, cfg=cfg, sq=sq,
        n_repeats=k_rep, block=(32, 32, 64),
    )
    yr = analog_matmul_reference(
        x, w, energy=jnp.asarray(e), key=KEY, cfg=cfg, sq=sq, n_repeats=k_rep
    )
    scale = float(jnp.abs(yr).max()) + 1e-6
    atol = 3e-5 * scale
    if cfg.out_bits is not None and sq.oqp is not None:
        # tiled f32 accumulation can flip a rounding boundary by one bin
        atol = max(atol, float(sq.oqp.delta) * 1.01)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=atol, rtol=1e-4)


def test_repeat_averaged_draws_bit_exact_under_tiling():
    """The repeat-averaged gaussian is a pure function of global indices:
    any tiled window must equal the corresponding slice of the full draw
    BIT-exactly — this is what makes kernel and oracle agree for any
    BlockSpec at any K."""
    k0, k1 = jnp.uint32(5), jnp.uint32(9)
    for k_rep in (1, 4, 16):
        full = repeat_averaged_gaussian_tile(k0, k1, 0, 0, (48, 40), k_rep)
        sub = repeat_averaged_gaussian_tile(k0, k1, 16, 8, (16, 16), k_rep)
        np.testing.assert_array_equal(
            np.asarray(full[16:32, 8:24]), np.asarray(sub)
        )


def test_repeat_streams_identity_and_decorrelation():
    """r=0 leaves the stream untouched (K=1 == single draw, bit-for-bit);
    r>0 streams are decorrelated."""
    k0, k1 = jnp.uint32(3), jnp.uint32(7)
    assert int(repeat_key(k1, 0)) == int(k1)
    g1 = repeat_averaged_gaussian_tile(k0, k1, 0, 0, (64, 64), 1).reshape(-1)
    from repro.kernels.prng import gaussian_tile

    g_single = gaussian_tile(k0, k1, 0, 0, (64, 64)).reshape(-1)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g_single))
    g_r1 = gaussian_tile(k0, repeat_key(k1, 1), 0, 0, (64, 64)).reshape(-1)
    corr = float(jnp.corrcoef(jnp.stack([g_single, g_r1]))[0, 1])
    assert abs(corr) < 0.05


@pytest.mark.parametrize(
    "cfg,e",
    [(AnalogConfig.shot(), 2.0), (AnalogConfig.weight(0.1), 1.0)],
    ids=["shot", "weight"],
)
def test_fused_variance_matches_explicit_oracle(cfg, e):
    """Fused K-repeat (kernel path) noise variance == the explicit O(K)
    time-averaging oracle's, within statistical tolerance."""
    x = jax.random.normal(KEY, (16, 64))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 24)) * 0.2
    clean = x @ w
    k_rep = 4

    def std(fn, n=160):
        ys = jax.vmap(fn)(jax.random.split(KEY, n))
        return float(jnp.std(ys - jnp.mean(ys, axis=0)[None]))

    s_fused = std(
        lambda k: analog_matmul(
            x, w, energy=jnp.asarray(e), key=k, cfg=cfg,
            n_repeats=k_rep, block=(16, 16, 32),
        )
    )
    s_explicit = std(
        lambda k: time_averaged_dot_explicit(
            x, w, cfg=cfg, base_energy=jnp.asarray(e), key=k, k_repeats=k_rep
        )
    )
    assert s_fused == pytest.approx(s_explicit, rel=0.15)
    # and both sit at 1/sqrt(K) of the single draw
    s_one = std(
        lambda k: analog_dot(x, w, cfg=cfg, energy=jnp.asarray(e), key=k)
    )
    assert s_one / s_fused == pytest.approx(np.sqrt(k_rep), rel=0.2)


def test_fused_path_matches_spatial_oracle_variance():
    cfg = AnalogConfig.weight(0.1, out_bits=None, weight_bits=None, act_bits=None)
    x = jax.random.normal(KEY, (8, 48))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (48, 16)) * 0.2

    def std(fn, n=160):
        ys = jax.vmap(fn)(jax.random.split(KEY, n))
        return float(jnp.std(ys - jnp.mean(ys, axis=0)[None]))

    s_fused = std(
        lambda k: time_averaged_dot(
            x, w, cfg=cfg, base_energy=jnp.asarray(1.0), key=k, k_repeats=4
        )
    )
    s_spatial = std(
        lambda k: spatial_averaged_dot_explicit(
            x, w, cfg=cfg, base_energy=jnp.asarray(1.0), key=k, k_repeats=4
        )
    )
    assert s_fused == pytest.approx(s_spatial, rel=0.2)


# ---------------------------------------------------------------------------
# backend dispatch
# ---------------------------------------------------------------------------


def test_resolve_backend_rules():
    big, small = (256, 256), (256, 8)
    w_big = (256, 256)
    assert resolve_backend(AnalogConfig.shot(backend="pallas"), big, w_big) == "pallas"
    assert resolve_backend(AnalogConfig.shot(backend="jnp"), big, w_big) == "jnp"
    assert resolve_backend(AnalogConfig.shot(use_kernel=True), big, w_big) == "pallas"
    assert resolve_backend(AnalogConfig(), big, w_big) == "jnp"  # digital
    if jax.default_backend() != "tpu":
        # auto never picks interpret-mode Pallas off-TPU
        assert resolve_backend(AnalogConfig.shot(), big, w_big) == "jnp"
    with pytest.raises(ValueError):
        AnalogConfig.shot(backend="cuda")


def test_analog_hook_reaches_pallas_kernel(monkeypatch):
    """AnalogHook.__call__ and .batched execute the fused Pallas kernel
    under backend="pallas" — the model hot path actually reaches
    analog_matmul_raw."""
    from repro.kernels import ops as kernel_ops

    calls = []
    real = kernel_ops.analog_matmul_raw

    def spy(*args, **kwargs):
        calls.append(kwargs.get("n_repeats"))
        return real(*args, **kwargs)

    monkeypatch.setattr(kernel_ops, "analog_matmul_raw", spy)
    cfg = AnalogConfig.shot(backend="pallas")
    x = jax.random.normal(KEY, (16, 32))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 8)) * 0.2
    hook = AnalogHook(cfg=cfg, energies={"q": jnp.asarray(8.0)}, key=KEY, n_repeats=4)
    y = hook("q", x, w)
    assert y.shape == (16, 8)
    assert calls == [4]

    xb = jax.random.normal(KEY, (2, 16, 32))
    wb = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 32, 8)) * 0.2
    yb = hook.batched("q", xb, wb)
    assert yb.shape == (2, 16, 8)
    assert len(calls) == 2  # one more trace through the kernel


def test_fused_jnp_equivalence_high_energy():
    """The jnp fallback implements n_repeats=K as a single draw at K*E:
    same distribution as the kernel's in-register average."""
    cfg = AnalogConfig.shot(backend="jnp")
    x = jax.random.normal(KEY, (16, 64))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 24)) * 0.2

    def std(fn, n=160):
        ys = jax.vmap(fn)(jax.random.split(KEY, n))
        return float(jnp.std(ys - jnp.mean(ys, axis=0)[None]))

    s_rep = std(
        lambda k: analog_dot(x, w, cfg=cfg, energy=jnp.asarray(2.0), key=k, n_repeats=8)
    )
    s_one = std(lambda k: analog_dot(x, w, cfg=cfg, energy=jnp.asarray(16.0), key=k))
    assert s_rep == pytest.approx(s_one, rel=0.15)


def test_analytic_traffic_fused_independent_of_k():
    """Acceptance criterion: fused HBM traffic is the same for every K while
    the unfused form scales ~K-fold."""
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.kernel_bench import analytic_traffic

    m, k, n = 512, 512, 512
    t1 = analytic_traffic(m, k, n, 1)
    t16 = analytic_traffic(m, k, n, 16)
    assert t1["hbm_bytes_fused"] == t16["hbm_bytes_fused"]
    ratio = t16["hbm_bytes_unfused"] / t1["hbm_bytes_unfused"]
    assert ratio == pytest.approx(16.0, rel=0.1)
