"""Scan-corrected HLO analysis: parser vs ground truth on an 8-device mesh
(subprocess: the test process must keep its single CPU device)."""
import os
import subprocess
import sys
import textwrap


def _run(code: str):
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": "src"},
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_scan_corrected_dot_flops_and_collectives():
    out = _run(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        L, B, D = 7, 32, 64
        def f(x, ws):
            def body(h, w):
                return jnp.tanh(jnp.dot(h, w)), None
            h, _ = jax.lax.scan(body, x, ws)
            return jnp.sum(h)
        x_sh = NamedSharding(mesh, P("data", "model"))
        w_sh = NamedSharding(mesh, P(None, "model", None))
        c = jax.jit(f, in_shardings=(x_sh, w_sh),
                    out_shardings=NamedSharding(mesh, P())).lower(
            jax.ShapeDtypeStruct((B, D), jnp.float32),
            jax.ShapeDtypeStruct((L, D, D), jnp.float32)).compile()
        stats = analyze(c.as_text(), 8)
        gt_flops = 2 * (B // 2) * (D // 4) * D * L   # per-device
        assert abs(stats.dot_flops - gt_flops) / gt_flops < 0.01, stats.dot_flops
        # the raw cost_analysis counts the body once (the bug we correct):
        ca = c.cost_analysis()
        raw = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
        assert stats.dot_flops > 3 * raw
        # per-layer all-reduce of f32[16,64] ring bytes: 2*(4-1)/4 * 4096 * L
        ar = stats.collective_bytes["all-reduce"]
        gt_ar = 2 * (4 - 1) / 4 * (B // 2) * D * 4 * L
        assert abs(ar - gt_ar) / gt_ar < 0.05, (ar, gt_ar)
        print("PARSER OK")
        """
    )
    assert "PARSER OK" in out


def test_sharded_train_step_matches_single_device():
    """Numerical equivalence: the sharded train step on an 8-device mesh
    produces the same loss/params as the 1-device run."""
    out = _run(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.data.pipeline import TokenTaskConfig, markov_batch
        from repro.launch.steps import TrainConfig, make_train_step
        from repro.models import init_params
        from repro.models.sharding import use_mesh
        from repro.optim.adam import adam_init

        cfg = dataclasses.replace(get_smoke_config("grok-1-314b"), dtype="float32")
        data = TokenTaskConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=1)
        batch = markov_batch(data, 0)
        tcfg = TrainConfig(lr=1e-3, opt_state_dtype="float32")
        results = {}
        for shape, axes in (((1, 1), ("data", "model")), ((2, 4), ("data", "model"))):
            mesh = jax.make_mesh(shape, axes)
            with use_mesh(mesh):
                params = init_params(jax.random.PRNGKey(0), cfg)
                _, jit_for, _ = make_train_step(cfg, mesh, tcfg)
                specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
                step = jit_for(specs)
                opt = adam_init(params, tcfg.adam())
                p2, _, m = step(params, opt, batch)
                results[shape] = (jax.device_get(p2), float(m["loss"]))
        l1, l8 = results[(1, 1)][1], results[(2, 4)][1]
        assert abs(l1 - l8) < 1e-3, (l1, l8)
        diffs = jax.tree.map(lambda a, b: float(np.abs(np.asarray(a) - np.asarray(b)).max()),
                             results[(1, 1)][0], results[(2, 4)][0])
        worst = max(jax.tree.leaves(diffs))
        assert worst < 5e-3, worst
        print("SHARDED OK", l1, l8, worst)
        """
    )
    assert "SHARDED OK" in out
