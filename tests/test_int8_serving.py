"""Int8 weight-streaming serving + Pallas-kernel serving path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import AnalogConfig, analog_dot
from repro.models import decode_step, forward_hidden, init_cache, init_params, prefill
from repro.models import lm
from repro.quant.weights import (
    dequantize_params,
    dequantize_weight,
    param_bytes,
    quantize_params,
    quantize_weight,
)

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def test_weight_roundtrip_error_bound():
    w = jax.random.normal(KEY, (4, 64, 32)) * 0.3
    iw = quantize_weight(w)
    back = dequantize_weight(iw, jnp.float32)
    err = jnp.abs(back - w)
    bound = jnp.max(jnp.abs(w), axis=-2, keepdims=True) / 127.0
    assert float((err - bound / 2).max()) < 1e-5
    assert iw.q.dtype == jnp.int8
    assert iw.scale.shape == (4, 1, 32)


@pytest.mark.parametrize("arch", ["granite-3-8b", "recurrentgemma-2b", "grok-1-314b"])
def test_int8_decode_matches_bf16(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(KEY, cfg)
    qparams = quantize_params(params)
    # at least 40% byte reduction (embeddings/norms stay high precision)
    assert param_bytes(qparams) < 0.62 * param_bytes(params)

    toks = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab_size)
    cache, _ = prefill(params, {"tokens": toks[:, :T]}, cfg, cache_len=T + 1)
    want, _ = decode_step(params, cache, {"tokens": toks[:, T:]}, T, cfg)
    cache_q, _ = prefill(qparams, {"tokens": toks[:, :T]}, cfg, cache_len=T + 1)
    got, _ = decode_step(qparams, cache_q, {"tokens": toks[:, T:]}, T, cfg)
    # int8 weights perturb logits mildly; ranking of the top token is the
    # serving-level contract we check alongside a loose numeric bound
    scale = float(jnp.abs(want).max()) + 1e-6
    assert float(jnp.abs(got - want).max()) < 0.25 * scale, arch
    agree = float(jnp.mean(jnp.argmax(got, -1) == jnp.argmax(want, -1)))
    assert agree >= 0.5, (arch, agree)


def test_int8_train_forward_also_works():
    cfg = dataclasses.replace(get_smoke_config("granite-3-8b"), dtype="float32")
    params = init_params(KEY, cfg)
    qparams = quantize_params(params)
    batch = {"tokens": jnp.ones((B, T), jnp.int32)}
    h1, _ = forward_hidden(params, batch, cfg, mode="train")
    h2, _ = forward_hidden(qparams, batch, cfg, mode="train")
    assert float(jnp.abs(h1 - h2).max()) < 0.3 * float(jnp.abs(h1).max()) + 1e-3


def test_kernel_serving_path_in_model():
    """AnalogConfig(use_kernel=True) routes matmuls through the fused Pallas
    kernel (interpret mode on CPU) inside a real model forward."""
    x = jax.random.normal(KEY, (8, 64))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 32)) * 0.2
    cfg_k = AnalogConfig.shot(use_kernel=True)
    cfg_j = AnalogConfig.shot()
    yk = analog_dot(x, w, cfg=cfg_k, energy=jnp.asarray(500.0), key=KEY)
    yj = analog_dot(x, w, cfg=cfg_j, energy=jnp.asarray(500.0), key=KEY)
    # different PRNG streams but identical statistics at high energy
    assert float(jnp.abs(yk - x @ w).max()) < 0.1
    assert float(jnp.abs(yj - x @ w).max()) < 0.1
    assert yk.shape == yj.shape
