"""Pallas analog-matmul kernel vs pure-jnp oracle: shape/dtype/noise sweeps
(interpret=True on CPU), plus statistical equivalence with analog_dot."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnalogConfig, SiteQuant
from repro.kernels import analog_matmul, analog_matmul_reference
from repro.kernels.prng import counter_gaussian, gaussian_tile, threefry2x32
from repro.quant import calibrate_minmax

KEY = jax.random.PRNGKey(11)

SHAPES = [(32, 64, 16), (96, 200, 72), (128, 128, 128), (17, 33, 9)]
BLOCKS = [(32, 32, 64), (64, 64, 64), (16, 16, 16)]


def _setup(m, k, n, dtype=jnp.float32):
    x = jax.random.normal(KEY, (m, k), dtype)
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype) * 0.2
    sq = SiteQuant(
        wqp=calibrate_minmax(w, channel_axis=1),
        xqp=calibrate_minmax(x),
        oqp=calibrate_minmax(x.astype(jnp.float32) @ w.astype(jnp.float32)),
    )
    return x, w, sq


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("block", BLOCKS[:2])
@pytest.mark.parametrize(
    "cfg,e",
    [
        (AnalogConfig.shot(), 10.0),
        (AnalogConfig.thermal(0.01), 4.0),
        (AnalogConfig.weight(0.1), 5.0),
        (AnalogConfig(mode="analog"), 1.0),
    ],
    ids=["shot", "thermal", "weight", "none"],
)
def test_kernel_matches_oracle(shape, block, cfg, e):
    m, k, n = shape
    x, w, sq = _setup(m, k, n)
    yk = analog_matmul(x, w, energy=jnp.asarray(e), key=KEY, cfg=cfg, sq=sq, block=block)
    yr = analog_matmul_reference(x, w, energy=jnp.asarray(e), key=KEY, cfg=cfg, sq=sq)
    scale = float(jnp.abs(yr).max()) + 1e-6
    atol = 3e-5 * scale
    if cfg.out_bits is not None and sq.oqp is not None:
        # tiled f32 accumulation can flip a rounding boundary by one bin
        atol = max(atol, float(sq.oqp.delta) * 1.01)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=atol, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    x, w, sq = _setup(64, 96, 32, dtype)
    cfg = AnalogConfig.shot()
    yk = analog_matmul(x, w, energy=jnp.asarray(5.0), key=KEY, cfg=cfg, block=(32, 32, 32))
    yr = analog_matmul_reference(x, w, energy=jnp.asarray(5.0), key=KEY, cfg=cfg)
    scale = float(jnp.abs(yr).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=3e-5 * scale, rtol=1e-3)


def test_kernel_per_channel_energy():
    x, w, sq = _setup(48, 64, 24)
    cfg = AnalogConfig.shot(granularity="per_channel")
    e = jnp.linspace(1.0, 40.0, 24)
    yk = analog_matmul(x, w, energy=e, key=KEY, cfg=cfg, block=(16, 16, 32))
    yr = analog_matmul_reference(x, w, energy=e, key=KEY, cfg=cfg)
    scale = float(jnp.abs(yr).max()) + 1e-6
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=3e-5 * scale)


def test_kernel_batched_inputs():
    """(..., K) leading batch dims reshape correctly."""
    x = jax.random.normal(KEY, (4, 8, 32))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (32, 16)) * 0.2
    cfg = AnalogConfig.shot()
    yk = analog_matmul(x, w, energy=jnp.asarray(5.0), key=KEY, cfg=cfg, block=(16, 16, 16))
    yr = analog_matmul_reference(x, w, energy=jnp.asarray(5.0), key=KEY, cfg=cfg)
    assert yk.shape == (4, 8, 16)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=1e-4)


def test_kernel_noise_statistics_match_analog_dot():
    """Kernel's counter-PRNG noise is distributionally equivalent to the
    jax.random path used by analog_dot (same analytic std)."""
    from repro.core.analog import analog_dot

    x, w, _ = _setup(32, 64, 16)
    cfg = AnalogConfig.shot()
    e = jnp.asarray(8.0)
    clean = x @ w

    def kstd(fn):
        ys = jax.vmap(fn)(jax.random.split(KEY, 128))
        return float(jnp.std(ys - clean[None]))

    s_kernel = kstd(lambda k: analog_matmul(x, w, energy=e, key=k, cfg=cfg, block=(32, 32, 32)))
    s_jnp = kstd(lambda k: analog_dot(x, w, cfg=cfg, energy=e, key=k))
    assert s_kernel == pytest.approx(s_jnp, rel=0.1)


# ---------------------------------------------------------------------------
# counter-based PRNG quality
# ---------------------------------------------------------------------------


def test_threefry_reference_vector():
    """Threefry-2x32(20 rounds) known-answer test (Random123 zero vector)."""
    x0, x1 = threefry2x32(
        jnp.uint32(0), jnp.uint32(0), jnp.uint32(0), jnp.uint32(0)
    )
    assert (int(x0), int(x1)) == (0x6B200159, 0x99BA4EFE)


def test_gaussian_moments_and_determinism():
    g1 = gaussian_tile(jnp.uint32(5), jnp.uint32(9), 0, 0, (64, 64)).reshape(-1)
    g2 = gaussian_tile(jnp.uint32(5), jnp.uint32(9), 0, 0, (64, 64)).reshape(-1)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    assert float(jnp.mean(g1)) == pytest.approx(0.0, abs=0.05)
    assert float(jnp.std(g1)) == pytest.approx(1.0, rel=0.05)
    # different key -> decorrelated
    g3 = gaussian_tile(jnp.uint32(6), jnp.uint32(9), 0, 0, (64, 64)).reshape(-1)
    corr = float(jnp.corrcoef(jnp.stack([g1, g3]))[0, 1])
    assert abs(corr) < 0.05


def test_gaussian_tile_offset_consistency():
    """Tiles are pure functions of global indices: a shifted window must
    reproduce the overlapping region exactly (kernel/oracle tiling parity)."""
    full = gaussian_tile(jnp.uint32(1), jnp.uint32(2), 0, 0, (32, 32))
    sub = gaussian_tile(jnp.uint32(1), jnp.uint32(2), 8, 16, (8, 8))
    np.testing.assert_array_equal(np.asarray(full[8:16, 16:24]), np.asarray(sub))
