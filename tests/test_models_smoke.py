"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a reduced config of the same family and runs one forward +
train step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, EXTRA_ARCHS, get_config, get_smoke_config
from repro.models import init_params, train_loss
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def _batch(cfg: ModelConfig):
    if cfg.frontend == "frames":
        return {
            "embeds": jnp.ones((B, T, cfg.d_model), cfg.compute_dtype),
            "labels": jnp.ones((B, T, cfg.n_codebooks), jnp.int32),
        }
    if cfg.frontend == "patch":
        p = cfg.n_frontend_tokens
        return {
            "tokens": jnp.ones((B, T - p), jnp.int32),
            "patch_embeds": jnp.ones((B, p, cfg.d_model), cfg.compute_dtype),
            "labels": jnp.ones((B, T), jnp.int32),
        }
    return {
        "tokens": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (B, T), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", sorted(ARCHS) + sorted(EXTRA_ARCHS))
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    full = get_config(arch)
    assert cfg.family == full.family, "smoke config must match the family"
    params = init_params(KEY, cfg)
    batch = _batch(cfg)

    loss = train_loss(params, batch, cfg)
    assert jnp.isfinite(loss), (arch, float(loss))

    # one gradient step moves the loss
    grads = jax.grad(lambda p: train_loss(p, batch, cfg))(params)
    gnorm = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype), params, grads)
    loss2 = train_loss(params2, batch, cfg)
    assert jnp.isfinite(loss2), arch
    assert float(loss2) < float(loss) + 0.5, (arch, float(loss), float(loss2))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_param_spec_construction(arch):
    """Full configs build parameter SPECS without allocation and the param
    count matches the closed-form used for MODEL_FLOPS (within 2%)."""
    from repro.models import param_specs

    cfg = get_config(arch)
    specs = param_specs(cfg)
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(specs))
    expect = cfg.param_count()
    # padded vocab adds a small delta; closed form excludes norms in places
    assert abs(n - expect) / expect < 0.02, (arch, n, expect)
