"""MoE dispatch invariants + virtual-expert equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params, train_loss
from repro.models.hooks import MatmulHook
from repro.models.moe import make_dispatch, moe_block, router_topk

KEY = jax.random.PRNGKey(3)


def test_topk_weights_normalized():
    logits = jax.random.normal(KEY, (4, 16, 8))
    gates, ids = router_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert int(ids.max()) < 8


def test_dispatch_capacity_respected():
    g, s, e, c, k = 2, 64, 4, 8, 2
    logits = jax.random.normal(KEY, (g, s, e))
    gates, ids = router_topk(logits, k)
    dispatch, combine = make_dispatch(ids, gates, e, c)
    # each (expert, slot) holds at most one token
    per_slot = np.asarray(dispatch.sum(axis=1))  # (g, e, c)
    assert per_slot.max() <= 1.0
    # each token occupies at most k slots
    per_token = np.asarray(dispatch.sum(axis=(2, 3)))  # (g, s)
    assert per_token.max() <= k
    # combine weights of surviving tokens sum to <= 1
    w_tok = np.asarray(combine.sum(axis=(2, 3)))
    assert w_tok.max() <= 1.0 + 1e-5


def test_high_capacity_drops_nothing():
    g, s, e, k = 1, 32, 4, 2
    logits = jax.random.normal(KEY, (g, s, e))
    gates, ids = router_topk(logits, k)
    dispatch, _ = make_dispatch(ids, gates, e, capacity=s * k)
    per_token = np.asarray(dispatch.sum(axis=(2, 3)))
    np.testing.assert_allclose(per_token, k)


def _moe_cfg(**kw):
    base = dict(
        name="m", family="moe", n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
        d_ff=64, vocab_size=64, n_experts=4, top_k=2, moe_every=1,
        capacity_factor=8.0, moe_group_size=64, attn_q_chunk=16,
        attn_kv_chunk=16, loss_chunk=16, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def test_moe_block_matches_dense_reference():
    """With capacity high enough to drop nothing, the dispatch/combine path
    equals explicitly computing every expert and mixing with gate weights."""
    cfg = _moe_cfg()
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.d_ff
    keys = jax.random.split(KEY, 5)
    p = {
        "router": jax.random.normal(keys[0], (d, e)) * 0.5,
        "w_gate": jax.random.normal(keys[1], (e, d, ff)) / np.sqrt(d),
        "w_up": jax.random.normal(keys[2], (e, d, ff)) / np.sqrt(d),
        "w_down": jax.random.normal(keys[3], (e, ff, d)) / np.sqrt(ff),
    }
    x = jax.random.normal(keys[4], (2, 16, d))
    got = moe_block(x, p, cfg, MatmulHook())

    logits = jnp.einsum("btd,de->bte", x, p["router"])
    gates, ids = router_topk(logits, cfg.top_k)
    h = jax.nn.silu(jnp.einsum("btd,edf->ebtf", x, p["w_gate"])) * jnp.einsum(
        "btd,edf->ebtf", x, p["w_up"]
    )
    ye = jnp.einsum("ebtf,efd->ebtd", h, p["w_down"])
    oh = jax.nn.one_hot(ids, e)  # (b,t,k,e)
    w = jnp.einsum("btke,btk->ebt", oh, gates)
    want = jnp.einsum("ebtd,ebt->btd", ye, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_virtual_expert_split_equivalence():
    """moe_ff_split=2 must be numerically identical given split weights."""
    cfg1 = _moe_cfg()
    cfg2 = dataclasses.replace(cfg1, moe_ff_split=2)
    p1 = init_params(KEY, cfg1)
    moe = p1["blocks"]["moe"]

    def split_ff(w):  # (L, E, d, ff) -> (L, 2E, d, ff/2)
        L, E, d, ff = w.shape
        w2 = w.reshape(L, E, d, 2, ff // 2)
        return jnp.moveaxis(w2, 3, 2).reshape(L, 2 * E, d, ff // 2)

    def split_in(w):  # (L, E, ff, d) -> (L, 2E, ff/2, d)
        L, E, ff, d = w.shape
        return w.reshape(L, 2 * E, ff // 2, d)

    p2 = dict(p1)
    p2["blocks"] = dict(p1["blocks"])
    p2["blocks"]["moe"] = {
        "router": moe["router"],
        "w_gate": split_ff(moe["w_gate"]),
        "w_up": split_ff(moe["w_up"]),
        "w_down": split_in(moe["w_down"]),
    }
    batch = {
        "tokens": jax.random.randint(KEY, (2, 32), 0, cfg1.vocab_size),
        "labels": jnp.ones((2, 32), jnp.int32),
    }
    l1, l2 = train_loss(p1, batch, cfg1), train_loss(p2, batch, cfg2)
    assert abs(float(l1) - float(l2)) < 1e-4
