"""Noise models (Eqs. 3-5, 9-11): moments, scaling laws, analytic variance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import noise as noise_lib
from repro.core import AnalogConfig, SiteQuant, analog_dot
from repro.quant import calibrate_minmax

KEY = jax.random.PRNGKey(0)


def _draws(cfg, x, w, energy, n=256, sq=None):
    clean = x @ w

    def one(k):
        return analog_dot(x, w, cfg=cfg, energy=jnp.asarray(energy), key=k, sq=sq)

    ys = jax.vmap(one)(jax.random.split(KEY, n))
    return ys - clean[None]


@pytest.fixture(scope="module")
def xw():
    x = jax.random.normal(KEY, (16, 64))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 24)) * 0.2
    return x, w


def test_inv_sqrt_energy_scaling_all_kinds(xw):
    """Noise std ~ 1/sqrt(E) — the redundant-coding law (paper §IV)."""
    x, w = xw
    sq = SiteQuant(
        wqp=calibrate_minmax(w, channel_axis=1),
        xqp=calibrate_minmax(x),
        oqp=None,
    )
    for cfg in (
        AnalogConfig.shot(),
        AnalogConfig.thermal(0.02, out_bits=None),
        AnalogConfig.weight(0.02, out_bits=None),
    ):
        s1 = float(jnp.std(_draws(cfg, x, w, 2.0, sq=sq)))
        s4 = float(jnp.std(_draws(cfg, x, w, 8.0, sq=sq)))
        assert s1 / s4 == pytest.approx(2.0, rel=0.15), cfg.noise.kind


def test_shot_noise_matches_eq11_analytically(xw):
    x, w = xw
    cfg = AnalogConfig.shot()
    e = 10.0
    err = _draws(cfg, x, w, e, n=512)
    emp_std = np.asarray(jnp.std(err, axis=0))  # (16, 24)
    photons = e / noise_lib.PHOTON_ENERGY_AJ
    pred = (
        np.linalg.norm(np.asarray(w), axis=0)[None, :]
        * np.linalg.norm(np.asarray(x), axis=1)[:, None]
        / np.sqrt(64 * photons)
    )
    np.testing.assert_allclose(emp_std, pred, rtol=0.25)


def test_thermal_noise_matches_eq9(xw):
    x, w = xw
    sq = SiteQuant(
        wqp=calibrate_minmax(w, channel_axis=1), xqp=calibrate_minmax(x), oqp=None
    )
    cfg = AnalogConfig.thermal(0.01, out_bits=None)
    e = 4.0
    err = _draws(cfg, x, w, e, n=512, sq=sq)
    emp = float(jnp.std(err))
    w_rng = np.asarray(sq.wqp.x_max - sq.wqp.x_min).mean()
    x_rng = float(sq.xqp.x_max - sq.xqp.x_min)
    pred = np.sqrt(64) * w_rng * x_rng * 0.01 / np.sqrt(e)
    assert emp == pytest.approx(pred, rel=0.2)


def test_weight_noise_scales_with_input_norm(xw):
    """Eq. 10: output variance = (r sigma/sqrt(E))^2 ||x||^2."""
    x, w = xw
    sq = SiteQuant(
        wqp=calibrate_minmax(w, channel_axis=1), xqp=calibrate_minmax(x), oqp=None
    )
    cfg = AnalogConfig.weight(0.05, out_bits=None)
    err = _draws(cfg, x, w, 4.0, n=512, sq=sq)
    emp_std_per_row = np.asarray(jnp.std(err, axis=(0, 2)))  # (16,)
    x_norms = np.linalg.norm(np.asarray(x), axis=1)
    corr = np.corrcoef(emp_std_per_row, x_norms)[0, 1]
    assert corr > 0.98


def test_per_channel_energy_reduces_noise_only_there(xw):
    x, w = xw
    cfg = AnalogConfig.shot(granularity="per_channel")
    e = jnp.full((24,), 2.0).at[0].set(200.0)
    err = _draws(cfg, x, w, e, n=256)
    stds = np.asarray(jnp.std(err, axis=(0, 1)))
    assert stds[0] < stds[1:].min() / 3


def test_discrete_energy_snaps_to_photon_quanta(xw):
    x, w = xw
    cfg = AnalogConfig.shot(discrete_energy=True)
    # 0.2 aJ with quantum 0.128 aJ -> snaps to 0.256 (2 photons)
    y1 = analog_dot(x, w, cfg=cfg, energy=jnp.asarray(0.2), key=KEY)
    cfg2 = AnalogConfig.shot()
    y2 = analog_dot(x, w, cfg=cfg2, energy=jnp.asarray(2 * noise_lib.PHOTON_ENERGY_AJ), key=KEY)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(e=st.floats(min_value=0.5, max_value=100.0))
def test_noise_variance_analytic_positive(e):
    var = noise_lib.noise_variance_for_layer(
        noise_lib.NoiseSpec(kind="thermal", sigma=0.01),
        n_macs=64,
        energy=jnp.asarray(e),
        w_range=jnp.asarray(1.0),
        x_range=jnp.asarray(2.0),
    )
    assert float(var) > 0
    var2 = noise_lib.noise_variance_for_layer(
        noise_lib.NoiseSpec(kind="thermal", sigma=0.01),
        n_macs=64,
        energy=jnp.asarray(4 * e),
        w_range=jnp.asarray(1.0),
        x_range=jnp.asarray(2.0),
    )
    assert float(var / var2) == pytest.approx(4.0, rel=1e-3)
