"""SLA-aware precision policy: governor hysteresis/dwell, accuracy floors,
shed-last ordering, power budget, tier reassignment FIFO, bounded fault
log, DriftEvent attribution, the online profile re-trim, and the random
load-ramp property (no demote->promote flapping inside the dwell window,
floors never violated, tier reassignment never causes a steady-state
retrace)."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import AnalogConfig, online_repeat_profile_search
from repro.models import init_energy_tree, init_params
from repro.models.config import ModelConfig
from repro.serving import (
    BoundedLog,
    ClusterRouter,
    MetricsFeed,
    NoiseDriftWatchdog,
    PolicyConfig,
    PrecisionGovernor,
    QueueFull,
    ReplicaCrash,
    Request,
    ServingEngine,
    TierScheduler,
    TierSpec,
    WatchdogConfig,
    load_signals,
)
from repro.serving.policy import TRANSITIONS
from test_serving import ENERGY_AJ, SB

KEY = jax.random.PRNGKey(0)
MODEL = ModelConfig(
    name="policy-test", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)

#: the test ladder: measured-accuracy stand-ins per uniform K tier
ACCS = {1: 0.80, 2: 0.90, 4: 0.97}
TIERS = tuple(TierSpec(k, a) for k, a in sorted(ACCS.items()))


@pytest.fixture(scope="module")
def env():
    params = init_params(KEY, MODEL)
    energies = init_energy_tree(MODEL, ENERGY_AJ)
    return dict(params=params, energies=energies)


def _policy(**kw):
    kw.setdefault("tiers", TIERS)
    kw.setdefault("demote_at", 1.0)
    kw.setdefault("promote_at", 0.25)
    kw.setdefault("shed_at", 3.0)
    kw.setdefault("min_dwell", 2)
    return PolicyConfig(**kw)


def _engine(env, *, analog=True, policy=None, **kw):
    extra = {}
    if analog:
        extra = dict(analog_cfg=AnalogConfig.shot(), energies=env["energies"])
    kw.setdefault("max_gen", 8)
    kw.setdefault("max_wait", 0.0)
    return ServingEngine(
        env["params"], MODEL, max_batch=4,
        batch_buckets=(1, 2, 4), seq_buckets=(SB,),
        continuous=True, pool_slots=2, k_ladder=(1, 2, 4),
        policy=policy, **extra, **kw,
    )


def _prompts(n, seed=3, length=8):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, 128, length).astype(np.int32) for _ in range(n)]


def _drain(eng, t, dt=0.01, max_iters=400):
    """Pump the virtual clock until in-flight work resolves; returns
    (results, final time). Bounded: a hang is a failure."""
    results = {}
    for _ in range(max_iters):
        if not eng.n_in_flight:
            break
        t += dt
        results.update(eng.pump_step(now=t))
    assert not eng.n_in_flight, "engine failed to drain (hang)"
    return results, t


# --------------------------------------------------------------------------
# config validation + governor construction
# --------------------------------------------------------------------------


def test_policy_config_validation():
    with pytest.raises(ValueError, match="at least one tier"):
        PolicyConfig(tiers=())
    with pytest.raises(ValueError, match="hysteresis"):
        _policy(demote_at=0.5, promote_at=0.5)  # band collapsed
    with pytest.raises(ValueError, match="hysteresis"):
        _policy(shed_at=0.5)  # shed below demote
    with pytest.raises(ValueError, match="min_dwell"):
        _policy(min_dwell=0)
    with pytest.raises(ValueError, match="power_budget"):
        _policy(power_budget_aj=0.0)
    with pytest.raises(ValueError, match="urgency_weight"):
        _policy(urgency_weight=-1.0)
    with pytest.raises(ValueError, match="drift_band"):
        _policy(drift_band=(1.1, 1.4))  # band must straddle nominal 1.0
    with pytest.raises(ValueError, match="drift_patience"):
        _policy(drift_band=(0.8, 1.25), drift_patience=0)
    # bare tier ids are promoted to TierSpec (accuracy resolved later)
    cfg = PolicyConfig(tiers=(1, TierSpec(2, 0.9)))
    assert all(isinstance(t, TierSpec) for t in cfg.tiers)


def test_governor_requires_analog_and_metadata(env):
    with pytest.raises(ValueError, match="analog"):
        _engine(env, analog=False, policy=_policy())
    # a tier without accuracy metadata can't back an accuracy floor
    with pytest.raises(ValueError, match="accuracy metadata"):
        _engine(env, policy=_policy(tiers=(TierSpec(1), TierSpec(4, 0.97))))
    # demotion must pick among *registered* profile tiers (AOT contract)
    with pytest.raises(ValueError, match="registered profile"):
        _engine(env, policy=_policy(tiers=(TierSpec("ghost", 0.9),)))


def test_governor_ladder_sorted_by_energy(env):
    eng = _engine(env, policy=_policy())
    energies = [e for e, _a, _t in eng.governor.tiers]
    assert energies == sorted(energies)
    assert [t for _e, _a, t in eng.governor.tiers] == [1, 2, 4]
    assert eng.governor.tier_accuracy(2) == ACCS[2]
    with pytest.raises(ValueError, match="not in the policy table"):
        eng.governor.tier_accuracy(8)


# --------------------------------------------------------------------------
# satellite: bounded fault log + attributable events
# --------------------------------------------------------------------------


def test_bounded_log_is_a_list_with_a_ring_bound():
    log = BoundedLog(maxlen=3)
    assert log == []  # plain-list equality survives (test_faults relies on it)
    for i in range(7):
        log.append(i)
    assert list(log) == [4, 5, 6] and log.dropped == 4
    assert BoundedLog(maxlen=None).maxlen is None
    with pytest.raises(ValueError, match="maxlen"):
        BoundedLog(maxlen=0)


def test_engine_fault_log_bound_and_dropped_stat(env):
    eng = _engine(env, fault_log_maxlen=4)
    for i in range(10):
        eng.fault_log.append({"kind": "synthetic", "i": i})
    assert len(eng.fault_log) == 4
    assert [e["i"] for e in eng.fault_log] == [6, 7, 8, 9]
    assert eng.stats["dropped_events"] == 6


def test_drift_event_carries_clock_and_measurement(env):
    eng = _engine(env)
    eng._fault_clock = 17  # pretend some decode steps already ran
    eng.set_noise_scale(3.0)  # hardware way off calibration
    wd = NoiseDriftWatchdog(
        eng, np.zeros((1, 8), np.int32),
        config=WatchdogConfig(interval=1, n_samples=2, band=(0.7, 1.4)),
    )
    event = wd.probe(step=0)
    assert event is not None and event.estimate > 1.4
    assert event.clock == 17  # the engine's fault clock, not the wd step
    assert event.residual_rms > 0.0  # the triggering measurement itself


# --------------------------------------------------------------------------
# scheduler: tier reassignment
# --------------------------------------------------------------------------


def _req(uid, *, k=4, arrival=0.0, floor=None):
    return Request(
        uid=uid, tokens=np.zeros(8, np.int32), n_repeats=k,
        arrival=arrival, accuracy_floor=floor,
    )


def test_reassign_moves_tiers_and_preserves_fifo():
    sched = TierScheduler(max_batch=4, max_wait=0.0, seq_buckets=(SB,))
    for uid in range(6):
        sched.submit(_req(uid, k=4, arrival=float(uid % 3)))
    moved = sched.reassign(lambda r: 1 if r.uid % 2 == 0 else None)
    assert [(r.uid, old, new) for r, old, new in moved] == [
        (0, 4, 1), (2, 4, 1), (4, 4, 1)
    ]
    # retiered requests really changed tier; survivors kept theirs
    tiers = {r.uid: r.tier for r in sched.queued_requests()}
    assert tiers == {0: 1, 1: 4, 2: 1, 3: 4, 4: 1, 5: 4}
    # destination queue is (arrival, uid)-sorted: global FIFO preserved
    q1 = [r.uid for r in sched.queued_requests() if r.tier == 1]
    assert q1 == sorted(q1, key=lambda u: (float(u % 3), u))
    # idempotent sweeps move nothing and profile ids round-trip
    assert sched.reassign(lambda r: r.tier) == []
    back = sched.reassign(lambda r: "prof-x" if r.tier == 1 else None)
    assert len(back) == 3
    assert all(r.profile_id == "prof-x" and r.n_repeats == 1
               for r, _o, _n in back)


def test_cross_engine_redispatch_preserves_fifo(env):
    """The reassign FIFO property extends across engines: when a cluster
    replica dies and its journal is replayed onto a survivor, the
    re-dispatched requests enter the survivor's tier queue in
    (arrival, cuid) order — failover must not reorder a tier's queue."""
    cluster = ClusterRouter(
        [_engine(env), _engine(env)],
        suspect_after=1, dead_after=3, backoff_rounds=0, backoff_jitter=0,
        faults=(ReplicaCrash(replica=0, at=1),),
    )
    for i, p in enumerate(_prompts(8, seed=5)):
        cluster.submit(p, tier=4, now=0.001 * i)
    t = 0.01
    results = {}
    for _ in range(10):
        results.update(cluster.pump_step(now=t))
        if cluster.health[0] == "dead":
            break
        t += 0.01
    assert cluster.health[0] == "dead" and cluster.stats["failed_over"] > 0
    # with zero backoff the orphans re-entered the survivor's queue inside
    # the same pump round; their queue positions (before the survivor's
    # next admission) must follow the journal replay order
    survivor = cluster.replicas[1]
    orphans = {
        c for c, e in cluster.journal.items() if e.failed_over and not e.done
    }
    queued = [
        survivor.uids[r.uid]
        for r in survivor.engine.scheduler.queued_requests()
        if survivor.uids.get(r.uid) in orphans
    ]
    assert len(queued) == len(orphans) > 0
    want = sorted(queued, key=lambda c: (cluster.journal[c].arrival, c))
    assert queued == want
    # and the episode still loses nothing
    for _ in range(400):
        if not cluster.n_in_flight:
            break
        t += 0.01
        results.update(cluster.pump_step(now=t))
    assert set(results) == set(range(8))
    assert cluster.stats["prefix_mismatches"] == 0


# --------------------------------------------------------------------------
# monitor: load / headroom signals
# --------------------------------------------------------------------------


def test_load_signals_counts_queue_and_urgency(env):
    eng = _engine(env)
    for p in _prompts(3):
        eng.submit(p, n_repeats=4, now=0.0, target_latency=1.0)
    eng.submit(_prompts(1)[0], n_repeats=4, now=0.0)  # no SLO
    sig = load_signals(eng, now=0.6)
    assert sig.queue_depth == 4
    assert sig.queue_pressure == pytest.approx(4 / 2)  # per-pool slots = 2
    # 3 SLO requests, all past half their 1.0s budget at t=0.6
    assert sig.urgent_frac == pytest.approx(1.0)
    assert sig.min_slack == pytest.approx(0.4)  # deadline 1.0 armed by SLO
    assert sig.active == 0 and sig.occupancy == 0.0
    assert load_signals(eng, now=0.1).urgent_frac == 0.0


# --------------------------------------------------------------------------
# submit: SLO plumbing
# --------------------------------------------------------------------------


def test_submit_slo_validation_and_conversion(env):
    eng = _engine(env, policy=_policy())
    with pytest.raises(ValueError, match="target_latency"):
        eng.submit(_prompts(1)[0], now=0.0, target_latency=0.0)
    with pytest.raises(ValueError, match="not both"):
        eng.submit(_prompts(1)[0], now=0.0, accuracy_floor=0.9,
                   max_degradation=0.05)
    # max_degradation resolves against the requested tier's accuracy
    eng.submit(_prompts(1)[0], n_repeats=4, now=0.0, max_degradation=0.05)
    (r,) = eng.scheduler.queued_requests()
    assert r.accuracy_floor == pytest.approx(ACCS[4] - 0.05)
    # target_latency arms the absolute deadline
    eng.submit(_prompts(1)[0], n_repeats=4, now=1.0, target_latency=2.5)
    r2 = eng.scheduler.queued_requests()[-1]
    assert r2.deadline == pytest.approx(3.5)
    assert r2.target_latency == pytest.approx(2.5)
    # an explicit deadline wins over the SLO default
    eng.submit(_prompts(1)[0], now=1.0, target_latency=2.5, deadline=9.0)
    assert eng.scheduler.queued_requests()[-1].deadline == 9.0


def test_max_degradation_needs_a_governor(env):
    eng = _engine(env)  # no policy
    with pytest.raises(ValueError, match="governor"):
        eng.submit(_prompts(1)[0], now=0.0, max_degradation=0.05)


# --------------------------------------------------------------------------
# the governor episode: demote -> serve -> promote back
# --------------------------------------------------------------------------


def test_demotion_respects_floors_and_recovers(env):
    eng = _engine(env, policy=_policy(min_dwell=2))
    floors = {}
    for i, p in enumerate(_prompts(9)):
        floor = (None, 0.85, 0.95)[i % 3]
        uid = eng.submit(p, n_repeats=4, now=0.0, max_new_tokens=4,
                         target_latency=5.0, accuracy_floor=floor)
        floors[uid] = floor
    results, _t = _drain(eng, 0.0)
    gov = eng.governor
    assert set(results) == set(floors)
    assert all(isinstance(v, np.ndarray) for v in results.values())
    # pressure 9/2 >= demote_at fired a demotion episode, then recovery
    kinds = [e.kind for e in gov.events]
    assert "demote" in kinds and "promote" in kinds
    assert gov.mode == "nominal" and not gov.shedding
    assert eng.stats["demoted"] > 0
    # the floor contract: every request was SERVED at a tier meeting it
    for uid, floor in floors.items():
        served = eng.served_tiers[uid]
        if floor is not None:
            assert ACCS[served] >= floor, (uid, floor, served)
    # floorless requests rode to the bottom rung; 0.95-floored could not
    # demote at all (only K=4 meets 0.95) — their ask was never violated
    assert any(eng.served_tiers[u] == 1 for u, f in floors.items() if f is None)
    assert all(eng.served_tiers[u] == 4 for u, f in floors.items() if f == 0.95)
    assert eng.stats["timed_out"] == 0


def test_promote_back_restores_original_tier(env):
    # promote_at high enough that promotion fires while demoted requests
    # are still queued — they must retrace their own ask, not a midpoint
    eng = _engine(env, policy=_policy(
        demote_at=2.0, promote_at=1.75, shed_at=4.0, min_dwell=1,
    ))
    uids = [eng.submit(p, n_repeats=4, now=0.0, max_new_tokens=4)
            for p in _prompts(6)]
    results, _t = _drain(eng, 0.0)
    gov = eng.governor
    promotes = [e for e in gov.events if e.kind == "promote"]
    assert promotes and any(e.moved > 0 for e in promotes)
    # a promoted-back request was dispatched at its original K=4
    restored = [u for e in promotes for u in e.uids]
    assert restored and all(eng.served_tiers[u] == 4 for u in restored)
    assert set(results) == set(uids)


def test_shedding_is_the_last_rung(env):
    eng = _engine(env, policy=_policy(
        demote_at=1.0, promote_at=0.25, shed_at=2.0, min_dwell=1,
    ))
    # every request pins its floor at the top tier: zero demotion headroom
    uids = [eng.submit(p, n_repeats=4, now=0.0, max_new_tokens=4,
                       accuracy_floor=ACCS[4])
            for p in _prompts(8)]
    # two pump rounds: demote (moved 0, no headroom), then shed_on
    eng.pump_step(now=0.01)
    eng.pump_step(now=0.02)
    gov = eng.governor
    kinds = [e.kind for e in gov.events]
    assert kinds[:2] == ["demote", "shed_on"]  # demotion engages first
    assert gov.shedding
    with pytest.raises(QueueFull, match="shedding"):
        eng.submit(_prompts(1, seed=9)[0], n_repeats=4, now=0.03)
    assert eng.stats["shed"] == 1
    shed_log = [e for e in eng.fault_log if e["kind"] == "shed"]
    assert shed_log and shed_log[0]["queue_depth"] > 0
    # drain -> shed_off -> promote -> nominal: new traffic flows again
    results, t = _drain(eng, 0.03)
    for _ in range(6):  # idle policy steps to walk the modes back down
        t += 0.01
        eng.pump_step(now=t)
    assert not gov.shedding and gov.mode == "nominal"
    assert set(results) == set(uids)
    uid = eng.submit(_prompts(1, seed=11)[0], n_repeats=4, now=t)
    res, _t = _drain(eng, t)
    assert isinstance(res[uid], np.ndarray)
    # every request was served at its floor tier: never demoted below
    assert all(eng.served_tiers[u] == 4 for u in uids)


def test_power_budget_demotes_and_blocks_promotion(env):
    e1 = [e for e, _a, t in _engine(env, policy=_policy()).governor.tiers
          if t == 1][0]
    e4 = [e for e, _a, t in _engine(env, policy=_policy()).governor.tiers
          if t == 4][0]
    # ceiling between K=1 and K=4 spend: K=4 traffic must demote even
    # though the queue alone is far below the demote threshold
    eng = _engine(env, policy=_policy(
        demote_at=50.0, promote_at=0.25, shed_at=50.0, min_dwell=1,
        power_budget_aj=(e1 + e4) / 2,
    ))
    uid = eng.submit(_prompts(1)[0], n_repeats=4, now=0.0, max_new_tokens=4)
    eng.pump_step(now=0.01)
    gov = eng.governor
    demotes = [e for e in gov.events if e.kind == "demote"]
    assert demotes and demotes[0].detail == "power budget"
    results, t = _drain(eng, 0.01)
    assert eng.served_tiers[uid] == 1  # floorless: rode to the cheapest rung
    # promotion back to nominal is allowed only once restoring original
    # tiers would fit the budget — with the queue empty it fits trivially
    for _ in range(4):
        t += 0.01
        eng.pump_step(now=t)
    assert gov.mode == "nominal"
    assert isinstance(results[uid], np.ndarray)


# --------------------------------------------------------------------------
# satellite: drift estimate as a demotion / promotion signal
# --------------------------------------------------------------------------


def test_load_signals_carry_the_feed_drift_estimate(env):
    feed = MetricsFeed(capacity=8)
    eng = _engine(env, metrics=feed)
    assert load_signals(eng, now=0.0).drift is None  # no probe yet
    feed.note_drift(1.3)
    assert load_signals(eng, now=0.0).drift == pytest.approx(1.3)
    feed.note_drift(None)  # recalibration clears it
    assert load_signals(eng, now=0.0).drift is None
    # an engine without a feed observes no drift axis at all
    assert load_signals(_engine(env), now=0.0).drift is None


def test_drift_excursion_demotes_and_blocks_promotion(env):
    feed = MetricsFeed(capacity=64)
    # thresholds far above any queue this test builds: only drift can
    # demote here — the point is it rides the same retier path as load
    eng = _engine(env, metrics=feed, policy=_policy(
        demote_at=50.0, promote_at=0.25, shed_at=60.0, min_dwell=1,
        drift_band=(0.8, 1.25), drift_patience=2,
    ))
    gov = eng.governor
    # no estimate yet, then an in-band one: both are nominal evidence
    eng.pump_step(now=0.01)
    feed.note_drift(1.05)
    eng.pump_step(now=0.02)
    assert gov.mode == "nominal" and gov.events == []
    # out-of-band: one step is scatter, drift_patience=2 steps is real
    feed.note_drift(1.6)
    eng.pump_step(now=0.03)
    assert gov.mode == "nominal"
    eng.pump_step(now=0.04)
    assert gov.mode == "demoted"
    demotes = [e for e in gov.events if e.kind == "demote"]
    assert demotes and demotes[0].detail == "drift"
    # traffic arriving during the episode joins it: a floorless K=4 ask
    # is retiered down the registry-resolved ladder before admission
    uid = eng.submit(_prompts(1)[0], n_repeats=4, now=0.05, max_new_tokens=4)
    results, t = _drain(eng, 0.05)
    assert eng.served_tiers[uid] == 1
    assert isinstance(results[uid], np.ndarray)
    # queue is empty (pressure 0) but the excursion persists: promotion
    # back to nominal stays blocked until the estimate returns in-band
    for _ in range(4):
        t += 0.01
        eng.pump_step(now=t)
    assert gov.mode == "demoted"
    feed.note_drift(1.0)  # recalibrated: streak resets immediately
    t += 0.01
    eng.pump_step(now=t)
    assert gov.mode == "nominal"
    kinds = [e.kind for e in gov.events]
    # demote opened the episode, the mid-episode submit was retiered into
    # it, and promotion closed it only after the estimate came back
    assert kinds[0] == "demote" and kinds[-1] == "promote"
    assert "retier" in kinds


# --------------------------------------------------------------------------
# core/search.py: online re-trim between serving epochs
# --------------------------------------------------------------------------


def _acc_by_total(reps):
    """Exact synthetic proxy: accuracy = sum(K) / 10 (no float fuzz)."""
    return sum(reps) / 10.0


def test_online_search_descends_from_frozen():
    res = online_repeat_profile_search(
        _acc_by_total, frozen=(4, 4, 4), float_acc=0.6, max_degradation=0.0,
        k_levels=(1, 2, 4), weights=(3.0, 2.0, 1.0),
    )
    assert res.feasible and not res.repaired
    assert sum(res.repeats) >= 6 and res.cost < 24.0  # trimmed below frozen
    assert res.accuracy == pytest.approx(sum(res.repeats) / 10.0)


def test_online_search_repairs_a_drifted_floor():
    # the frozen schedule was feasible offline; live stats say it is not
    res = online_repeat_profile_search(
        _acc_by_total, frozen=(1, 1, 1), float_acc=0.6, max_degradation=0.0,
        k_levels=(1, 2, 4), weights=(3.0, 2.0, 1.0),
    )
    assert res.feasible and res.repaired
    assert sum(res.repeats) >= 6
    # repair is energy-ordered: the cheap layer (w=1) absorbed the raise
    assert res.repeats == (1, 1, 4)


def test_online_search_budget_keeps_the_vetted_profile():
    res = online_repeat_profile_search(
        _acc_by_total, frozen=(1, 1, 1), float_acc=0.6, max_degradation=0.0,
        k_levels=(1, 2, 4), max_evals=2,
    )
    # budget died mid-repair with no feasible schedule known: serving
    # keeps the frozen profile rather than adopting an unvetted one
    assert not res.feasible and res.repeats == (1, 1, 1)
    assert res.n_evals == 2

    def unreachable(reps):
        return 0.0  # no schedule is feasible

    res2 = online_repeat_profile_search(
        unreachable, frozen=(4, 4, 4), float_acc=0.6, max_degradation=0.0,
        k_levels=(1, 2, 4),
    )
    assert not res2.feasible and res2.repeats == (4, 4, 4)


# --------------------------------------------------------------------------
# satellite: hypothesis property — random load ramps through the governor
# --------------------------------------------------------------------------

_RAMP = {}


def _ramp_engine():
    """One warm shared engine across property examples: every policy tier
    and admission shape compiles during warmup, so the examples themselves
    must run at zero retraces (the AOT contract under reassignment)."""
    if not _RAMP:
        params = init_params(KEY, MODEL)
        energies = init_energy_tree(MODEL, ENERGY_AJ)
        eng = ServingEngine(
            params, MODEL, analog_cfg=AnalogConfig.shot(), energies=energies,
            max_gen=8, max_batch=4, max_wait=0.0, batch_buckets=(1, 2, 4),
            seq_buckets=(SB,), continuous=True, pool_slots=2,
            k_ladder=(1, 2, 4),
            policy=_policy(demote_at=1.0, promote_at=0.25, shed_at=6.0,
                           min_dwell=3),
        )
        # warmup: solo + paired admissions at every policy tier (floors at
        # the top so the warmup traffic itself never demotes)
        t = 0.0
        for k in (1, 2, 4):
            for n in (1, 2):
                for p in _prompts(n, seed=100 + k + n):
                    eng.submit(p, n_repeats=k, now=t, max_new_tokens=3,
                               accuracy_floor=ACCS[4])
                _, t = _drain(eng, t)
        for _ in range(8):  # walk the governor back to nominal
            t += 0.01
            eng.pump_step(now=t)
        assert eng.governor.mode == "nominal"
        _RAMP.update(eng=eng, t=t, traces=eng.trace_count)
    return _RAMP["eng"]


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_random_load_ramp_property(seed):
    eng = _ramp_engine()
    gov = eng.governor
    rng = np.random.default_rng(seed)
    t = _RAMP["t"]
    floors = {}
    # a random ramp: 12 ticks, 0-3 arrivals each, all asking for K=4
    for _tick in range(12):
        for _ in range(int(rng.integers(0, 4))):
            p = rng.integers(0, 128, 8).astype(np.int32)
            floor = (None, ACCS[2], ACCS[4])[int(rng.integers(0, 3))]
            uid = eng.submit(p, n_repeats=4, now=t, target_latency=50.0,
                             accuracy_floor=floor,
                             max_new_tokens=int(rng.integers(1, 5)))
            floors[uid] = floor
        t += 0.01
        eng.pump_step(now=t)
    _, t = _drain(eng, t)
    for _ in range(2 * gov.config.min_dwell + 2):  # recovery policy steps
        t += 0.01
        eng.pump_step(now=t)
    _RAMP["t"] = t

    # recovery: the governor always walks back to nominal after the drain
    assert gov.mode == "nominal" and not gov.shedding
    # no flapping: mode transitions are at least min_dwell steps apart
    flips = [e for e in gov.events if e.kind in TRANSITIONS]
    for a, b in zip(flips, flips[1:]):
        assert b.step - a.step >= gov.config.min_dwell, (a, b)
    # accuracy floors are never violated at the SERVED tier
    for uid, floor in floors.items():
        if floor is not None:
            assert ACCS[eng.served_tiers[uid]] >= floor, (uid, floor)
    # tier reassignment never causes a steady-state retrace: every tier
    # and admission shape was warmed, so whole episodes compile nothing
    assert eng.trace_count == _RAMP["traces"], "steady-state retrace"
    # events are attributable: clock + triggering measurement on each
    for e in gov.events:
        assert e.clock >= 0 and e.pressure >= 0.0
