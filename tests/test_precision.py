"""Noise-bits analysis (paper §III): Eq. 7/8 and the Table-I equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import noise_bits, noise_var_from_bits, thermal_noise_bits
from repro.core.precision import average_bits, empirical_noise_var


@settings(max_examples=50, deadline=None)
@given(
    rng=st.floats(min_value=1e-2, max_value=1e3),
    bits=st.floats(min_value=1.0, max_value=12.0),
)
def test_bits_variance_inverse_roundtrip(rng, bits):
    var = noise_var_from_bits(rng, bits)
    b = noise_bits(rng, var)
    assert float(b) == pytest.approx(bits, rel=1e-4)


def test_noise_bits_monotonic_in_noise():
    rng = 4.0
    bits = [float(noise_bits(rng, v)) for v in (1e-6, 1e-4, 1e-2, 1.0)]
    assert bits == sorted(bits, reverse=True)


def test_eq8_matches_generic_formula():
    """Eq. 8 == Eq. 7 applied to the Eq. 3 thermal variance."""
    n, wr, xr, sig, e, out_rng = 256, 1.5, 2.5, 0.01, 4.0, 3.0
    var = n * (wr * xr * sig) ** 2 / e
    b_generic = noise_bits(out_rng, var)
    b_explicit = thermal_noise_bits(out_rng, n, wr, xr, sig, e)
    assert float(b_generic) == pytest.approx(float(b_explicit), rel=1e-5)


def test_noisy_accuracy_matches_equivalent_bits():
    """Table-I mechanism at unit scale: evaluating a linear layer under
    gaussian noise of variance V ~= quantizing its output to B_eps(V) bits
    (measured as MSE agreement within 2x)."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2048, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8)) * 0.3
    y = x @ w
    out_rng = float(y.max() - y.min())
    for target_bits in (3.0, 5.0, 7.0):
        var = float(noise_var_from_bits(out_rng, target_bits))
        noisy = y + jax.random.normal(jax.random.fold_in(key, 2), y.shape) * np.sqrt(var)
        # quantize to the equivalent number of bits
        from repro.quant import QuantParams, fake_quant

        qp = QuantParams(
            x_min=jnp.asarray(float(y.min())),
            x_max=jnp.asarray(float(y.max())),
            bits=target_bits,
        )
        quantized = fake_quant(y, qp)
        mse_noise = float(jnp.mean((noisy - y) ** 2))
        mse_quant = float(jnp.mean((quantized - y) ** 2))
        ratio = mse_noise / mse_quant
        assert 1 / 2.5 < ratio < 2.5, (target_bits, ratio)


def test_average_bits_unweighted_is_plain_layer_mean():
    """The Table-I default: a plain mean over layers (per-channel layers
    mean-reduced first), regardless of how the MACs are distributed."""
    bits = {"a": 2.0, "b": jnp.asarray([4.0, 8.0]), "c": 6.0}
    macs = {"a": 1e9, "b": jnp.asarray([1.0, 1.0]), "c": 1.0}
    got = float(average_bits(bits, macs))
    assert got == pytest.approx((2.0 + 6.0 + 6.0) / 3.0)
    # per_layer_macs is genuinely unused in the unweighted form
    assert got == pytest.approx(float(average_bits(bits)))


def test_average_bits_weighted_by_macs():
    """weighted=True: sum_l B_l * n_l / sum_l n_l with n_l the layer's total
    MACs — a giant low-bit layer dominates, a tiny high-bit head does not."""
    bits = {"big": 2.0, "head": 10.0}
    macs = {"big": 3.0, "head": 1.0}
    got = float(average_bits(bits, macs, weighted=True))
    assert got == pytest.approx((2.0 * 3.0 + 10.0 * 1.0) / 4.0)
    # per-channel layers: mean bits, summed MACs
    bits2 = {"a": jnp.asarray([1.0, 3.0]), "b": 4.0}
    macs2 = {"a": jnp.asarray([5.0, 5.0]), "b": 10.0}
    got2 = float(average_bits(bits2, macs2, weighted=True))
    assert got2 == pytest.approx((2.0 * 10.0 + 4.0 * 10.0) / 20.0)
    # uniform MACs: weighted collapses to the unweighted mean
    uni = {k: 7.0 for k in bits}
    assert float(average_bits(bits, uni, weighted=True)) == pytest.approx(
        float(average_bits(bits))
    )
    with pytest.raises(ValueError, match="per_layer_macs"):
        average_bits(bits, weighted=True)


def test_empirical_noise_var():
    key = jax.random.PRNGKey(3)
    clean = jnp.zeros((4096,))
    noisy = clean + 0.3 * jax.random.normal(key, clean.shape)
    assert float(empirical_noise_var(clean, noisy)) == pytest.approx(0.09, rel=0.1)
