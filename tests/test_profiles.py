"""Per-layer precision profiles: the PrecisionProfile object, the greedy
repeat-schedule search, the segmented same-K layer scan in models/lm.py
(with its unrolled-loop and scaled-energy equivalence oracles), and profile
tiers through the serving engine — solo vs padded-bucket-batched tokens must
stay bit-identical under a non-uniform profile, exactly like uniform K."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AnalogConfig,
    PrecisionProfile,
    apply_repeats,
    coalesce_runs,
    repeat_profile_search,
)
from repro.models import init_energy_tree, init_params, lm
from repro.models.config import ModelConfig
from repro.serving import ServingEngine

KEY = jax.random.PRNGKey(0)
ENERGY_AJ = 20.0
SB = 32

_BASE = dict(
    n_layers=2, d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
    vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16, loss_chunk=32,
    dtype="float32",
)
#: families x non-uniform schedules. griffin: one scan group of 3 sublayers
#: plus per-sublayer Ks; xlstm: (mlstm, slstm) group; dense: per-group
#: segments (2 segments for (1, 2)).
FAMILY_CASES = {
    "dense": (
        ModelConfig(name="prof-dense", family="dense", d_ff=64, **_BASE),
        (1, 2),
    ),
    "windowed": (
        ModelConfig(name="prof-win", family="dense", d_ff=64, sliding_window=8, **_BASE),
        (2, 1),
    ),
    "griffin": (
        ModelConfig(
            name="prof-griffin", family="griffin", n_layers=3, d_model=32,
            n_heads=2, n_kv_heads=1, head_dim=16, d_ff=64, vocab_size=128,
            rnn_width=32, conv_width=4, local_window=8, attn_q_chunk=16,
            attn_kv_chunk=16, loss_chunk=32, dtype="float32",
        ),
        (2, 1, 1),
    ),
    "xlstm": (
        ModelConfig(
            name="prof-xlstm", family="xlstm", d_ff=0, slstm_ratio=2,
            n_kv_heads=2, **{k: v for k, v in _BASE.items() if k != "n_kv_heads"}
        ),
        (2, 1),
    ),
}


# --------------------------------------------------------------------------
# the profile object: validation, degenerate uniform case, persistence
# --------------------------------------------------------------------------


def test_profile_validation_and_uniform():
    p = PrecisionProfile((2, 1, 4), name="p")
    assert p.n_layers == 3 and p.max_k == 4 and not p.is_uniform
    u = PrecisionProfile.uniform(2, 3)
    assert u.is_uniform and u.repeats == (2, 2, 2) and u.name == "uniform-2"
    with pytest.raises(ValueError, match=">= 1"):
        PrecisionProfile((1, 0), name="bad")
    with pytest.raises(ValueError, match="at least one"):
        PrecisionProfile((), name="empty")
    with pytest.raises(ValueError, match="name"):
        PrecisionProfile((1,), name="")


def test_profile_cache_key_degenerate_and_distinct():
    """Uniform profiles key as the bare K (they ARE the n_repeats tier and
    must share its executables); non-uniform schedules key on the repeat
    tuple; the unrolled oracle form never aliases the coalesced trace."""
    assert PrecisionProfile.uniform(4, 3).cache_key() == 4
    assert PrecisionProfile((2, 1), name="p").cache_key() == (2, 1)
    assert PrecisionProfile((2, 1), name="p", coalesce=False).cache_key() == (
        "unrolled", 2, 1,
    )
    assert PrecisionProfile((2, 1), name="a").cache_key() == (
        PrecisionProfile((2, 1), name="b").cache_key()
    )  # identity is the schedule, not the name


def test_profile_save_load_roundtrip(tmp_path):
    p = PrecisionProfile((4, 2, 1, 1), name="resnet-ish")
    path = str(tmp_path / "profile.json")
    p.save(path)
    q = PrecisionProfile.load(path)
    assert q == p


def test_coalesce_runs():
    rows = [(2,), (2,), (1,), (1,), (2,)]
    assert coalesce_runs(rows) == [(0, 2, (2,)), (2, 4, (1,)), (4, 5, (2,))]
    assert coalesce_runs(rows, coalesce=False) == [
        (i, i + 1, r) for i, r in enumerate(rows)
    ]
    assert coalesce_runs([]) == []


# --------------------------------------------------------------------------
# greedy repeat search: lowers exactly the layers that can afford it
# --------------------------------------------------------------------------


def _needs_acc_fn(needs, drop=0.05):
    """Accuracy model: each layer below its required K costs ``drop``."""
    return lambda reps: 1.0 - drop * sum(k < n for k, n in zip(reps, needs))


def test_repeat_profile_search_finds_layer_needs():
    needs = (4, 1, 2)
    res = repeat_profile_search(
        _needs_acc_fn(needs), n_layers=3, float_acc=1.0, max_degradation=0.02,
        k_levels=(1, 2, 4),
    )
    assert res.feasible
    assert res.repeats == needs  # every layer at exactly its minimum K
    assert res.accuracy == 1.0
    assert res.cost < res.uniform_cost
    assert res.n_evals == len(res.trace) == len({r for r, _ in res.trace})


def test_repeat_profile_search_weights_order_not_result():
    """Energy weights steer the descent order, not the fixed point."""
    needs = (2, 1)
    for w in ((1.0, 100.0), (100.0, 1.0)):
        res = repeat_profile_search(
            _needs_acc_fn(needs), n_layers=2, float_acc=1.0,
            k_levels=(1, 2, 4), weights=w,
        )
        assert res.repeats == needs
        assert res.cost == sum(k * wl for k, wl in zip(needs, w))


def test_repeat_profile_search_infeasible_start():
    res = repeat_profile_search(
        lambda reps: 0.5, n_layers=2, float_acc=1.0, max_degradation=0.02,
        k_levels=(1, 2),
    )
    assert not res.feasible
    assert res.repeats == (2, 2)  # unchanged uniform max: nothing to serve


def test_repeat_profile_search_warm_init():
    """A warm start (e.g. the schedule learned at a neighbouring floor) is
    honoured and only descended, never raised."""
    needs = (2, 1, 1)
    res = repeat_profile_search(
        _needs_acc_fn(needs), n_layers=3, float_acc=1.0,
        k_levels=(1, 2, 4), init=(2, 2, 1),
    )
    assert res.repeats == needs
    # the search only descends: no evaluated schedule exceeds init anywhere
    assert all(
        all(k <= k0 for k, k0 in zip(r, (2, 2, 1))) for r, _ in res.trace
    )
    # the savings baseline stays uniform max-K even under a warm start
    assert res.uniform_cost == 4 * 3
    with pytest.raises(ValueError, match="ladder"):
        repeat_profile_search(
            _needs_acc_fn(needs), n_layers=3, float_acc=1.0,
            k_levels=(1, 2, 4), init=(3, 1, 1),
        )


# --------------------------------------------------------------------------
# segmented layer scan: three independent equivalence oracles
# --------------------------------------------------------------------------

MODEL3 = ModelConfig(
    name="prof-dense3", family="dense", n_layers=3, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=128, attn_q_chunk=16, attn_kv_chunk=16,
    loss_chunk=32, dtype="float32",
)


def _forward(cfg, params, energies, toks, **spec_kw):
    analog = lm.AnalogSpec(
        cfg=AnalogConfig.shot(), energies=energies, key=KEY, **spec_kw
    )
    return lm.forward_hidden(
        params, {"tokens": toks}, cfg, mode="prefill", analog=analog,
        cache_len=toks.shape[1] + 4,
    )


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_uniform_profile_matches_plain_n_repeats():
    """The degenerate case really is degenerate: a uniform profile's forward
    (one segment spanning the whole scan) is bit-identical to n_repeats=K."""
    params = init_params(KEY, MODEL3)
    energies = init_energy_tree(MODEL3, ENERGY_AJ)
    toks = jax.random.randint(KEY, (2, 16), 0, MODEL3.vocab_size)
    h_k, c_k = _forward(MODEL3, params, energies, toks, n_repeats=2)
    h_p, c_p = _forward(
        MODEL3, params, energies, toks, profile=PrecisionProfile.uniform(2, 3)
    )
    np.testing.assert_array_equal(np.asarray(h_k), np.asarray(h_p))
    _assert_trees_equal(c_k, c_p)


def test_segmented_scan_matches_unrolled_loop_oracle():
    """The segmentation oracle: merging contiguous same-K groups into shared
    scan segments must be invisible — coalesce=False runs every scan group
    as its own segment (a python loop of single-group scans) and must match
    the coalesced form bit-exactly."""
    params = init_params(KEY, MODEL3)
    energies = init_energy_tree(MODEL3, ENERGY_AJ)
    toks = jax.random.randint(KEY, (2, 16), 0, MODEL3.vocab_size)
    reps = (2, 2, 1)  # coalesced: segments [0:2], [2:3]
    h_c, c_c = _forward(
        MODEL3, params, energies, toks, profile=PrecisionProfile(reps, name="p")
    )
    h_u, c_u = _forward(
        MODEL3, params, energies, toks,
        profile=PrecisionProfile(reps, name="p", coalesce=False),
    )
    np.testing.assert_array_equal(np.asarray(h_c), np.asarray(h_u))
    _assert_trees_equal(c_c, c_u)


@pytest.mark.parametrize("family", sorted(FAMILY_CASES))
def test_profile_matches_scaled_energy_oracle(family):
    """Independent-semantics oracle, every family: serving layer l at K_l
    repeats is (on the jnp path) bit-identical to serving at K=1 with that
    layer's energies scaled by K_l — profile forward must equal the plain
    forward over apply_repeats(energies, profile_repeat_tree). Covers the
    per-sublayer hook threading, segment boundaries, global layer indices
    (noise streams), and the griffin tail layers."""
    cfg, reps = FAMILY_CASES[family]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    profile = PrecisionProfile(reps, name="p")
    h_p, c_p = _forward(cfg, params, energies, toks, profile=profile)
    scaled = apply_repeats(energies, lm.profile_repeat_tree(cfg, profile))
    h_s, c_s = _forward(cfg, params, scaled, toks)
    np.testing.assert_array_equal(np.asarray(h_p), np.asarray(h_s))
    _assert_trees_equal(c_p, c_s)
    # decode: same equivalence from the (identical) caches
    pos = jnp.asarray(16)
    shot = AnalogConfig.shot()
    l_p, _ = lm.decode_step(
        params, c_p, {"tokens": toks[:, :1]}, pos, cfg,
        analog=lm.AnalogSpec(cfg=shot, energies=energies, key=KEY, profile=profile),
    )
    l_s, _ = lm.decode_step(
        params, c_s, {"tokens": toks[:, :1]}, pos, cfg,
        analog=lm.AnalogSpec(cfg=shot, energies=scaled, key=KEY),
    )
    np.testing.assert_array_equal(np.asarray(l_p), np.asarray(l_s))


def test_moe_profile_matches_scaled_energy_oracle():
    """MoE coverage of the same oracle (prefill only — expert dispatch is the
    slow compile): per-sublayer K reaches the router, expert-batched sites,
    and the batch-level noise stream identically to scaled energies."""
    cfg = ModelConfig(
        name="prof-moe", family="moe", d_ff=64, n_experts=4, top_k=2,
        moe_every=2, capacity_factor=2.0, moe_group_size=64, **_BASE
    )
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    profile = PrecisionProfile((2, 1), name="p")  # (attn+mlp, attn+moe) group
    h_p, c_p = _forward(cfg, params, energies, toks, profile=profile)
    scaled = apply_repeats(energies, lm.profile_repeat_tree(cfg, profile))
    h_s, c_s = _forward(cfg, params, scaled, toks)
    np.testing.assert_array_equal(np.asarray(h_p), np.asarray(h_s))
    _assert_trees_equal(c_p, c_s)


def test_profile_shape_validation():
    params = init_params(KEY, MODEL3)
    energies = init_energy_tree(MODEL3, ENERGY_AJ)
    toks = jax.random.randint(KEY, (1, 8), 0, MODEL3.vocab_size)
    with pytest.raises(ValueError, match="layers"):
        _forward(MODEL3, params, energies, toks,
                 profile=PrecisionProfile((2, 1), name="short"))
    with pytest.raises(ValueError, match="overrides n_repeats"):
        _forward(MODEL3, params, energies, toks, n_repeats=2,
                 profile=PrecisionProfile((2, 1, 1), name="p"))


def test_profile_repeat_tree_and_token_energy():
    """sum_l K_l * E_l * MACs_l, pinned by hand on the 2-layer dense model:
    per-layer K scales every site of its layer, the (digitally served)
    lm_head stays at K=1."""
    cfg, _ = FAMILY_CASES["dense"]
    energies = init_energy_tree(cfg, ENERGY_AJ)
    macs = lm.energy_macs(cfg, 1)
    profile = PrecisionProfile((4, 1), name="p")
    tree = lm.profile_repeat_tree(cfg, profile)
    assert float(tree["lm_head"]) == 1.0
    for site, k in tree["groups"].items():
        np.testing.assert_array_equal(np.asarray(k).reshape(-1), [4.0, 1.0])
    expect = float(tree["lm_head"]) * float(energies["lm_head"]) * float(macs["lm_head"])
    for site in energies["groups"]:
        e = np.asarray(energies["groups"][site], np.float64)
        m = np.asarray(macs["groups"][site], np.float64)
        expect += float((np.asarray([4.0, 1.0]) * e * m).sum())
    got = lm.profile_token_energy(cfg, energies, profile)
    assert got == pytest.approx(expect, rel=1e-6)
    # uniform pricing: K * (all analog sites) + 1 * lm_head
    uni = lm.profile_token_energy(cfg, energies, PrecisionProfile.uniform(2, 2))
    base = lm.profile_token_energy(cfg, energies, PrecisionProfile.uniform(1, 2))
    head = float(energies["lm_head"]) * float(macs["lm_head"])
    assert uni == pytest.approx(2 * (base - head) + head, rel=1e-6)


# --------------------------------------------------------------------------
# serving: a profile is a tier — learn, freeze, serve, bit-identical
# --------------------------------------------------------------------------


def _prompts_and_keys(n=3):
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, 128, L) for L in (7, 19, 28)[:n]]
    keys = [jax.random.fold_in(jax.random.PRNGKey(5), i) for i in range(n)]
    return prompts, keys


@pytest.mark.parametrize("family", sorted(FAMILY_CASES))
def test_family_profile_solo_vs_batched_equivalence(family):
    """The acceptance contract, per family: a request served under a
    NON-UNIFORM profile in a padded bucket batch (pad rows + shorter
    batch-mates) is bit-identical to its solo run through the same engine.
    (MoE is excluded exactly as for uniform K: expert capacity buffers mix
    requests, so analog MoE is reproducible per batch composition.)"""
    cfg, reps = FAMILY_CASES[family]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    profile = PrecisionProfile(reps, name="learned")
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=8, max_batch=4, max_wait=1.0, batch_buckets=(1, 2, 4),
        seq_buckets=(SB,), profiles=[profile],
    )
    prompts, keys = _prompts_and_keys()
    uids = [
        eng.submit(p, profile="learned", max_new_tokens=4, key=k, now=0.0)
        for p, k in zip(prompts, keys)
    ]
    padded_before = eng.stats["padded_rows"]
    batched = eng.flush()
    assert eng.stats["padded_rows"] - padded_before == 1  # bb=4 held 3 reqs
    for uid, p, k in zip(uids, prompts, keys):
        solo_uid = eng.submit(p, profile="learned", max_new_tokens=4, key=k, now=0.0)
        solo = eng.flush()[solo_uid]
        np.testing.assert_array_equal(batched[uid], solo)
    # steady state: replaying the same trace is all cache hits, no retraces
    eng.exe_cache.reset_stats()
    traces_before = eng.trace_count
    for p, k in zip(prompts, keys):
        eng.submit(p, profile="learned", max_new_tokens=4, key=k, now=0.0)
    eng.flush()
    assert eng.exe_cache.stats()["misses"] == 0
    assert eng.trace_count == traces_before


def test_profile_tier_never_mixes_with_uniform_tiers():
    """A profile tier is its own scheduling group: its requests never share
    a batch with uniform-K traffic (K schedules are baked into traces)."""
    cfg, reps = FAMILY_CASES["dense"]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=8, max_batch=4, max_wait=1.0, batch_buckets=(1, 2, 4),
        seq_buckets=(SB,), profiles=[PrecisionProfile(reps, name="learned")],
    )
    prompts, keys = _prompts_and_keys()
    batches_before = eng.stats["batches"]
    uids_p = [eng.submit(p, profile="learned", max_new_tokens=4, key=k, now=0.0)
              for p, k in zip(prompts, keys)]
    uids_u = [eng.submit(p, n_repeats=2, max_new_tokens=4, key=k, now=0.0)
              for p, k in zip(prompts, keys)]
    out = eng.flush()
    assert set(out) == set(uids_p) | set(uids_u)
    assert eng.stats["batches"] - batches_before == 2  # one batch per tier


def test_uniform_profile_degenerates_to_k_tier():
    """uniform-K as a profile IS the n_repeats=K tier: same scheduling
    group (shared batch), same executables, bit-identical tokens."""
    cfg, _ = FAMILY_CASES["dense"]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=8, max_batch=4, max_wait=1.0, batch_buckets=(1, 2, 4),
        seq_buckets=(SB,),
    )
    prompts, keys = _prompts_and_keys(2)
    batches_before = eng.stats["batches"]
    u0 = eng.submit(prompts[0], profile=PrecisionProfile.uniform(2, 2),
                    max_new_tokens=4, key=keys[0], now=0.0)
    u1 = eng.submit(prompts[1], n_repeats=2, max_new_tokens=4, key=keys[1], now=0.0)
    out = eng.flush()
    assert eng.stats["batches"] - batches_before == 1  # one shared batch
    # same request under either spelling: bit-identical
    s0 = eng.submit(prompts[0], n_repeats=2, max_new_tokens=4, key=keys[0], now=0.0)
    np.testing.assert_array_equal(out[u0], eng.flush()[s0])
    # a uniform UNROLLED-oracle profile must NOT degenerate: its trace is
    # deliberately distinct, so it stays its own tier (and never shares a
    # batch with the K tier) — while its tokens still match bit-exactly
    oracle = PrecisionProfile.uniform(2, 2)
    oracle = dataclasses.replace(oracle, name="oracle", coalesce=False)
    batches_before = eng.stats["batches"]
    o0 = eng.submit(prompts[0], profile=oracle, max_new_tokens=4, key=keys[0], now=0.0)
    k0 = eng.submit(prompts[1], n_repeats=2, max_new_tokens=4, key=keys[1], now=0.0)
    out2 = eng.flush()
    assert eng.stats["batches"] - batches_before == 2  # oracle tier separate
    np.testing.assert_array_equal(out[u0], out2[o0])


def test_engine_profile_registry_validation():
    cfg, reps = FAMILY_CASES["dense"]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=4, max_batch=2, max_wait=0.0, batch_buckets=(1, 2),
        seq_buckets=(SB,),
    )
    with pytest.raises(ValueError, match="layers"):
        eng.register_profile(PrecisionProfile((1, 2, 4), name="wrong-depth"))
    eng.register_profile(PrecisionProfile(reps, name="p"))
    eng.register_profile(PrecisionProfile(reps, name="p"))  # idempotent
    with pytest.raises(ValueError, match="frozen"):
        eng.register_profile(PrecisionProfile((4, 4, 1)[:2], name="p"))
    with pytest.raises(ValueError, match="unknown profile"):
        eng.submit(np.arange(4), profile="never-registered", now=0.0)
    with pytest.raises(ValueError, match="not both"):
        eng.submit(np.arange(4), profile="p", n_repeats=2, now=0.0)
    with pytest.raises(ValueError, match="unknown profile"):
        eng.tier_energy_per_token("never-registered")
    assert eng.scheduler.n_pending == 0  # nothing half-enqueued


def test_digital_engine_ignores_profiles():
    """K is a no-op without noise: digital engines coalesce profile and
    uniform submissions into one batch, exactly like mixed K."""
    cfg, reps = FAMILY_CASES["dense"]
    params = init_params(KEY, cfg)
    eng = ServingEngine(
        params, cfg, max_gen=4, max_batch=2, max_wait=0.0,
        batch_buckets=(1, 2), seq_buckets=(SB,),
        profiles=[PrecisionProfile(reps, name="p")],
    )
    u0 = eng.submit(np.arange(10) % cfg.vocab_size, profile="p", now=0.0)
    u1 = eng.submit(np.arange(4) % cfg.vocab_size, n_repeats=4, now=0.0)
    out = eng.flush()
    assert set(out) == {u0, u1}
    assert eng.stats["batches"] == 1
    with pytest.raises(ValueError, match="digital"):
        eng.tier_energy_per_token("p")


def test_engine_tier_energy_accounting():
    """The engine prices tiers by the true schedule: a profile that lowers
    any layer undercuts its uniform ceiling, and uniform pricing matches
    profile_token_energy on the degenerate profile."""
    cfg, reps = FAMILY_CASES["dense"]
    params = init_params(KEY, cfg)
    energies = init_energy_tree(cfg, ENERGY_AJ)
    profile = PrecisionProfile(reps, name="learned")
    eng = ServingEngine(
        params, cfg, analog_cfg=AnalogConfig.shot(), energies=energies,
        max_gen=4, max_batch=2, max_wait=0.0, batch_buckets=(1, 2),
        seq_buckets=(SB,), profiles=[profile],
    )
    e_prof = eng.tier_energy_per_token("learned")
    e_hi = eng.tier_energy_per_token(max(reps))
    e_lo = eng.tier_energy_per_token(min(reps))
    assert e_lo < e_prof < e_hi
    assert e_prof == pytest.approx(
        lm.profile_token_energy(cfg, energies, profile), rel=1e-6
    )
    assert e_hi == pytest.approx(
        lm.profile_token_energy(
            cfg, energies, PrecisionProfile.uniform(max(reps), cfg.n_layers)
        ),
        rel=1e-6,
    )
