"""Affine quantization: unit + property tests (paper §II-B, Eq. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.quant import (
    QuantParams,
    calibrate_minmax,
    calibrate_percentile,
    dequantize,
    fake_quant,
    quantize,
    ste_round,
)


def test_roundtrip_error_bounded_by_half_delta():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512,)) * 3.0
    qp = calibrate_minmax(x, bits=8)
    err = jnp.abs(fake_quant(x, qp) - x)
    assert float(err.max()) <= float(qp.delta) / 2 + 1e-6


@settings(max_examples=50, deadline=None)
@given(
    bits=st.floats(min_value=2.0, max_value=8.0),
    scale=st.floats(min_value=1e-2, max_value=1e3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quant_property_roundtrip(bits, scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=64) * scale, jnp.float32)
    qp = calibrate_minmax(x, bits=bits)
    y = fake_quant(x, qp)
    # inside the calibrated range, error <= delta/2
    assert float(jnp.abs(y - x).max()) <= float(qp.delta) / 2 + 1e-4 * scale
    # idempotent
    y2 = fake_quant(y, qp)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y), rtol=1e-6, atol=1e-6)


def test_fractional_bits_bins():
    """Paper footnote 1: 4.644 bits -> 25 bins (rounding up)."""
    qp = QuantParams(x_min=jnp.zeros(()), x_max=jnp.ones(()), bits=4.644)
    assert int(qp.n_bins) == 25


def test_codes_are_integers_in_range():
    x = jnp.linspace(-2, 5, 101)
    qp = calibrate_minmax(x, bits=4)
    codes = quantize(x, qp)
    assert float(jnp.min(codes)) >= 0
    assert float(jnp.max(codes)) <= float(qp.n_bins)
    np.testing.assert_allclose(np.asarray(codes), np.round(np.asarray(codes)))
    # dequantize stays within range bounds (up to one delta)
    y = dequantize(codes, qp)
    assert float(y.min()) >= float(x.min()) - float(qp.delta)
    assert float(y.max()) <= float(x.max()) + float(qp.delta)


def test_per_channel_calibration_shapes():
    x = jnp.stack([jnp.linspace(-1, 1, 32), jnp.linspace(-5, 5, 32)], axis=1)
    qp = calibrate_minmax(x, bits=8, channel_axis=1)
    assert qp.x_max.shape == (1, 2)
    # channel 1 has 5x the range
    ratio = float(qp.delta[0, 1] / qp.delta[0, 0])
    assert 4.5 < ratio < 5.5


def test_percentile_clipping_shrinks_range():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (10000,))
    x = x.at[0].set(100.0)  # outlier
    qp_mm = calibrate_minmax(x)
    qp_pct = calibrate_percentile(x, percentile=99.9)
    assert float(qp_pct.x_max) < float(qp_mm.x_max) / 10


def test_ste_gradient_is_identity():
    g = jax.grad(lambda x: jnp.sum(ste_round(x * 3.0)))(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(g), 3.0)


def test_fake_quant_gradient_flows():
    x = jnp.linspace(-1, 1, 16)
    qp = calibrate_minmax(x, bits=4)
    g = jax.grad(lambda v: jnp.sum(fake_quant(v, qp)))(x)
    # STE: gradient 1 strictly inside the clip range (ties at the exact
    # endpoints get jnp.maximum's 0.5 subgradient)
    assert float(jnp.abs(g[1:-1] - 1.0).max()) < 1e-6
