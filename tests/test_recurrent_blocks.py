"""xLSTM / Griffin internals: chunkwise == step-by-step recurrence,
associative scan == sequential reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.griffin import causal_conv1d, rg_lru_scan
from repro.models.xlstm import mlstm_chunkwise, mlstm_decode

KEY = jax.random.PRNGKey(5)


def test_mlstm_chunkwise_equals_stepwise():
    b, t, h, d = 2, 32, 2, 8
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, h, d))
    li = jax.random.normal(jax.random.fold_in(KEY, 3), (b, t, h)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(jax.random.fold_in(KEY, 4), (b, t, h)) + 1.0)

    for chunk in (4, 8, 16, 32):
        out_c, st_c = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
        # stepwise reference via mlstm_decode
        st = None
        outs = []
        for i in range(t):
            o, st = mlstm_decode(
                q[:, i : i + 1], k[:, i : i + 1], v[:, i : i + 1],
                li[:, i : i + 1], lf[:, i : i + 1],
                st or (jnp.zeros((b, h, d, d)), jnp.zeros((b, h, d)),
                       jnp.full((b, h), -1e30)),
            )
            outs.append(o)
        out_s = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(out_c), np.asarray(out_s), rtol=2e-4, atol=2e-4,
        )
        # final states agree
        for a, b_ in zip(st_c[:2], st[:2]):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=2e-4, atol=2e-4)


def test_mlstm_state_carry_across_segments():
    b, t, h, d = 1, 16, 2, 4
    q = jax.random.normal(KEY, (b, t, h, d))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, h, d))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, t, h, d))
    li = jnp.zeros((b, t, h))
    lf = jnp.full((b, t, h), -0.2)
    full, _ = mlstm_chunkwise(q, k, v, li, lf, chunk=4)
    first, st = mlstm_chunkwise(q[:, :8], k[:, :8], v[:, :8], li[:, :8], lf[:, :8], chunk=4)
    second, _ = mlstm_chunkwise(
        q[:, 8:], k[:, 8:], v[:, 8:], li[:, 8:], lf[:, 8:], chunk=4, state=st
    )
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([first, second], 1)), np.asarray(full),
        rtol=2e-4, atol=2e-4,
    )


def test_rg_lru_scan_equals_sequential():
    b, t, r = 2, 24, 8
    a = jax.nn.sigmoid(jax.random.normal(KEY, (b, t, r)))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, r))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 2), (b, r))
    got = rg_lru_scan(a, x, h0)
    h = h0
    seq = []
    for i in range(t):
        h = a[:, i] * h + x[:, i]
        seq.append(h)
    want = jnp.stack(seq, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_causal_conv_state_continuity():
    b, t, r, cw = 2, 16, 4, 4
    w = jax.random.normal(KEY, (cw, r))
    bias = jnp.zeros((r,))
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (b, t, r))
    full, _ = causal_conv1d(x, w, bias)
    first, st = causal_conv1d(x[:, :10], w, bias)
    second, _ = causal_conv1d(x[:, 10:], w, bias, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([first, second], 1)), np.asarray(full),
        rtol=1e-5, atol=1e-5,
    )
