"""Redundant coding (paper §IV, Fig. 3): K-repeat averaging laws."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AnalogConfig
from repro.core.redundant import discrete_levels, spatial_averaged_dot, time_averaged_dot
from repro.core.analog import analog_dot

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def xw():
    x = jax.random.normal(KEY, (8, 48))
    w = jax.random.normal(jax.random.fold_in(KEY, 1), (48, 16)) * 0.2
    return x, w


def _std(fn, n=192):
    ys = jax.vmap(fn)(jax.random.split(KEY, n))
    return float(jnp.std(ys - jnp.mean(ys, axis=0)[None]))


def test_time_averaging_reduces_noise_sqrt_k(xw):
    """Fig. 3a: K clock cycles -> noise / sqrt(K)."""
    x, w = xw
    cfg = AnalogConfig.shot()
    e0 = 1.0
    s1 = _std(lambda k: time_averaged_dot(x, w, cfg=cfg, base_energy=jnp.asarray(e0), key=k, k_repeats=1))
    s4 = _std(lambda k: time_averaged_dot(x, w, cfg=cfg, base_energy=jnp.asarray(e0), key=k, k_repeats=4))
    assert s1 / s4 == pytest.approx(2.0, rel=0.2)


def test_time_averaging_equals_single_high_energy_draw(xw):
    """K repeats at E0 is statistically identical to one draw at K*E0 —
    the identity that justifies the continuous-E parameterization."""
    x, w = xw
    cfg = AnalogConfig.shot()
    s_rep = _std(lambda k: time_averaged_dot(x, w, cfg=cfg, base_energy=jnp.asarray(2.0), key=k, k_repeats=8))
    s_one = _std(lambda k: analog_dot(x, w, cfg=cfg, energy=jnp.asarray(16.0), key=k))
    assert s_rep == pytest.approx(s_one, rel=0.15)


def test_spatial_averaging_weight_noise(xw):
    """Fig. 3b: K spatial copies of W with independent device noise."""
    x, w = xw
    cfg = AnalogConfig.weight(0.1, out_bits=None, weight_bits=None, act_bits=None)
    s1 = _std(lambda k: spatial_averaged_dot(x, w, cfg=cfg, base_energy=jnp.asarray(1.0), key=k, k_repeats=1))
    s4 = _std(lambda k: spatial_averaged_dot(x, w, cfg=cfg, base_energy=jnp.asarray(1.0), key=k, k_repeats=4))
    assert s1 / s4 == pytest.approx(2.0, rel=0.25)


def test_spatial_averaging_unbiased(xw):
    x, w = xw
    cfg = AnalogConfig.weight(0.05, out_bits=None, weight_bits=None, act_bits=None)
    ys = jax.vmap(
        lambda k: spatial_averaged_dot(x, w, cfg=cfg, base_energy=jnp.asarray(1.0), key=k, k_repeats=4)
    )(jax.random.split(KEY, 256))
    np.testing.assert_allclose(
        np.asarray(jnp.mean(ys, axis=0)), np.asarray(x @ w), atol=0.05
    )


def test_discrete_levels_ste():
    e = jnp.asarray([0.3, 1.2, 2.7])
    q = discrete_levels(e, 1.0)
    np.testing.assert_allclose(np.asarray(q), [1.0, 1.0, 3.0])
    # STE gradient passes through
    g = jax.grad(lambda v: jnp.sum(discrete_levels(v, 1.0)))(e)
    np.testing.assert_allclose(np.asarray(g), 1.0)
