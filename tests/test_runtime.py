"""Fault-tolerant driver: bit-exact restart, mid-save crashes, stragglers,
elastic resharding, gradient compression."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import TokenTaskConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import TrainConfig
from repro.runtime.driver import DriverConfig, SimulatedFailure, StragglerMonitor, TrainDriver

CFG = None


def _driver(tmp, hook=None, max_steps=24):
    model = get_smoke_config("granite-3-8b")
    data = TokenTaskConfig(vocab_size=model.vocab_size, seq_len=32, global_batch=8, seed=3)
    return TrainDriver(
        model, data, make_local_mesh(), ckpt_dir=str(tmp),
        driver_cfg=DriverConfig(max_steps=max_steps, ckpt_every=8, ckpt_async=False),
        train_cfg=TrainConfig(lr=1e-3, opt_state_dtype="float32"),
        failure_hook=hook,
    )


def test_failure_recovery_bitexact(tmp_path):
    clean = _driver(tmp_path / "clean").run()
    fails = {5: True, 17: True}

    def hook(step):
        if fails.pop(step, None):
            raise SimulatedFailure(f"crash@{step}")

    drv = _driver(tmp_path / "faulty", hook=hook)
    faulty = drv.run()
    assert drv.restarts == 2
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        clean["state"]["params"], faulty["state"]["params"],
    )
    assert max(jax.tree.leaves(diffs)) == 0.0


def test_loss_decreases(tmp_path):
    out = _driver(tmp_path, max_steps=40).run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]


def test_too_many_restarts_raises(tmp_path):
    def hook(step):
        raise SimulatedFailure("always")

    drv = _driver(tmp_path, hook=hook)
    with pytest.raises(SimulatedFailure):
        drv.run()


def test_straggler_monitor_flags_and_persists():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, patience=3)
    for i in range(10):
        mon.observe(i, 0.1)
    assert not mon.persistent
    assert mon.observe(10, 0.5)  # 5x EWMA -> flagged
    mon.observe(11, 0.5)
    mon.observe(12, 0.5)
    assert mon.persistent
    # outliers must not drag the baseline up
    assert mon.ewma == pytest.approx(0.1, rel=0.05)
    mon.observe(13, 0.1)
    assert not mon.persistent


def test_grad_compression_trains(tmp_path):
    model = get_smoke_config("granite-3-8b")
    data = TokenTaskConfig(vocab_size=model.vocab_size, seq_len=32, global_batch=8, seed=3)
    drv = TrainDriver(
        model, data, make_local_mesh(), ckpt_dir=str(tmp_path),
        driver_cfg=DriverConfig(max_steps=30, ckpt_every=30, ckpt_async=False),
        train_cfg=TrainConfig(lr=1e-3, opt_state_dtype="float32",
                              grad_compression="int8_ef"),
    )
    out = drv.run()
    losses = [m["loss"] for m in out["metrics"]]
    assert losses[-1] < losses[0]


def test_resize_then_run_resumes_bitexact(tmp_path):
    """Elastic resize mid-training: resize() must reshard the live restored
    state onto the new mesh and checkpoint it such that a subsequent run()
    resumes bit-exactly vs an uninterrupted run."""
    from repro.launch.mesh import make_mesh_for_devices

    clean = _driver(tmp_path / "clean", max_steps=16).run()

    _driver(tmp_path / "resized", max_steps=8).run()  # ckpt at step 8
    drv = _driver(tmp_path / "resized", max_steps=16)
    drv.resize(make_mesh_for_devices(1))  # new mesh object, rebuilt step
    out = drv.run()  # resumes 8 -> 16 on the new mesh

    assert out["step"] == 16
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
        clean["state"]["params"], out["state"]["params"],
    )
    assert max(jax.tree.leaves(diffs)) == 0.0


def test_elastic_reshard_roundtrip(tmp_path):
    """Checkpoint from one mesh restores onto another (elastic path)."""
    from repro.checkpoint.store import reshard
    from repro.launch.steps import param_shardings

    model = get_smoke_config("granite-3-8b")
    mesh1 = make_local_mesh()
    params = jax.jit(lambda k: __import__("repro.models", fromlist=["lm"]).init_params(k, model))(
        jax.random.PRNGKey(0)
    )
    sh = param_shardings(model, mesh1)
    moved = reshard(params, sh)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(moved)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_microbatched_step_matches_full_batch(tmp_path):
    """Gradient accumulation is numerically equivalent to the full batch."""
    from repro.launch.steps import make_train_step
    from repro.models import init_params
    from repro.optim.adam import adam_init
    from repro.data.pipeline import markov_batch

    model = dataclasses.replace(get_smoke_config("granite-3-8b"), dtype="float32")
    data = TokenTaskConfig(vocab_size=model.vocab_size, seq_len=32, global_batch=8, seed=3)
    mesh = make_local_mesh()
    batch = markov_batch(data, 0)

    outs = {}
    for m in (1, 4):
        # the jitted step donates (params, opt): re-init per variant
        params = init_params(jax.random.PRNGKey(0), model)
        tcfg = TrainConfig(lr=1e-3, opt_state_dtype="float32", microbatches=m)
        _, jit_for, _ = make_train_step(model, mesh, tcfg)
        specs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
        step = jit_for(specs)
        opt = adam_init(params, tcfg.adam())
        p2, _, metrics = step(params, opt, batch)
        outs[m] = (p2, float(metrics["loss"]))
    assert outs[1][1] == pytest.approx(outs[4][1], rel=1e-4)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), outs[1][0], outs[4][0]
    )
    assert max(jax.tree.leaves(diffs)) < 1e-4
